"""Validate the multi-pod dry-run deliverable from its artifacts.

These tests read artifacts/dryrun/*.json (produced by
``python -m repro.launch.dryrun``).  They are skipped when the artifacts are
absent (fresh checkout) — run the dry-run first.
"""
import glob
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

EXPECT_ARCHS = 10
EXPECT_SHAPES = 4
EXPECT_MESHES = ("pod16x16", "pod2x16x16")


def _load():
    files = glob.glob(os.path.join(ART, "*.json"))
    return [json.load(open(f)) for f in files]


arts = _load()
pytestmark = pytest.mark.skipif(
    len(arts) < 70, reason="dry-run artifacts incomplete; run "
    "`python -m repro.launch.dryrun` first")


def test_every_cell_accounted():
    """40 cells x 2 meshes: each either compiled ok or documented skip."""
    seen = {}
    for a in arts:
        if a["mesh"] not in EXPECT_MESHES:
            continue
        seen[(a["arch"], a["shape"], a["mesh"])] = a["status"]
    assert len(seen) == EXPECT_ARCHS * EXPECT_SHAPES * len(EXPECT_MESHES)
    assert all(s in ("ok", "skipped") for s in seen.values()), \
        {k: v for k, v in seen.items() if v not in ("ok", "skipped")}


def test_skips_are_long_context_only():
    for a in arts:
        if a.get("status") == "skipped":
            assert a["shape"] == "long_500k"
            assert "sub-quadratic" in a["reason"]


def test_multi_pod_shards_the_pod_axis():
    """Multi-pod peak memory <= single-pod peak for train cells (DP over pod
    halves per-device batch)."""
    by = {}
    for a in arts:
        if a.get("status") == "ok" and a["mesh"] in EXPECT_MESHES:
            by[(a["arch"], a["shape"], a["mesh"])] = a
    checked = 0
    for (arch, shape, mesh), a in by.items():
        if mesh != "pod16x16" or a["kind"] != "train":
            continue
        b = by.get((arch, shape, "pod2x16x16"))
        if b is None:
            continue
        assert (b["memory"]["peak_bytes"]
                <= a["memory"]["peak_bytes"] * 1.10), (arch, shape)
        checked += 1
    assert checked >= 8


def test_memory_fits_hbm():
    """Every ok cell fits v5e HBM (16 GiB, 0.5 GiB reserved)."""
    over = [(a["arch"], a["shape"], a["mesh"],
             round(a["memory"]["peak_bytes"] / 2**30, 2))
            for a in arts if a.get("status") == "ok"
            and a["memory"]["peak_bytes"] > 15.5 * 2**30]
    assert not over, over


def test_collectives_present_and_priced():
    for a in arts:
        if a.get("status") != "ok":
            continue
        assert a["comm_model"]["model_time"] >= a["comm_model"]["naive_time"] * 0 \
            and a["comm_model"]["model_time"] >= 0
        if a["kind"] == "train":
            # training always reduces gradients -> collectives must exist
            assert a["collectives"], (a["arch"], a["shape"], a["mesh"])


def test_flops_calibration_sane():
    """Calibrated HLO flops within sane bounds of the 6ND analytic estimate."""
    for a in arts:
        if a.get("status") != "ok" or a["kind"] != "train":
            continue
        chips = 512 if "2x16x16" in a["mesh"] else 256
        tokens = a["global_batch"] * a["seq_len"]
        model = 6 * a["n_active_params"] * tokens / chips
        hlo = a["cost"]["flops_per_device"]
        # remat/attention overheads push HLO above 6ND; capacity-dropping
        # fine-grained MoE (deepseek: 64 experts top-6, cf=1.25) pushes it
        # below the active-param estimate
        lo = 0.3 if "moe" in a["arch"] else 0.8
        assert lo * model < hlo < 6 * model, \
            (a["arch"], a["shape"], a["mesh"], model, hlo)
