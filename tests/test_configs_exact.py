"""The assigned architecture table, verified field by field."""
import pytest

from repro.configs import get_config, ARCH_IDS

# (layers, d_model, heads, kv_heads, d_ff, vocab)
EXPECT = {
    "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
    "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, None, 151936),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    "whisper-small": (12, 768, 12, 12, 3072, 51865),
}


def test_all_archs_present():
    assert sorted(ARCH_IDS) == sorted(EXPECT)


@pytest.mark.parametrize("arch", sorted(EXPECT))
def test_config_exact(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = EXPECT[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_details():
    ds = get_config("deepseek-moe-16b")
    assert (ds.n_experts, ds.n_experts_active, ds.n_shared_experts,
            ds.moe_d_ff) == (64, 6, 2, 1408)
    assert ds.first_dense_layers == 1
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.n_experts_active, q.moe_d_ff) == (128, 8, 768)


def test_family_flags():
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("qwen3-32b").qk_norm
    assert get_config("qwen2-vl-72b").m_rope
    assert get_config("whisper-small").cross_attention
    assert get_config("whisper-small").encoder_seq == 1500
