"""Optional-hypothesis shim: property tests skip cleanly when it's absent.

``hypothesis`` is a hard import in several test modules, which breaks
*collection* of the deterministic tests in environments without it (tier-1
CI only guarantees numpy + pytest).  Import ``given`` / ``settings`` / ``st``
from here instead: with hypothesis installed they are the real thing; without
it, ``@given`` marks the test as skipped and the strategy namespace accepts
any call, so module import and all deterministic tests still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any attribute access / call so strategy expressions parse."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            # replace the test body: the parametrized arguments would
            # otherwise look like (unresolvable) pytest fixtures
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed")
            skipped.__name__ = getattr(fn, "__name__", "test_skipped")
            return skipped
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
