"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd_intra_chunk
from repro.kernels.spmv_ell import spmv_block_ell, csr_to_block_ell
from repro.kernels import ref
from repro.sparse import poisson_3d, elasticity_like_3d


# ------------------------------------------------------------ flash ---------
@pytest.mark.parametrize("S,H,KH,D", [
    (256, 4, 4, 64),     # MHA
    (256, 4, 2, 64),     # GQA 2x
    (512, 8, 1, 64),     # MQA
    (256, 4, 2, 128),    # bigger head dim
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(S, H, KH, D, causal):
    rng = np.random.default_rng(0)
    B = 2
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(1)
    B, S, H, KH, D = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=0.06, atol=0.06)


def test_flash_block_shape_invariance():
    """Different tilings produce the same result."""
    rng = np.random.default_rng(2)
    B, S, H, KH, D = 1, 512, 2, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=256, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# -------------------------------------------------------------- SSD ---------
@pytest.mark.parametrize("q,n,p", [(64, 32, 16), (128, 128, 64), (32, 8, 8)])
def test_ssd_kernel_matches_ref(q, n, p):
    rng = np.random.default_rng(0)
    G = 6
    dtx = jnp.asarray(rng.standard_normal((G, q, p)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((G, q, n)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((G, q, n)), jnp.float32)
    # realistic decaying cumA (negative, decreasing)
    a = -jnp.asarray(rng.uniform(0.001, 0.1, (G, q, 1)), jnp.float32)
    cumA = jnp.cumsum(a, axis=1)
    y, s = ssd_intra_chunk(dtx, Bm, Cm, cumA, interpret=True)
    y_ref, s_ref = ref.ssd_intra_chunk_ref(dtx, Bm, Cm, cumA)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=2e-4,
                               atol=2e-4)


def test_ssd_kernel_consistent_with_model_ssd():
    """Kernel output == the model's chunked-SSD intra term."""
    from repro.nn.ssm import ssd_chunked
    rng = np.random.default_rng(3)
    b, l, h, p, n, chunk = 2, 64, 3, 16, 8, 32
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, l, h)), jnp.float32)
    A_log = jnp.asarray(rng.uniform(-1, 0.5, (h,)), jnp.float32)
    D = jnp.zeros((h,), jnp.float32)
    y_model = ssd_chunked(x, Bm, Cm, dt, A_log, D, chunk)

    # reproduce via kernel: intra + manual inter-chunk recurrence
    nc = l // chunk
    xr = x.reshape(b, nc, chunk, h, p)
    Br = Bm.reshape(b, nc, chunk, n)
    Cr = Cm.reshape(b, nc, chunk, n)
    dtr = dt.reshape(b, nc, chunk, h)
    aa = -jnp.exp(A_log)[None, None, None] * dtr
    cumA = jnp.cumsum(aa, axis=2)                       # [b,nc,q,h]
    dtx = xr * dtr[..., None]
    # flatten (b, nc, h) -> G
    def flat(t, has_p):
        # t: [b,nc,q,h,p] or [b,nc,q,n] or [b,nc,q,h]
        if has_p == "hp":
            return t.transpose(0, 1, 3, 2, 4).reshape(-1, chunk, p)
        if has_p == "n":
            return jnp.broadcast_to(t[:, :, None], (b, nc, h, chunk, n)
                                    ).reshape(-1, chunk, n)
        return t.transpose(0, 1, 3, 2).reshape(-1, chunk, 1)
    G_dtx = flat(dtx, "hp")
    G_B = flat(Br.transpose(0, 1, 2, 3), "n")
    G_C = flat(Cr.transpose(0, 1, 2, 3), "n")
    G_A = flat(cumA, "h")
    y_intra, s_c = ssd_intra_chunk(G_dtx, G_B, G_C, G_A, interpret=True)
    y_intra = y_intra.reshape(b, nc, h, chunk, p).transpose(0, 1, 3, 2, 4)
    s_c = s_c.reshape(b, nc, h, n, p)
    # inter-chunk
    dec = jnp.exp(cumA[:, :, -1, :])                    # [b,nc,h]
    S = jnp.zeros((b, h, n, p))
    y = jnp.zeros_like(y_intra)
    for c in range(nc):
        y_inter = jnp.einsum("bqn,bhnp,bqh->bqhp", Cr[:, c], S,
                             jnp.exp(cumA[:, c]))
        y = y.at[:, c].set(y_intra[:, c] + y_inter)
        S = S * dec[:, c][:, :, None, None] + s_c[:, c]
    np.testing.assert_allclose(np.asarray(y.reshape(b, l, h, p)),
                               np.asarray(y_model), rtol=3e-4, atol=3e-4)


# ------------------------------------------------------------- SpMV ---------
@pytest.mark.parametrize("bs", [4, 8, 16])
def test_spmv_block_ell_matches_ref(bs):
    rng = np.random.default_rng(0)
    A = poisson_3d(6)  # 216 rows
    blocks, cols, _ = csr_to_block_ell(A, bs=bs)
    n_pad = blocks.shape[0] * bs
    x = jnp.asarray(rng.standard_normal(n_pad), jnp.float32)
    y = spmv_block_ell(blocks, cols, x, interpret=True)
    y_ref = ref.spmv_block_ell_ref(blocks, cols, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5,
                               atol=1e-5)


def test_spmv_block_ell_matches_csr():
    """Kernel (via conversion) == the CSR numpy SpMV on the real matrix."""
    rng = np.random.default_rng(1)
    A = elasticity_like_3d(4)       # 192 rows, 3-dof blocks
    bs = 8
    blocks, cols, _ = csr_to_block_ell(A, bs=bs)
    n = A.n_rows
    n_pad = blocks.shape[0] * bs
    x = rng.standard_normal(n_pad)
    x[n:] = 0.0
    y = spmv_block_ell(blocks, cols, jnp.asarray(x, jnp.float32),
                       interpret=True)
    y_np = A.spmv(x[:n])
    np.testing.assert_allclose(np.asarray(y)[:n], y_np, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmv_dtypes(dtype):
    rng = np.random.default_rng(2)
    A = poisson_3d(4)
    blocks, cols, _ = csr_to_block_ell(A, bs=8)
    blocks = blocks.astype(dtype)
    x = jnp.asarray(rng.standard_normal(blocks.shape[0] * 8), dtype)
    y = spmv_block_ell(blocks, cols, x, interpret=True)
    y_ref = ref.spmv_block_ell_ref(blocks, cols, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=0.05, atol=0.05)
