"""Round-trip coverage for the parameter-recovery layer (core/fitting.py).

The paper's calibration story is a round trip: ping-pong style measurements
on a few nodes -> fitted (alpha, R_b, R_N, gamma, delta) -> model applied at
scale.  These tests close the loop against the simulator's ground-truth
tables: noiseless synthetic sweeps from :mod:`repro.net.pingpong` must give
fits that recover the known :class:`~repro.core.CommParams` entries within
tight tolerances (the only systematic offset being the simulator's one
queue-step gamma per ping, which is orders of magnitude below every alpha).
"""
import numpy as np
import pytest

from repro.core import (PROTOCOL_NAMES, fit_alpha_beta, fit_delta, fit_gamma,
                        fit_node_aware_table, fit_rails, fit_RN)
from repro.net import (blue_waters_machine, contention_line_test,
                       frontier_machine, high_volume_pingpong,
                       lassen_machine, pingpong_sweep, ppn_sweep)

BW = blue_waters_machine((2, 2, 2))

#: >= 2 sizes per protocol bucket (short <= 512 < eager <= 8192 < rend)
SIZES = np.array([64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0, 262144.0])

LOCALITY_KINDS = ("intra_socket", "intra_node", "inter_node")


def _loc_index(kind: str) -> int:
    return BW.params.locality_names.index(kind)


# ------------------------------------------------ alpha / R_b ---------------
@pytest.mark.parametrize("kind", LOCALITY_KINDS)
def test_fit_alpha_beta_recovers_table_row(kind):
    times = pingpong_sweep(BW, kind, SIZES, reps=1, noise=0.0)
    fits = fit_alpha_beta(SIZES, times, BW.params)
    li = _loc_index(kind)
    for pi, name in enumerate(PROTOCOL_NAMES):
        alpha_true = BW.params.alpha[li, pi]
        Rb_true = BW.params.Rb[li, pi]
        alpha_fit, Rb_fit = fits[name]
        # the simulated ping pays one queue step (gamma) on top of alpha
        assert alpha_fit == pytest.approx(alpha_true + BW.params.gamma,
                                          rel=1e-6)
        assert Rb_fit == pytest.approx(Rb_true, rel=1e-6)


def test_fit_node_aware_table_round_trip():
    sweeps = {kind: (SIZES, pingpong_sweep(BW, kind, SIZES, reps=1,
                                           noise=0.0))
              for kind in LOCALITY_KINDS}
    table = fit_node_aware_table(sweeps, BW.params)
    for kind in LOCALITY_KINDS:
        li = _loc_index(kind)
        for pi, name in enumerate(PROTOCOL_NAMES):
            alpha_fit, Rb_fit = table[kind][name]
            assert alpha_fit == pytest.approx(
                BW.params.alpha[li, pi] + BW.params.gamma, rel=1e-6)
            assert Rb_fit == pytest.approx(BW.params.Rb[li, pi], rel=1e-6)


def test_fit_alpha_beta_skips_underpopulated_buckets():
    sizes = np.array([64.0, 128.0])                 # short-protocol only
    times = pingpong_sweep(BW, "inter_node", sizes, reps=1, noise=0.0)
    fits = fit_alpha_beta(sizes, times, BW.params)
    assert set(fits) == {"short"}


def test_fit_alpha_beta_tolerates_noise():
    rngs = pingpong_sweep(BW, "inter_node", SIZES, reps=8, noise=0.02,
                          seed=1)
    fits = fit_alpha_beta(SIZES, rngs, BW.params)
    li = _loc_index("inter_node")
    for pi, name in enumerate(PROTOCOL_NAMES):
        _, Rb_fit = fits[name]
        assert Rb_fit == pytest.approx(BW.params.Rb[li, pi], rel=0.25)


# ------------------------------------------------ R_N -----------------------
def test_fit_RN_recovers_injection_cap():
    size = float(1 << 20)                           # rendezvous regime
    ks, ts = ppn_sweep(BW, size, noise=0.0)
    li = _loc_index("inter_node")
    pi = PROTOCOL_NAMES.index("rend")
    RN = fit_RN(ks, ts, size, BW.params.alpha[li, pi], BW.params.Rb[li, pi])
    # saturated slope is size/R_N exactly: T(k) = alpha + gamma + k*size/R_N
    assert RN == pytest.approx(BW.params.RN[li, pi], rel=1e-6)


def test_fit_RN_unsaturated_reports_inf():
    ks = np.arange(1.0, 9.0)
    times = 3e-6 - 1e-8 * ks          # non-positive slope: no saturation seen
    assert fit_RN(ks, times, 4096.0, 3e-6, 2.9e9) == float("inf")


# ------------------------------------------------ n_rails -------------------
@pytest.mark.parametrize("build, expect", [
    (lambda: blue_waters_machine((2, 2, 2)), 1),   # single NIC: rises every k
    (lassen_machine, 2),                           # dual-rail EDR
    (frontier_machine, 4),                         # four-NIC Slingshot node
], ids=["blue_waters", "lassen", "frontier"])
def test_fit_rails_round_trip(build, expect):
    """The per-rail byte staircase in a rendezvous-regime ppn sweep recovers
    each preset's CommParams.n_rails: T(k) steps only when ceil(k/r)
    increments, so the step period (or the leading plateau for one step)
    is the rail count."""
    machine = build()
    assert machine.params.n_rails == expect        # the ground truth we chase
    ks, times = ppn_sweep(machine, float(1 << 20), noise=0.0)
    assert fit_rails(ks, times) == expect


def test_fit_rails_unsaturated_reports_one():
    """A flat sweep (cap never binds) is indistinguishable from one rail."""
    ks = np.arange(1.0, 9.0)
    assert fit_rails(ks, np.full(8, 3e-6)) == 1
    assert fit_rails(np.array([1.0]), np.array([3e-6])) == 1


def test_fit_rails_pairs_with_stack_rail_counters():
    """The arena's per-rail byte counters split each phase's network bytes
    by the same src % n_rails binding fit_rails assumes — rows sum back to
    the phase's network bytes and move to the recovered rail count."""
    from repro.comm import CommPhase, PhaseStack
    machine = lassen_machine()
    rng = np.random.default_rng(3)
    ppn = machine.procs_per_node
    src = np.arange(ppn)
    dst = ppn + np.arange(ppn)                     # node 0 -> node 1: all net
    size = rng.integers(1 << 10, 1 << 16, ppn).astype(float)
    ph = CommPhase.build(machine, src, dst, size, n_procs=2 * ppn)
    stack = PhaseStack.build([ph])
    r = int(machine.params.n_rails)
    rails = stack.rail_bytes()                     # defaults to params.n_rails
    assert rails.shape == (1, r)
    np.testing.assert_allclose(rails.sum(axis=1),
                               [np.where(ph.is_net, ph.size, 0.0).sum()])
    want = np.bincount(src % r, weights=size, minlength=r)
    np.testing.assert_allclose(rails[0], want)
    # a single-rail view collapses the split into the plain net-byte total
    np.testing.assert_allclose(stack.rail_bytes(1)[:, 0], rails.sum(axis=1))


# ------------------------------------------------ gamma ---------------------
def test_fit_gamma_exact_synthetic():
    n = np.array([8.0, 16.0, 32.0, 64.0])
    base = 1e-4 + 3e-6 * n
    gamma_true = 8.4e-9
    assert fit_gamma(n, base + gamma_true * n * n, base) == \
        pytest.approx(gamma_true, rel=1e-12)


def test_fit_gamma_from_reversed_high_volume_pingpong():
    """Reversed-order HVPP residuals: the simulator's exact queue walk costs
    gamma * n(n+1)/2, so fitting the paper's gamma * n^2 upper-bound form
    recovers ~gamma/2 — the over-bounding the paper itself reports."""
    ns = (8, 16, 32, 64)
    resid, n2 = [], []
    for n in ns:
        _, r1, _ = high_volume_pingpong(BW, [(0, 32)], n, 4096.0,
                                        order="reversed", noise=0.0)
        resid.append(r1.time)
        n2.append(n)
    measured = np.asarray(resid)
    modeled_no_queue = measured - np.asarray(
        [high_volume_pingpong(BW, [(0, 32)], n, 4096.0, order="reversed",
                              noise=0.0)[1].queue for n in ns])
    gamma_fit = fit_gamma(np.asarray(n2, dtype=float), measured,
                          modeled_no_queue)
    gamma_true = BW.params.gamma
    assert 0.4 * gamma_true < gamma_fit < 0.65 * gamma_true


# ------------------------------------------------ delta ---------------------
def test_fit_delta_recovers_contention_penalty():
    machine = blue_waters_machine((4, 1, 1))        # the Gemini line (Fig. 6)
    ells, measured, modeled_no_cont = [], [], []
    for size in (1 << 14, 1 << 16, 1 << 18):
        _, r1, _ = contention_line_test(machine, n=4, size=float(size),
                                        noise=0.0)
        assert r1.max_link_bytes > 0                # the G1-G2 link funnels
        ells.append(r1.max_link_bytes)
        measured.append(r1.time)
        modeled_no_cont.append(r1.time - r1.contention)
    delta_fit = fit_delta(np.asarray(ells), np.asarray(measured),
                          np.asarray(modeled_no_cont))
    assert delta_fit == pytest.approx(machine.params.delta, rel=1e-9)


def test_fit_gamma_delta_zero_denominator():
    z = np.zeros(3)
    assert fit_gamma(z, z, z) == 0.0
    assert fit_delta(z, z, z) == 0.0
