"""Concurrency + chaos soak for the production strategy service.

The ISSUE-9 acceptance pins live here (DESIGN.md §13): the full
``DEFAULT_SCENARIOS`` registry queried from >= 4 threads under every-site
fault injection (including the ``serve.cache_*`` and ``serve.deadline``
sites), with the disk cache corrupted mid-run, completes with one
:class:`repro.serve.ServiceResult` per pattern, verdicts bit-identical to
a clean serial numpy run, ``degraded`` / ``Overloaded`` / deadline flags
set where applicable, and no unhandled exception anywhere.  Cold and warm
(restored-snapshot) runs agree, and the optimizer steering loop
(:func:`repro.sparse.optimize_partition` -> :meth:`StrategyService.reprice`)
prices drift without degrading.
"""
import glob
import os
import threading

import numpy as np

from repro.comm import faults, pattern_fingerprint
from repro.comm.health import get_health
from repro.net.machine import lassen_machine
from repro.serve import (AdmissionQueue, ArenaCache, Deadline,
                         DeadlineExceeded, StrategyService)
from repro.sparse import (RowPartition, optimize_partition, poisson_3d,
                          spmv_comm_pattern)
from repro.sparse.partition import CommPattern
from repro.workloads.registry import DEFAULT_SCENARIOS, scenario_patterns

LASSEN = lassen_machine((2, 2, 2))

#: Every registered fault site armed at once — the ambient storm the
#: chaos CI soak row also runs under.
STORM = ",".join(f"{site}:raise" for site in faults.SITES)


def _registry_patterns():
    return [p for sc in DEFAULT_SCENARIOS for _, p in scenario_patterns(sc)]


def _patterns(P, m=6, n=48):
    rng = np.random.default_rng(7)
    return [CommPattern(src=rng.integers(0, P, n), dst=rng.integers(0, P, n),
                        size=rng.integers(64, 4096, n).astype(float),
                        n_procs=P)
            for _ in range(m)]


def _verdict_key(v):
    return (v.model, v.sim, v.model_winner, v.sim_winner)


def _run_threads(n, fn, join_timeout=120.0):
    """Run ``fn(i)`` on ``n`` barrier-synchronised threads; fail the test
    on ANY escaped exception; return the per-thread results."""
    errs, out = [], [None] * n
    barrier = threading.Barrier(n)

    def work(i):
        try:
            barrier.wait(timeout=30)
            out[i] = fn(i)
        except BaseException as e:  # noqa: BLE001 - the assertion IS "none"
            errs.append((i, repr(e)))

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=join_timeout)
    assert not errs, f"unhandled exceptions escaped worker threads: {errs}"
    assert not any(t.is_alive() for t in threads), "worker thread hung"
    return out


# ========================================================== threaded storm ==
def test_threaded_query_many_is_bit_identical_under_storm(monkeypatch):
    """N threads x M patterns under an every-site fault storm: one result
    per pattern per call, all verdicts bit-identical to the clean serial
    numpy reference, and the health ledger stays consistent."""
    monkeypatch.delenv(faults.ENV_VAR, raising=False)  # clean reference
    pats = _patterns(LASSEN.n_procs)
    reference = [
        _verdict_key(r.verdict)
        for r in StrategyService(LASSEN, backend="numpy").query_many(pats)]

    monkeypatch.setenv(faults.ENV_VAR, STORM)
    svc = StrategyService(LASSEN)                # shared; default backend
    n_threads = 6

    def work(i):
        return svc.query_many(pats)

    for results in _run_threads(n_threads, work):
        assert len(results) == len(pats)         # one result per pattern
        for res, want in zip(results, reference):
            assert res.ok, res.error
            assert _verdict_key(res.verdict) == want
    h = get_health()
    assert h.n_events == len(h.events) + h.dropped_events
    assert all(ev.site in faults.SITES or ev.site.startswith("serve.")
               for ev in h.events)


# ========================================================= acceptance soak ==
def test_registry_soak_under_storm_with_midrun_corruption(tmp_path,
                                                          monkeypatch):
    """The headline soak: full DEFAULT_SCENARIOS from 4 no-timeout threads
    plus an overloaded client and a deadline client, every fault site
    armed, disk cache corrupted (and memory tier dropped) mid-run."""
    monkeypatch.delenv(faults.ENV_VAR, raising=False)  # clean warm-up
    pats = _registry_patterns()
    reference = [
        _verdict_key(r.verdict)
        for r in StrategyService(LASSEN, backend="numpy").query_many(pats)]

    disk = str(tmp_path / "cache")
    cache = ArenaCache(disk)
    svc = StrategyService(LASSEN, cache=cache)
    # a clean warm-up pass lands real entries on disk to corrupt later
    warm = svc.query_many(pats)
    assert [r.ok for r in warm] == [True] * len(pats)
    entry_files = glob.glob(os.path.join(disk, "*.json"))
    assert entry_files

    monkeypatch.setenv(faults.ENV_VAR, STORM)

    n_threads = 4
    checkpoint = threading.Barrier(n_threads + 1)   # workers + corrupter

    def work(i):
        first = svc.query_many(pats)
        checkpoint.wait(timeout=30)                  # cache dies here
        checkpoint.wait(timeout=30)
        second = svc.query_many(pats)
        return first + second

    def corrupt_mid_run():
        checkpoint.wait(timeout=30)
        for fname in entry_files:
            with open(fname, "w") as f:
                f.write("\x00torn mid-soak\x00")
        cache.clear()                                # force disk re-reads
        checkpoint.wait(timeout=30)

    corrupter = threading.Thread(target=corrupt_mid_run)
    corrupter.start()
    per_thread = _run_threads(n_threads, work)
    corrupter.join(timeout=30)
    assert not corrupter.is_alive()

    for results in per_thread:
        assert len(results) == 2 * len(pats)
        for res, want in zip(results, reference + reference):
            assert res.ok, res.error
            # degraded flags are fine (expected, even) under the storm —
            # the numbers still must not move
            assert _verdict_key(res.verdict) == want
    h = get_health()
    assert h.n_events == len(h.events) + h.dropped_events

    # -- the overloaded client: a held queue sheds its whole batch --------
    q = AdmissionQueue(capacity=8, policy="reject")
    busy = StrategyService(LASSEN, backend="numpy", admission=q)
    q.acquire(8, Deadline(None))                     # queue already full
    try:
        shed = busy.query_many(pats)
    finally:
        q.release(8)
    assert len(shed) == len(pats)
    assert all((not r.ok) and r.overloaded for r in shed)
    assert q.n_shed > 0
    recovered = busy.query_many(pats)                # drains once released
    assert all(r.ok for r in recovered)

    # -- the deadline client: storm's serve.deadline site + timeout=0 -----
    hasty = StrategyService(LASSEN, backend="numpy", timeout=0.0)
    late = hasty.query_many(pats)
    assert len(late) == len(pats)
    assert all(not r.ok for r in late)
    assert all(isinstance(r.error, DeadlineExceeded) for r in late)


def test_cold_and_warm_registry_runs_agree():
    """A restored-snapshot (warm) service answers the whole registry from
    cache, bit-identical to the cold run that produced the snapshot."""
    pats = _registry_patterns()
    cold_svc = StrategyService(LASSEN, backend="numpy")
    cold = cold_svc.query_many(pats)
    assert all(r.ok and not r.cached for r in cold)

    # identical-content patterns share one fingerprint (llama3-tp's two
    # collectives), so the snapshot holds one entry per distinct shape
    distinct = len({pattern_fingerprint(p) for p in pats})
    warm_svc = StrategyService(LASSEN, backend="numpy")
    assert warm_svc.restore(cold_svc.snapshot()) == distinct
    warm = warm_svc.query_many(pats)
    for c, w in zip(cold, warm):
        assert w.ok and w.cached
        assert _verdict_key(w.verdict) == _verdict_key(c.verdict)


# ======================================================= optimizer steering ==
def test_optimizer_steering_reprices_without_degrading():
    """The drift loop the service exists for: optimize a partition with
    per-move strategy verdicts, then reprice initial -> optimized through
    the service — incremental, ok, and never degraded."""
    A = poisson_3d(6)
    P = 16
    res = optimize_partition(A, LASSEN, n_procs=P, moves=32, seed=0,
                             rerun_strategies=True)
    assert res.cost <= res.initial_cost
    assert res.verdicts                              # rerun_strategies ran
    initial = spmv_comm_pattern(A, RowPartition.balanced(A.n_rows, P))
    svc = StrategyService(LASSEN, backend="numpy")
    out = svc.reprice(initial, res.pattern)
    assert out.ok, out.error
    assert not out.degraded
    # repricing the same drift again is a cache hit with the same verdict
    again = svc.reprice(initial, res.pattern)
    assert again.cached
    assert _verdict_key(again.verdict) == _verdict_key(out.verdict)
