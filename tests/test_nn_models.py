"""Per-architecture smoke tests + numerical consistency of the mixers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, get_config
from repro.nn import (init_params, lm_loss, init_cache, decode_step,
                      forward_logits, prefill)
from repro.nn.ssm import ssd_chunked


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "vlm":
        batch = {
            "embeds": jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                                  dtype=jnp.bfloat16),
            "positions": jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        }
    elif cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            dtype=jnp.bfloat16)
    return batch


# ---------------------------------------------------- per-arch smoke --------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    """Reduced config: one forward + one grad step on CPU, finite outputs."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, 0)
    batch = _batch_for(cfg)

    def loss_fn(p):
        return lm_loss(p, cfg, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, 0)
    B, S = 2, 16
    cache = init_cache(cfg, B, S)
    tok = jnp.ones((B,), dtype=jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, 0))(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_constructible(arch):
    """Full configs build shape trees without allocation."""
    from repro.nn import abstract_params
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    n_bytes = sum(np.prod(l.shape) * l.dtype.itemsize
                  for l in jax.tree.leaves(tree))
    assert n_bytes > 1e8   # full configs are >100MB of parameters


# ------------------------------------------- decode == full forward ---------
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen3-32b", "mamba2-130m",
                                  "hymba-1.5b", "deepseek-moe-16b"])
def test_decode_matches_full_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits."""
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        # capacity dropping differs between batched and stepwise eval; use
        # a capacity factor that guarantees no drops for the test
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, 0)
    B, S = 1, 8
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))

    full_logits, _ = forward_logits(params, cfg, tokens=tokens, remat=False)

    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i),
                   static_argnums=())
    outs = []
    for i in range(S):
        lg, cache = decode_step(params, cfg, cache, tokens[:, i], i)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, dtype=np.float32),
                               np.asarray(full_logits, dtype=np.float32),
                               rtol=0.15, atol=0.15)


def test_prefill_matches_decode_continuation():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(cfg, 0)
    B, S = 1, 8
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    last_logits, cache = prefill(params, cfg, tokens=tokens, max_seq=S + 4)
    full_logits, _ = forward_logits(params, cfg, tokens=tokens, remat=False)
    np.testing.assert_allclose(np.asarray(last_logits, dtype=np.float32),
                               np.asarray(full_logits[:, -1], dtype=np.float32),
                               rtol=0.1, atol=0.1)
    # continue decoding one token; position S
    nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    lg, _ = decode_step(params, cfg, cache, nxt, S)
    assert jnp.all(jnp.isfinite(lg.astype(jnp.float32)))


# --------------------------------------------------------- SSD math ---------
def _ssd_naive(x, Bm, Cm, dt, A_log, D):
    """O(L^2)-free naive recurrence oracle."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    a = np.exp(-np.exp(np.asarray(A_log, np.float64))
               * np.asarray(dt, np.float64))          # [b,l,h]
    S = np.zeros((b, h, n, p))
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        dtx = np.asarray(x[:, t], np.float64) * np.asarray(dt[:, t], np.float64)[..., None]
        S = S * a[:, t][..., None, None] + np.einsum(
            "bn,bhp->bhnp", np.asarray(Bm[:, t], np.float64), dtx)
        ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, t], np.float64), S) \
            + np.asarray(D, np.float64)[None, :, None] * np.asarray(x[:, t], np.float64)
    return ys


@pytest.mark.parametrize("l,chunk", [(16, 4), (24, 8), (32, 32)])
def test_ssd_chunked_matches_recurrence(l, chunk):
    rng = np.random.default_rng(0)
    b, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), dtype=jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, l, n)), dtype=jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, l, n)), dtype=jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, l, h)), dtype=jnp.float32)
    A_log = jnp.asarray(rng.uniform(-1, 1, (h,)), dtype=jnp.float32)
    D = jnp.asarray(rng.standard_normal((h,)), dtype=jnp.float32)
    y = ssd_chunked(x, Bm, Cm, dt, A_log, D, chunk)
    y_ref = _ssd_naive(x, Bm, Cm, dt, A_log, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- configs --------
def test_param_counts_match_family_scale():
    """Full configs land in the right parameter-count ballpark."""
    expectations = {
        "llama3.2-3b": (2.5e9, 4.5e9),
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "starcoder2-3b": (2.5e9, 4e9),
        "qwen3-32b": (28e9, 37e9),
        "deepseek-moe-16b": (13e9, 20e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "hymba-1.5b": (1.0e9, 2.1e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "whisper-small": (0.15e9, 0.45e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_smaller():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.n_active_params() < 0.25 * cfg.n_params()


def test_long_context_applicability():
    from repro.configs import SHAPES, cell_applicable
    assert cell_applicable(get_config("mamba2-130m"), SHAPES["long_500k"])[0]
    assert cell_applicable(get_config("hymba-1.5b"), SHAPES["long_500k"])[0]
    ok, why = cell_applicable(get_config("llama3.2-3b"), SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why


def test_int8_kv_cache_decode_close():
    """kv_quant=True decode tracks the full-precision forward closely."""
    cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"),
                              kv_quant=True)
    params = init_params(cfg, 0)
    B, S = 1, 8
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    full, _ = forward_logits(params,
                             dataclasses.replace(cfg, kv_quant=False),
                             tokens=tokens, remat=False)
    cache = init_cache(cfg, B, S)
    outs = []
    for i in range(S):
        lg, cache = decode_step(params, cfg, cache, tokens[:, i], i)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full.astype(jnp.float32)))
                / jnp.max(jnp.abs(full.astype(jnp.float32))))
    assert rel < 0.08, rel
    # the cache really is int8
    assert cache["layers"]["k"].dtype == jnp.int8
