"""Runtime tests: checkpoint/restore (incl. elastic), crash-resume equality,
data determinism + re-dispatch, serving engine, straggler watchdog."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (save_checkpoint, load_checkpoint, latest_step,
                        CheckpointManager)
from repro.configs import get_smoke_config
from repro.data import SyntheticTokens, shard_assignment
from repro.nn import init_params, decode_step, init_cache
from repro.serve import ServeEngine, Request
from repro.train import Trainer, TrainConfig
from repro.train.optim import AdamWConfig


# ------------------------------------------------------------- ckpt ---------
def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    t2 = load_checkpoint(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # flip a byte
    f = next(p for p in os.listdir(tmp_path / "step_1") if p.endswith(".npy")
             and p.startswith("a"))
    path = tmp_path / "step_1" / f
    raw = bytearray(path.read_bytes())
    raw[-1] ^= 0xFF
    path.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        load_checkpoint(str(tmp_path), 1, t)


def test_checkpoint_manager_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t, wait=True)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_async_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    th = mgr.save(5, _tree(), wait=False)
    th.join()
    mgr.wait()
    assert latest_step(str(tmp_path)) == 5


# ------------------------------------------------- crash-resume equality ----
def test_crash_resume_bitwise(tmp_path):
    """Train 6 steps straight == train 3, 'crash', resume 3 more."""
    cfg = get_smoke_config("tinyllama-1.1b")
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq_len=16)

    def train(steps, ckpt_dir):
        t = Trainer(cfg, TrainConfig(steps=steps, ckpt_every=3,
                                     ckpt_dir=ckpt_dir, log_every=100),
                    AdamWConfig(warmup_steps=2, total_steps=10))
        return t.run(data)

    full = train(6, str(tmp_path / "a"))
    part = train(3, str(tmp_path / "b"))       # writes ckpt at step 3
    resumed = train(6, str(tmp_path / "b"))    # resumes from 3
    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_elastic_restore_different_topology(tmp_path):
    """Checkpoint written once restores under a different sharding layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, t)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    t2 = load_checkpoint(str(tmp_path), 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(t["w"]))
    assert t2["w"].sharding == sh["w"]


# ------------------------------------------------------------- data ---------
def test_data_determinism_and_redispatch():
    d = SyntheticTokens(1000, batch=8, seq_len=16, n_shards=4, shard=2)
    a = d.batch_at(5)
    b = d.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # failure re-dispatch: any survivor can recompute shard 2's batch
    assign = shard_assignment(8, alive_hosts=[0, 1, 3])
    assert sorted(sum(assign.values(), [])) == list(range(8))
    assert all(h in (0, 1, 3) for h in assign)


def test_data_prefetch_iterator():
    d = SyntheticTokens(100, batch=2, seq_len=8)
    it = iter(d)
    b1 = next(it)
    b2 = next(it)
    assert b1["tokens"].shape == (2, 8)
    assert not np.array_equal(b1["tokens"], b2["tokens"])


# ---------------------------------------------------------- watchdog --------
def test_straggler_watchdog(tmp_path):
    cfg = get_smoke_config("tinyllama-1.1b")
    data = SyntheticTokens(cfg.vocab_size, batch=2, seq_len=16)

    def hook(step):
        if step == 8:
            time.sleep(6.0)     # injected straggler

    # fixed SLA (not the running median) so background CPU load cannot
    # inflate the baseline and mask the injected straggler; fresh ckpt dir so
    # no stale checkpoint short-circuits the run
    t = Trainer(cfg, TrainConfig(steps=10, ckpt_every=100,
                                 ckpt_dir=str(tmp_path / "wd"), log_every=100,
                                 sla_seconds=1.5, sla_tolerance=3.0),
                AdamWConfig(), step_hook=hook)
    t.run(data)
    assert any(s == 8 for s, _ in t.stragglers)


# ------------------------------------------------------------- serve --------
def test_serve_engine_batched_decode():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(cfg, 0)
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32)
    for uid in range(3):                    # more requests than slots
        eng.submit(Request(uid=uid, prompt=[1 + uid, 2, 3],
                           max_new_tokens=4))
    eng.run_until_done(max_ticks=100)
    assert not eng.queue and all(s is None for s in eng.slots)


def test_serve_matches_raw_decode():
    """Engine output for a single request == hand-rolled decode loop."""
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(cfg, 0)
    prompt = [5, 9, 2]
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run_until_done(max_ticks=50)

    cache = init_cache(cfg, 1, 32)
    toks = list(prompt)
    for i, t in enumerate(prompt):
        logits, cache = decode_step(params, cfg, cache,
                                    jnp.asarray([t], jnp.int32), i)
    out = []
    cur = int(jnp.argmax(logits, -1)[0])
    for j in range(4):
        out.append(cur)
        logits, cache = decode_step(params, cfg, cache,
                                    jnp.asarray([cur], jnp.int32),
                                    len(prompt) + j)
        cur = int(jnp.argmax(logits, -1)[0])
    out.append(cur)
    assert req.output == out
