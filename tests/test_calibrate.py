"""Round-trip goldens for the calibration layer: fitting the recorded
(noiseless) sweep suite of a preset must recover the preset — the fitted
table re-predicts every recorded measurement within float tolerance and
the rail count exactly.

The one systematic offset is documented in :mod:`repro.exec.calibrate`:
the simulator charges one queue step per received message, so fitted
alphas absorb gamma (``alpha_fit == alpha_true + gamma``); rates and the
injection cap round-trip exactly.
"""
import numpy as np
import pytest
from pytest import approx

from repro.core.fitting import fit_RN_rails
from repro.core.params import REND
from repro.exec import SweepRecord, calibrate, record_sweeps
from repro.net.machine import (blue_waters_machine, frontier_machine,
                               lassen_machine)

PRESETS = {
    "lassen": lambda: lassen_machine((2, 2, 2)),
    "frontier": lambda: frontier_machine((2, 2, 2)),
    "blue_waters": lambda: blue_waters_machine((2, 1, 1)),
}


@pytest.fixture(scope="module", params=sorted(PRESETS))
def calibrated(request):
    machine = PRESETS[request.param]()
    record = record_sweeps(machine)
    return machine, record, calibrate(record, machine.params)


def test_rails_recovered_exactly(calibrated):
    machine, _, result = calibrated
    assert result.n_rails == machine.params.n_rails
    for kind, rails in result.rails_by_class.items():
        assert rails == machine.params.n_rails, kind


def test_fitted_alpha_absorbs_gamma_rates_exact(calibrated):
    machine, record, result = calibrated
    true, fit = machine.params, result.params
    for kind in record.pingpong:
        li = true.class_index(kind)
        for s in record.sizes:
            pi = int(true.protocol_of(np.asarray([s]))[0])
            assert fit.alpha[li, pi] == approx(true.alpha[li, pi]
                                               + true.gamma, rel=1e-6)
            assert fit.Rb[li, pi] == approx(true.Rb[li, pi], rel=1e-6)


def test_fitted_table_repredicts_pingpong_sweeps(calibrated):
    _, record, result = calibrated
    p = result.params
    for kind, times in record.pingpong.items():
        li = p.class_index(kind)
        for s, t in zip(record.sizes, times):
            pi = int(p.protocol_of(np.asarray([s]))[0])
            assert p.alpha[li, pi] + s / p.Rb[li, pi] == approx(t, rel=1e-6)


def test_fitted_table_repredicts_ppn_saturation_sweeps(calibrated):
    machine, record, result = calibrated
    p = result.params
    for kind, (ks, ts) in record.ppn.items():
        li = p.class_index(kind)
        pi = int(p.protocol_of(np.asarray([record.ppn_size]))[0])
        x = np.ceil(ks / result.n_rails)
        pred = (p.alpha[li, pi]
                + x * record.ppn_size / np.minimum(p.RN[li, pi],
                                                   x * p.Rb[li, pi]))
        np.testing.assert_allclose(pred, ts, rtol=1e-6)
        # and the cap itself round-trips to the ground truth
        assert p.RN[li, REND] == approx(machine.params.RN[li, REND],
                                        rel=1e-6)


def test_record_json_round_trip(calibrated):
    machine, record, result = calibrated
    back = SweepRecord.from_json(record.to_json())
    assert back.machine == record.machine
    np.testing.assert_array_equal(back.sizes, record.sizes)
    assert set(back.pingpong) == set(record.pingpong)
    for kind in record.pingpong:
        np.testing.assert_array_equal(back.pingpong[kind],
                                      record.pingpong[kind])
    for kind in record.ppn:
        np.testing.assert_array_equal(back.ppn[kind][1], record.ppn[kind][1])
    # calibrating the deserialized record gives the identical table
    again = calibrate(back, machine.params)
    np.testing.assert_array_equal(again.params.alpha, result.params.alpha)
    np.testing.assert_array_equal(again.params.RN, result.params.RN)
    assert again.n_rails == result.n_rails


def test_fit_RN_rails_handles_unsaturated_and_multirail():
    # never-saturating sweep -> inf (cap not observable)
    ks = np.arange(1, 9, dtype=float)
    flat = 1e-6 + np.zeros(8)
    assert fit_RN_rails(ks, flat + 1.0 / 1e10, 1.0, 1e-6, 1e10,
                        rails=2) == float("inf")
    # exact staircase, r=2: the legacy straight-line fit would be biased
    size, alpha, Rb, RN, r = float(1 << 20), 1e-6, 1e10, 5e9, 2
    x = np.ceil(ks / r)
    times = alpha + x * size / np.minimum(RN, x * Rb)
    assert fit_RN_rails(ks, times, size, alpha, Rb, rails=r) == approx(
        RN, rel=1e-12)
