"""Golden winner table for the shipped LLM workload scenarios (ISSUE 7).

The registry sweep is deterministic end to end (seeded routing histograms,
seeded arrival streams, noise-free simulator defaults), so the strategy the
model predicts — and the one the simulator confirms — for every
(machine, scenario, phase) cell is a reproducible artifact.  This test pins
the full table the way PR 5's crossover golden pinned the GPU-strategy
switch: a strategy-selection regression anywhere in the model ladder, the
rewrites, the simulator or the workload derivations flips a cell and fails
loudly with the diff.

The pinned verdicts are physics, not coincidence: on lassen (dual-rail
host NICs, host-staged path competitive) the dense MoE all-to-alls
aggregate via ``host_staged`` and the bulk-volume TP/pipeline phases via
``three_step``; on frontier (GPU-side NICs) and the CPU baseline
(blue_waters Gemini) the cheap paths win — ``standard`` for the
already-minimal-message shapes, ``three_step`` where combine-side
aggregation pays.  Model and simulator agree on every cell.
"""
import pytest

from repro.workloads import DEFAULT_SCENARIOS, default_machines, sweep

# (machine, scenario, phase) -> (model_winner, sim_winner)
GOLDEN = {
    ("lassen", "qwen3-moe-a2a", "dispatch"): ("host_staged", "host_staged"),
    ("lassen", "qwen3-moe-a2a", "combine"): ("host_staged", "host_staged"),
    ("lassen", "deepseek-moe-a2a", "dispatch"): ("host_staged", "host_staged"),
    ("lassen", "deepseek-moe-a2a", "combine"): ("host_staged", "host_staged"),
    ("lassen", "llama3-tp", "reduce_scatter"): ("three_step", "three_step"),
    ("lassen", "llama3-tp", "all_gather"): ("three_step", "three_step"),
    ("lassen", "llama3-pipeline", "p2p"): ("three_step", "three_step"),
    ("frontier", "qwen3-moe-a2a", "dispatch"): ("standard", "standard"),
    ("frontier", "qwen3-moe-a2a", "combine"): ("three_step", "three_step"),
    ("frontier", "deepseek-moe-a2a", "dispatch"): ("standard", "standard"),
    ("frontier", "deepseek-moe-a2a", "combine"): ("three_step", "three_step"),
    ("frontier", "llama3-tp", "reduce_scatter"): ("standard", "standard"),
    ("frontier", "llama3-tp", "all_gather"): ("standard", "standard"),
    ("frontier", "llama3-pipeline", "p2p"): ("three_step", "three_step"),
    ("blue_waters", "qwen3-moe-a2a", "dispatch"): ("standard", "standard"),
    ("blue_waters", "qwen3-moe-a2a", "combine"): ("three_step", "three_step"),
    ("blue_waters", "deepseek-moe-a2a", "dispatch"): ("standard", "standard"),
    ("blue_waters", "deepseek-moe-a2a", "combine"): ("three_step", "three_step"),
    ("blue_waters", "llama3-tp", "reduce_scatter"): ("standard", "standard"),
    ("blue_waters", "llama3-tp", "all_gather"): ("standard", "standard"),
    ("blue_waters", "llama3-pipeline", "p2p"): ("standard", "standard"),
}


@pytest.fixture(scope="module")
def rows():
    return sweep()


def test_table_covers_the_full_cross_product(rows):
    keys = [(r.machine, r.scenario, r.phase) for r in rows]
    assert len(keys) == len(set(keys)) == len(GOLDEN)
    assert set(keys) == set(GOLDEN)
    # machines in preset order, scenarios in registry order within each
    machine_order = [m for m, _, _ in keys]
    assert machine_order == sorted(machine_order,
                                   key=list(default_machines()).index)


def test_winners_match_golden(rows):
    got = {(r.machine, r.scenario, r.phase): (r.model_winner, r.sim_winner)
           for r in rows}
    mismatches = {k: (got[k], GOLDEN[k]) for k in GOLDEN if got[k] != GOLDEN[k]}
    assert not mismatches, f"winner table drifted: {mismatches}"


def test_model_and_simulator_agree_everywhere(rows):
    disagree = [(r.machine, r.scenario, r.phase, r.model_winner, r.sim_winner)
                for r in rows if not r.agree]
    assert not disagree


def test_costs_are_sane(rows):
    for r in rows:
        assert 0 < r.sim < 1.0, (r.scenario, r.sim)      # sub-second phases
        assert 0 < r.model < 1.0
        assert r.n_msgs > 0 and r.total_bytes > 0


def test_sweep_is_deterministic(rows):
    again = sweep()
    assert [(r.machine, r.scenario, r.phase, r.model_winner, r.sim_winner,
             r.model, r.sim) for r in rows] == \
           [(r.machine, r.scenario, r.phase, r.model_winner, r.sim_winner,
             r.model, r.sim) for r in again]


def test_scenarios_are_the_shipped_set():
    assert [sc.name for sc in DEFAULT_SCENARIOS] == \
        ["qwen3-moe-a2a", "deepseek-moe-a2a", "llama3-tp", "llama3-pipeline"]
