"""Tests for HLO collective parsing and the p2p decomposition/pricing."""
import numpy as np
import pytest

from repro.core import (parse_collectives, shape_bytes, tpu_v5e,
                        PodGeometry, decompose_collective, price_collective,
                        price_step)
from repro.core.hlo import CollectiveOp, parse_iota_groups

HLO = """
HloModule jit_step

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}

%body (p: (s32[], bf16[8,128])) -> (s32[], bf16[8,128]) {
  %p = (s32[], bf16[8,128]) parameter(0)
  %g = bf16[8,128]{1,0} get-tuple-element(%p), index=1
  %ar = bf16[8,128]{1,0} all-reduce(%g), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], bf16[8,128]) tuple(%i, %ar)
}

%cond (p: (s32[], bf16[8,128])) -> pred[] {
  %p = (s32[], bf16[8,128]) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: bf16[8,128]) -> bf16[8,128] {
  %a = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[8,2048]{1,0} all-gather(%a), channel_id=2, replica_groups=[32,16]<=[512], dimensions={1}, use_global_device_ids=true
  %rs = bf16[8,128]{1,0} reduce-scatter(%ag), channel_id=3, replica_groups=[32,16]<=[512], dimensions={1}, to_apply=%add
  %a2a = bf16[8,128]{1,0} all-to-all(%rs), channel_id=4, replica_groups=[64,8]<=[512], dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%a2a), channel_id=5, source_target_pairs={{0,16},{16,32},{32,0}}
  %w = (s32[], bf16[8,128]) tuple-and-while-stand-in(%cp)
  %wh = (s32[], bf16[8,128]) while(%w), condition=%cond, body=%body
  ROOT %out = bf16[8,128]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert shape_bytes("(f32[4,4], bf16[2])") == 64 + 4
    assert shape_bytes("f32[]") == 4


def test_iota_groups():
    g = parse_iota_groups(2, 4, [8], None)
    assert g.shape == (2, 4)
    assert list(g[0]) == [0, 1, 2, 3]
    gt = parse_iota_groups(4, 2, [2, 4], [1, 0])
    # iota(8).reshape(2,4).T.reshape(4,2) -> rows [0,4],[1,5],[2,6],[3,7]
    assert list(gt[0]) == [0, 4]
    assert list(gt[1]) == [1, 5]


def test_parse_collectives_kinds_and_loops():
    ops = parse_collectives(HLO, default_trip_count=12)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    by_kind = {o.kind: o for o in ops}
    assert by_kind["all-reduce"].count == 12          # inside while body
    assert by_kind["all-gather"].count == 1
    assert by_kind["all-reduce"].group_size == 16
    assert by_kind["all-to-all"].group_size == 8
    assert by_kind["collective-permute"].source_target_pairs == [
        (0, 16), (16, 32), (32, 0)]
    assert by_kind["all-gather"].result_bytes == 8 * 2048 * 2


def test_decompose_all_reduce_ring():
    op = CollectiveOp("all-reduce", 1024.0,
                      np.arange(8).reshape(1, 8), None, 1, "")
    ms = decompose_collective(op)
    # ring: every device sends 2(k-1) shards of B/k to its neighbor
    assert ms.src.size == 8
    assert np.allclose(ms.size, 1024 / 8)
    assert np.allclose(ms.mult, 14)
    assert ms.outstanding == 1 and ms.waves == 14
    # bytes on the wire per device: 2(k-1)/k * B  (the classic ring volume)
    assert ms.size[0] * ms.mult[0] == pytest.approx(2 * 7 / 8 * 1024)


def test_decompose_all_to_all_pairwise():
    op = CollectiveOp("all-to-all", 800.0, np.arange(4).reshape(1, 4), None, 1, "")
    ms = decompose_collective(op)
    assert ms.src.size == 4 * 3
    assert ms.outstanding == 3 and ms.waves == 1
    assert np.allclose(ms.size, 200.0)


def test_geometry_locality_and_hops():
    g = PodGeometry(n_pods=2)
    assert g.locality(0, 3) == 0            # same host
    assert g.locality(0, 4) == 1            # same pod ICI
    assert g.locality(0, 256) == 2          # cross pod DCN
    assert g.hops(0, 1) == 1
    assert g.hops(0, 15) == 1               # torus wraps columns
    assert g.hops(0, 16) == 1               # next row
    assert g.hops(0, 8 * 16 + 8) == 16      # mid-torus: 8 + 8


def test_price_ring_vs_a2a_queue():
    """The paper's point, adapted: fragmented many-peer comm pays gamma*n^2."""
    params = tpu_v5e()
    geom = PodGeometry(n_pods=1)
    ring = CollectiveOp("all-reduce", 1 << 20,
                        np.arange(256).reshape(1, 256), None, 1, "")
    a2a = CollectiveOp("all-to-all", 1 << 20,
                       np.arange(256).reshape(1, 256), None, 1, "")
    c_ring = price_collective(ring, geom, params)
    c_a2a = price_collective(a2a, geom, params)
    assert c_ring.queue < c_a2a.queue      # 255 outstanding transfers vs 1
    assert c_a2a.contention > c_ring.contention  # hop-distance sharing
    assert c_ring.naive_time > 0


def test_price_step_totals():
    params = tpu_v5e()
    geom = PodGeometry(n_pods=1)
    ops = [CollectiveOp("all-gather", 4096.0, np.arange(16).reshape(1, 16),
                        None, 3, "")]
    m = price_step(ops, geom, params)
    one = price_collective(ops[0], geom, params)
    assert m.model_time == pytest.approx(3 * one.model_time)
    assert m.naive_time == pytest.approx(3 * one.naive_time)


def test_dcn_pricing():
    """Cross-pod rings pay DCN latency/bandwidth on pod-crossing messages."""
    params = tpu_v5e()
    geom = PodGeometry(n_pods=2)
    # group strides across pods: devices 0 and 256 etc.
    grp = np.array([[0, 256]])
    op = CollectiveOp("all-reduce", 1 << 20, grp, None, 1, "")
    c = price_collective(op, geom, params)
    intra = CollectiveOp("all-reduce", 1 << 20, np.array([[0, 4]]), None, 1, "")
    ci = price_collective(intra, geom, params)
    assert c.transport > ci.transport      # DCN much slower than ICI
