"""Tests for the unified CommPhase engine: vectorized routing, batched queue
walk, shared active-sender primitive, and model/simulator agreement with the
pre-refactor scalar implementations (golden values captured from the seed
code paths)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.comm import (CommPhase, active_senders_per_node,
                        queue_traversal_steps, batched_queue_traversal_steps)
from repro.core import phase_cost, phase_cost_many, model_ladder, model_ladder_many
from repro.core.topology import TorusTopology
from repro.net import (blue_waters_machine, tpu_v5e_machine, simulate,
                       simulate_phase, simulate_many)


# ------------------------------------------------- vectorized routing -------
TORI = [((4, 4), True), ((4, 4), False), ((3, 4, 5), True), ((3, 4, 5), False),
        ((8,), True), ((2, 1, 3), True)]


@pytest.mark.parametrize("dims,wrap", TORI)
def test_route_link_ids_matches_scalar(dims, wrap):
    """Vectorized per-dimension segment expansion == per-message route_links."""
    t = TorusTopology(dims, wrap=wrap)
    rng = np.random.default_rng(0)
    n = 150
    src = rng.integers(0, t.size, n)
    dst = rng.integers(0, t.size, n)
    size = rng.integers(1, 1000, n).astype(float)
    ref: dict = {}
    for s, d, z in zip(src, dst, size):
        for link in t.route_links(int(s), int(d)):
            ref[link] = ref.get(link, 0.0) + float(z)
    got = t.accumulate_link_bytes(src, dst, size)
    assert set(got) == set(ref)
    for k in ref:
        assert got[k] == pytest.approx(ref[k])


@pytest.mark.parametrize("dims,wrap", TORI)
def test_route_link_bytes_conservation(dims, wrap):
    """Per-link byte sum == sum over messages of size * hops."""
    t = TorusTopology(dims, wrap=wrap)
    rng = np.random.default_rng(1)
    n = 200
    src = rng.integers(0, t.size, n)
    dst = rng.integers(0, t.size, n)
    size = rng.integers(1, 1000, n).astype(float)
    dense = t.link_bytes(src, dst, size)
    assert dense.size == t.link_slots
    expect = float((size * t.hops(src, dst)).sum())
    assert dense.sum() == pytest.approx(expect)
    # per-message emitted-link counts equal hop counts
    midx, _ = t.route_link_ids(src, dst)
    assert np.array_equal(np.bincount(midx, minlength=n), t.hops(src, dst))


# ------------------------------------------------- batched queue walk -------
def test_batched_queue_steps_matches_per_process():
    rng = np.random.default_rng(2)
    for _ in range(20):
        counts = rng.integers(1, 50, rng.integers(1, 8))
        bounds = np.concatenate([[0], np.cumsum(counts)])
        posted = np.concatenate([rng.permutation(c) for c in counts])
        arrive = np.concatenate([rng.permutation(c) for c in counts])
        got = batched_queue_traversal_steps(posted, arrive, bounds)
        for r, c in enumerate(counts):
            s, e = bounds[r], bounds[r + 1]
            ref = queue_traversal_steps(posted[s:e], arrive[s:e])
            assert np.array_equal(got[s:e], ref)


def test_batched_queue_steps_extremes():
    n = 64
    b = [0, n]
    same = batched_queue_traversal_steps(np.arange(n), np.arange(n), b)
    assert same.sum() == n                       # every arrival matches head
    rev = batched_queue_traversal_steps(np.arange(n)[::-1], np.arange(n), b)
    assert rev.sum() == n * (n + 1) // 2         # full queue walk each time
    assert batched_queue_traversal_steps([], [], [0]).size == 0


def test_phase_queue_steps_matches_reference():
    """CommPhase.queue_steps == per-receiver scalar Fenwick, mixed defaults."""
    m = blue_waters_machine((2, 1, 1))
    rng = np.random.default_rng(3)
    n = 300
    src = rng.integers(0, 16, n)
    dst = 32 + rng.integers(0, 12, n)
    size = rng.integers(8, 1 << 16, n).astype(float)
    phase = CommPhase.build(m, src, dst, size)
    receivers = np.unique(dst)
    # custom arrival for half the receivers, custom posting for a third
    arrival = {int(p): rng.permutation(np.nonzero(dst == p)[0])
               for p in receivers[::2]}
    posted = {int(p): np.nonzero(dst == p)[0][::-1] for p in receivers[::3]}
    got = phase.queue_steps(posted, arrival)
    for p in receivers:
        ids = np.nonzero(dst == p)[0]
        local = {mid: k for k, mid in enumerate(ids)}
        po = (np.asarray([local[x] for x in posted[int(p)]])
              if int(p) in posted else np.arange(ids.size))
        ao = (np.asarray([local[x] for x in arrival[int(p)]])
              if int(p) in arrival else np.arange(ids.size))
        assert got[p] == queue_traversal_steps(po, ao).sum()
    assert got.sum() == got[receivers].sum()     # silent procs pay nothing


def test_queue_steps_rejects_foreign_message_index():
    """An order entry naming a message not destined to that receiver is a
    silent-corruption hazard — it must fail loudly (the pre-refactor dict
    lookup raised KeyError)."""
    m = tpu_v5e_machine((4, 4))
    ph = CommPhase.build(m, [0, 0, 1], [5, 5, 6], [1e4, 1e4, 1e4])
    with pytest.raises(ValueError):
        ph.queue_steps(arrival_order={5: np.array([0, 2])})   # msg 2 -> proc 6
    with pytest.raises(ValueError):
        ph.queue_steps(recv_post_order={5: np.array([0])})    # wrong length
    with pytest.raises(ValueError):
        ph.queue_steps(arrival_order={5: np.array([0, 0])})   # duplicate index


def test_link_contention_source_ids_beyond_torus_size():
    """torus_over_procs machines can have source ids >= torus.size; the
    per-(link, source) grouping must not bleed source bits into the link key.
    Golden value from the pre-refactor scalar dict implementation."""
    mt = tpu_v5e_machine((4, 4))
    rng = np.random.default_rng(11)
    n = 400
    src = rng.integers(0, 256, n)
    dst = (src + rng.integers(1, 256, n)) % 256
    size = rng.integers(8, 1 << 16, n).astype(float)
    r = simulate_phase(mt, src, dst, size)
    assert r.max_link_bytes == pytest.approx(1124767.0, rel=1e-12)
    assert r.contention == pytest.approx(5.623835e-05, rel=1e-10)


def test_default_order_queue_is_linear():
    m = blue_waters_machine((2, 1, 1))
    src = np.zeros(40, dtype=np.int64)
    dst = np.full(40, 32)
    phase = CommPhase.build(m, src, dst, np.full(40, 1e4))
    assert phase.queue_steps().sum() == 40


# ------------------------------------------------- active senders -----------
def test_active_senders_matches_dict_of_sets():
    rng = np.random.default_rng(4)
    n = 500
    src = rng.integers(0, 128, n)
    node = src // 16
    is_net = rng.random(n) < 0.7
    got = active_senders_per_node(src, node, is_net)
    active: dict = {}
    for p, nd, net in zip(src, node, is_net):
        if net:
            active.setdefault(int(nd), set()).add(int(p))
    for i in range(n):
        expect = len(active.get(int(node[i]), ())) if is_net[i] else 1
        assert got[i] == max(expect, 1)


def test_active_senders_no_net():
    assert (active_senders_per_node([1, 2], [0, 0], [False, False]) == 1).all()
    assert active_senders_per_node([], [], []).size == 0


# ------------------------------------------------- CommPhase caching --------
def test_comm_phase_caches_machine_views():
    m = blue_waters_machine((2, 2, 1))
    rng = np.random.default_rng(5)
    n = 200
    src = rng.integers(0, m.n_procs, n)
    dst = (src + rng.integers(1, m.n_procs, n)) % m.n_procs
    size = rng.integers(8, 1 << 18, n).astype(float)
    ph = CommPhase.build(m, src, dst, size)
    assert np.array_equal(ph.loc, m.locality(src, dst))
    assert np.array_equal(ph.send_node, m.node_of(src))
    assert np.array_equal(ph.torus_src, m.torus_node_of(src))
    assert np.array_equal(ph.proto, m.params.protocol_of(size))
    assert ph.n_procs == int(max(src.max(), dst.max())) + 1
    assert ph.total_bytes == pytest.approx(size.sum())
    assert ph.net_bytes == pytest.approx(size[ph.is_net].sum())


def test_comm_phase_empty():
    m = blue_waters_machine((2, 1, 1))
    ph = CommPhase.build(m, [], [], [])
    assert ph.n_msgs == 0 and ph.n_procs == 0
    assert simulate(ph).time == 0.0


# ---------------------------------------- model/simulator agreement ---------
def _random_phase(machine, n, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, machine.n_procs, n)
    dst = (src + rng.integers(1, machine.n_procs, n)) % machine.n_procs
    size = rng.integers(8, 1 << 18, n).astype(float)
    return src, dst, size


def test_phase_cost_many_matches_phase_cost():
    m = blue_waters_machine((2, 2, 2))
    src, dst, size = _random_phase(m, 300, 6)
    ph = CommPhase.build(m, src, dst, size)
    batched = model_ladder_many([ph])[0]
    arrays = model_ladder(m.params, src, dst, size, m.locality(src, dst),
                          node_of=m.node_of, n_torus_nodes=m.torus.size,
                          torus_ndim=m.torus.ndim,
                          procs_per_torus_node=m.procs_per_torus_node,
                          n_procs=ph.n_procs)
    for lvl, cb in arrays.items():
        assert batched[lvl].total == pytest.approx(cb.total)
        assert batched[lvl].transport == pytest.approx(cb.transport)
        assert batched[lvl].queue == pytest.approx(cb.queue)
        assert batched[lvl].contention == pytest.approx(cb.contention)
    assert len(phase_cost_many([ph, ph], level="queue")) == 2


def test_phase_cost_phase_params_override_recomputes_ppn():
    """An override params table that reclassifies localities must not reuse
    active-sender counts cached under the machine's network_locality."""
    from repro.core import phase_cost_phase
    m = blue_waters_machine((2, 2, 1))           # network_locality = 2
    src, dst, size = _random_phase(m, 200, 10)
    ph = CommPhase.build(m, src, dst, size)
    override = m.params.replace(network_locality=1)
    got = phase_cost_phase(ph, level="maxrate", params=override)
    from repro.comm import active_senders_per_node
    ppn = active_senders_per_node(src, m.node_of(src),
                                  ph.loc >= override.network_locality)
    want = phase_cost(override, src, dst, size, ph.loc,
                      n_torus_nodes=m.torus.size, torus_ndim=m.torus.ndim,
                      procs_per_torus_node=m.procs_per_torus_node,
                      n_procs=ph.n_procs, level="maxrate", active_ppn=ppn)
    assert got.total == pytest.approx(want.total)
    # the reclassification genuinely produces different active-sender counts
    # (totals may still coincide when RN never binds, so compare the arrays)
    assert not np.array_equal(ppn, ph.active_ppn)


def test_simulate_many_matches_simulate_phase():
    m = tpu_v5e_machine((4, 4))
    phases, arrivals, singles = [], [], []
    for seed in (7, 8, 9):
        src, dst, size = _random_phase(m, 120, seed)
        ph = CommPhase.build(m, src, dst, size)
        rng = np.random.default_rng(seed)
        ao = ph.random_arrival_order(rng)
        phases.append(ph)
        arrivals.append(ao)
        singles.append(simulate_phase(m, src, dst, size, arrival_order=ao))
    for got, want in zip(simulate_many(phases, arrival_orders=arrivals), singles):
        assert got.time == pytest.approx(want.time)
        assert got.queue == pytest.approx(want.queue)
        assert got.contention == pytest.approx(want.contention)


# ------------------------------------------------- golden regression --------
# Values captured from the pre-refactor (seed) scalar simulator on the same
# deterministic phase: a seeded random pattern on a 4x4 wrapped v5e torus,
# with reversed posting and random arrival.  Guards the acceptance criterion
# that the vectorized engine reproduces the old PhaseResult exactly.
def _tpu_golden_phase():
    mt = tpu_v5e_machine((4, 4))
    rng = np.random.default_rng(3)
    src = rng.integers(0, 16, 60)
    dst = (src + rng.integers(1, 16, 60)) % 16
    size = rng.integers(8, 1 << 16, 60).astype(float)
    arrival = {int(p): rng.permutation(np.nonzero(dst == p)[0])
               for p in np.unique(dst)}
    post = {int(p): np.nonzero(dst == p)[0][::-1] for p in np.unique(dst)}
    return mt, src, dst, size, post, arrival


def test_simulator_golden_tpu_custom_orders():
    mt, src, dst, size, post, arrival = _tpu_golden_phase()
    r = simulate_phase(mt, src, dst, size,
                       recv_post_order=post, arrival_order=arrival)
    assert r.time == pytest.approx(2.335131111111111e-05, rel=1e-12)
    assert r.transport == pytest.approx(1.4821111111111112e-05, rel=1e-12)
    assert r.queue == pytest.approx(1.7e-07, rel=1e-12)
    assert r.contention == pytest.approx(8.3602e-06, rel=1e-12)
    assert r.max_link_bytes == 167204.0
    assert r.total_net_bytes == 1900397.0
    assert int(r.per_proc_queue_steps.sum()) == 105
    assert int(r.per_proc_queue_steps.max()) == 17


def test_simulator_golden_tpu_default_orders():
    mt, src, dst, size, _, _ = _tpu_golden_phase()
    r = simulate_phase(mt, src, dst, size)
    assert r.time == pytest.approx(2.3241311111111113e-05, rel=1e-12)
    assert int(r.per_proc_queue_steps.sum()) == 60
    assert int(r.per_proc_queue_steps.max()) == 6


@given(st.integers(1, 120), st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_property_batched_queue_bounds(n, seed):
    """Any order costs between n (head hits) and n(n+1)/2 (worst case)."""
    rng = np.random.default_rng(seed)
    posted = rng.permutation(n)
    arrive = rng.permutation(n)
    total = batched_queue_traversal_steps(posted, arrive, [0, n]).sum()
    assert n <= total <= n * (n + 1) // 2
