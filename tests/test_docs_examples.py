"""Executable documentation: every fenced ``python`` block in the docs runs.

The docs promise working code — README's quickstart, api.md's usage
snippets, paper_map.md's claim demonstrations.  This test extracts every
fenced ``python`` block from those files and executes it (numpy backend,
small shapes), so a snippet that drifts from the API fails CI instead of
rotting silently.  Each block must be self-contained (its own imports);
``sh`` blocks and inline code spans are not executed.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOCS = [ROOT / "README.md",
        ROOT / "docs" / "api.md",
        ROOT / "docs" / "paper_map.md"]

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _blocks():
    out = []
    for doc in DOCS:
        for i, m in enumerate(_FENCE.finditer(doc.read_text())):
            out.append(pytest.param(doc, m.group(1),
                                    id=f"{doc.name}#{i}"))
    return out


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_every_doc_has_executable_snippets(doc):
    """Each documented surface ships at least one runnable example — and the
    extraction regex cannot silently match nothing."""
    assert doc.exists(), doc
    assert _FENCE.search(doc.read_text()), \
        f"{doc.name} has no fenced python block"


@pytest.mark.parametrize("doc, code", _blocks())
def test_docs_snippet_executes(doc, code):
    """The block runs top to bottom in a fresh namespace (asserts inside the
    snippet are part of the documented claim)."""
    exec(compile(code, f"<{doc.name} snippet>", "exec"),
         {"__name__": "__docs__"})
