"""The execution layer's contract: every lowered strategy schedule delivers
payloads bit-identical to the numpy reference executor.

The numpy half (planner invariants, serial oracle equality, edge cases,
hypothesis property sweep) runs in-process.  The JAX half lowers every
strategy x all four host-scale machine presets onto a forced 8-device host
mesh in a subprocess (``XLA_FLAGS`` must be set before jax imports; the
parent pytest process keeps its single-device view) and pins exact
``np.array_equal`` payload identity plus digest agreement through the
fused segment kernels.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.comm.phase import CommPhase
from repro.comm.strategies import ROLES, strategies_for
from repro.exec import (build_schedule, delivered_digest, host_machines,
                        pairs_subset_of_plan, reference_delivered,
                        run_reference, units_for)

from _hypothesis_compat import given, settings, st

MACHINES = host_machines()
CASES = [(mname, strat) for mname, m in MACHINES.items()
         for strat in strategies_for(m)]


def _phase(machine, n=40, seed=0, n_procs=8, max_size=6000):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_procs, n)
    dst = (src + rng.integers(1, n_procs, n)) % n_procs
    size = rng.integers(1, max_size, n).astype(float)
    return CommPhase.build(machine, src, dst, size, n_procs=n_procs)


# ---------------------------------------------------------------- planner --

@pytest.mark.parametrize("mname,strat", CASES,
                         ids=[f"{m}-{s}" for m, s in CASES])
def test_reference_execution_is_bit_identical(mname, strat):
    ph = _phase(MACHINES[mname])
    for coloring in ("greedy", "per_message"):
        sched = build_schedule(ph, strat, coloring=coloring)
        assert np.array_equal(run_reference(sched),
                              reference_delivered(sched))


@pytest.mark.parametrize("mname,strat", CASES,
                         ids=[f"{m}-{s}" for m, s in CASES])
def test_lowered_pairs_subset_of_pricing_plan(mname, strat):
    sched = build_schedule(_phase(MACHINES[mname]), strat)
    assert pairs_subset_of_plan(sched)
    # and the plan side exposes every lowered role
    plan_roles = set(sched.plan.roles)
    for ph in sched.phases:
        assert ph.role in plan_roles or ph.role in ("standard",)


def test_flow_conservation_every_unit_delivered_once():
    m = MACHINES["lassen_8"]
    ph = _phase(m, n=64, seed=3)
    for strat in strategies_for(m):
        sched = build_schedule(ph, strat)
        deliv = run_reference(sched)
        # each unit appears exactly once, at its destination, with payload
        hits = deliv != 0
        assert hits.sum() == sched.n_units
        np.testing.assert_array_equal(hits.sum(axis=0),
                                      np.ones(sched.n_units))
        # digest through the fused kernels agrees with the payload totals
        np.testing.assert_array_equal(
            delivered_digest(deliv, sched),
            np.bincount(sched.unit_dst, weights=sched.payload.astype(float),
                        minlength=sched.n_procs))


def test_rounds_are_valid_permutations():
    m = MACHINES["frontier_8"]
    for strat in strategies_for(m):
        sched = build_schedule(_phase(m, n=64, seed=7), strat)
        for ph in sched.phases:
            for rnd in ph.rounds:
                senders = [s for s, _ in rnd.perm]
                receivers = [d for d, _ in rnd.perm]
                assert len(set(senders)) == len(senders)
                assert len(set(receivers)) == len(receivers)
            assert ph.n_rounds <= max(1, ph.n_msgs)


def test_per_message_coloring_is_one_round_per_message():
    m = MACHINES["blue_waters_8"]
    sched = build_schedule(_phase(m), "two_step", coloring="per_message")
    for ph in sched.phases:
        assert ph.n_rounds == ph.n_msgs
    greedy = build_schedule(_phase(m), "two_step")
    assert greedy.n_rounds <= sched.n_rounds


def test_units_for_floors_and_splits():
    u = units_for([0.0, 1.0, 512.0, 513.0, 5120.0], unit_bytes=512.0)
    np.testing.assert_array_equal(u, [1, 1, 1, 2, 10])


def test_split_strategies_fan_units_across_injectors():
    m = MACHINES["blue_waters_8"]
    # one big remote message: three_step must spread units over k ranks
    ph = CommPhase.build(m, [1], [6], [8 * 512.0], n_procs=8)
    sched = build_schedule(ph, "three_step")
    inter = [p for p in sched.phases if p.role == "inter"]
    assert len(inter) == 1
    assert inter[0].n_msgs == 4        # k = min(avail) = ppn = 4 injectors
    assert np.array_equal(run_reference(sched), reference_delivered(sched))


def test_edge_cases_empty_self_single_rank():
    m = MACHINES["lassen_8"]
    empty = CommPhase.build(m, [], [], [], n_procs=8)
    selfmsg = CommPhase.build(m, [0, 3, 5], [0, 3, 5],
                              [64.0, 1024.0, 0.0], n_procs=8)
    onerank = CommPhase.build(m, [0, 0], [0, 0], [100.0, 200.0], n_procs=1)
    for phase in (empty, selfmsg, onerank):
        for strat in strategies_for(m):
            sched = build_schedule(phase, strat)
            assert sched.n_rounds == 0      # nothing crosses a rank
            assert np.array_equal(run_reference(sched),
                                  reference_delivered(sched))


def test_unknown_coloring_raises():
    m = MACHINES["lassen_8"]
    with pytest.raises(ValueError, match="coloring"):
        build_schedule(_phase(m), "standard", coloring="rainbow")


def test_copy_phases_present_and_roundless_for_host_staged():
    m = MACHINES["lassen_8"]
    sched = build_schedule(_phase(m), "host_staged")
    roles = [p.role for p in sched.phases]
    assert "d2h" in roles and "h2d" in roles
    for ph in sched.phases:
        if ph.role in ("d2h", "h2d"):
            assert ph.n_rounds == 0
            np.testing.assert_array_equal(ph.msg_src, ph.msg_dst)
    # role order follows the canonical ROLES order
    assert roles == sorted(roles, key=ROLES.index)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 48))
@settings(max_examples=25, deadline=None)
def test_property_random_patterns_bit_identical(seed, n):
    for mname in ("blue_waters_8", "lassen_8"):
        m = MACHINES[mname]
        ph = _phase(m, n=n, seed=seed)
        for strat in strategies_for(m):
            sched = build_schedule(ph, strat)
            assert np.array_equal(run_reference(sched),
                                  reference_delivered(sched))


# -------------------------------------------------- jax: 8-device mesh ----

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
from repro.comm.phase import CommPhase
from repro.comm.strategies import strategies_for
from repro.exec import (build_schedule, execute, host_machines,
                        run_reference, time_schedule)

results = {"mismatches": {}, "digest_err": {}}
for mname, m in host_machines().items():
    rng = np.random.default_rng(11)
    n = 40
    src = rng.integers(0, 8, n)
    dst = (src + rng.integers(1, 8, n)) % 8
    size = rng.integers(1, 6000, n).astype(float)
    ph = CommPhase.build(m, src, dst, size, n_procs=8)
    for strat in strategies_for(m):
        sched = build_schedule(ph, strat)
        want = run_reference(sched)
        got, digest = execute(sched, digest_backend="jax")
        key = f"{mname}/{strat}"
        results["mismatches"][key] = int((got != want).sum())
        # same fused-kernel backend on both sides: the device digest of the
        # executed exchange must match the reference exchange's exactly
        # (the jax path reduces in float32, so it is only comparable to
        # itself, not to a float64 bincount)
        from repro.exec import delivered_digest
        ref_digest = delivered_digest(want, sched, backend="jax")
        results["digest_err"][key] = float(np.abs(digest - ref_digest).max())

# a timed run works end to end on the mesh
m = host_machines()["lassen_8"]
rng = np.random.default_rng(5)
src = rng.integers(0, 8, 24); dst = (src + rng.integers(1, 8, 24)) % 8
ph = CommPhase.build(m, src, dst, rng.integers(1, 4096, 24).astype(float),
                     n_procs=8)
meas = time_schedule(build_schedule(ph, "three_step"), reps=3, warmup=1)
results["median_s"] = meas.median_s
results["n_rounds"] = meas.n_rounds
print(json.dumps(results))
"""


@pytest.fixture(scope="module")
def mesh_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_every_strategy_bit_identical_on_8_device_mesh(mesh_results):
    assert mesh_results["mismatches"], "no strategy cases ran"
    bad = {k: v for k, v in mesh_results["mismatches"].items() if v != 0}
    assert not bad, f"payload mismatch vs reference executor: {bad}"
    # all four machines x their full strategy set were covered
    covered = {k.split("/")[0] for k in mesh_results["mismatches"]}
    assert covered == set(MACHINES)
    assert len(mesh_results["mismatches"]) == len(CASES)


def test_device_digest_matches_payload_totals(mesh_results):
    worst = max(mesh_results["digest_err"].values())
    assert worst == 0.0


def test_timed_run_reports_positive_median(mesh_results):
    assert mesh_results["median_s"] > 0.0
    assert mesh_results["n_rounds"] > 0
