"""Tests for the node-aware strategy layer (repro.comm.strategies).

Three layers of certification:

* **conservation** — flow identities over the rewritten message arrays alone
  must reproduce the original per-source / per-destination / per-node-pair
  payload (so a rewrite can neither drop, duplicate, nor misroute bytes);
  the power-of-two variant makes the per-destination check a *pairwise*
  certificate (sums of distinct powers of two decode uniquely);
* **equivalence** — the vectorized np.unique/bincount rewrites match a
  deliberately scalar dict-based reference, message for message;
* **golden crossover** — on a fixed AMG level the model ladder must predict
  an aggregated winner and the simulator must agree (the NAPSpMV result the
  example prints).
"""
import numpy as np
import pytest

from repro.comm import (CommPhase, STRATEGIES, best_strategy,
                        delivered_payload, injected_payload, rewrite,
                        sum_by_pairs, segmented_arange)
from repro.core import phase_cost_many, sequence_cost
from repro.net import (blue_waters_machine, tpu_v5e_machine, simulate_many,
                       simulate_sequence)
from repro.sparse import (RowPartition, build_hierarchy, elasticity_like_3d,
                          spmv_comm_pattern)

MACHINES = [blue_waters_machine((2, 2, 1)), tpu_v5e_machine((4, 4))]


def _random_phase(machine, n_msgs, seed, n_procs=None):
    rng = np.random.default_rng(seed)
    P = n_procs or machine.n_procs
    src = rng.integers(0, P, n_msgs)
    dst = rng.integers(0, P, n_msgs)
    keep = src != dst
    size = rng.integers(8, 1 << 14, n_msgs).astype(float)
    return CommPhase.build(machine, src[keep], dst[keep], size[keep],
                           n_procs=P)


# ------------------------------------------------------- conservation -------
@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_payload_conservation(machine, strategy, seed):
    """Injected / delivered / node-pair payloads survive every rewrite."""
    phase = _random_phase(machine, 400, seed)
    plan = rewrite(phase, strategy)
    P = phase.n_procs
    np.testing.assert_allclose(
        injected_payload(plan),
        np.bincount(phase.src, weights=phase.size, minlength=P))
    np.testing.assert_allclose(
        delivered_payload(plan),
        np.bincount(phase.dst, weights=phase.size, minlength=P))
    # payload crossing each (send-node, recv-node) boundary is invariant
    sn_o = phase.send_node
    dn_o = np.asarray(machine.node_of(phase.dst))
    rem = sn_o != dn_o
    ref = sum_by_pairs(sn_o[rem], dn_o[rem], phase.size[rem])
    got = plan.inter_node_pair_bytes()
    for a, b in zip(got, ref):
        np.testing.assert_allclose(a, b)


@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
@pytest.mark.parametrize("strategy", ["two_step", "three_step"])
def test_phase_roles_stay_in_their_lane(machine, strategy):
    """gather/scatter never cross nodes; the inter phase always does."""
    phase = _random_phase(machine, 500, 3)
    plan = rewrite(phase, strategy)
    assert plan.roles[0] in ("local", "gather")          # execution order
    for ph, role in zip(plan.phases, plan.roles):
        crosses = ph.send_node != np.asarray(machine.node_of(ph.dst))
        if role == "inter":
            assert crosses.all()
        else:
            assert not crosses.any()


@pytest.mark.parametrize("strategy", ["two_step", "three_step"])
def test_pairwise_conservation_powers_of_two(strategy):
    """Per-destination sums of distinct powers of two decode uniquely, so
    matching them certifies delivery of each individual (src, dst) payload."""
    machine = blue_waters_machine((2, 1, 1))
    rng = np.random.default_rng(7)
    P = machine.n_procs
    src = rng.integers(0, P, 120)
    dst = rng.integers(0, P, 120)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # size = 2^(rank of the message within its destination's group)
    order = np.argsort(dst, kind="stable")
    rank = np.empty(src.size, dtype=np.int64)
    rank[order] = segmented_arange(np.bincount(dst, minlength=P))
    size = np.power(2.0, rank + 6)       # >= 64 bytes, distinct per receiver
    phase = CommPhase.build(machine, src, dst, size, n_procs=P)
    plan = rewrite(phase, strategy)
    np.testing.assert_array_equal(
        delivered_payload(plan),
        np.bincount(dst, weights=size, minlength=P))


# -------------------------------------------- scalar-reference equivalence --
def _two_step_reference(phase):
    """Dict-based per-message reference for the two_step rewrite."""
    m, ppn = phase.machine, phase.machine.procs_per_node
    local, gather, inter, scatter = {}, {}, {}, {}
    for s, d, z in zip(phase.src, phase.dst, phase.size):
        s, d, z = int(s), int(d), float(z)
        sn, dn = s // ppn, d // ppn
        if sn == dn:
            local[(s, d)] = local.get((s, d), 0.0) + z
            continue
        ls, ld = sn * ppn, dn * ppn
        if s != ls:
            gather[(s, ls)] = gather.get((s, ls), 0.0) + z
        inter[(ls, ld)] = inter.get((ls, ld), 0.0) + z
        if d != ld:
            scatter[(ld, d)] = scatter.get((ld, d), 0.0) + z
    return {"local": local, "gather": gather, "inter": inter,
            "scatter": scatter}


@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
def test_two_step_matches_scalar_reference(machine):
    """The vectorized rewrite == the per-message dict walk, exactly."""
    phase = _random_phase(machine, 600, 11)
    plan = rewrite(phase, "two_step")
    ref = _two_step_reference(phase)
    for role in ("local", "gather", "inter", "scatter"):
        ph = plan.phase_by_role(role)
        got: dict = {}
        for s, d, z in zip(*( (ph.src, ph.dst, ph.size) if ph is not None
                              else ((), (), ()) )):
            # the local phase keeps original duplicates as-is; sum them for
            # comparison against the aggregating reference
            got[(int(s), int(d))] = got.get((int(s), int(d)), 0.0) + float(z)
        assert got == pytest.approx(ref[role]), role


def test_two_step_reduces_inter_node_msgs_clustered():
    """On a clustered pattern (every process talks to every process of two
    peer nodes) aggregation collapses inter-node traffic to one message per
    node pair."""
    machine = blue_waters_machine((2, 2, 1))
    ppn = machine.procs_per_node
    src, dst = [], []
    for node in range(4):
        for peer in ((node + 1) % 4, (node + 2) % 4):
            for i in range(ppn):
                for j in range(0, ppn, 4):
                    src.append(node * ppn + i)
                    dst.append(peer * ppn + j)
    size = np.full(len(src), 256.0)
    phase = CommPhase.build(machine, src, dst, size, n_procs=4 * ppn)
    std = rewrite(phase, "standard")
    two = rewrite(phase, "two_step")
    assert std.inter_node_msgs == len(src)
    assert two.inter_node_msgs == 8          # one per (node, peer) pair
    assert two.inter_node_msgs < std.inter_node_msgs
    # three_step trades message count for injection spread, but still far
    # fewer than standard on a clustered pattern
    three = rewrite(phase, "three_step")
    assert two.inter_node_msgs <= three.inter_node_msgs
    assert three.inter_node_msgs < std.inter_node_msgs


# ------------------------------------------------------ cost plumbing -------
def test_sequence_cost_and_simulation_sum_over_phases():
    machine = blue_waters_machine((2, 2, 1))
    phase = _random_phase(machine, 300, 5)
    plan = rewrite(phase, "three_step")
    seq = sequence_cost(plan.phases, level="contention")
    parts = phase_cost_many(plan.phases, level="contention")
    assert seq.total == pytest.approx(sum(p.total for p in parts))
    assert seq.queue == pytest.approx(sum(p.queue for p in parts))
    sim = simulate_sequence(plan.phases)
    sims = simulate_many(plan.phases)
    assert sim.time == pytest.approx(sum(r.time for r in sims))
    assert len(sim.phases) == plan.n_phases


def test_standard_is_identity():
    machine = blue_waters_machine((2, 1, 1))
    phase = _random_phase(machine, 100, 9)
    plan = rewrite(phase, "standard")
    assert plan.phases == (phase,)
    assert plan.roles == ("standard",)
    assert sequence_cost(plan.phases).total == pytest.approx(
        phase_cost_many([phase])[0].total)


def test_unknown_strategy_raises():
    machine = blue_waters_machine((2, 1, 1))
    phase = _random_phase(machine, 10, 0)
    with pytest.raises(ValueError, match="unknown strategy"):
        rewrite(phase, "four_step")


def test_intra_node_phase_degenerates_to_identity():
    """A phase with no inter-node traffic is untouched by every strategy."""
    machine = blue_waters_machine((2, 1, 1))
    src = np.arange(0, 8)
    dst = np.arange(8, 16)        # same node (ppn = 16)
    phase = CommPhase.build(machine, src, dst, np.full(8, 64.0), n_procs=16)
    for s in STRATEGIES:
        plan = rewrite(phase, s)
        assert plan.roles == ("standard",)
        assert plan.phases == (phase,)


# ------------------------------------------------------ golden crossover ----
def test_golden_amg_crossover_model_and_simulator_agree():
    """The message-heavy AMG level flips to an aggregated strategy: the
    model ladder predicts it and the simulator confirms it, with a solid
    margin (golden expectations pinned from the example output)."""
    A = elasticity_like_3d(12)
    levels = build_hierarchy(A)
    machine = blue_waters_machine((4, 2, 2))
    lvl = levels[1]
    part = RowPartition.balanced(lvl.A.n_rows, max(lvl.A.n_rows // 2, 2))
    v = spmv_comm_pattern(lvl.A, part).best_strategy(machine, seed=0)
    assert v.model_winner == "three_step"
    assert v.sim_winner == "three_step"
    assert v.agree
    # aggregation must win by a real margin on both sides of the gap
    assert v.model["three_step"] < 0.75 * v.model["standard"]
    assert v.sim["three_step"] < 0.75 * v.sim["standard"]
    # and the coarsest level must NOT flip (little traffic, nothing to win)
    coarse = levels[-1]
    partc = RowPartition.balanced(coarse.A.n_rows,
                                  max(coarse.A.n_rows // 2, 2))
    vc = spmv_comm_pattern(coarse.A, partc).best_strategy(machine, seed=0)
    assert vc.sim_winner == "standard"


def test_best_strategy_requires_machine_for_patterns():
    A = elasticity_like_3d(8)
    part = RowPartition.balanced(A.n_rows, 8)
    cp = spmv_comm_pattern(A, part)
    with pytest.raises(ValueError, match="needs a machine"):
        best_strategy(cp)
    with pytest.raises(ValueError, match="unknown arrival"):
        best_strategy(cp, blue_waters_machine((2, 1, 1)), arrival="Random")


def test_best_strategy_rebinds_phase_to_explicit_machine():
    """Passing a bound phase plus a different machine must re-evaluate on
    that machine, not silently reuse the stale binding."""
    bw = blue_waters_machine((2, 1, 1))          # 32 procs
    tpu = tpu_v5e_machine((8, 4))                # 32 procs, other parameters
    phase = _random_phase(bw, 300, 13, n_procs=bw.n_procs)
    v_bw = best_strategy(phase, seed=0)
    v_tpu = best_strategy(phase, tpu, seed=0)
    assert v_tpu.plans["standard"].phases[0].machine is tpu
    assert v_tpu.sim != v_bw.sim      # other parameter table -> other times
