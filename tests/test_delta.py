"""DeltaStack bit-identity + the incremental partition/search machinery.

The acceptance contract of the delta engine is the same as the stack's, one
level up: for ANY sequence of ``apply`` mutations, every ladder level and
every simulator output served from the delta caches must equal a freshly
built :class:`~repro.comm.PhaseStack` over the mutated phases — bit for bit,
including the edge cases a local search actually produces (empty deltas,
receivers drained to zero, receivers that never existed before).  The sparse
half pins that :func:`spmv_comm_pattern_delta` re-derives exactly the fresh
:func:`spmv_comm_pattern` message set, and that the optimizer's incremental
pricer never diverges from rebuild-per-candidate.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.comm import CommPhase, DeltaStack, PhaseStack
from repro.comm.delta import _MaxTree
from repro.core import (MODEL_LEVELS, model_ladder_many, phase_cost_many,
                        phase_cost_phase)
from repro.net import (blue_waters_machine, frontier_machine, lassen_machine,
                       tpu_v5e_machine, simulate, simulate_many)
from repro.sparse import (RowPartition, SpmvPatternState, optimize_partition,
                          poisson_3d, spmv_comm_pattern,
                          spmv_comm_pattern_delta)

BW = blue_waters_machine((2, 2, 2))
TPU = tpu_v5e_machine((4, 4))
# heterogeneous presets: the delta contract holds per rate table / rail count
LASSEN = lassen_machine((2, 2, 2))
FRONTIER = frontier_machine((2, 2, 1))
MACHINES = [BW, TPU, LASSEN, FRONTIER]


def _random_phase(machine, n, seed, n_procs=None):
    rng = np.random.default_rng(seed)
    P = n_procs or machine.n_procs
    if n == 0:
        return CommPhase.build(machine, [], [], [], n_procs=P)
    src = rng.integers(0, P, n)
    dst = (src + rng.integers(1, P, n)) % P
    size = rng.integers(8, 1 << 18, n).astype(float)
    return CommPhase.build(machine, src, dst, size, n_procs=P)


def _sweep(machine, seed=0):
    return [_random_phase(machine, n, seed + i)
            for i, n in enumerate((0, 1, 40, 300, 2))]


def _random_delta(delta, rng, max_rm=25, max_add=12):
    """A random mutation touching a random subset of phases."""
    total = delta.total_msgs
    n_rm = int(rng.integers(0, min(max_rm, total) + 1))
    rm = rng.choice(total, size=n_rm, replace=False) if n_rm else None
    add = {}
    for pi in range(delta.n_phases):
        if rng.random() < 0.5:
            continue
        k = int(rng.integers(0, max_add))
        if k == 0:
            continue
        P = delta.phases[pi].n_procs
        src = rng.integers(0, P, k)
        add[pi] = (src, (src + rng.integers(1, P, k)) % P,
                   rng.integers(8, 1 << 18, k).astype(float))
    return rm, add


def _assert_matches_fresh(delta):
    """The full contract: ladder + simulator vs a rebuilt-from-raw stack."""
    rebuilt = [CommPhase.build(ph.machine, ph.src, ph.dst, ph.size,
                               n_procs=ph.n_procs) for ph in delta.phases]
    stack = PhaseStack.build(rebuilt)
    for lvl in MODEL_LEVELS:
        assert phase_cost_many(delta, level=lvl) == \
            phase_cost_many(stack, level=lvl)
    got, want = simulate_many(delta), simulate_many(stack)
    for g, w in zip(got, want):
        assert g.time == w.time
        assert g.transport == w.transport
        assert g.queue == w.queue
        assert g.contention == w.contention
        assert g.max_link_bytes == w.max_link_bytes
        assert g.total_net_bytes == w.total_net_bytes
        assert np.array_equal(g.per_proc_transport, w.per_proc_transport)
        assert np.array_equal(g.per_proc_queue_steps, w.per_proc_queue_steps)


# ------------------------------------------------------ construction --------
def test_from_phases_accepts_phases_and_stack():
    phases = _sweep(BW)
    a = DeltaStack.from_phases(phases)
    b = DeltaStack.from_phases(PhaseStack.build(phases))
    assert a.n_phases == b.n_phases == len(phases)
    assert phase_cost_many(a) == phase_cost_many(b)


def test_from_phases_rejects_mixed_machines_and_unbound():
    with pytest.raises(ValueError, match="mixed machines"):
        DeltaStack.from_phases([_random_phase(BW, 10, 0),
                                _random_phase(TPU, 10, 0)])
    from repro.sparse import CommPattern
    cp = CommPattern(np.array([0]), np.array([1]), np.array([8.0]), 2)
    with pytest.raises(TypeError, match="bound CommPhase"):
        DeltaStack.from_phases([cp])


def test_generation_zero_matches_fresh():
    delta = DeltaStack.from_phases(_sweep(BW))
    _assert_matches_fresh(delta)
    delta.check()


def test_empty_stack():
    delta = DeltaStack.from_phases([])
    assert delta.n_phases == 0 and delta.total_msgs == 0
    assert phase_cost_many(delta) == []
    assert simulate_many(delta) == []
    d2 = delta.apply()
    assert d2.n_phases == 0


# ------------------------------------------------------ mutation ------------
def test_empty_delta_is_identity():
    delta = DeltaStack.from_phases(_sweep(BW, seed=3))
    for d2 in (delta.apply(), delta.apply([], {}), delta.apply(None, None)):
        assert phase_cost_many(d2) == phase_cost_many(delta)
        d2.check()


@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
def test_random_move_sequences_bit_identical(machine):
    delta = DeltaStack.from_phases(_sweep(machine, seed=11))
    rng = np.random.default_rng(5)
    for step in range(6):
        delta = delta.apply(*_random_delta(delta, rng))
        if step % 2:          # materialize the lazy routing path mid-chain
            simulate_many(delta)
        _assert_matches_fresh(delta)


def test_remove_all_from_one_receiver():
    ph = _random_phase(BW, 200, 17)
    delta = DeltaStack.from_phases([ph, _random_phase(BW, 50, 18)])
    receiver = int(np.bincount(ph.dst).argmax())
    rm = np.nonzero(ph.dst == receiver)[0]       # phase 0: arena idx == local
    assert rm.size > 0
    delta = delta.apply(rm)
    assert not (delta.phases[0].dst == receiver).any()
    _assert_matches_fresh(delta)


def test_remove_entire_phase_then_refill():
    delta = DeltaStack.from_phases(_sweep(BW, seed=23))
    off = delta.offsets
    rm = np.arange(off[3], off[4])                # drain phase 3 completely
    delta = delta.apply(rm)
    assert delta.phases[3].n_msgs == 0
    _assert_matches_fresh(delta)
    delta = delta.apply(None, {3: ([0, 1, 2], [9, 9, 9],
                                   [64.0, 4096.0, 1 << 16])})
    assert delta.phases[3].n_msgs == 3
    _assert_matches_fresh(delta)


def test_new_receiver_appears():
    """Messages to a process that received nothing before the delta."""
    P = BW.n_procs
    rng = np.random.default_rng(29)
    src = rng.integers(0, P // 2, 80)
    dst = rng.integers(0, P // 2, 80)             # upper half silent
    keep = src != dst
    ph = CommPhase.build(BW, src[keep], dst[keep],
                         rng.integers(8, 1 << 16, int(keep.sum()))
                         .astype(float), n_procs=P)
    delta = DeltaStack.from_phases([ph])
    newcomer = P - 1
    assert not (ph.dst == newcomer).any()
    delta = delta.apply(None, {0: ([0, 3], [newcomer, newcomer],
                                   [1 << 14, 1 << 10])})
    assert (delta.phases[0].dst == newcomer).sum() == 2
    _assert_matches_fresh(delta)


def test_verify_mode_checks_every_apply():
    delta = DeltaStack.from_phases(_sweep(BW, seed=31), verify=True)
    rng = np.random.default_rng(7)
    for _ in range(3):
        delta = delta.apply(*_random_delta(delta, rng))   # check() inside
    assert delta.verify


# ------------------------------------------------------ validation ----------
def test_apply_validates_inputs():
    delta = DeltaStack.from_phases(_sweep(BW, seed=37))
    with pytest.raises(ValueError, match="duplicate"):
        delta.apply([1, 1])
    with pytest.raises(ValueError, match="out of range"):
        delta.apply([delta.total_msgs])
    with pytest.raises(ValueError, match="out of range"):
        delta.apply([-1])
    with pytest.raises(ValueError, match="phase index"):
        delta.apply(None, {99: ([0], [1], [8.0])})
    # added-message validation now runs through the typed guard layer:
    # the errors are PatternError subclasses (still ValueErrors)
    from repro.comm.guard import MessageSizeError, PatternError, RankError
    P = delta.phases[2].n_procs
    with pytest.raises(RankError, match="out of range"):
        delta.apply(None, {2: ([0], [P], [8.0])})
    with pytest.raises(PatternError, match="lengths differ"):
        delta.apply(None, {2: ([0, 1], [2], [8.0])})
    with pytest.raises(MessageSizeError, match="not finite"):
        delta.apply(None, {2: ([0], [1], [np.nan])})


# ------------------------------------------------------ consumers -----------
def test_model_ladder_many_on_delta():
    delta = DeltaStack.from_phases(_sweep(BW, seed=41))
    delta = delta.apply(*_random_delta(delta, np.random.default_rng(2)))
    want = [{lvl: phase_cost_phase(ph, level=lvl) for lvl in MODEL_LEVELS}
            for ph in delta.phases]
    assert model_ladder_many(delta) == want


def test_single_phase_delta_matches_loop():
    """The optimizer case: a one-phase arena still rides the delta caches."""
    delta = DeltaStack.from_phases([_random_phase(BW, 300, 43)])
    delta = delta.apply([0, 5, 7], {0: ([1], [2], [4096.0])})
    assert phase_cost_many(delta) == [phase_cost_phase(delta.phases[0])]


def test_params_override_falls_back_correctly():
    delta = DeltaStack.from_phases(_sweep(BW, seed=47))
    delta = delta.apply(*_random_delta(delta, np.random.default_rng(3)))
    override = BW.params.replace(network_locality=1)
    got = phase_cost_many(delta, params=override)
    want = [phase_cost_phase(ph, params=override) for ph in delta.phases]
    assert got == want


def test_custom_orders_on_mutated_arena():
    delta = DeltaStack.from_phases(_sweep(BW, seed=53))
    delta = delta.apply(*_random_delta(delta, np.random.default_rng(4)))
    rng = np.random.default_rng(0)
    arrivals = [ph.random_arrival_order(rng) for ph in delta.phases]
    got = simulate_many(delta, arrival_orders=arrivals)
    want = [simulate(ph, arrival_order=ao)
            for ph, ao in zip(delta.phases, arrivals)]
    for g, w in zip(got, want):
        assert g.time == w.time
        assert np.array_equal(g.per_proc_queue_steps, w.per_proc_queue_steps)


def test_noise_stream_matches_loop():
    delta = DeltaStack.from_phases(
        [_random_phase(BW, n, 59 + n) for n in (50, 0, 80)])
    got = simulate_many(delta, rng=np.random.default_rng(5), noise=0.1)
    rng = np.random.default_rng(5)
    want = [simulate(ph, rng=rng, noise=0.1) for ph in delta.phases]
    assert [r.time for r in got] == [r.time for r in want]


def test_unknown_backend_raises_eagerly():
    delta = DeltaStack.from_phases(_sweep(BW, seed=61))
    with pytest.raises(ValueError, match="unknown stack backend"):
        delta.cost_arrays(backend="cuda")
    with pytest.raises(ValueError, match="unknown stack backend"):
        delta.sim_arrays(backend="tpu")


# ------------------------------------------------------ property test -------
@given(st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_property_random_mutation_chain(seed):
    rng = np.random.default_rng(seed)
    delta = DeltaStack.from_phases(
        [_random_phase(BW, int(rng.integers(0, 150)),
                       int(rng.integers(1 << 30))) for _ in range(3)])
    for _ in range(3):
        delta = delta.apply(*_random_delta(delta, rng))
    _assert_matches_fresh(delta)


# ------------------------------------------------------ _MaxTree ------------
def test_max_tree_point_and_batch_updates():
    rng = np.random.default_rng(67)
    values = rng.integers(0, 100, 37)
    tree = _MaxTree(values)
    assert tree.max() == values.max()
    for _ in range(50):
        i = int(rng.integers(0, values.size))
        values[i] = int(rng.integers(0, 100))
        tree.update(i, values[i])
        assert tree.max() == values.max()
    batch = rng.integers(0, values.size, 9)
    values[batch] = 0
    tree.update_many(np.unique(batch), values[np.unique(batch)])
    assert tree.max() == values.max()
    empty = _MaxTree(np.zeros(0, dtype=np.int64))
    assert empty.max() == 0


# ============================================== incremental SpMV pattern ====
def _canon(src, dst, size):
    order = np.lexsort((dst, src))
    return src[order], dst[order], size[order]


def test_spmv_state_build_matches_fresh_pattern():
    A = poisson_3d(8)
    part = RowPartition.balanced(A.n_rows, 16)
    state = SpmvPatternState.build(A, part)
    ref = spmv_comm_pattern(A, part)
    assert np.array_equal(state.src, ref.src)
    assert np.array_equal(state.dst, ref.dst)
    assert np.array_equal(state.size, ref.size)


def test_spmv_delta_matches_fresh_over_random_walk():
    A = poisson_3d(9)
    P = 24
    state = SpmvPatternState.build(A, RowPartition.balanced(A.n_rows, P))
    rng = np.random.default_rng(0)
    starts = state.starts.copy()
    walked = 0
    for _ in range(40):
        b = int(rng.integers(1, P))
        d = int(rng.choice((-5, 5)))
        ns = starts.copy()
        ns[b] += d
        if not starts[b - 1] < ns[b] < starts[b + 1]:
            continue
        rm, add, state2 = spmv_comm_pattern_delta(state, ns)
        fresh = spmv_comm_pattern(A, RowPartition(ns))
        got = _canon(state2.src, state2.dst, state2.size)
        want = _canon(fresh.src, fresh.dst, fresh.size)
        assert all(np.array_equal(g, w) for g, w in zip(got, want))
        # survivors really survive: removed indices name every message that
        # touches the two adjacent processes, nothing else
        cm = np.zeros(P, dtype=bool)
        cm[[b - 1, b]] = True
        assert np.array_equal(rm, np.nonzero(cm[state.src]
                                             | cm[state.dst])[0])
        if walked % 2 == 0:        # alternate accept/reject to walk the
            state, starts = state2, ns     # lazy-splice chain forward
        walked += 1
    assert walked > 10


def test_spmv_delta_feeds_delta_stack():
    """The (removed, added) delta drives DeltaStack.apply bit-identically."""
    A = poisson_3d(8)
    P = 16
    machine = BW
    state = SpmvPatternState.build(A, RowPartition.balanced(A.n_rows, P))
    delta = DeltaStack.from_phases([state.pattern.bind(machine)])
    rng = np.random.default_rng(1)
    starts = state.starts.copy()
    for _ in range(10):
        b = int(rng.integers(1, P))
        d = int(rng.choice((-4, 4)))
        ns = starts.copy()
        ns[b] += d
        if not starts[b - 1] < ns[b] < starts[b + 1]:
            continue
        rm, add, state = spmv_comm_pattern_delta(state, ns)
        delta = delta.apply(rm, {0: add})
        starts = ns
        _assert_matches_fresh(delta)
        # the delta arena mirrors the state's message order exactly
        assert np.array_equal(delta.phases[0].src, state.src)
        assert np.array_equal(delta.phases[0].dst, state.dst)
        assert np.array_equal(delta.phases[0].size, state.size)


def test_spmv_delta_validates_new_starts():
    A = poisson_3d(6)
    state = SpmvPatternState.build(A, RowPartition.balanced(A.n_rows, 8))
    with pytest.raises(ValueError, match="process count"):
        spmv_comm_pattern_delta(state, state.starts[:-1])
    bad = state.starts.copy()
    bad[-1] += 1
    with pytest.raises(ValueError, match="partition"):
        spmv_comm_pattern_delta(state, bad)
    bad = state.starts.copy()
    bad[1], bad[2] = bad[2] + 5, bad[1]
    with pytest.raises(ValueError, match="partition"):
        spmv_comm_pattern_delta(state, bad)


def test_spmv_delta_noop_returns_same_state():
    A = poisson_3d(6)
    state = SpmvPatternState.build(A, RowPartition.balanced(A.n_rows, 8))
    rm, add, state2 = spmv_comm_pattern_delta(state, state.starts)
    assert rm.size == 0 and add[0].size == 0
    assert state2 is state


# ============================================== the partition optimizer =====
def test_optimize_partition_improves_or_holds():
    A = poisson_3d(8)
    res = optimize_partition(A, BW, n_procs=16, moves=24, seed=0)
    assert res.cost <= res.initial_cost
    assert len(res.moves) == 24
    assert res.n_accepted == sum(m.accepted for m in res.moves)
    # the returned pattern really is the final partition's pattern
    fresh = spmv_comm_pattern(A, res.partition)
    got = _canon(res.pattern.src, res.pattern.dst, res.pattern.size)
    want = _canon(fresh.src, fresh.dst, fresh.size)
    assert all(np.array_equal(g, w) for g, w in zip(got, want))


def test_optimize_partition_delta_pricing_matches_rebuild():
    """Every candidate the delta pricer recorded re-prices to the same cost
    under full reconstruction.  Replaying the recorded candidate partitions
    (rather than racing two independent searches) pins both sides to
    identical candidates, so an ulp-level cost tie cannot fork the accept
    decisions and flake the comparison."""
    A = poisson_3d(8)
    res = optimize_partition(A, BW, n_procs=16, moves=24, seed=3)
    priced = 0
    for mv in res.moves:
        if np.isnan(mv.cost):
            continue
        phase = spmv_comm_pattern(A, RowPartition(mv.starts)).bind(BW)
        assert mv.cost == pytest.approx(phase_cost_phase(phase).total,
                                        rel=1e-9)
        priced += 1
    assert priced > 5


def test_optimize_partition_rebuild_pricer_smoke():
    """The reference pricer runs the same search loop end to end."""
    res = optimize_partition(poisson_3d(7), BW, n_procs=12, moves=12,
                             seed=0, pricer="rebuild")
    assert res.cost <= res.initial_cost
    assert len(res.moves) == 12


def test_optimize_partition_verify_mode():
    res = optimize_partition(poisson_3d(6), BW, n_procs=8, moves=8, seed=0,
                             verify=True)
    assert res.cost <= res.initial_cost


def test_optimize_partition_rerun_strategies():
    res = optimize_partition(poisson_3d(7), BW, n_procs=12, moves=12, seed=1,
                             rerun_strategies=True)
    assert len(res.verdicts) == res.n_accepted
    for it, verdict in res.verdicts:
        assert res.moves[it].accepted
        assert verdict.model_winner in verdict.model


def test_optimize_partition_validates():
    A = poisson_3d(6)
    with pytest.raises(ValueError, match="n_procs or an explicit part"):
        optimize_partition(A, BW)
    with pytest.raises(ValueError, match="unknown model level"):
        optimize_partition(A, BW, n_procs=8, level="psychic")
    with pytest.raises(ValueError, match="unknown pricer"):
        optimize_partition(A, BW, n_procs=8, pricer="magic")
