"""The typed validation layer + adversarial patterns + StrategyService.

Satellite coverage for ISSUE 8: :mod:`repro.comm.guard`'s ``PatternError``
hierarchy at the unit level, its wiring through ``CommPhase.build`` /
``CommPattern`` / the workload derivers, degenerate and adversarial
patterns across all four machine presets (typed rejection or bit-identical
numpy-fallback pricing), the :meth:`repro.comm.PhaseStack._dev`
int32-overflow degradation, and the never-fail
:class:`repro.serve.StrategyService` front end.
"""
import numpy as np
import pytest

from repro.comm.faults import inject
from repro.comm.guard import (INT32_MAX, ArenaOverflowError,
                              MessageSizeError, PatternError, RankError,
                              validate_messages, validate_phase)
from repro.comm.health import get_health
from repro.kernels import comm_stack as cs
from repro.net.machine import (blue_waters_machine, frontier_machine,
                               lassen_machine, tpu_v5e_machine)
from repro.sparse.partition import CommPattern

PRESETS = {
    "blue_waters": blue_waters_machine((2, 1, 1)),
    "tpu_v5e": tpu_v5e_machine((2, 2)),
    "lassen": lassen_machine((2, 2, 2)),
    "frontier": frontier_machine((2, 2, 2)),
}

requires_jax = pytest.mark.skipif(not cs.have_jax(), reason="needs jax")


# -- validate_messages units --------------------------------------------------

def test_error_hierarchy_is_valueerror():
    for cls in (PatternError, MessageSizeError, RankError,
                ArenaOverflowError):
        assert issubclass(cls, ValueError)
    for cls in (MessageSizeError, RankError, ArenaOverflowError):
        assert issubclass(cls, PatternError)


def test_empty_message_set_is_valid():
    validate_messages(np.array([], dtype=np.int64),
                      np.array([], dtype=np.int64),
                      np.array([], dtype=np.float64), n_procs=4)


def test_rejects_non_1d_and_mismatched_lengths():
    with pytest.raises(PatternError, match="one-dimensional"):
        validate_messages(np.zeros((2, 2)), np.zeros(4), np.zeros(4))
    with pytest.raises(PatternError, match="lengths differ"):
        validate_messages(np.zeros(3, dtype=int), np.zeros(4, dtype=int),
                          np.zeros(4))


def test_rejects_bad_ranks_with_offending_index():
    size = np.ones(3)
    with pytest.raises(RankError, match=r"src\[1\] = -2 is negative"):
        validate_messages(np.array([0, -2, 1]), np.array([1, 1, 1]), size,
                          n_procs=4)
    with pytest.raises(RankError, match=r"dst\[2\] = 4 is out of range"):
        validate_messages(np.array([0, 1, 1]), np.array([1, 1, 4]), size,
                          n_procs=4)
    with pytest.raises(RankError, match="not an integral rank"):
        validate_messages(np.array([0.0, 1.5]), np.array([1, 1]), np.ones(2),
                          n_procs=4)
    with pytest.raises(RankError, match="not an integral rank"):
        validate_messages(np.array([0.0, np.nan]), np.array([1, 1]),
                          np.ones(2), n_procs=4)
    with pytest.raises(RankError, match="n_procs must be >= 1"):
        validate_messages(np.array([0]), np.array([0]), np.ones(1),
                          n_procs=0)


def test_rejects_bad_sizes_with_offending_index():
    src = np.array([0, 1])
    dst = np.array([1, 0])
    with pytest.raises(MessageSizeError, match=r"size\[1\] = nan"):
        validate_messages(src, dst, np.array([1.0, np.nan]), n_procs=2)
    with pytest.raises(MessageSizeError, match="not finite"):
        validate_messages(src, dst, np.array([np.inf, 1.0]), n_procs=2)
    with pytest.raises(MessageSizeError, match="is negative"):
        validate_messages(src, dst, np.array([1.0, -8.0]), n_procs=2)


def test_int32_overflow_is_typed():
    big = INT32_MAX + 1
    with pytest.raises(ArenaOverflowError, match="int32 range"):
        validate_messages(np.array([big]), np.array([0]), np.ones(1),
                          n_procs=big + 1)
    # just inside the range is fine (no pricing here — validation only)
    validate_messages(np.array([INT32_MAX - 1]), np.array([0]), np.ones(1),
                      n_procs=INT32_MAX)


def test_where_labels_error_text():
    with pytest.raises(RankError, match="my-scenario/dispatch"):
        validate_messages(np.array([-1]), np.array([0]), np.ones(1),
                          where="my-scenario/dispatch")


def test_validate_phase_duck_types():
    pat = CommPattern(src=np.array([5]), dst=np.array([0]),
                      size=np.ones(1), n_procs=4)
    with pytest.raises(RankError, match="CommPattern: src"):
        validate_phase(pat)
    with pytest.raises(RankError, match="labelled: src"):
        validate_phase(pat, where="labelled")


# -- wiring: build / bind / derivers ------------------------------------------

def test_comm_phase_build_validates():
    from repro.comm.phase import CommPhase
    m = PRESETS["lassen"]
    with pytest.raises(MessageSizeError, match="CommPhase.build"):
        CommPhase.build(m, [0], [1], [np.nan], validate=True)
    # default stays permissive: NaN was silently cast before this PR and
    # callers opt in to the typed layer
    CommPhase.build(m, [0], [1], [8.0], validate=True)


def test_pattern_validate_chains_and_bind_threads():
    good = CommPattern(src=np.array([0]), dst=np.array([1]),
                       size=np.ones(1), n_procs=4)
    assert good.validate() is good
    bad = CommPattern(src=np.array([0]), dst=np.array([9]),
                      size=np.ones(1), n_procs=4)
    with pytest.raises(RankError):
        bad.validate()
    with pytest.raises(RankError):
        bad.bind(PRESETS["lassen"], validate=True)


def test_phase_cost_and_simulate_validate():
    from repro.core.models import phase_cost
    from repro.net.simulator import simulate_phase
    m = PRESETS["lassen"]
    loc = np.zeros(1, dtype=bool)
    with pytest.raises(MessageSizeError, match="phase_cost"):
        phase_cost(m.params, [0], [1], [-1.0], loc, validate=True)
    with pytest.raises(MessageSizeError):
        simulate_phase(m, [0], [1], [np.inf], validate=True)


def test_workload_derivers_validate_their_output():
    from repro.configs import get_config
    from repro.workloads.pipe import pipeline_p2p_pattern
    from repro.workloads.tp import tp_collective_patterns
    cfg = get_config("llama3.2-3b")
    with pytest.raises(MessageSizeError, match="pipeline_p2p_pattern"):
        pipeline_p2p_pattern(cfg, 4, 2, microbatch_tokens=-64)
    with pytest.raises(MessageSizeError, match="tp_collective_patterns"):
        tp_collective_patterns(cfg, 8, tokens=-2048)
    # clean derivations still validate quietly
    pipeline_p2p_pattern(cfg, 4, 2, microbatch_tokens=64)
    tp_collective_patterns(cfg, 8, tokens=2048)


def test_moe_deriver_validates_its_output():
    from repro.workloads.moe import pattern_from_counts
    counts = np.array([[0, 3], [2, 0]])
    out = pattern_from_counts(counts, d_model=16, capacity=4)
    validate_phase(out.dispatch)
    validate_phase(out.combine)


# -- satellite d: degenerate/adversarial patterns on every preset -------------

def _degenerates(P):
    e = np.array([], dtype=np.int64)
    return {
        "empty": (e, e, np.array([], dtype=np.float64)),
        "zero_size": ([0, 1], [1, 0], [0.0, 0.0]),
        "self_messages": ([0, 1, 2], [0, 1, 2], [8.0, 8.0, 8.0]),
        "max_rank": ([0, P - 1], [P - 1, 0], [64.0, 64.0]),
    }


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_degenerate_patterns_price_on_every_preset(preset):
    from repro.comm.strategies import best_strategy
    m = PRESETS[preset]
    for name, (src, dst, size) in _degenerates(m.n_procs).items():
        pat = CommPattern(src=np.asarray(src, dtype=np.int64),
                          dst=np.asarray(dst, dtype=np.int64),
                          size=np.asarray(size, dtype=np.float64),
                          n_procs=m.n_procs)
        pat.validate(where=name)                    # degenerate, not invalid
        v = best_strategy(pat, m, backend="numpy", validate=True)
        assert np.isfinite(v.model[v.model_winner]), (preset, name)
        assert not v.degraded


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_adversarial_patterns_rejected_typed_on_every_preset(preset):
    from repro.comm.strategies import best_strategy
    m = PRESETS[preset]
    P = m.n_procs
    adversarial = {
        "rank_past_end": ([0, P], [1, 0], [8.0, 8.0], RankError),
        "negative_rank": ([0, -1], [1, 0], [8.0, 8.0], RankError),
        "nan_size": ([0, 1], [1, 0], [8.0, np.nan], MessageSizeError),
        "negative_size": ([0, 1], [1, 0], [8.0, -8.0], MessageSizeError),
        "rank_past_int32": ([0, INT32_MAX + 1], [1, 0], [8.0, 8.0],
                            RankError),
    }
    for name, (src, dst, size, err) in adversarial.items():
        pat = CommPattern(src=np.asarray(src, dtype=np.int64),
                          dst=np.asarray(dst, dtype=np.int64),
                          size=np.asarray(size, dtype=np.float64),
                          n_procs=P)
        with pytest.raises(err):
            best_strategy(pat, m, backend="numpy", validate=True)


@requires_jax
@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_degenerate_patterns_fault_fallback_bit_identical(preset):
    """Under total device-site failure the degenerate patterns still price,
    bit-identical to the clean numpy reference, on every preset."""
    from repro.comm.strategies import best_strategy
    m = PRESETS[preset]
    for name, (src, dst, size) in _degenerates(m.n_procs).items():
        pat = CommPattern(src=np.asarray(src, dtype=np.int64),
                          dst=np.asarray(dst, dtype=np.int64),
                          size=np.asarray(size, dtype=np.float64),
                          n_procs=m.n_procs)
        clean = best_strategy(pat, m, backend="numpy")
        with inject("*", "raise"):
            chaos = best_strategy(pat, m, backend="jax")
        assert chaos.model == clean.model, (preset, name)
        assert chaos.sim == clean.sim, (preset, name)
        get_health().reset()                        # fresh quarantine state


# -- satellite a: int32-overflow arenas degrade, not crash --------------------

def test_dev_overflow_raises_typed_error():
    from repro.comm.phase import CommPhase
    from repro.comm.stack import as_stack
    if not cs.have_jax():
        pytest.skip("needs jax")
    m = PRESETS["lassen"]
    phases = [CommPhase.build(m, [0, 1], [1, 0], [8.0, 8.0]),
              CommPhase.build(m, [2, 3], [3, 2], [8.0, 8.0])]
    stack = as_stack(phases)
    object.__setattr__(stack, "huge_col",
                       np.array([2 ** 31, 0], dtype=np.int64))
    with pytest.raises(ArenaOverflowError, match="int32 range"):
        stack._dev("huge_col")
    object.__setattr__(stack, "ok_col",
                       np.array([2 ** 31 - 1, -2 ** 31], dtype=np.int64))
    assert stack._dev("ok_col").dtype == np.int32


@requires_jax
def test_overflow_routes_through_degradation_mid_sweep(monkeypatch):
    from repro.comm.phase import CommPhase
    from repro.comm.stack import as_stack
    m = PRESETS["lassen"]
    phases = [CommPhase.build(m, [0, 1], [1, 0], [8.0, 8.0]),
              CommPhase.build(m, [2, 3], [3, 2], [8.0, 8.0])]
    stack = as_stack(phases)

    def overflow(*a, **kw):
        raise ArenaOverflowError("arena column '_src_key' exceeds int32")

    monkeypatch.setattr(type(stack), "_device_cost_dense", overflow)
    t_np, q_np, b_np = stack.cost_arrays(backend="numpy")
    t, q, b = stack.cost_arrays(backend="jax")
    np.testing.assert_array_equal(t, t_np)
    np.testing.assert_array_equal(q, q_np)
    np.testing.assert_array_equal(b, b_np)
    events = get_health().events_for("jax", "stack.device_store")
    assert events and "ArenaOverflowError" in events[0].error


# -- the StrategyService front end --------------------------------------------

def _service_patterns(P):
    good = CommPattern(src=np.array([0, 1]), dst=np.array([1, 0]),
                       size=np.array([64.0, 64.0]), n_procs=P)
    bad = CommPattern(src=np.array([0, P]), dst=np.array([1, 0]),
                      size=np.array([64.0, 64.0]), n_procs=P)
    return good, bad


def test_service_imports_without_touching_jax():
    import os
    import subprocess
    import sys
    env = dict(os.environ, PYTHONPATH="src")
    code = ("import sys; from repro.serve import StrategyService, "
            "ServiceResult; assert 'jax' not in sys.modules, "
            "'StrategyService import pulled in jax'")
    subprocess.run([sys.executable, "-c", code], check=True,
                   cwd=os.path.dirname(os.path.dirname(__file__)))


def test_service_rejects_bad_patterns_individually():
    from repro.serve import StrategyService
    m = PRESETS["lassen"]
    good, bad = _service_patterns(m.n_procs)
    svc = StrategyService(m, backend="numpy")
    results = svc.query_many([good, bad, good])
    assert [r.ok for r in results] == [True, False, True]
    assert isinstance(results[1].error, RankError)
    assert "query[1]" in str(results[1].error)
    assert results[0].verdict.model_winner == results[2].verdict.model_winner
    single = svc.query(bad)
    assert not single.ok and isinstance(single.error, PatternError)


@requires_jax
def test_service_degrades_and_never_raises():
    from repro.serve import StrategyService
    m = PRESETS["lassen"]
    good, bad = _service_patterns(m.n_procs)
    svc = StrategyService(m, backend="jax")
    clean = StrategyService(m, backend="numpy").query(good)
    with inject("*", "raise"):
        res = svc.query_many([good, bad])
    assert res[0].ok and res[0].degraded
    assert res[0].verdict.model == clean.verdict.model
    assert not res[1].ok
    assert svc.health().n_events > 0


def test_service_worst_case_retry_on_sweep_failure(monkeypatch):
    from repro.comm import strategies
    from repro.serve import StrategyService
    m = PRESETS["lassen"]
    good, _ = _service_patterns(m.n_procs)
    real = strategies.best_strategy_many
    calls = []

    def flaky(patterns, machine=None, **kw):
        calls.append(kw.get("backend"))
        if kw.get("backend") != "numpy":
            raise RuntimeError("sweep exploded")
        return real(patterns, machine, **kw)

    monkeypatch.setattr(strategies, "best_strategy_many", flaky)
    svc = StrategyService(m, backend="jax")
    res = svc.query(good)
    assert res.ok and res.degraded
    assert res.verdict.model_winner in res.verdict.model
    assert calls == ["jax", "numpy"]
    events = get_health().events_for(site="serve.query_many")
    assert len(events) == 1


def test_service_returns_error_result_when_even_numpy_fails(monkeypatch):
    from repro.comm import strategies
    from repro.serve import StrategyService
    m = PRESETS["lassen"]
    good, _ = _service_patterns(m.n_procs)

    def always_fails(*a, **kw):
        raise RuntimeError("everything is broken")

    monkeypatch.setattr(strategies, "best_strategy_many", always_fails)
    svc = StrategyService(m)
    res = svc.query(good)                           # must not raise
    assert not res.ok and res.degraded
    assert isinstance(res.error, RuntimeError)
    assert len(get_health().events_for(site="serve.query_many")) == 2


def test_serve_engine_import_is_jax_free_and_error_is_clear():
    """`import repro.serve.engine` (and ServeEngine itself) must work on a
    host with no jax at all; only *constructing* the engine may demand it,
    with an actionable message.  Runs in a subprocess with jax blocked via
    a meta-path hook so the check is real even on this jax-equipped host."""
    import os
    import subprocess
    import sys
    code = (
        "import sys\n"
        "class _NoJax:\n"
        "    def find_spec(self, name, path=None, target=None):\n"
        "        if name == 'jax' or name.startswith('jax.'):\n"
        "            raise ImportError('jax is blocked in this test')\n"
        "        return None\n"
        "sys.meta_path.insert(0, _NoJax())\n"
        "from repro.serve import ServeEngine, Request\n"
        "assert 'jax' not in sys.modules\n"
        "try:\n"
        "    ServeEngine(cfg=None, params=None, max_seq=8)\n"
        "except RuntimeError as e:\n"
        "    assert 'jax' in str(e) and 'StrategyService' in str(e), e\n"
        "else:\n"
        "    raise SystemExit('ServeEngine built without jax?!')\n")
    env = dict(os.environ, PYTHONPATH="src")
    subprocess.run([sys.executable, "-c", code], check=True, env=env,
                   cwd=os.path.dirname(os.path.dirname(__file__)))


def test_serve_engine_submit_validates():
    pytest.importorskip("jax")
    from repro.serve.engine import Request, ServeEngine
    eng = object.__new__(ServeEngine)               # validation needs no jit
    eng.max_seq = 8
    eng.queue = __import__("collections").deque()
    with pytest.raises(ValueError, match="prompt must be non-empty"):
        eng.submit(Request(uid=0, prompt=[]))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(uid=1, prompt=[1], max_new_tokens=0))
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(uid=2, prompt=list(range(8))))
    eng.submit(Request(uid=3, prompt=[1, 2]))
    assert len(eng.queue) == 1
