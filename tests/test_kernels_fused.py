"""Parity and policy tests for the fused device kernels (PR 6 tentpole).

Three surfaces:

* ``fused_segment_reduce`` / ``segment_sum`` / ``segment_max`` — the tiled
  scatter-accumulate bincount kernel that replaced the one-hot matmul, on
  ragged / empty / single-message inputs, against the numpy reference.
* ``queue_walk`` — the device-resident Fenwick queue walk, bit-equal to
  :func:`repro.comm.primitives.batched_queue_traversal_steps` (the walk is
  integer-exact, so every backend must agree exactly).
* ``resolve_backend`` / ``autotune_crossover`` — the 'auto' policy: env
  override, disk cache round-trip, and the numpy-below / jax-above split.

Property tests ride the optional-hypothesis shim and skip cleanly when
hypothesis is absent; the deterministic parity tests always run.
"""
import json

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.comm import CommPhase, PhaseStack
from repro.comm.primitives import (batched_queue_traversal_steps,
                                   grouped_queue_steps)
from repro.kernels import comm_stack as cs
from repro.net import blue_waters_machine

needs_jax = pytest.mark.skipif(not cs.have_jax(), reason="jax not installed")

DEVICE_BACKENDS = ("jax", "pallas")


def _random_segments(rng, n, n_seg):
    # non-negative, like the byte counts / times the stacked reductions see
    # (segment_max documents 0.0 for empty segments under that contract)
    vals = np.abs(rng.standard_normal(n)) * 10.0
    ids = rng.integers(0, n_seg, n) if n else np.zeros(0, dtype=np.int64)
    return vals, ids


def _np_sum(vals, ids, n_seg):
    return np.bincount(ids, weights=vals, minlength=n_seg).astype(np.float64)


def _np_max(vals, ids, n_seg):
    out = np.zeros(n_seg, dtype=np.float64)
    if len(vals):
        np.maximum.at(out, ids, vals)
    return out


# ------------------------------------------------ fused scatter reduce ------
@needs_jax
@pytest.mark.parametrize("n,n_seg", [(0, 5), (1, 1), (7, 3), (513, 2),
                                     (2000, 300), (5000, 1)])
def test_fused_segment_reduce_matches_numpy(n, n_seg):
    rng = np.random.default_rng(n * 31 + n_seg)
    vals, ids = _random_segments(rng, n, n_seg)
    sums, maxs = cs.fused_segment_reduce(vals, ids, n_seg)
    np.testing.assert_allclose(sums, _np_sum(vals, ids, n_seg), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(maxs, _np_max(vals, ids, n_seg), rtol=1e-5,
                               atol=1e-5)


@needs_jax
@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_segment_ops_device_parity(backend):
    rng = np.random.default_rng(7)
    vals, ids = _random_segments(rng, 1234, 77)
    np.testing.assert_allclose(
        cs.segment_sum(vals, ids, 77, backend=backend),
        cs.segment_sum(vals, ids, 77, backend="numpy"), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        cs.segment_max(vals, ids, 77, backend=backend),
        cs.segment_max(vals, ids, 77, backend="numpy"), rtol=1e-5, atol=1e-5)


@needs_jax
def test_fused_reduce_empty_segment_gets_zero_not_neg_inf():
    vals = np.array([3.0])
    ids = np.array([2])
    sums, maxs = cs.fused_segment_reduce(vals, ids, 4)
    np.testing.assert_allclose(sums, [0.0, 0.0, 3.0, 0.0])
    np.testing.assert_allclose(maxs, [0.0, 0.0, 3.0, 0.0])


@needs_jax
@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=400),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_fused_reduce_parity(n, n_seg, seed):
    rng = np.random.default_rng(seed)
    vals, ids = _random_segments(rng, n, n_seg)
    sums, maxs = cs.fused_segment_reduce(vals, ids, n_seg)
    np.testing.assert_allclose(sums, _np_sum(vals, ids, n_seg), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(maxs, _np_max(vals, ids, n_seg), rtol=1e-4,
                               atol=1e-4)


# ------------------------------------------------ device queue walk ---------
def _random_regions(rng, n_regions, max_count):
    counts = rng.integers(0, max_count + 1, n_regions)
    bounds = np.concatenate([[0], np.cumsum(counts)])
    posted, arrival = [], []
    for c in counts:
        posted.append(rng.permutation(c))
        arrival.append(rng.permutation(c))
    cat = lambda xs: (np.concatenate(xs) if xs else
                      np.zeros(0, dtype=np.int64))
    return cat(posted), cat(arrival), bounds.astype(np.int64)


@needs_jax
@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
@pytest.mark.parametrize("n_regions,max_count", [(1, 1), (3, 0), (5, 9),
                                                 (40, 25), (2, 200)])
def test_queue_walk_bit_equal_to_numpy(backend, n_regions, max_count):
    rng = np.random.default_rng(n_regions * 1000 + max_count)
    posted, arrival, bounds = _random_regions(rng, n_regions, max_count)
    want = batched_queue_traversal_steps(posted, arrival, bounds)
    got = cs.queue_walk(posted, arrival, bounds, backend=backend)
    np.testing.assert_array_equal(got, want)   # integer walk: exact


@needs_jax
@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_queue_walk_handles_ragged_and_empty_regions(backend):
    # hand-built layout: empty region sandwiched between ragged ones
    posted = np.array([2, 0, 1,    0,    3, 1, 0, 2])
    arrival = np.array([1, 2, 0,   0,    2, 0, 3, 1])
    bounds = np.array([0, 3, 3, 4, 8])
    want = batched_queue_traversal_steps(posted, arrival, bounds)
    got = cs.queue_walk(posted, arrival, bounds, backend=backend)
    np.testing.assert_array_equal(got, want)


@needs_jax
@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=12),
       st.integers(min_value=0, max_value=40),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_queue_walk_parity(n_regions, max_count, seed):
    rng = np.random.default_rng(seed)
    posted, arrival, bounds = _random_regions(rng, n_regions, max_count)
    want = batched_queue_traversal_steps(posted, arrival, bounds)
    for backend in DEVICE_BACKENDS:
        got = cs.queue_walk(posted, arrival, bounds, backend=backend)
        np.testing.assert_array_equal(got, want)


@needs_jax
@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_grouped_queue_steps_backend_parity(backend):
    rng = np.random.default_rng(11)
    group = rng.integers(0, 9, 120)
    want = grouped_queue_steps(group, 9)                 # numpy reference
    got = grouped_queue_steps(group, 9, backend=backend)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------ auto policy ---------------
@pytest.fixture
def fresh_autotune(monkeypatch):
    """Reset the crossover memo and isolate env overrides per test."""
    monkeypatch.setattr(cs, "_crossover", None)
    monkeypatch.delenv("REPRO_STACK_AUTOTUNE", raising=False)
    monkeypatch.delenv("REPRO_STACK_AUTOTUNE_CACHE", raising=False)
    yield
    cs._crossover = None


def test_resolve_backend_auto_env_override(fresh_autotune, monkeypatch):
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE", "1000")
    assert cs.resolve_backend("auto", n_values=999) == "numpy"
    if cs.have_jax():
        assert cs.resolve_backend("auto", n_values=1000) == "jax"
        assert cs.resolve_backend(None, n_values=10 ** 9) == "jax"
    assert cs.resolve_backend(None) == "auto"        # no size: defer


def test_resolve_backend_auto_inf_always_numpy(fresh_autotune, monkeypatch):
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE", "inf")
    assert cs.resolve_backend("auto", n_values=1 << 40) == "numpy"


def test_autotune_disk_cache_round_trip(fresh_autotune, monkeypatch, tmp_path):
    if not cs.have_jax():
        pytest.skip("autotune probe needs jax")
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE_CACHE", str(cache))
    first = cs.autotune_crossover(refresh=True)
    assert cache.exists()
    payload = json.loads(cache.read_text())
    assert payload["tag"] == cs._probe_tag()
    # a fresh memo must come from the cache file, not a re-probe: poison the
    # stored value and check it is believed verbatim
    payload["crossover"] = 12345.0
    cache.write_text(json.dumps(payload))
    cs._crossover = None
    assert cs.autotune_crossover() == 12345.0
    assert first == first                      # probe result itself was finite-or-inf


def test_autotune_cache_ignored_on_tag_mismatch(fresh_autotune, monkeypatch,
                                                tmp_path):
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE", "2048")   # pin: no live probe
    cache = tmp_path / "autotune.json"
    cache.write_text(json.dumps({"tag": "someone-elses-machine",
                                 "crossover": 7.0}))
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE_CACHE", str(cache))
    assert cs.autotune_crossover() == 2048.0   # env wins over a stale cache


def test_backends_tuple_includes_auto():
    assert "auto" in cs.BACKENDS
    assert "auto" in PhaseStack.__init__.__module__ or True  # sanity import
    from repro.comm.stack import STACK_BACKENDS
    assert STACK_BACKENDS == cs.BACKENDS


# ------------------------------------------------ stack-level auto ----------
BW = blue_waters_machine((2, 2, 2))


def _bw_phases(n_phases=3, n=150, seed=0):
    rng = np.random.default_rng(seed)
    P = BW.n_procs
    out = []
    for i in range(n_phases):
        src = rng.integers(0, P, n)
        dst = (src + rng.integers(1, P, n)) % P
        size = rng.integers(1, 1 << 14, n).astype(np.float64)
        out.append(CommPhase.build(BW, src, dst, size))
    return out


def test_stack_auto_high_crossover_is_bit_identical_to_numpy(
        fresh_autotune, monkeypatch):
    """auto -> numpy below the crossover: byte-for-byte the numpy path."""
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE", "inf")
    stack = PhaseStack.build(_bw_phases())
    a = stack.cost_arrays(backend="auto")
    b = stack.cost_arrays(backend="numpy")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@needs_jax
def test_stack_auto_low_crossover_takes_device_path(fresh_autotune,
                                                    monkeypatch):
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE", "1")
    stack = PhaseStack.build(_bw_phases())
    a = stack.cost_arrays(backend="auto")
    b = stack.cost_arrays(backend="numpy")
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=2e-4, atol=1e-12)


# ------------------------------------------------ streaming build -----------
@pytest.mark.parametrize("chunk", [1, 2, 3, 7, 100, 1 << 16])
def test_build_streaming_bit_identical(chunk):
    phases = _bw_phases(n_phases=4, n=37, seed=5)
    mono = PhaseStack.build(phases)
    stream = PhaseStack.build_streaming(iter(phases), chunk_msgs=chunk)
    from repro.comm.stack import _ARENA_FIELDS
    for f in _ARENA_FIELDS:
        a, b = getattr(mono, f), getattr(stream, f)
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
    for x, y in zip(mono.cost_arrays(), stream.cost_arrays()):
        np.testing.assert_array_equal(x, y)


@needs_jax
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=50),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_build_streaming_every_chunk_size(chunk, seed):
    phases = _bw_phases(n_phases=3, n=29, seed=seed)
    mono = PhaseStack.build(phases)
    stream = PhaseStack.build_streaming(iter(phases), chunk_msgs=chunk)
    np.testing.assert_array_equal(mono.phase_id, stream.phase_id)
    np.testing.assert_array_equal(mono.src, stream.src)
    np.testing.assert_array_equal(mono.size, stream.size)


def test_build_streaming_rejects_bad_chunk_and_empty_ok():
    with pytest.raises(ValueError, match="chunk_msgs"):
        PhaseStack.build_streaming([], chunk_msgs=0)
    # an empty iterable mirrors build([]): a valid zero-message stack
    empty = PhaseStack.build_streaming([])
    assert empty.total_msgs == 0


def test_deprecated_one_hot_shim_still_importable():
    from repro.comm.health import reset_health
    assert cs.PALLAS_ONE_HOT_LIMIT == 1 << 24
    reset_health()                       # clear the warn-once registry
    with pytest.warns(DeprecationWarning, match="fused scatter-accumulate"):
        assert cs.pallas_within_limit(1 << 30, 1 << 20) is True
