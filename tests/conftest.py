"""Shared fixtures: isolate the per-process robustness state between tests.

The comm stack keeps process-wide mutable state for its degradation
machinery — the :class:`repro.comm.health.BackendHealth` ledger (failure
events, quarantines, and the warn-once registry that replaced the old
module-level ``_warned_*`` globals).  Without isolation a test that
triggers a fallback warning or quarantines a backend silently changes
the behaviour of every test after it; the autouse fixture below resets
the registry around each test so warn-once / quarantine assertions are
order-independent.
"""
import pytest

from repro.comm import faults
from repro.comm.health import reset_health


@pytest.fixture(autouse=True)
def _fresh_backend_health():
    """Reset the process-wide BackendHealth ledger around every test.

    The fault-injection env cache is cleared too: parsed ``FaultSpec``
    objects carry fire counts, so two tests using the same
    ``REPRO_FAULT_INJECT`` string must not share the parsed plan.
    """
    reset_health()
    faults._env_cache.clear()
    yield
    reset_health()
    faults._env_cache.clear()
