"""Certification of the derived LLM workload patterns (the ISSUE-7 harness).

Four layers of trust, weakest to strongest:

* **Flow conservation** (property tests): the MoE combine exchange returns
  exactly the bytes dispatch sent per (src, dst) pair, TP ring volumes
  match the analytic ``2 * (M - 1) / M * bytes`` all-reduce formula, and
  pipeline totals are ``microbatches x boundaries x activation bytes``.
* **RNG contract**: the same seed gives bit-identical histograms and
  patterns across calls (pinned in the module docstrings).
* **Cross-check**: the pattern from the real seeded router forward pass
  (:func:`repro.workloads.router_routing_counts` — the numpy twin of the
  :mod:`repro.nn.moe` router math) equals the histogram lowering of its own
  counts, and obeys the same conservation law as the synthetic generator.
* **jax parity** (skipped where jax is absent): the numpy top-K routing
  reproduces ``jax.lax.top_k`` decisions on identical logits, and the
  numpy-only row-parallel op count matches the count read off the real
  ``param_pspecs`` sharding tree on a fake 8-device mesh.

Property tests ride the optional-hypothesis shim; every deterministic test
is numpy-only and runs without jax.
"""
import subprocess
import sys

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config, get_smoke_config
from repro.workloads import (a2a_capacity, moe_a2a_pattern,
                             pattern_from_counts, pipeline_p2p_pattern,
                             router_routing_counts, row_parallel_ops_per_layer,
                             synthetic_routing_counts, tp_collective_patterns)

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def _pair_bytes(pattern):
    """(src, dst) -> total bytes, as a dict."""
    out = {}
    for s, d, z in zip(pattern.src, pattern.dst, pattern.size):
        out[(int(s), int(d))] = out.get((int(s), int(d)), 0.0) + float(z)
    return out


# ------------------------------------------------- MoE flow conservation ----
@settings(max_examples=25, deadline=None)
@given(n_ranks=st.sampled_from([2, 4, 8]),
       tokens=st.integers(min_value=1, max_value=64),
       experts_per_rank=st.integers(min_value=1, max_value=4),
       top_k=st.integers(min_value=1, max_value=3),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_moe_flow_conservation(n_ranks, tokens, experts_per_rank, top_k, seed):
    E = n_ranks * experts_per_rank
    top_k = min(top_k, E)
    counts = synthetic_routing_counts(n_ranks, tokens, E, top_k, seed=seed)
    assert counts.shape == (n_ranks, E)
    assert counts.sum() == n_ranks * tokens * top_k
    pat = pattern_from_counts(counts, d_model=32, capacity=tokens)
    # combine returns exactly what dispatch sent, per pair, reversed
    disp, comb = _pair_bytes(pat.dispatch), _pair_bytes(pat.combine)
    assert comb == {(d, s): z for (s, d), z in disp.items()}
    assert pat.dispatch.total_bytes == pat.combine.total_bytes
    # no self-messages; clip bounded by both counts and capacity
    assert np.all(pat.dispatch.src != pat.dispatch.dst)
    assert np.all(pat.sent <= pat.counts)
    assert np.all(pat.sent <= pat.capacity)
    assert pat.dropped_tokens == (pat.counts - pat.sent).sum() >= 0
    # every wire byte is a clipped routed token that left its origin rank
    owner = np.repeat(np.arange(n_ranks), E // n_ranks)
    offrank = sum(int(pat.sent[r, e]) for r in range(n_ranks)
                  for e in range(E) if owner[e] != r)
    assert pat.dispatch.total_bytes == offrank * pat.token_bytes


# ------------------------------------------------------ TP ring volumes ----
@settings(max_examples=25, deadline=None)
@given(tp=st.sampled_from([2, 4, 8, 16]),
       tokens=st.integers(min_value=1, max_value=512),
       n_groups=st.sampled_from([1, 2]))
def test_tp_ring_matches_allreduce_formula(tp, tokens, n_groups):
    cfg = get_smoke_config("llama3.2-3b")     # wo: 64, w2: 128 — both divide
    tc = tp_collective_patterns(cfg, tp, tokens, n_groups=n_groups)
    payload = tokens * cfg.d_model * 2.0
    assert tc.payload_bytes == payload
    assert tc.n_ops == row_parallel_ops_per_layer(cfg, tp) == 2
    for _, phase in tc.phases():
        assert phase.n_procs == n_groups * tp
        sent = np.bincount(phase.src, weights=phase.size,
                           minlength=phase.n_procs)
        # each phase is half the all-reduce: (M-1)/M x payload per rank
        assert np.allclose(sent, tc.n_ops * (tp - 1) / tp * payload)
        # ring: every message goes to the in-group successor
        group = phase.src // tp
        assert np.array_equal(phase.dst,
                              group * tp + (phase.src % tp + 1) % tp)
    assert 2 * sent.sum() == pytest.approx(n_groups * tp * tc.per_rank_bytes)


def test_tp_rejects_degenerate():
    cfg = get_smoke_config("llama3.2-3b")
    with pytest.raises(ValueError):
        tp_collective_patterns(cfg, 1, 16)
    with pytest.raises(ValueError):              # 64 and 128 both indivisible
        tp_collective_patterns(cfg, 7, 16)


# ------------------------------------------------------- pipeline totals ----
@settings(max_examples=25, deadline=None)
@given(n_stages=st.integers(min_value=2, max_value=8),
       n_microbatches=st.integers(min_value=1, max_value=16),
       mb_tokens=st.integers(min_value=1, max_value=256))
def test_pipeline_totals(n_stages, n_microbatches, mb_tokens):
    cfg = get_smoke_config("llama3.2-3b")
    pat = pipeline_p2p_pattern(cfg, n_stages, n_microbatches, mb_tokens)
    mb_bytes = mb_tokens * cfg.d_model * 2
    assert pat.n_msgs == (n_stages - 1) * n_microbatches
    assert pat.total_bytes == (n_stages - 1) * n_microbatches * mb_bytes
    # every message crosses exactly one interior boundary, forward
    assert np.array_equal(np.unique(pat.src), np.arange(n_stages - 1))
    assert np.array_equal(pat.dst, pat.src + 1)


def test_pipeline_rank_blocks():
    cfg = get_smoke_config("llama3.2-3b")
    pat = pipeline_p2p_pattern(cfg, 4, 2, 16, n_procs=64)
    assert pat.n_procs == 64
    assert np.array_equal(np.unique(pat.src), [0, 16, 32])
    assert np.array_equal(np.unique(pat.dst), [16, 32, 48])
    with pytest.raises(ValueError):
        pipeline_p2p_pattern(cfg, 3, 2, 16, n_procs=64)   # 3 !| 64


# ----------------------------------------------------------- RNG contract ----
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_same_seed_bit_identical(seed):
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    for source in ("synthetic", "router"):
        a = moe_a2a_pattern(cfg, 4, 16, seed=seed, source=source)
        b = moe_a2a_pattern(cfg, 4, 16, seed=seed, source=source)
        assert np.array_equal(a.counts, b.counts)
        for pa, pb in ((a.dispatch, b.dispatch), (a.combine, b.combine)):
            assert np.array_equal(pa.src, pb.src)
            assert np.array_equal(pa.dst, pb.dst)
            assert np.array_equal(pa.size, pb.size)


def test_seed_actually_matters():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    a = moe_a2a_pattern(cfg, 4, 64, seed=0)
    b = moe_a2a_pattern(cfg, 4, 64, seed=1)
    assert not np.array_equal(a.counts, b.counts)


# ------------------------------------------- router / histogram cross-check ----
def test_router_pattern_matches_histogram_lowering():
    """The pattern from the real (numpy) router forward pass is exactly the
    histogram lowering of that forward pass's own routing counts — the
    generator adds nothing the counts don't determine."""
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    via_router = moe_a2a_pattern(cfg, 4, 32, seed=7, source="router")
    counts = router_routing_counts(cfg, 4, 32, seed=7)
    via_counts = pattern_from_counts(counts, cfg.d_model,
                                     a2a_capacity(32, cfg))
    assert np.array_equal(via_router.counts, via_counts.counts)
    for pa, pb in ((via_router.dispatch, via_counts.dispatch),
                   (via_router.combine, via_counts.combine)):
        assert np.array_equal(pa.src, pb.src)
        assert np.array_equal(pa.dst, pb.dst)
        assert np.array_equal(pa.size, pb.size)
    # and the router-derived pattern obeys the same conservation law
    disp = _pair_bytes(via_router.dispatch)
    assert _pair_bytes(via_router.combine) == \
        {(d, s): z for (s, d), z in disp.items()}
    # a real top-K router routes every token K times (before clipping)
    assert via_router.counts.sum() == 4 * 32 * cfg.n_experts_active


def test_capacity_formula_pinned_to_ep_a2a():
    # the exact inline expression of repro.parallel.ep_a2a.moe_ffn_ep
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    for T in (1, 16, 256, 4096):
        expected = max(8, int(T * cfg.n_experts_active * cfg.capacity_factor
                              // cfg.n_experts) + 1)
        assert a2a_capacity(T, cfg) == expected


# --------------------------------------------------------------- jax parity ----
@needs_jax
def test_numpy_topk_matches_jax_topk():
    """router_routing_counts' stable argsort reproduces jax.lax.top_k expert
    choices (including lowest-index tie-breaking) on the identical logits."""
    import jax
    import jax.numpy as jnp

    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    n_ranks, T, seed = 4, 32, 3
    counts = router_routing_counts(cfg, n_ranks, T, seed=seed)
    # rebuild the exact same logits the numpy path drew
    rng = np.random.default_rng(seed)
    d, E, K = cfg.d_model, cfg.n_experts, cfg.n_experts_active
    x = rng.standard_normal((n_ranks * T, d)).astype(np.float32)
    router = (rng.standard_normal((d, E)) / np.sqrt(d)).astype(np.float32)
    logits = jnp.asarray(x) @ jnp.asarray(router)
    probs = jax.nn.softmax(logits, axis=-1)       # the moe_ffn routing path
    _, idx = jax.lax.top_k(probs, K)
    rank_of_token = np.repeat(np.arange(n_ranks), T)
    flat = rank_of_token[:, None] * E + np.asarray(idx)
    jax_counts = np.bincount(flat.ravel(),
                             minlength=n_ranks * E).reshape(n_ranks, E)
    assert np.array_equal(counts, jax_counts)


_PSPEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import make_mesh_plan
from repro.workloads import row_parallel_ops_from_pspecs, \
    row_parallel_ops_per_layer

plan = make_mesh_plan(make_mesh((1, 8), ("data", "model")))
for arch in ("llama3.2-3b", "qwen3-moe-30b-a3b", "deepseek-moe-16b",
             "mamba2-130m", "hymba-1.5b"):
    cfg = get_smoke_config(arch)
    analytic = row_parallel_ops_per_layer(cfg, 8)
    actual = row_parallel_ops_from_pspecs(cfg, plan)
    assert analytic == actual, (arch, analytic, actual)
    print(arch, actual)
"""


@needs_jax
def test_row_parallel_ops_match_real_pspecs():
    """The numpy-only op count equals the count read off the real
    param_pspecs tree, per arch, on a fake 8-device mesh (subprocess, so the
    main process keeps its single-device view)."""
    proc = subprocess.run([sys.executable, "-c", _PSPEC_SCRIPT],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    got = dict(line.split() for line in proc.stdout.strip().splitlines())
    # attention wo everywhere (but mamba), +w2/shared_w2/out_proj per family
    assert got == {"llama3.2-3b": "2", "qwen3-moe-30b-a3b": "1",
                   "deepseek-moe-16b": "2", "mamba2-130m": "1",
                   "hymba-1.5b": "3"}


# ------------------------------------------------- full-size registry shapes ----
def test_registry_scenarios_derive():
    """Every shipped scenario derives: full-size configs, 64 ranks."""
    from repro.workloads import DEFAULT_SCENARIOS, scenario_patterns
    for sc in DEFAULT_SCENARIOS:
        for label, pat in scenario_patterns(sc):
            assert pat.n_procs == sc.n_ranks
            assert pat.n_msgs > 0
            assert np.all(pat.src != pat.dst)
            assert np.all(pat.size > 0)


def test_moe_full_size_conservation():
    cfg = get_config("qwen3-moe-30b-a3b")
    pat = moe_a2a_pattern(cfg, 64, 256, seed=0)
    disp = _pair_bytes(pat.dispatch)
    assert _pair_bytes(pat.combine) == \
        {(d, s): z for (s, d), z in disp.items()}
    assert pat.capacity == a2a_capacity(256, cfg)
