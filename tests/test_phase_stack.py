"""Stack-vs-loop equivalence for the PhaseStack sweep engine.

The acceptance contract of the stacked fast path is *bit-identity*: for any
sweep of phases bound to one machine, the segmented passes must reproduce
the per-phase ``phase_cost_phase`` / ``simulate`` results exactly (numpy
backend), including empty phases, single-message phases and custom receive
orders.  The optional JAX/Pallas backends are held to allclose parity
(they run float32).
"""
import warnings

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.comm import (CommPhase, PhaseStack, STRATEGIES, best_strategy,
                        grouped_queue_steps, rewrite)
from repro.core import (MODEL_LEVELS, model_ladder_many, phase_cost_many,
                        phase_cost_phase, sequence_cost)
from repro.net import (blue_waters_machine, frontier_machine, lassen_machine,
                       tpu_v5e_machine, simulate, simulate_many,
                       simulate_sequence)
from repro.sparse import (RowPartition, build_hierarchy, elasticity_like_3d,
                          spmv_comm_pattern, stack_patterns)

BW = blue_waters_machine((2, 2, 2))
TPU = tpu_v5e_machine((4, 4))
# the heterogeneous presets ride every bit-identity contract too
LASSEN = lassen_machine((2, 2, 2))
FRONTIER = frontier_machine((2, 2, 1))
MACHINES = [BW, TPU, LASSEN, FRONTIER]


def _random_phase(machine, n, seed, n_procs=None):
    rng = np.random.default_rng(seed)
    P = n_procs or machine.n_procs
    if n == 0:
        return CommPhase.build(machine, [], [], [], n_procs=P)
    src = rng.integers(0, P, n)
    dst = (src + rng.integers(1, P, n)) % P
    size = rng.integers(8, 1 << 18, n).astype(float)
    return CommPhase.build(machine, src, dst, size, n_procs=P)


def _sweep(machine, seed=0):
    """A ragged sweep: empty, single-message, small and message-heavy phases."""
    return [_random_phase(machine, n, seed + i)
            for i, n in enumerate((0, 1, 40, 300, 800, 2))]


def _assert_results_equal(got, want):
    for g, w in zip(got, want):
        assert g.time == w.time
        assert g.transport == w.transport
        assert g.queue == w.queue
        assert g.contention == w.contention
        assert g.max_link_bytes == w.max_link_bytes
        assert g.total_net_bytes == w.total_net_bytes
        assert np.array_equal(g.per_proc_transport, w.per_proc_transport)
        assert np.array_equal(g.per_proc_queue_steps, w.per_proc_queue_steps)


# ------------------------------------------------------ construction --------
def test_build_concatenates_cached_arrays():
    phases = _sweep(BW)
    stack = PhaseStack.build(phases)
    assert stack.n_phases == len(phases)
    assert stack.total_msgs == sum(ph.n_msgs for ph in phases)
    for i, ph in enumerate(phases):
        s = slice(stack.offsets[i], stack.offsets[i + 1])
        assert np.array_equal(stack.src[s], ph.src)
        assert np.array_equal(stack.loc[s], ph.loc)
        assert np.array_equal(stack.active_ppn[s], ph.active_ppn)
        assert (stack.phase_id[s] == i).all()
        assert stack.n_procs[i] == ph.n_procs


def test_build_rejects_mixed_machines():
    with pytest.raises(ValueError, match="mixed machines"):
        PhaseStack.build([_random_phase(BW, 10, 0), _random_phase(TPU, 10, 0)])


def test_build_rejects_unbound_patterns():
    from repro.sparse import CommPattern
    cp = CommPattern(np.array([0]), np.array([1]), np.array([8.0]), 2)
    with pytest.raises(TypeError, match="bound CommPhase"):
        PhaseStack.build([cp, cp])


def test_empty_stack():
    stack = PhaseStack.build([])
    assert stack.n_phases == 0 and stack.total_msgs == 0
    t, q, b = stack.cost_arrays()
    assert t.size == q.size == b.size == 0
    assert phase_cost_many(stack) == []
    assert simulate_many(stack) == []


# ------------------------------------------------- model-side identity ------
@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
@pytest.mark.parametrize("level", MODEL_LEVELS)
def test_phase_cost_many_bit_identical(machine, level):
    phases = _sweep(machine)
    got = phase_cost_many(phases, level=level)
    want = [phase_cost_phase(ph, level=level) for ph in phases]
    assert got == want              # CostBreakdown is a frozen dataclass: ==


def test_phase_cost_many_accepts_a_stack():
    phases = _sweep(BW)
    stack = PhaseStack.build(phases)
    assert phase_cost_many(stack) == phase_cost_many(phases)
    assert model_ladder_many(stack) == model_ladder_many(phases)


def test_model_ladder_many_bit_identical():
    phases = _sweep(BW, seed=3)
    got = model_ladder_many(phases)
    want = [{lvl: phase_cost_phase(ph, level=lvl) for lvl in MODEL_LEVELS}
            for ph in phases]
    assert got == want


def test_params_override_reclassifies_localities():
    """An override table with a different network locality must recompute the
    active-sender counts per phase, exactly like phase_cost_phase does."""
    phases = _sweep(BW, seed=5)
    override = BW.params.replace(network_locality=1)
    for level in ("maxrate", "contention"):
        got = phase_cost_many(phases, level=level, params=override)
        want = [phase_cost_phase(ph, level=level, params=override)
                for ph in phases]
        assert got == want


def test_mixed_machine_sweep_falls_back_to_loop():
    phases = [_random_phase(BW, 30, 0), _random_phase(TPU, 30, 0)]
    got = phase_cost_many(phases)
    want = [phase_cost_phase(ph) for ph in phases]
    assert got == want


def test_unknown_level_raises():
    with pytest.raises(ValueError, match="unknown model level"):
        phase_cost_many(_sweep(BW), level="psychic")


# --------------------------------------------------- sim-side identity ------
@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
def test_simulate_many_bit_identical_default_orders(machine):
    phases = _sweep(machine, seed=7)
    _assert_results_equal(simulate_many(phases),
                          [simulate(ph) for ph in phases])


@pytest.mark.parametrize("machine", MACHINES, ids=lambda m: m.name)
def test_simulate_many_bit_identical_custom_orders(machine):
    phases = _sweep(machine, seed=9)
    rng = np.random.default_rng(0)
    arrivals = [ph.random_arrival_order(rng) for ph in phases]
    posts = []
    for ph in phases:                    # reversed posting, every 2nd receiver
        posts.append({int(p): np.nonzero(ph.dst == p)[0][::-1]
                      for p in np.unique(ph.dst)[::2]})
    got = simulate_many(phases, recv_post_orders=posts,
                        arrival_orders=arrivals)
    want = [simulate(ph, recv_post_order=po, arrival_order=ao)
            for ph, po, ao in zip(phases, posts, arrivals)]
    _assert_results_equal(got, want)


def test_simulate_many_noise_stream_matches_loop():
    """The stacked path must consume the shared rng exactly like the loop —
    including skipping the draw for empty phases, which the per-phase early
    return never reaches."""
    phases = [_random_phase(BW, n, 11 + n) for n in (50, 0, 80, 120)]
    got = simulate_many(phases, rng=np.random.default_rng(5), noise=0.1)
    rng = np.random.default_rng(5)
    want = [simulate(ph, rng=rng, noise=0.1) for ph in phases]
    assert [r.time for r in got] == [r.time for r in want]


def test_simulate_requires_rng_for_noise():
    ph = _random_phase(BW, 10, 0)
    with pytest.raises(ValueError, match="noise > 0 needs an explicit rng"):
        simulate(ph, noise=0.1)


def test_simulate_many_default_seed_documented():
    """noise without an rng seeds default_rng(0) once for the whole sweep."""
    phases = [_random_phase(BW, 50, 21), _random_phase(BW, 60, 22)]
    a = simulate_many(phases, noise=0.05)
    b = simulate_many(phases, rng=np.random.default_rng(0), noise=0.05)
    assert [r.time for r in a] == [r.time for r in b]


def test_stacked_queue_rejects_foreign_and_duplicate_ids():
    phases = [_random_phase(BW, 20, 1), _random_phase(BW, 60, 2)]
    receivers = np.unique(phases[1].dst)
    p, q = int(receivers[0]), int(receivers[1])    # both have messages
    ids_p = np.nonzero(phases[1].dst == p)[0]
    ids_q = np.nonzero(phases[1].dst == q)[0]
    # p's messages offered as q's order: wrong receiver (or wrong length)
    with pytest.raises(ValueError, match="permutation"):
        simulate_many(phases, arrival_orders=[None, {q: ids_p, p: ids_p}])
    if ids_q.size >= 2:
        dup = ids_q.copy()
        dup[0] = dup[1]
        with pytest.raises(ValueError, match="permutation"):
            simulate_many(phases, arrival_orders=[None, {q: dup}])


def test_grouped_queue_steps_matches_phase_queue_steps():
    """The shared grouped primitive == CommPhase.queue_steps, slot for slot."""
    ph = _random_phase(BW, 200, 13)
    ao = ph.random_arrival_order(np.random.default_rng(1))
    got = grouped_queue_steps(ph.dst, ph.n_procs, arrival_order=ao)
    assert np.array_equal(got, ph.queue_steps(arrival_order=ao))


def test_flat_and_dict_orders_agree():
    """random_arrival_flat and random_arrival_order share the rng stream and
    the flat (slots, lens, ids) form prices identically to the dict form."""
    ph = _random_phase(BW, 250, 15)
    flat = ph.random_arrival_flat(np.random.default_rng(2))
    dct = ph.random_arrival_order(np.random.default_rng(2))
    slots, lens, ids = flat
    assert np.array_equal(np.sort(slots), np.asarray(sorted(dct)))
    assert np.array_equal(
        ph.queue_steps(arrival_order=flat),
        ph.queue_steps(arrival_order=dct))
    _assert_results_equal(
        [simulate(ph, arrival_order=flat)],
        [simulate(ph, arrival_order=dct)])


# ------------------------------------------------- strategy sweep -----------
def test_best_strategy_many_mixed_machines_falls_back():
    """A candidate set spanning machines can't share one arena — it must
    fall back to the loop, element-wise identical to per-pattern calls."""
    from repro.comm import best_strategy_many
    phases = [_random_phase(BW, 120, 41), _random_phase(TPU, 120, 42)]
    got = best_strategy_many(phases, seed=0)
    want = [best_strategy(ph, seed=0) for ph in phases]
    assert [v.model for v in got] == [v.model for v in want]
    assert [v.sim for v in got] == [v.sim for v in want]


def test_best_strategy_matches_per_phase_loop():
    """One stacked sweep over all strategies == the per-strategy loop."""
    phase = _random_phase(BW, 400, 17)
    v = best_strategy(phase, seed=0)
    model, sim = {}, {}
    for name in STRATEGIES:
        plan = rewrite(phase, name)
        rng = np.random.default_rng(0)
        arrs = [p.random_arrival_order(rng) for p in plan.phases]
        model[name] = sum(phase_cost_phase(p).total for p in plan.phases)
        sim[name] = sum(simulate(p, arrival_order=a).time
                        for p, a in zip(plan.phases, arrs))
    assert v.model == model
    assert v.sim == sim


def test_sequence_cost_rides_the_stack():
    plan = rewrite(_random_phase(BW, 300, 19), "three_step")
    seq = sequence_cost(plan.phases)
    want = [phase_cost_phase(p) for p in plan.phases]
    assert seq.total == sum(p.total for p in want)
    sim = simulate_sequence(plan.phases)
    assert sim.time == sum(simulate(p).time for p in plan.phases)


# ------------------------------------------------- sparse sweep entry -------
def test_stack_patterns_amg_hierarchy():
    A = elasticity_like_3d(8)
    levels = build_hierarchy(A)
    pats = []
    for lvl in levels:
        part = RowPartition.balanced(lvl.A.n_rows, max(lvl.A.n_rows // 2, 2))
        cp = spmv_comm_pattern(lvl.A, part)
        if cp.n_msgs:
            pats.append(cp)
    stack = stack_patterns(pats, BW)
    assert stack.n_phases == len(pats)
    got = phase_cost_many(stack)
    want = [phase_cost_phase(cp.bind(BW)) for cp in pats]
    assert got == want


# ------------------------------------------------- backend parity -----------
from repro.kernels.comm_stack import have_jax  # numpy-safe import

needs_jax = pytest.mark.skipif(not have_jax(), reason="jax not installed")


@needs_jax
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_backend_parity_cost_arrays(backend):
    stack = PhaseStack.build(_sweep(BW, seed=23))
    t0, q0, b0 = stack.cost_arrays()
    t1, q1, b1 = stack.cost_arrays(backend=backend)
    np.testing.assert_allclose(t1, t0, rtol=1e-4)
    np.testing.assert_array_equal(q1, q0)     # counts stay numpy-exact
    np.testing.assert_array_equal(b1, b0)     # net bytes stay numpy-exact


@needs_jax
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_backend_parity_link_contention(backend):
    stack = PhaseStack.build(_sweep(BW, seed=29))
    m0, n0 = stack.link_contention_many()
    m1, n1 = stack.link_contention_many(backend=backend)
    np.testing.assert_allclose(m1, m0, rtol=1e-4)
    np.testing.assert_array_equal(n1, n0)


def test_unknown_backend_raises():
    stack = PhaseStack.build(_sweep(BW, seed=31))
    with pytest.raises(ValueError, match="unknown stack backend"):
        stack.cost_arrays(backend="cuda")


def test_backend_error_is_eager_and_lists_allowed_values():
    """Validation happens before any reduction and names every legal value
    plus where the bad name came from (kwarg vs env var)."""
    stack = PhaseStack.build(_sweep(BW, seed=33))
    with pytest.raises(ValueError, match=r"numpy.*jax.*pallas"):
        stack.cost_arrays(backend="rocm")
    with pytest.raises(ValueError, match="backend argument"):
        stack.sim_arrays(backend="rocm")
    with pytest.raises(ValueError, match="unknown stack backend"):
        phase_cost_many(stack, backend="rocm")
    with pytest.raises(ValueError, match="unknown stack backend"):
        stack.link_contention_many(backend="rocm")


def test_env_backend_validated_eagerly(monkeypatch):
    monkeypatch.setenv("REPRO_STACK_BACKEND", "cuda")
    stack = PhaseStack.build(_sweep(BW, seed=35))
    with pytest.raises(ValueError, match="REPRO_STACK_BACKEND"):
        stack.cost_arrays()
    with pytest.raises(ValueError, match="REPRO_STACK_BACKEND"):
        simulate_many(stack)


# ------------------------------------------------- pallas size guard --------
from repro.kernels import comm_stack as _cs  # numpy-safe import


def test_stack_backends_mirror_kernels():
    """The eagerly-validated tuple (kept kernels-import-free in stack.py)
    must never drift from the kernels module's own backend list."""
    from repro.comm import STACK_BACKENDS
    assert STACK_BACKENDS == _cs.BACKENDS


def test_pallas_one_hot_shim_warns_once_and_allows_everything():
    """The one-hot work ceiling is retired: the deprecation shim warns once
    per process (via the resettable health registry), then reports every
    size as within limit (the fused scatter-accumulate kernel is
    O(messages), no reroute exists)."""
    from repro.comm.health import reset_health
    reset_health()                       # clear the warn-once registry
    with pytest.warns(DeprecationWarning, match="fused scatter-accumulate"):
        assert _cs.pallas_within_limit(1, 1)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # a second warning would raise
        assert _cs.pallas_within_limit(
            _cs.PALLAS_ONE_HOT_LIMIT, _cs.PALLAS_ONE_HOT_LIMIT)


@needs_jax
@pytest.mark.parametrize("op", ["sum", "max"])
def test_pallas_handles_sizes_beyond_retired_one_hot_limit(op):
    """Sizes that the retired one-hot kernel had to reroute to jax now run
    directly on the fused pallas kernel and match numpy."""
    fn = _cs.segment_sum if op == "sum" else _cs.segment_max
    rng = np.random.default_rng(0)
    n_seg = _cs.PALLAS_ONE_HOT_LIMIT // _cs._CHUNK + 1   # over the old limit
    vals = rng.random(4 * _cs._CHUNK)
    ids = rng.integers(0, n_seg, vals.size)
    want = fn(vals, ids, n_seg, backend="numpy")
    got = fn(vals, ids, n_seg, backend="pallas")
    np.testing.assert_allclose(got, want, rtol=1e-4)


@needs_jax
def test_env_backend_cannot_poison_numpy_caches(monkeypatch):
    """REPRO_STACK_BACKEND must not leak float32 accelerator results into
    the bit-exact numpy arena caches (they pin backend='numpy' internally)."""
    phases = _sweep(BW, seed=37)
    want = phase_cost_many(PhaseStack.build(phases))      # clean numpy run
    monkeypatch.setenv("REPRO_STACK_BACKEND", "jax")
    stack = PhaseStack.build(phases)
    got = phase_cost_many(stack, backend="numpy")
    assert got == want
    monkeypatch.delenv("REPRO_STACK_BACKEND")
    assert phase_cost_many(stack) == want                 # cache stayed clean
    _assert_results_equal(simulate_many(stack),
                          [simulate(ph) for ph in phases])


# ------------------------------------------------- property test ------------
@given(st.integers(0, 500), st.integers(0, 500), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_property_stack_matches_loop(n1, n2, seed):
    """Any two-phase sweep is priced and simulated bit-identically."""
    rng = np.random.default_rng(seed)
    phases = [_random_phase(BW, n1, int(rng.integers(1 << 30))),
              _random_phase(BW, n2, int(rng.integers(1 << 30)))]
    got = phase_cost_many(phases)
    want = [phase_cost_phase(ph) for ph in phases]
    assert got == want
    _assert_results_equal(simulate_many(phases),
                          [simulate(ph) for ph in phases])
