"""Fault injection, graceful degradation, and the chaos acceptance sweep.

Covers the DESIGN.md §12 contract end to end: the deterministic
fault-injection framework (:mod:`repro.comm.faults`), the per-site
degradation wrappers in :mod:`repro.kernels.comm_stack` and
:mod:`repro.comm.stack`, the :class:`repro.comm.health.BackendHealth`
quarantine ledger, the hardened autotune cache/probe, and — the acceptance
criterion — the PR-7 scenario-registry sweep under every fault mode,
bit-identical to a clean numpy run with ``degraded=True`` on every row.
"""
import json

import numpy as np
import pytest

from repro.comm import faults
from repro.comm.faults import (FaultSpec, InjectedFault, InjectedTimeout,
                               inject)
from repro.comm.health import get_health, reset_health
from repro.kernels import comm_stack as cs

requires_jax = pytest.mark.skipif(not cs.have_jax(), reason="needs jax")


# -- the framework itself -----------------------------------------------------

def test_site_and_mode_registries():
    assert set(faults.SITES) == {
        "kernel.segment_reduce", "kernel.queue_walk", "stack.device_store",
        "autotune.probe", "autotune.cache_read", "autotune.cache_write",
        "serve.cache_read", "serve.cache_write", "serve.deadline"}
    assert set(faults.MODES) == {"raise", "timeout", "nan", "corrupt"}


def test_spec_rejects_bad_mode_and_times():
    with pytest.raises(ValueError, match="unknown fault mode"):
        FaultSpec(site="kernel.queue_walk", mode="explode")
    with pytest.raises(ValueError, match="times must be >= 1"):
        FaultSpec(site="kernel.queue_walk", mode="raise", times=0)


def test_spec_glob_matching():
    spec = FaultSpec(site="kernel.*", mode="raise")
    assert spec.matches("kernel.segment_reduce")
    assert spec.matches("kernel.queue_walk")
    assert not spec.matches("stack.device_store")
    exact = FaultSpec(site="autotune.probe", mode="timeout")
    assert exact.matches("autotune.probe")
    assert not exact.matches("autotune.cache_read")


def test_fail_point_fires_and_counts():
    with inject("kernel.segment_reduce", "raise") as spec:
        with pytest.raises(InjectedFault):
            faults.fail_point("kernel.segment_reduce")
        faults.fail_point("kernel.queue_walk")      # non-matching: no-op
    assert spec.fired == 1
    faults.fail_point("kernel.segment_reduce")      # disarmed outside block


def test_times_caps_firing():
    with inject("stack.device_store", "raise", times=2) as spec:
        for _ in range(2):
            with pytest.raises(InjectedFault):
                faults.fail_point("stack.device_store")
        faults.fail_point("stack.device_store")     # exhausted: no-op
    assert spec.fired == 2
    assert not spec.armed


def test_timeout_mode_is_a_timeout_error():
    with inject("autotune.cache_read", "timeout"):
        with pytest.raises(TimeoutError):
            faults.fail_point("autotune.cache_read")
        with inject("autotune.cache_read", "timeout"):
            pass
    # InjectedTimeout is also an OSError, so disk-cache handlers catch it
    assert issubclass(InjectedTimeout, OSError)
    assert issubclass(InjectedTimeout, InjectedFault)


def test_poison_nan_and_corrupt_shapes():
    f = np.array([1.0, 2.0])
    i = np.array([1, 2])
    with inject("kernel.segment_reduce", "nan"):
        out = faults.poison("kernel.segment_reduce", f)
        assert np.isnan(out).all()
        # integer outputs cannot hold NaN and finite-verify cannot see a
        # shift: nan-mode leaves them intact (corrupt is the integer mode)
        assert (faults.poison("kernel.segment_reduce", i) == i).all()
    with inject("kernel.segment_reduce", "corrupt"):
        a, b = faults.poison("kernel.segment_reduce", (f, i))
        # floats shift relatively (allclose-proof at any magnitude),
        # integers by +1 (parity compares them exactly)
        assert (a == f * 1.01 + 1.0).all() and (b == i + 1).all()
        assert faults.poison("kernel.segment_reduce",
                             '{"x": 1}').startswith("\x00corrupt\x00")
    assert faults.poison("kernel.segment_reduce", f) is f  # disarmed


def test_innermost_context_fires_first():
    with inject("kernel.*", "raise") as outer:
        with inject("kernel.queue_walk", "timeout") as inner:
            with pytest.raises(InjectedTimeout):
                faults.fail_point("kernel.queue_walk")
        assert inner.fired == 1 and outer.fired == 0


def test_env_plan_parses_globs_and_times(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR,
                       "kernel.*:raise, autotune.probe:timeout:1")
    with pytest.raises(InjectedFault):
        faults.fail_point("kernel.segment_reduce")
    with pytest.raises(InjectedTimeout):
        faults.fail_point("autotune.probe")
    faults.fail_point("autotune.probe")             # times=1 exhausted
    with pytest.raises(InjectedFault):
        faults.fail_point("kernel.queue_walk")      # unbounded glob spec


def test_env_plan_rejects_bad_entries(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "kernel.segment_reduce")
    with pytest.raises(ValueError, match="expected site:mode"):
        faults.any_armed()


# -- device_guard degradation -------------------------------------------------

@requires_jax
def test_segment_reduce_degrades_bit_identically():
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 16, size=2048)
    vals = rng.random(2048)
    want = np.bincount(ids, weights=vals, minlength=16)
    with inject("kernel.segment_reduce", "raise") as spec:
        got = cs.segment_sum(vals, ids, 16, backend="jax")
    assert spec.fired == 1
    np.testing.assert_array_equal(got, want)
    events = get_health().events_for("jax", "kernel.segment_reduce")
    assert len(events) == 1 and "InjectedFault" in events[0].error
    assert get_health().failure_streak("jax") == 1


@requires_jax
def test_queue_walk_degrades_bit_identically():
    from repro.comm.primitives import batched_queue_traversal_steps
    rng = np.random.default_rng(1)
    bounds = np.array([0, 5, 12, 12, 20])
    posted = np.concatenate([rng.permutation(n)
                             for n in np.diff(bounds)]).astype(np.int64)
    arrival = np.concatenate([rng.permutation(n)
                              for n in np.diff(bounds)]).astype(np.int64)
    want = batched_queue_traversal_steps(posted, arrival, bounds)
    with inject("kernel.queue_walk", "timeout"):
        got = cs.queue_walk(posted, arrival, bounds, backend="jax")
    np.testing.assert_array_equal(got, want)
    assert get_health().events_for("jax", "kernel.queue_walk")


@requires_jax
def test_success_clears_failure_streak():
    rng = np.random.default_rng(2)
    ids = rng.integers(0, 8, size=512)
    vals = rng.random(512)
    with inject("kernel.segment_reduce", "raise", times=2):
        cs.segment_sum(vals, ids, 8, backend="jax")
        cs.segment_sum(vals, ids, 8, backend="jax")
        assert get_health().failure_streak("jax") == 2
        cs.segment_sum(vals, ids, 8, backend="jax")   # spec exhausted: clean
    assert get_health().failure_streak("jax") == 0
    assert not get_health().is_quarantined("jax")


@requires_jax
def test_quarantine_after_consecutive_failures():
    health = get_health()
    assert health.quarantine_after == 3
    rng = np.random.default_rng(3)
    ids = rng.integers(0, 8, size=256)
    vals = rng.random(256)
    want = np.bincount(ids, weights=vals, minlength=8)
    with inject("kernel.segment_reduce", "raise"):
        for _ in range(3):
            cs.segment_sum(vals, ids, 8, backend="jax")
    assert health.is_quarantined("jax")
    assert health.warned("quarantine:jax")
    # quarantined: resolve_backend reroutes to numpy (with one warning)...
    assert cs.resolve_backend("jax") == "numpy"
    # ...and device_guard short-circuits without recording new events
    n = health.n_events
    out = cs.device_guard("kernel.segment_reduce", "jax",
                          lambda: 1 / 0, lambda: want)
    np.testing.assert_array_equal(out, want)
    assert health.n_events == n
    reset_health()
    assert cs.resolve_backend("jax") == "jax"         # reset lifts quarantine


def test_fallback_warns_once_per_backend_site():
    health = get_health()
    with pytest.warns(RuntimeWarning, match="falling back to"):
        health.record_failure("jax", "kernel.queue_walk", RuntimeError("x"))
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")                       # repeat must be silent
        health.record_failure("jax", "kernel.queue_walk", RuntimeError("y"))


# -- REPRO_STACK_VERIFY post-kernel checks ------------------------------------

def test_verify_mode_rejects_unknown_value(monkeypatch):
    monkeypatch.setenv("REPRO_STACK_VERIFY", "bogus")
    with pytest.raises(ValueError, match="REPRO_STACK_VERIFY"):
        cs.verify_mode()


@requires_jax
@pytest.mark.parametrize("mode,verify", [("nan", "finite"),
                                         ("corrupt", "parity")])
def test_verify_catches_poisoned_device_output(mode, verify, monkeypatch):
    monkeypatch.setenv("REPRO_STACK_VERIFY", verify)
    rng = np.random.default_rng(4)
    ids = rng.integers(0, 16, size=1024)
    vals = rng.random(1024)
    want = np.bincount(ids, weights=vals, minlength=16)
    with inject("kernel.segment_reduce", mode) as spec:
        got = cs.segment_sum(vals, ids, 16, backend="jax")
    assert spec.fired == 1
    np.testing.assert_array_equal(got, want)
    events = get_health().events_for("jax", "kernel.segment_reduce")
    assert len(events) == 1 and "BackendVerifyError" in events[0].error


@requires_jax
def test_poison_without_verify_passes_through(monkeypatch):
    monkeypatch.delenv("REPRO_STACK_VERIFY", raising=False)
    rng = np.random.default_rng(5)
    ids = rng.integers(0, 16, size=1024)
    vals = rng.random(1024)
    with inject("kernel.segment_reduce", "nan"):
        got = cs.segment_sum(vals, ids, 16, backend="jax")
    # no verify mode: the poisoned output is NOT caught — this is exactly
    # what REPRO_STACK_VERIFY exists to close
    assert np.isnan(got).all()
    assert get_health().n_events == 0


# -- autotune hardening (disk cache + probe) ----------------------------------

@pytest.fixture
def autotune_env(monkeypatch, tmp_path):
    """Fresh autotune state: no env override, no memo, a tmp cache path."""
    monkeypatch.delenv("REPRO_STACK_AUTOTUNE", raising=False)
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE_CACHE", str(path))
    old = cs._crossover
    cs._crossover = None
    yield path
    cs._crossover = old


def test_cache_read_corrupt_file_degrades(autotune_env):
    autotune_env.write_text("{not json!")
    assert cs._read_probe_cache(str(autotune_env), cs._probe_tag()) is None
    events = get_health().events_for("disk-cache", "autotune.cache_read")
    assert len(events) == 1


def test_cache_read_wrong_schema_degrades(autotune_env):
    autotune_env.write_text(json.dumps({"tag": cs._probe_tag(),
                                        "crossover": None}))
    assert cs._read_probe_cache(str(autotune_env), cs._probe_tag()) is None
    assert get_health().events_for("disk-cache", "autotune.cache_read")


def test_cache_read_stale_tag_is_not_an_event(autotune_env):
    autotune_env.write_text(json.dumps({"tag": "other-stack",
                                        "crossover": 4096.0}))
    assert cs._read_probe_cache(str(autotune_env), cs._probe_tag()) is None
    assert get_health().n_events == 0     # a stale tag is normal, not a fault


def test_cache_read_fault_injected(autotune_env):
    autotune_env.write_text(json.dumps({"tag": cs._probe_tag(),
                                        "crossover": 4096.0}))
    tag = cs._probe_tag()
    assert cs._read_probe_cache(str(autotune_env), tag) == 4096.0
    reset_health()
    with inject("autotune.cache_read", "timeout"):
        assert cs._read_probe_cache(str(autotune_env), tag) is None
    assert get_health().events_for("disk-cache", "autotune.cache_read")
    with inject("autotune.cache_read", "corrupt"):    # garbled file text
        assert cs._read_probe_cache(str(autotune_env), tag) is None


def test_cache_write_unwritable_path_degrades(tmp_path):
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("")
    path = blocker / "cache.json"                 # NotADirectoryError
    cs._write_probe_cache(str(path), cs._probe_tag(), 4096.0)
    events = get_health().events_for("disk-cache", "autotune.cache_write")
    assert len(events) == 1


def test_cache_write_fault_injected(autotune_env):
    with inject("autotune.cache_write", "timeout"):
        cs._write_probe_cache(str(autotune_env), cs._probe_tag(), 4096.0)
    assert not autotune_env.exists()
    assert get_health().events_for("disk-cache", "autotune.cache_write")


@requires_jax
def test_probe_timeout_degrades_to_numpy_always(autotune_env):
    with inject("autotune.probe", "timeout") as spec:
        assert cs._probe_crossover() == float("inf")
    assert spec.fired == 1                        # a timeout ends the probe
    assert get_health().events_for("autotune", "autotune.probe")


@requires_jax
def test_probe_retries_then_degrades(autotune_env):
    with inject("autotune.probe", "raise") as spec:
        assert cs._probe_crossover() == float("inf")
    # non-timeout failures retry with backoff before giving up
    assert spec.fired == cs._PROBE_RETRIES
    assert len(get_health().events_for("autotune",
                                       "autotune.probe")) == cs._PROBE_RETRIES


@requires_jax
def test_autotune_end_to_end_corrupt_cache_then_probe_fault(autotune_env):
    autotune_env.write_text("junk{{{")
    with inject("autotune.probe", "timeout"):
        assert cs.autotune_crossover(refresh=False) == float("inf")
    sites = {e.site for e in get_health().events}
    assert sites == {"autotune.cache_read", "autotune.probe"}
    # the degraded probe result was still persisted for the next process
    assert json.loads(autotune_env.read_text())["crossover"] == float("inf")


def test_autotune_env_override_skips_probe(monkeypatch):
    monkeypatch.setenv("REPRO_STACK_AUTOTUNE", "4096")
    old = cs._crossover
    cs._crossover = None
    try:
        with inject("autotune.*", "raise"):
            assert cs.autotune_crossover() == 4096.0
    finally:
        cs._crossover = old
    assert get_health().n_events == 0


# -- stack + sweep degradation ------------------------------------------------

def _small_pattern():
    from repro.sparse.partition import CommPattern
    rng = np.random.default_rng(6)
    n = 200
    return CommPattern(src=rng.integers(0, 32, n),
                       dst=rng.integers(0, 32, n),
                       size=rng.integers(1, 1 << 16, n).astype(np.float64),
                       n_procs=32)


@requires_jax
def test_device_store_fault_degrades_sweep_bit_identically():
    from repro.comm.strategies import best_strategy
    from repro.net.machine import lassen_machine
    machine = lassen_machine((2, 2, 2))
    pat = _small_pattern()
    clean = best_strategy(pat, machine, backend="numpy")
    assert not clean.degraded
    with inject("*", "raise"):
        chaos = best_strategy(pat, machine, backend="jax")
    assert chaos.degraded
    assert chaos.model == clean.model and chaos.sim == clean.sim
    assert get_health().events_for(site="stack.device_store")


@requires_jax
def test_sweep_retries_on_numpy_when_pricing_raises(monkeypatch):
    from repro.comm.strategies import best_strategy
    from repro.core import models
    from repro.net.machine import lassen_machine
    machine = lassen_machine((2, 2, 2))
    pat = _small_pattern()
    clean = best_strategy(pat, machine, backend="numpy")
    real = models.phase_cost_many

    def flaky(stack, *a, backend=None, **kw):
        if backend != "numpy":
            raise RuntimeError("pricing pass exploded")
        return real(stack, *a, backend=backend, **kw)

    monkeypatch.setattr(models, "phase_cost_many", flaky)
    verdict = best_strategy(pat, machine, backend="jax")
    assert verdict.degraded
    assert verdict.model == clean.model and verdict.sim == clean.sim
    events = get_health().events_for("jax", "strategies.best_strategy_many")
    assert len(events) == 1


# -- the acceptance criterion: chaos registry sweep ---------------------------

@requires_jax
@pytest.mark.parametrize("mode,verify", [
    ("raise", ""),
    ("timeout", ""),
    ("nan", "finite"),
    ("corrupt", "parity"),
])
def test_chaos_registry_sweep_bit_identical_to_clean_numpy(mode, verify,
                                                           monkeypatch):
    """ISSUE 8 acceptance: every fault mode over the PR-7 scenario registry
    completes on all machine presets, prices bit-identically to a clean
    numpy run, and marks every row degraded with events in the ledger."""
    from repro.workloads.registry import default_machines, sweep

    monkeypatch.setenv("REPRO_STACK_BACKEND", "numpy")
    clean = sweep(machines=default_machines())
    assert clean and not any(r.degraded for r in clean)

    reset_health()
    monkeypatch.setenv("REPRO_STACK_BACKEND", "jax")
    monkeypatch.setenv("REPRO_STACK_VERIFY", verify)
    monkeypatch.setenv(faults.ENV_VAR, f"*:{mode}")
    chaos = sweep(machines=default_machines())

    assert get_health().n_events > 0
    assert all(r.degraded for r in chaos)
    assert {r.machine for r in chaos} == set(default_machines())
    for a, b in zip(clean, chaos):
        assert (a.machine, a.scenario, a.phase) == (b.machine, b.scenario,
                                                    b.phase)
        assert a.model_winner == b.model_winner
        assert a.sim_winner == b.sim_winner
        assert a.model == b.model                 # bit-identical floats
        assert a.sim == b.sim
