"""Multi-device validation of shard_map components (compression, pipeline,
EP all-to-all).  Runs in a subprocess with 8 fake host devices so the main
pytest process keeps its single-device view."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh

results = {}

# ---------------------------------------------------------- compression ----
from repro.parallel.compression import dp_grads_compressed
mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)}
batch = {"x": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32),
         "y": jnp.asarray(rng.standard_normal((32, 4)), jnp.float32)}

def loss_fn(p, b):
    pred = b["x"] @ p["w"]
    return jnp.mean((pred - b["y"]) ** 2)

g_ref = jax.grad(lambda p: loss_fn(p, batch))(params)
# per-shard mean-of-grads == grad of mean loss when shards are equal-sized
g_c, err = dp_grads_compressed(loss_fn, params, batch, mesh)
rel = float(jnp.linalg.norm(g_c["w"] - g_ref["w"]) / jnp.linalg.norm(g_ref["w"]))
results["compress_rel_err"] = rel
results["compress_err_state_shape"] = list(err["w"].shape)

# error feedback: with EF, averaged compressed grads over repeated steps
# converge to the true gradient
acc_ef = jnp.zeros_like(g_ref["w"])
errs = None
for _ in range(30):
    g_c, errs = dp_grads_compressed(loss_fn, params, batch, mesh, errors=errs)
    acc_ef = acc_ef + g_c["w"]
rel_ef = float(jnp.linalg.norm(acc_ef / 30 - g_ref["w"])
               / jnp.linalg.norm(g_ref["w"]))
results["compress_ef_rel_err"] = rel_ef

# -------------------------------------------------------------- pipeline ---
from repro.parallel.pipeline import gpipe, stack_stages
mesh2 = make_mesh((4, 2), ("pod", "data"))
L, d = 8, 16
layers = {"w": jnp.asarray(rng.standard_normal((L, d, d)) / np.sqrt(d),
                           jnp.float32)}

def layer_fn(w, x):
    return jnp.tanh(x @ w)

def stage_fn(stage_params, x):
    def body(h, w):
        return layer_fn(w, h), ()
    h, _ = jax.lax.scan(body, x, stage_params["w"])
    return h

M, mb = 6, 4
xs = jnp.asarray(rng.standard_normal((M, mb, d)), jnp.float32)
stages = stack_stages(layers, 4)
y_pipe = gpipe(stage_fn, stages, xs, mesh2, axis="pod")
# sequential reference
y_ref = xs
for i in range(L):
    y_ref = jax.vmap(lambda x: layer_fn(layers["w"][i], x))(y_ref)
results["pipeline_max_err"] = float(jnp.max(jnp.abs(y_pipe - y_ref)))

# ---------------------------------------------------------------- EP a2a ---
from repro.parallel.ep_a2a import moe_ffn_ep
from repro.nn.moe import moe_ffn
from repro.configs import get_smoke_config
import dataclasses
cfg = get_smoke_config("qwen3-moe-30b-a3b")
cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # no drops
mesh3 = make_mesh((8,), ("model",))
d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
p = {"router": jnp.asarray(rng.standard_normal((d, E)) * 0.02, jnp.float32),
     "w1": jnp.asarray(rng.standard_normal((E, d, f)) / np.sqrt(d), jnp.float32),
     "w3": jnp.asarray(rng.standard_normal((E, d, f)) / np.sqrt(d), jnp.float32),
     "w2": jnp.asarray(rng.standard_normal((E, f, d)) / np.sqrt(f), jnp.float32)}
x = jnp.asarray(rng.standard_normal((2, 16, d)), jnp.float32)
y_ref, _ = moe_ffn(x, p, cfg)
y_ep = moe_ffn_ep(x, p, cfg, mesh3, axis_name="model")
results["ep_rel_err"] = float(jnp.linalg.norm(y_ep - y_ref)
                              / jnp.linalg.norm(y_ref))
print(json.dumps(results))
"""


@pytest.fixture(scope="module")
def multidevice_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_compressed_allreduce_close(multidevice_results):
    r = multidevice_results
    assert r["compress_rel_err"] < 0.02          # int8 one-shot error
    assert r["compress_err_state_shape"][0] == 8  # per-device EF state


def test_error_feedback_reduces_bias(multidevice_results):
    r = multidevice_results
    assert r["compress_ef_rel_err"] < r["compress_rel_err"]
    assert r["compress_ef_rel_err"] < 0.005


def test_pipeline_matches_sequential(multidevice_results):
    assert multidevice_results["pipeline_max_err"] < 1e-5


def test_ep_a2a_matches_dense_dispatch(multidevice_results):
    assert multidevice_results["ep_rel_err"] < 1e-4
