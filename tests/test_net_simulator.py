"""Tests for the mechanistic network simulator and the paper's validation loop:
fitted parameters must recover the ground truth the simulator was built with
(the stand-in for the paper's Blue Waters measurements)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import blue_waters
from repro.core.fitting import fit_alpha_beta, fit_RN, fit_gamma
from repro.core.params import PROTOCOL_NAMES
from repro.net import (blue_waters_machine, tpu_v5e_machine, simulate_phase,
                       pingpong_sweep, ppn_sweep, high_volume_pingpong,
                       contention_line_test)
from repro.net.simulator import queue_traversal_steps


# ------------------------------------------------------------ queue sim -----
def test_queue_same_order_linear():
    n = 100
    steps = queue_traversal_steps(np.arange(n), np.arange(n))
    assert steps.sum() == n          # every arrival matches the queue head


def test_queue_reversed_order_quadratic():
    n = 100
    steps = queue_traversal_steps(np.arange(n)[::-1], np.arange(n))
    assert steps.sum() == n * (n + 1) // 2


@given(st.integers(1, 200), st.randoms())
@settings(max_examples=25, deadline=None)
def test_queue_steps_bounds(n, rnd):
    """Any order costs between n (all head hits) and n(n+1)/2 (worst case)."""
    posted = np.arange(n)
    arrive = np.arange(n)
    rnd.shuffle(arrive)
    total = queue_traversal_steps(posted, arrive).sum()
    assert n <= total <= n * (n + 1) // 2


def test_random_order_near_n_squared_over_3():
    """Paper Section 5: measured queue cost ~ n^2/3 for random-ish orders."""
    n = 2000
    rng = np.random.default_rng(0)
    arrive = rng.permutation(n)
    total = queue_traversal_steps(np.arange(n), arrive).sum()
    assert 0.25 * n * n < total < 0.42 * n * n


# ----------------------------------------------------------- locality -------
def test_bw_locality_classes():
    m = blue_waters_machine((2, 1, 1))
    assert m.locality(0, 1) == 0          # same socket
    assert m.locality(0, 8) == 1          # cross socket, same node
    assert m.locality(0, 16) == 2         # different node (same Gemini)
    assert m.locality(0, 32) == 2         # different Gemini
    assert m.torus_node_of(0) == m.torus_node_of(31)   # 2 nodes/Gemini


def test_tpu_locality_classes():
    m = tpu_v5e_machine()
    assert m.locality(0, 3) == 0          # same host (4 chips)
    assert m.locality(0, 4) == 1          # cross host, same pod
    assert m.torus_node_of(7) == 7        # chip == torus node


# ------------------------------------------------- fits recover truth -------
def test_fit_recovers_table1():
    m = blue_waters_machine((2, 1, 1))
    gt = m.params
    sizes = np.unique(np.round(np.logspace(0, 6, 48)).astype(int))
    for li, kind in enumerate(gt.locality_names):
        times = pingpong_sweep(m, kind, sizes, reps=2, noise=0.0)
        fit = fit_alpha_beta(sizes, times, gt)
        for pi, proto in enumerate(PROTOCOL_NAMES):
            a, rb = fit[proto]
            assert a == pytest.approx(gt.alpha[li, pi], rel=0.05), (kind, proto)
            assert rb == pytest.approx(gt.Rb[li, pi], rel=0.15), (kind, proto)


def test_fit_recovers_RN():
    m = blue_waters_machine((2, 1, 1))
    gt = m.params
    ks, ts = ppn_sweep(m, 1e6)
    rn = fit_RN(ks, ts, 1e6, gt.alpha[2, 2], gt.Rb[2, 2])
    assert rn == pytest.approx(6.6e9, rel=0.05)


def test_fit_recovers_gamma():
    """Reversed-order HighVolumePingPong residuals ~ gamma * n^2 (Fig. 5)."""
    m = blue_waters_machine((2, 1, 1))
    gt = m.params
    ns = np.array([100, 300, 1000, 3000])
    total_bytes = 1 << 22
    meas, base = [], []
    for n in ns:
        s = total_bytes // n
        t_rev, *_ = high_volume_pingpong(m, [(0, 32)], int(n), s, order="reversed")
        t_same, *_ = high_volume_pingpong(m, [(0, 32)], int(n), s, order="same")
        meas.append(t_rev)
        base.append(t_same)
    # each phase pays ~gamma*n(n+1)/2 twice (both directions) minus the O(n)
    # baseline; fitted coefficient should be ~2 * gamma/2 = gamma
    g = fit_gamma(ns, np.array(meas), np.array(base))
    assert g == pytest.approx(gt.gamma, rel=0.1)


# ------------------------------------------------------ contention ----------
def test_contention_only_with_shared_links():
    """A single flow never pays contention; the Fig. 6 pattern does."""
    m = blue_waters_machine((4, 1, 1))
    ppt = m.procs_per_torus_node
    # one pair, far apart: no sharing
    r = simulate_phase(m, [0], [3 * ppt], [1e6])
    assert r.contention == 0.0
    # the paper's line test: G0->G2 and G1->G3 share the G1-G2 link
    _, r1, _ = contention_line_test(m, n=4, size=1e5)
    assert r1.contention > 0.0
    assert r1.max_link_bytes > 0


def test_contention_grows_with_size():
    m = blue_waters_machine((4, 1, 1))
    _, a, _ = contention_line_test(m, n=4, size=1e4)
    _, b, _ = contention_line_test(m, n=4, size=1e6)
    assert b.contention > a.contention * 10


# ------------------------------------------------------ max-rate mech -------
def test_injection_saturation_in_sim():
    """Doubling active senders less-than-doubles after the R_N cap binds."""
    m = blue_waters_machine((2, 1, 1))
    ks, ts = ppn_sweep(m, 1 << 20)
    # unsaturated region: going 1->2 senders grows time by < 1.5x
    # saturated region: slope is linear in k (each k adds s/RN)
    d_lo = ts[1] - ts[0]
    d_hi = ts[-1] - ts[-2]
    assert d_hi > d_lo
    assert ts[-1] > ts[0]


def test_phase_noise_reproducible():
    m = blue_waters_machine((2, 1, 1))
    rng1 = np.random.default_rng(7)
    rng2 = np.random.default_rng(7)
    a = simulate_phase(m, [0], [32], [1e5], rng=rng1, noise=0.05).time
    b = simulate_phase(m, [0], [32], [1e5], rng=rng2, noise=0.05).time
    assert a == b
