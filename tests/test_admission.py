"""Admission control, deadlines, retry, and the circuit breaker.

Covers the service-layer robustness primitives of DESIGN.md §13: the
bounded :class:`repro.serve.AdmissionQueue` with both shedding policies,
cooperative :class:`repro.serve.Deadline` enforcement (real clocks and the
``serve.deadline`` fault site), deterministic
:class:`repro.serve.RetryPolicy` backoff, the per-backend
:class:`repro.comm.CircuitBreaker` state machine over
:class:`repro.comm.health.BackendHealth`, and the bounded health event
ring.  Ends with the service-level integration: overload shedding, expired
requests, and breaker-open rerouting through
:class:`repro.serve.StrategyService`.
"""
import threading

import numpy as np
import pytest

from repro.comm import faults
from repro.comm.health import (BackendHealth, CircuitBreaker,
                               DEFAULT_MAX_EVENTS, get_health)
from repro.net.machine import lassen_machine
from repro.serve import (AdmissionQueue, Deadline, DeadlineExceeded,
                         Overloaded, RetryPolicy, StrategyService)
from repro.sparse.partition import CommPattern

LASSEN = lassen_machine((2, 2, 2))


def _pattern(P, n=24, seed=0):
    rng = np.random.default_rng(seed)
    return CommPattern(src=rng.integers(0, P, n), dst=rng.integers(0, P, n),
                       size=rng.integers(64, 4096, n).astype(float),
                       n_procs=P)


# ================================================================ Deadline ==
def test_deadline_remaining_and_expiry():
    t = [0.0]
    dl = Deadline(2.0, clock=lambda: t[0])
    assert dl.remaining() == 2.0 and not dl.expired
    dl.check()                                  # inside the window: no-op
    t[0] = 3.0
    assert dl.expired and dl.remaining() == 0.0
    with pytest.raises(DeadlineExceeded, match="sweep"):
        dl.check(where="sweep")


def test_deadline_unarmed_is_a_noop():
    dl = Deadline(None)
    assert dl.remaining() is None and not dl.expired
    dl.check()                                  # never raises
    with faults.inject("serve.deadline", "raise") as spec:
        dl.check()                              # unarmed: fault site silent
    assert spec.fired == 0


def test_deadline_fault_site_converts_to_deadline_exceeded():
    dl = Deadline(1000.0)
    with faults.inject("serve.deadline", "raise") as spec:
        with pytest.raises(DeadlineExceeded, match="injected"):
            dl.check(where="probe")
    assert spec.fired == 1
    # the typed error is a TimeoutError, like a real expiry would look
    assert issubclass(DeadlineExceeded, TimeoutError)


def test_deadline_validates():
    with pytest.raises(ValueError, match="timeout"):
        Deadline(-1.0)


# ========================================================== AdmissionQueue ==
def test_admission_reject_policy_sheds_newest():
    q = AdmissionQueue(capacity=2, policy="reject")
    q.acquire(2)
    with pytest.raises(Overloaded, match="shed"):
        q.acquire(1)
    assert q.n_shed == 1 and q.pending == 2
    q.release(2)
    q.acquire(1)                                # capacity freed
    q.release(1)
    assert q.n_admitted == 3 and q.pending == 0


def test_admission_oversized_batch_admits_when_idle():
    q = AdmissionQueue(capacity=2, policy="reject")
    q.acquire(10)                               # idle: never wedge a batch
    with pytest.raises(Overloaded):
        q.acquire(1)                            # but non-idle overload sheds
    q.release(10)


def test_admission_block_policy_waits_for_capacity():
    q = AdmissionQueue(capacity=1, policy="block")
    q.acquire(1)
    got = []

    def waiter():
        with q.admit(1):
            got.append(True)

    t = threading.Thread(target=waiter)
    t.start()
    assert not got                              # parked on the condition
    q.release(1)
    t.join(timeout=5)
    assert got == [True]


def test_admission_block_policy_respects_deadline():
    q = AdmissionQueue(capacity=1, policy="block")
    q.acquire(1)
    t = [0.0]
    with pytest.raises(DeadlineExceeded, match="admission"):
        q.acquire(1, Deadline(0.0, clock=lambda: t[0]))
    assert q.n_shed == 1
    q.release(1)


def test_admission_validates():
    with pytest.raises(ValueError, match="capacity"):
        AdmissionQueue(capacity=0)
    with pytest.raises(ValueError, match="policy"):
        AdmissionQueue(policy="drop-oldest")
    with pytest.raises(ValueError, match="units"):
        AdmissionQueue().acquire(-1)


# ============================================================= RetryPolicy ==
def test_retry_policy_backoff_is_deterministic():
    a = RetryPolicy(attempts=5, base=0.1, cap=2.0, jitter=0.5, seed=7)
    b = RetryPolicy(attempts=5, base=0.1, cap=2.0, jitter=0.5, seed=7)
    da = [a.delay(i) for i in range(4)]
    db = [b.delay(i) for i in range(4)]
    assert da == db                             # same seed, same sequence
    assert all(0 < d <= 2.0 for d in da)
    nj = RetryPolicy(attempts=2, base=0.1, jitter=0.0)
    assert nj.delay(0) == 0.1 and nj.delay(10) == nj.cap


def test_retry_policy_runs_and_reraises():
    sleeps = []
    rp = RetryPolicy(attempts=3, base=0.01, seed=1, sleep=sleeps.append)
    n = [0]

    def flaky():
        n[0] += 1
        if n[0] < 3:
            raise ValueError("boom")
        return "ok"

    seen = []
    assert rp.run(flaky, on_failure=lambda e, a: seen.append(a)) == "ok"
    assert seen == [0, 1] and len(sleeps) == 2
    with pytest.raises(ZeroDivisionError):
        RetryPolicy(attempts=2, base=0.0,
                    sleep=lambda s: None).run(lambda: 1 / 0)


def test_retry_policy_honors_deadline():
    t = [0.0]
    dl = Deadline(1.0, clock=lambda: t[0])

    def fail_and_expire():
        t[0] = 2.0
        raise ValueError("first attempt")

    rp = RetryPolicy(attempts=5, base=0.0, sleep=lambda s: None)
    with pytest.raises(DeadlineExceeded):       # no second attempt burned
        rp.run(fail_and_expire, deadline=dl)


def test_retry_policy_validates():
    with pytest.raises(ValueError, match="attempts"):
        RetryPolicy(attempts=0)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=2.0)


# ========================================================== CircuitBreaker ==
def test_breaker_state_machine():
    t = [0.0]
    br = CircuitBreaker("jax", fail_threshold=2, reset_after=10.0,
                        clock=lambda: t[0])
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"                 # under threshold
    br.record_failure()
    assert br.state == "open" and br.n_opens == 1
    assert not br.allow()                       # open: shed
    t[0] = 11.0
    assert br.allow()                           # hold elapsed: one probe
    assert br.state == "half_open"
    assert not br.allow()                       # only one probe at a time
    br.record_success()
    assert br.state == "closed" and br.allow()


def test_breaker_half_open_failure_reopens():
    t = [0.0]
    br = CircuitBreaker("jax", fail_threshold=3, reset_after=5.0,
                        clock=lambda: t[0])
    for _ in range(3):
        br.record_failure()
    t[0] = 6.0
    assert br.allow() and br.state == "half_open"
    br.record_failure()                         # probe failed
    assert br.state == "open" and br.n_opens == 2
    assert not br.allow() and br.n_shed > 0
    br.reset()
    assert br.state == "closed"


def test_breaker_validates_and_registers_per_backend():
    with pytest.raises(ValueError, match="fail_threshold"):
        CircuitBreaker("jax", fail_threshold=0)
    with pytest.raises(ValueError, match="reset_after"):
        CircuitBreaker("jax", reset_after=-1.0)
    h = get_health()
    br = h.breaker_for("jax")
    assert h.breaker_for("jax") is br           # one breaker per backend
    assert h.breaker_for("numpy") is not br
    h.reset()
    assert h.breaker_for("jax") is not br       # reset clears the registry


# ==================================================== bounded health ring ==
def test_health_event_ring_is_bounded():
    h = BackendHealth(max_events=4)
    for i in range(10):
        h.record_failure("jax", "kernel.segment_reduce", ValueError(str(i)))
    assert len(h.events) == 4                   # ring keeps the newest
    assert h.n_events == 10                     # total stays monotone
    assert h.dropped_events == 6
    assert [e.error for e in h.events][-1] == "ValueError('9')"
    h.reset()
    assert h.n_events == 0 and h.dropped_events == 0 and h.events == ()


def test_health_ring_default_cap_from_env(monkeypatch):
    assert BackendHealth()._events.maxlen == DEFAULT_MAX_EVENTS
    monkeypatch.setenv("REPRO_HEALTH_MAX_EVENTS", "7")
    assert BackendHealth()._events.maxlen == 7


def test_health_warn_once_survives_ring_wrap():
    h = BackendHealth(max_events=2)
    with pytest.warns(RuntimeWarning, match="falling back"):
        h.record_failure("jax", "site.a", ValueError("x"))
    # further failures at the same site wrap the ring but never re-warn
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        for _ in range(4):
            try:
                h.record_failure("jax", "site.a", ValueError("y"))
            except RuntimeWarning as w:  # pragma: no cover - assertion aid
                if "falling back" in str(w):
                    raise AssertionError("warn-once broke under ring wrap")


# ==================================================== service integration ==
def test_service_sheds_batch_with_overloaded_results():
    q = AdmissionQueue(capacity=1, policy="reject")
    svc = StrategyService(LASSEN, backend="numpy", admission=q)
    pat = _pattern(LASSEN.n_procs)
    q.acquire(1)                                # someone else is in flight
    res = svc.query_many([pat, pat])
    assert [r.ok for r in res] == [False, False]
    assert all(r.overloaded and isinstance(r.error, Overloaded) for r in res)
    q.release(1)
    assert svc.query(pat).ok                    # capacity back: answers again


def test_service_expired_deadline_yields_typed_results():
    svc = StrategyService(LASSEN, backend="numpy", timeout=0.0)
    res = svc.query(_pattern(LASSEN.n_procs))
    assert not res.ok and isinstance(res.error, DeadlineExceeded)
    # a per-call override beats the service default
    assert svc.query(_pattern(LASSEN.n_procs), timeout=None).ok


def test_service_deadline_fault_site_degrades_to_error_result():
    svc = StrategyService(LASSEN, backend="numpy", timeout=1000.0)
    with faults.inject("serve.deadline", "raise"):
        res = svc.query(_pattern(LASSEN.n_procs))
    assert not res.ok and isinstance(res.error, DeadlineExceeded)
    # without a deadline the same fault plan is inert
    svc2 = StrategyService(LASSEN, backend="numpy")
    with faults.inject("serve.deadline", "raise"):
        assert svc2.query(_pattern(LASSEN.n_procs)).ok


def test_service_breaker_opens_and_reroutes_to_numpy(monkeypatch):
    from repro.comm import strategies
    real = strategies.best_strategy_many
    calls = []

    def broken_jax(patterns, machine=None, **kw):
        calls.append(kw.get("backend"))
        if kw.get("backend") != "numpy":
            raise RuntimeError("device wedged")
        return real(patterns, machine, **kw)

    monkeypatch.setattr(strategies, "best_strategy_many", broken_jax)
    svc = StrategyService(LASSEN, backend="jax", breaker_threshold=2,
                          breaker_reset=3600.0)
    pats = [_pattern(LASSEN.n_procs, seed=s) for s in range(3)]
    r0, r1 = svc.query(pats[0]), svc.query(pats[1])
    assert r0.ok and r0.degraded and r1.ok and r1.degraded
    assert get_health().breaker_for("jax").state == "open"
    r2 = svc.query(pats[2])                     # rerouted, no jax attempt
    assert r2.ok and r2.degraded
    assert calls.count("jax") == 2 and calls[-1] == "numpy"
    # the reroute swept the full strategy set, not the worst-case single
    assert len(r2.verdict.model) > 1


def test_service_retry_policy_heals_transients(monkeypatch):
    from repro.comm import strategies
    real = strategies.best_strategy_many
    n = [0]

    def transient(patterns, machine=None, **kw):
        if kw.get("backend") == "jax":
            n[0] += 1
            if n[0] < 2:
                raise RuntimeError("blip")
            kw["backend"] = "numpy"             # pretend the retry worked
        return real(patterns, machine, **kw)

    monkeypatch.setattr(strategies, "best_strategy_many", transient)
    svc = StrategyService(
        LASSEN, backend="jax",
        retry=RetryPolicy(attempts=3, base=0.0, sleep=lambda s: None))
    res = svc.query(_pattern(LASSEN.n_procs))
    assert res.ok and not res.degraded and n[0] == 2
    assert get_health().breaker_for("jax").state == "closed"
