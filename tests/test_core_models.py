"""Unit + property tests for the performance-model core (the paper itself)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (blue_waters, tpu_v5e, message_time, queue_time,
                        phase_cost, model_ladder, MODEL_LEVELS,
                        TorusTopology, average_hops, contention_ell, cube_side)
from repro.core.params import SHORT, EAGER, REND


# ---------------------------------------------------------------- params ----
def test_table1_values():
    p = blue_waters()
    # spot-check the paper's Table 1
    assert p.alpha[0, SHORT] == pytest.approx(4.4e-7)
    assert p.alpha[2, EAGER] == pytest.approx(7.0e-6)
    assert p.Rb[1, REND] == pytest.approx(6.2e9)
    assert np.isinf(p.RN[2, SHORT])
    assert p.RN[2, REND] == pytest.approx(6.6e9)
    assert p.gamma == pytest.approx(8.4e-9)   # Eq. (4)
    assert p.delta == pytest.approx(1.0e-10)  # Eq. (6)


def test_protocol_classification():
    p = blue_waters()
    assert list(p.protocol_of([1, 512, 513, 8192, 8193])) == [
        SHORT, SHORT, EAGER, EAGER, REND]


# ---------------------------------------------------------------- models ----
def test_postal_equals_alpha_beta():
    p = blue_waters()
    t = message_time(p, 1000, 2, use_maxrate=False)
    assert t == pytest.approx(p.alpha[2, EAGER] + 1000 / p.Rb[2, EAGER])


def test_maxrate_reduces_to_postal_at_low_ppn():
    """Eq. (2): with ppn*Rb < RN the max-rate model is the postal model."""
    p = blue_waters()
    s = 1 << 20
    t_postal = message_time(p, s, 2, use_maxrate=False)
    t_mr = message_time(p, s, 2, ppn=1)
    # ppn=1: min(RN, Rb) = Rb since Rb=2.9e9 < RN=6.6e9
    assert t_mr == pytest.approx(t_postal)


def test_maxrate_saturates_injection():
    """With many senders the node injection cap dominates."""
    p = blue_waters()
    s = 1 << 20
    t4 = message_time(p, s, 2, ppn=4)     # 4*2.9e9 > 6.6e9 -> capped
    expect = p.alpha[2, REND] + 4 * s / 6.6e9
    assert t4 == pytest.approx(expect)


def test_node_aware_cheaper_on_socket():
    p = blue_waters()
    t_sock = message_time(p, 4096, 0)
    t_net = message_time(p, 4096, 2)
    assert t_sock < t_net


def test_queue_time_quadratic():
    p = blue_waters()
    assert queue_time(p, 1000) == pytest.approx(p.gamma * 1e6)


@given(st.integers(1, 10**6), st.integers(0, 2))
@settings(max_examples=50, deadline=None)
def test_message_time_monotone_in_size(size, loc):
    """Property: cost is nondecreasing in message size (within a protocol)."""
    p = blue_waters()
    t1 = float(message_time(p, size, loc))
    t2 = float(message_time(p, size + max(size // 10, 1), loc))
    proto_same = p.protocol_of(size) == p.protocol_of(size + max(size // 10, 1))
    if proto_same:
        assert t2 >= t1


@given(st.integers(0, 5000))
@settings(max_examples=30, deadline=None)
def test_queue_monotone(n):
    p = blue_waters()
    assert queue_time(p, n + 1) > queue_time(p, n) or n == 0 and queue_time(p, 1) > 0


def test_phase_cost_ladder_ordering():
    """Each model rung adds a nonnegative term."""
    rng = np.random.default_rng(0)
    n_procs, n_msgs = 64, 400
    src = rng.integers(0, n_procs, n_msgs)
    dst = (src + rng.integers(1, n_procs, n_msgs)) % n_procs
    size = rng.integers(8, 1 << 18, n_msgs).astype(float)
    loc = np.where(src // 16 == dst // 16, 1, 2)
    p = blue_waters()
    ladder = model_ladder(p, src, dst, size, loc, node_of=lambda q: q // 16,
                          n_torus_nodes=4, torus_ndim=3,
                          procs_per_torus_node=32, n_procs=n_procs)
    t_na = ladder["node_aware"].total
    t_q = ladder["queue"].total
    t_c = ladder["contention"].total
    assert t_q >= t_na
    assert t_c >= t_q
    assert ladder["queue"].queue > 0
    assert ladder["contention"].contention > 0


def test_phase_cost_empty():
    p = blue_waters()
    cb = phase_cost(p, [], [], [], [])
    assert cb.total == 0.0


# -------------------------------------------------------------- topology ----
def test_torus_coords_roundtrip():
    t = TorusTopology((4, 3, 5))
    ranks = np.arange(t.size)
    assert np.array_equal(t.rank(t.coords(ranks)), ranks)


def test_torus_hops_symmetric_and_triangle():
    t = TorusTopology((5, 5))
    rng = np.random.default_rng(1)
    for _ in range(20):
        a, b, c = rng.integers(0, t.size, 3)
        assert t.hops(a, b) == t.hops(b, a)
        assert t.hops(a, b) <= t.hops(a, c) + t.hops(c, b)
        assert t.hops(a, a) == 0


def test_torus_wraparound():
    t = TorusTopology((8,), wrap=True)
    assert t.hops(0, 7) == 1
    t2 = TorusTopology((8,), wrap=False)
    assert t2.hops(0, 7) == 7


def test_route_length_matches_hops():
    t = TorusTopology((4, 4), wrap=True)
    rng = np.random.default_rng(2)
    for _ in range(20):
        a, b = rng.integers(0, 16, 2)
        assert len(t.route_links(int(a), int(b))) == t.hops(a, b)


def test_route_links_conserve_bytes():
    """Sum of per-link bytes == sum over messages of size*hops."""
    t = TorusTopology((4, 4, 4), wrap=False)
    rng = np.random.default_rng(3)
    src = rng.integers(0, 64, 50)
    dst = rng.integers(0, 64, 50)
    size = rng.integers(1, 1000, 50).astype(float)
    acc = t.accumulate_link_bytes(src, dst, size)
    expect = float(sum(z * t.hops(a, b) for a, b, z in zip(src, dst, size)))
    assert sum(acc.values()) == pytest.approx(expect)


def test_cube_side_and_avg_hops():
    assert cube_side(64, 3) == 4
    assert cube_side(65, 3) == 5
    assert average_hops(1, 3) == 0.0
    # line of length 4: E|i-j| = (16-1)/12 = 1.25; 3 dims -> 3.75
    assert average_hops(64, 3) == pytest.approx(3.75)


def test_contention_ell_formula():
    # Eq. (7): ell = 2 h^3 b ppn
    h = average_hops(64, 3)
    assert contention_ell(64, 3, 100.0, 32) == pytest.approx(2 * h**3 * 100 * 32)


@given(st.integers(2, 512), st.sampled_from([2, 3]))
@settings(max_examples=40, deadline=None)
def test_avg_hops_bounded_by_diameter(n, d):
    c = cube_side(n, d)
    assert 0 <= average_hops(n, d) <= d * c
