"""Fingerprints, the crash-consistent ArenaCache, and drift repricing.

Pins DESIGN.md §13's cache contract: content-hash pattern fingerprints
(:func:`repro.comm.pattern_fingerprint` — deliberately order-sensitive),
multiset message diffs (:func:`repro.comm.message_delta`) feeding
:meth:`repro.comm.DeltaStack.apply`, atomic checksummed on-disk entries
that degrade to a rebuild under corruption / version skew / injected I/O
faults (never an error), ``snapshot()``/``restore()`` warm restarts, and
the :class:`repro.serve.StrategyService` integration: cache-hit verdicts
bit-identical to fresh sweeps, and :meth:`StrategyService.reprice` pricing
drift incrementally with a full-rebuild fallback.
"""
import glob
import json
import os

import numpy as np
import pytest

from repro.comm import (DeltaStack, faults, message_delta,
                        pattern_fingerprint, phase_fingerprint)
from repro.comm.health import get_health
from repro.net.machine import lassen_machine
from repro.serve import ArenaCache, StrategyService
from repro.serve.cache import CACHE_VERSION
from repro.sparse.partition import CommPattern

LASSEN = lassen_machine((2, 2, 2))


def _pattern(P, n=40, seed=0):
    rng = np.random.default_rng(seed)
    return CommPattern(src=rng.integers(0, P, n), dst=rng.integers(0, P, n),
                       size=rng.integers(64, 4096, n).astype(float),
                       n_procs=P)


def _drift(pat, keep, extra, seed=99):
    """A drifted copy of ``pat``: first ``keep`` messages plus ``extra``
    fresh ones."""
    rng = np.random.default_rng(seed)
    P = pat.n_procs
    return CommPattern(
        src=np.concatenate([pat.src[:keep], rng.integers(0, P, extra)]),
        dst=np.concatenate([pat.dst[:keep], rng.integers(0, P, extra)]),
        size=np.concatenate([pat.size[:keep],
                             rng.integers(64, 4096, extra).astype(float)]),
        n_procs=P)


# ============================================================ fingerprints ==
def test_fingerprint_is_content_hash():
    pat = _pattern(64)
    f = pattern_fingerprint(pat)
    assert f == pattern_fingerprint(_pattern(64))       # same content
    assert f == phase_fingerprint(pat.src, pat.dst, pat.size, pat.n_procs)
    assert len(f) == 64 and int(f, 16) >= 0             # hex sha256
    # bound phase hashes like its unbound pattern
    assert pattern_fingerprint(pat.bind(LASSEN)) == f


def test_fingerprint_is_order_sensitive():
    """Simulator verdicts depend on message order (seeded per-candidate
    arrival streams), so permuted phases must not share a cache entry."""
    pat = _pattern(64)
    perm = np.random.default_rng(1).permutation(pat.n_msgs)
    shuffled = CommPattern(src=pat.src[perm], dst=pat.dst[perm],
                           size=pat.size[perm], n_procs=pat.n_procs)
    assert pattern_fingerprint(shuffled) != pattern_fingerprint(pat)
    # any single-field change moves the hash too
    bigger = CommPattern(src=pat.src, dst=pat.dst, size=pat.size * 2.0,
                         n_procs=pat.n_procs)
    assert pattern_fingerprint(bigger) != pattern_fingerprint(pat)
    wider = CommPattern(src=pat.src, dst=pat.dst, size=pat.size,
                        n_procs=pat.n_procs + 1)
    assert pattern_fingerprint(wider) != pattern_fingerprint(pat)


def test_delta_stack_fingerprint_tracks_mutations():
    pat = _pattern(64)
    arena = DeltaStack.from_phases([pat.bind(LASSEN)])
    f0 = arena.fingerprint()
    assert f0 == DeltaStack.from_phases([pat.bind(LASSEN)]).fingerprint()
    mutated = arena.apply([0, 1], {0: ([3], [5], [256.0])})
    assert mutated.fingerprint() != f0
    ph = mutated.phases[0]
    assert mutated.fingerprint() == DeltaStack.from_phases(
        [ph]).fingerprint()


# =========================================================== message_delta ==
def test_message_delta_round_trips_through_apply():
    pat = _pattern(64, n=60)
    new = _drift(pat, keep=50, extra=7)
    removed, added = message_delta(pat, new)
    assert removed.size <= 10 and added[0].size <= 17
    arena = DeltaStack.from_phases([pat.bind(LASSEN)])
    mutated = arena.apply(removed, {0: added}, verify=True)  # bit-identity
    ph = mutated.phases[0]
    got = np.sort(np.rec.fromarrays([ph.src, ph.dst, ph.size]))
    want = np.sort(np.rec.fromarrays([new.src.astype(np.int64),
                                      new.dst.astype(np.int64),
                                      np.asarray(new.size, float)]))
    for f in ("f0", "f1", "f2"):
        assert np.array_equal(getattr(got, f), getattr(want, f))


def test_message_delta_identity_and_duplicates():
    pat = _pattern(64)
    removed, added = message_delta(pat, pat)
    assert removed.size == 0 and added[0].size == 0
    # duplicate triples match multiset-style: min(a, b) copies survive,
    # and removals take the LAST occurrences (earliest survivors keep slots)
    old = CommPattern(src=np.array([1, 1, 1, 2]), dst=np.array([2, 2, 2, 3]),
                      size=np.array([8.0, 8.0, 8.0, 4.0]), n_procs=8)
    new = CommPattern(src=np.array([1, 2, 2]), dst=np.array([2, 3, 3]),
                      size=np.array([8.0, 4.0, 4.0]), n_procs=8)
    removed, added = message_delta(old, new)
    assert removed.tolist() == [1, 2]           # last two (1->2) duplicates
    assert added[0].tolist() == [2] and added[2].tolist() == [4.0]


# ========================================================= ArenaCache core ==
def test_cache_memory_roundtrip_and_lru():
    c = ArenaCache(max_entries=2)
    assert c.get("a") is None and c.stats()["misses"] == 1
    c.put("a", {"x": 1})
    c.put("b", {"x": 2})
    c.put("c", {"x": 3})                        # evicts "a" (LRU)
    assert c.get("a") is None and c.get("b") == {"x": 2}
    assert c.n_entries == 2
    c.clear()
    assert c.n_entries == 0
    with pytest.raises(ValueError, match="max_entries"):
        ArenaCache(max_entries=0)


def test_cache_disk_persistence_is_atomic(tmp_path):
    d = str(tmp_path / "cache")
    c = ArenaCache(d)
    c.put("key", {"model": {"standard": 1.5}})
    # no temp droppings; exactly one checksummed entry file
    assert glob.glob(os.path.join(d, "*.tmp")) == []
    (fname,) = glob.glob(os.path.join(d, "*.json"))
    obj = json.loads(open(fname).read())
    assert obj["version"] == CACHE_VERSION and "checksum" in obj
    # a fresh cache (cold restart) reloads it
    assert ArenaCache(d).get("key") == {"model": {"standard": 1.5}}


@pytest.mark.parametrize("damage", ["truncate", "garbage", "skew", "tamper"])
def test_cache_rejects_damaged_entries_and_degrades(tmp_path, damage):
    d = str(tmp_path / "cache")
    ArenaCache(d).put("key", {"x": 1})
    (fname,) = glob.glob(os.path.join(d, "*.json"))
    text = open(fname).read()
    if damage == "truncate":
        open(fname, "w").write(text[: len(text) // 2])  # torn write
    elif damage == "garbage":
        open(fname, "w").write("\x00not json\x00")
    elif damage == "skew":
        obj = json.loads(text)
        obj["version"] = CACHE_VERSION + 1
        open(fname, "w").write(json.dumps(obj))
    else:                                       # tamper: body != checksum
        obj = json.loads(text)
        obj["body"] = {"x": 999}
        open(fname, "w").write(json.dumps(obj))
    events_before = get_health().n_events
    c = ArenaCache(d)
    assert c.get("key") is None                 # degrade to a miss
    assert c.stats()["rejected"] == 1
    assert get_health().n_events == events_before + 1
    assert get_health().events_for("cache", "serve.cache_read")


def test_cache_fault_sites(tmp_path):
    d = str(tmp_path / "cache")
    c = ArenaCache(d)
    with faults.inject("serve.cache_write", "raise") as spec:
        c.put("k", {"x": 1})
    assert spec.fired == 1 and c.stats()["write_errors"] == 1
    assert c.get("k") == {"x": 1}               # memory tier still serves
    assert ArenaCache(d).get("k") is None       # disk write was skipped
    c.put("k", {"x": 1})                        # clean write this time
    with faults.inject("serve.cache_read", "timeout") as spec:
        assert ArenaCache(d).get("k") is None   # injected I/O timeout
    assert spec.fired == 1
    # corrupt-mode poisons the written bytes; the next read's checksum
    # validation catches it and degrades to a rebuild
    with faults.inject("serve.cache_write", "corrupt"):
        c.put("k2", {"x": 2})
    fresh = ArenaCache(d)
    assert fresh.get("k2") is None and fresh.stats()["rejected"] == 1
    assert fresh.get("k") == {"x": 1}           # other entries unharmed


def test_cache_snapshot_restore_roundtrip():
    c = ArenaCache()
    c.put("a", {"x": 1})
    c.put("b", {"y": [1.5, 2.5]})
    snap = c.snapshot()
    assert snap["version"] == CACHE_VERSION
    warm = ArenaCache()
    assert warm.restore(snap) == 2
    assert warm.get("a") == {"x": 1} and warm.get("b") == {"y": [1.5, 2.5]}
    # damaged snapshots restore nothing, with a health event — never raise
    events_before = get_health().n_events
    bad = dict(snap, version=CACHE_VERSION + 1)
    assert ArenaCache().restore(bad) == 0
    assert ArenaCache().restore({"entries": {}}) == 0
    assert ArenaCache().restore("junk") == 0
    assert get_health().n_events == events_before + 3
    # snapshots are JSON-safe end to end
    assert ArenaCache().restore(json.loads(json.dumps(snap))) == 2


# ==================================================== service integration ==
def test_service_cache_hits_are_bit_identical():
    pat = _pattern(LASSEN.n_procs)
    svc = StrategyService(LASSEN, backend="numpy")
    cold = svc.query(pat)
    hit = svc.query(pat)
    assert not cold.cached and hit.cached and hit.ok
    assert hit.verdict.model == cold.verdict.model
    assert hit.verdict.sim == cold.verdict.sim
    assert hit.verdict.model_winner == cold.verdict.model_winner
    assert hit.verdict.sim_winner == cold.verdict.sim_winner


def test_service_cache_keys_include_the_configuration():
    pat = _pattern(LASSEN.n_procs)
    shared = ArenaCache()
    a = StrategyService(LASSEN, backend="numpy", seed=0, cache=shared)
    b = StrategyService(LASSEN, backend="numpy", seed=1, cache=shared)
    ra = a.query(pat)
    rb = b.query(pat)
    assert not rb.cached                        # different seed, no cross-hit
    assert a.query(pat).cached and b.query(pat).cached
    assert ra.ok and rb.ok


def test_service_warm_restart_agrees_with_cold(tmp_path):
    pat = _pattern(LASSEN.n_procs)
    disk = str(tmp_path / "cache")
    svc = StrategyService(LASSEN, backend="numpy", cache=ArenaCache(disk))
    cold = svc.query(pat)
    # warm path 1: snapshot/restore into a fresh memory-only service
    warm = StrategyService(LASSEN, backend="numpy")
    assert warm.restore(svc.snapshot()) >= 1
    r = warm.query(pat)
    assert r.cached and r.verdict.plans == {}   # restored: no plans
    assert r.verdict.model == cold.verdict.model
    assert r.verdict.sim == cold.verdict.sim
    # warm path 2: a fresh service over the same disk directory
    disk_warm = StrategyService(LASSEN, backend="numpy",
                                cache=ArenaCache(disk))
    r2 = disk_warm.query(pat)
    assert r2.cached and r2.verdict.sim == cold.verdict.sim


def test_service_survives_cache_corruption(tmp_path):
    pat = _pattern(LASSEN.n_procs)
    disk = str(tmp_path / "cache")
    svc = StrategyService(LASSEN, backend="numpy", cache=ArenaCache(disk))
    cold = svc.query(pat)
    for f in glob.glob(os.path.join(disk, "*.json")):
        open(f, "w").write("corrupted mid-run")
    fresh = StrategyService(LASSEN, backend="numpy", cache=ArenaCache(disk))
    rebuilt = fresh.query(pat)                  # rebuild, not an error
    assert rebuilt.ok and not rebuilt.cached
    assert rebuilt.verdict.sim == cold.verdict.sim
    assert get_health().events_for("cache", "serve.cache_read")


# ========================================================= drift repricing ==
def test_reprice_small_drift_is_incremental_and_exact():
    pat = _pattern(LASSEN.n_procs, n=60)
    new = _drift(pat, keep=55, extra=4)
    svc = StrategyService(LASSEN, backend="numpy")
    res = svc.reprice(pat, new)
    assert res.ok and not res.degraded
    # the verdict equals a from-scratch sweep of the canonical mutated
    # order (survivors in place, additions appended) — bit for bit
    arena = DeltaStack.from_phases([pat.bind(LASSEN)])
    removed, added = message_delta(arena.phases[0], new)
    canonical = arena.apply(removed, {0: added}).phases[0]
    ref = StrategyService(LASSEN, backend="numpy").query(canonical)
    assert res.verdict.model == ref.verdict.model
    assert res.verdict.sim == ref.verdict.sim
    # and a repeat reprice of the same drift hits the cache
    again = svc.reprice(pat, new)
    assert again.cached and again.verdict.sim == res.verdict.sim


def test_reprice_chains_across_generations():
    pat = _pattern(LASSEN.n_procs, n=60)
    svc = StrategyService(LASSEN, backend="numpy")
    prev = pat
    seen = set()
    for gen in range(3):
        new = _drift(prev, keep=prev.n_msgs - 4, extra=4, seed=100 + gen)
        res = svc.reprice(prev, new)
        assert res.ok, res.error
        key = (res.verdict.model_winner, res.verdict.sim_winner)
        seen.add(key)
        prev = new
    assert seen                                 # every generation answered


def test_reprice_large_drift_falls_back_to_rebuild():
    pat = _pattern(LASSEN.n_procs, n=60)
    totally_new = _pattern(LASSEN.n_procs, n=60, seed=123)
    svc = StrategyService(LASSEN, backend="numpy", drift_threshold=0.25)
    res = svc.reprice(pat, totally_new)
    assert res.ok
    # the rebuild path prices the new order itself, so the verdict equals
    # a plain query of the new pattern
    ref = StrategyService(LASSEN, backend="numpy").query(totally_new)
    assert res.verdict.sim == ref.verdict.sim


def test_reprice_rejects_invalid_and_never_raises():
    pat = _pattern(LASSEN.n_procs)
    bad = CommPattern(src=np.array([0, LASSEN.n_procs]),
                      dst=np.array([1, 0]), size=np.array([8.0, 8.0]),
                      n_procs=LASSEN.n_procs)
    svc = StrategyService(LASSEN, backend="numpy")
    res = svc.reprice(pat, bad)
    assert not res.ok and res.error is not None
    # an unusable `old` degrades to a full rebuild of `new`
    res2 = svc.reprice(bad, pat)
    assert res2.ok
