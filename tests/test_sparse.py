"""Tests for the sparse substrate: CSR ops, problems, partitions, AMG."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.sparse import (CSR, eye, poisson_3d, elasticity_like_3d,
                          build_hierarchy, vcycle, RowPartition,
                          spmv_comm_pattern, spgemm_comm_pattern)
from repro.sparse.partition import SPMV_ENTRY_BYTES, SPGEMM_NNZ_BYTES


def _random_csr(rng, n, m, density=0.1):
    nnz = max(1, int(n * m * density))
    rows = rng.integers(0, n, nnz)
    cols = rng.integers(0, m, nnz)
    vals = rng.standard_normal(nnz)
    return CSR.from_coo(rows, cols, vals, (n, m))


# ---------------------------------------------------------------- CSR -------
def test_from_coo_sums_duplicates():
    A = CSR.from_coo([0, 0, 1], [1, 1, 0], [2.0, 3.0, 1.0], (2, 2))
    assert A.to_dense().tolist() == [[0.0, 5.0], [1.0, 0.0]]


@given(st.integers(1, 30), st.integers(1, 30), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_spmv_matches_dense(n, m, seed):
    rng = np.random.default_rng(seed)
    A = _random_csr(rng, n, m, 0.2)
    x = rng.standard_normal(m)
    np.testing.assert_allclose(A.spmv(x), A.to_dense() @ x, rtol=1e-10, atol=1e-12)


@given(st.integers(1, 25), st.integers(1, 25), st.integers(1, 25),
       st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_spgemm_matches_dense(n, k, m, seed):
    rng = np.random.default_rng(seed)
    A = _random_csr(rng, n, k, 0.2)
    B = _random_csr(rng, k, m, 0.2)
    C = A.matmul(B, chunk_rows=7)
    np.testing.assert_allclose(C.to_dense(), A.to_dense() @ B.to_dense(),
                               rtol=1e-10, atol=1e-12)


def test_transpose_roundtrip():
    rng = np.random.default_rng(3)
    A = _random_csr(rng, 17, 11, 0.3)
    np.testing.assert_allclose(A.transpose().to_dense(), A.to_dense().T)


def test_diagonal_and_prune():
    A = CSR.from_coo([0, 0, 1], [0, 1, 1], [5.0, 1e-14, 2.0], (2, 2))
    np.testing.assert_allclose(A.diagonal(), [5.0, 2.0])
    assert A.prune(1e-12).nnz == 2


# ------------------------------------------------------------ problems ------
def test_poisson_symmetric_spd():
    A = poisson_3d(4)
    Ad = A.to_dense()
    np.testing.assert_allclose(Ad, Ad.T)
    assert np.linalg.eigvalsh(Ad).min() > 0


def test_elasticity_structure():
    A = elasticity_like_3d(5)
    assert A.shape == (375, 375)
    Ad = A.to_dense()
    np.testing.assert_allclose(Ad, Ad.T, atol=1e-12)
    assert np.linalg.eigvalsh(Ad).min() > 0
    # interior nodes: 27-point stencil x 3 dof = 81 nnz/row
    interior = 3 * (5 * 5 * 2 + 5 * 2 + 2)  # some interior dof index
    assert A.row_lengths().max() == 81


# ------------------------------------------------------------ partition -----
def test_balanced_partition():
    p = RowPartition.balanced(10, 3)
    assert list(np.diff(p.starts)) == [4, 3, 3]
    assert p.owner_of([0, 3, 4, 9]).tolist() == [0, 0, 1, 2]


def test_spmv_pattern_conservation():
    """Each off-process (row-block, column) need is counted exactly once."""
    A = poisson_3d(6)
    part = RowPartition.balanced(A.n_rows, 8)
    cp = spmv_comm_pattern(A, part)
    # manual count of distinct (requester, column) pairs
    rows = np.repeat(np.arange(A.n_rows), A.row_lengths())
    req = part.owner_of(rows)
    own = part.owner_of(A.indices)
    off = req != own
    expect = len(set(zip(req[off], A.indices[off]))) * SPMV_ENTRY_BYTES
    assert cp.total_bytes == expect
    assert (cp.src != cp.dst).all()


def test_spgemm_pattern_counts_remote_rows():
    A = poisson_3d(5)
    part = RowPartition.balanced(A.n_rows, 5)
    cp = spgemm_comm_pattern(A, A, part)
    rows = np.repeat(np.arange(A.n_rows), A.row_lengths())
    req = part.owner_of(rows)
    own = part.owner_of(A.indices)
    off = req != own
    pairs = set(zip(req[off], A.indices[off]))
    expect = sum(A.row_lengths()[c] for _, c in pairs) * SPGEMM_NNZ_BYTES
    assert cp.total_bytes == expect


def test_no_partition_no_comm():
    A = poisson_3d(4)
    cp = spmv_comm_pattern(A, RowPartition.balanced(A.n_rows, 1))
    assert cp.n_msgs == 0


# ------------------------------------------------------------ AMG -----------
def test_hierarchy_coarsens():
    A = poisson_3d(10)
    levels = build_hierarchy(A)
    sizes = [l.A.n_rows for l in levels]
    assert len(levels) >= 3
    assert all(sizes[i] > sizes[i + 1] for i in range(len(sizes) - 1))
    # coarse matrices get denser per row (the paper's premise)
    nnz_per_row = [l.A.nnz / l.A.n_rows for l in levels]
    assert nnz_per_row[1] > nnz_per_row[0]


def test_vcycle_converges_poisson():
    A = poisson_3d(8)
    levels = build_hierarchy(A)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(A.n_rows)
    x = np.zeros_like(b)
    for _ in range(20):
        x = vcycle(levels, b, x)
    assert np.linalg.norm(b - A.spmv(x)) < 1e-3 * np.linalg.norm(b)


def test_galerkin_is_pt_a_p():
    from repro.sparse.amg import galerkin
    rng = np.random.default_rng(1)
    A = _random_csr(rng, 12, 12, 0.3)
    P = _random_csr(rng, 12, 5, 0.4)
    Ac = galerkin(A, P)
    np.testing.assert_allclose(Ac.to_dense(),
                               P.to_dense().T @ A.to_dense() @ P.to_dense(),
                               rtol=1e-10, atol=1e-12)


def test_interpolation_partitions_unity_for_mmatrix():
    """For an M-matrix with zero row sums, direct interp rows sum to ~1."""
    from repro.sparse.amg import strength_matrix, cf_split, direct_interpolation
    n = 32
    # 1-D Laplacian without boundary elimination: rows sum to zero inside
    rows = list(range(n)) + list(range(n - 1)) + list(range(1, n))
    cols = list(range(n)) + list(range(1, n)) + list(range(n - 1))
    vals = [2.0] * n + [-1.0] * (2 * (n - 1))
    A = CSR.from_coo(rows, cols, vals, (n, n))
    S = strength_matrix(A, 0.25)
    state = cf_split(S)
    P = direct_interpolation(A, S, state)
    # interior F-points (zero row sum) must interpolate a partition of unity;
    # boundary rows have nonzero row sums and legitimately sum to less.
    fpts = [i for i in np.nonzero(state == -1)[0] if 0 < i < n - 1]
    row_sums = np.asarray([P.row(i)[1].sum() for i in fpts])
    assert row_sums.size > 0
    np.testing.assert_allclose(row_sums, 1.0, atol=1e-12)
