"""Docstring coverage for the public comm + machine surface.

The hetero PR grows the public API (device geometry, rails, staged
strategies); this test makes "ships documented" a contract, not a habit:
every public callable defined in the :mod:`repro.comm` modules and in
:mod:`repro.net.machine` must carry a docstring that *mentions each of its
parameters by name* — a reader should never have to reverse-engineer an
argument from the implementation.

Scope rules: public = not underscore-prefixed and defined in the module
under test (re-exports are covered where they are defined).  For classes,
the class itself must have a docstring and each public method (including
classmethods/staticmethods) is checked like a function; properties,
dataclass machinery and dunders are skipped.  A parameter counts as
mentioned if its name appears as a word anywhere in the callable's — or,
for ``__init__``-less dataclasses, the owning class's — docstring.
"""
import inspect
import re

import pytest

import repro.comm.delta
import repro.comm.faults
import repro.comm.guard
import repro.comm.health
import repro.comm.phase
import repro.comm.primitives
import repro.comm.stack
import repro.comm.strategies
import repro.exec.calibrate
import repro.exec.lower
import repro.exec.measure
import repro.exec.plan
import repro.exec.presets
import repro.exec.reference
import repro.net.machine
import repro.serve.admission
import repro.serve.cache
import repro.serve.strategy
import repro.workloads.moe
import repro.workloads.pipe
import repro.workloads.registry
import repro.workloads.tp

MODULES = [repro.comm.phase, repro.comm.primitives, repro.comm.stack,
           repro.comm.delta, repro.comm.strategies, repro.net.machine,
           repro.workloads.moe, repro.workloads.tp, repro.workloads.pipe,
           repro.workloads.registry, repro.comm.guard, repro.comm.faults,
           repro.comm.health, repro.serve.strategy,
           repro.serve.admission, repro.serve.cache,
           repro.exec.plan, repro.exec.reference, repro.exec.lower,
           repro.exec.measure, repro.exec.calibrate, repro.exec.presets]

#: Parameter names that need no mention: conventions, not API.
IGNORED_PARAMS = {"self", "cls", "args", "kwargs", "kw"}


def _methods_of(klass):
    for name, member in vars(klass).items():
        if name.startswith("_"):
            continue
        if isinstance(member, (classmethod, staticmethod)):
            yield name, member.__func__, klass
        elif inspect.isfunction(member):
            yield name, member, klass


def _public_callables():
    out = []
    for mod in MODULES:
        for name, obj in sorted(vars(mod).items()):
            if name.startswith("_") or getattr(obj, "__module__",
                                               None) != mod.__name__:
                continue
            if inspect.isfunction(obj):
                out.append((f"{mod.__name__}.{name}", obj, None))
            elif inspect.isclass(obj):
                out.append((f"{mod.__name__}.{name}", obj, None))
                for mname, fn, klass in _methods_of(obj):
                    out.append((f"{mod.__name__}.{name}.{mname}", fn, klass))
    return out


CALLABLES = _public_callables()
assert len(CALLABLES) > 40            # the surface is real, not a no-op scan


def _mentions(doc: str, param: str) -> bool:
    return re.search(rf"\b{re.escape(param)}\b", doc) is not None


@pytest.mark.parametrize("qualname, obj, klass",
                         CALLABLES, ids=[c[0] for c in CALLABLES])
def test_public_callable_documents_its_parameters(qualname, obj, klass):
    doc = inspect.getdoc(obj)
    assert doc, f"{qualname} has no docstring"
    if inspect.isclass(obj):
        return                        # methods are checked individually
    try:
        sig = inspect.signature(obj)
    except (TypeError, ValueError):   # builtins/descriptors: nothing to check
        return
    class_doc = inspect.getdoc(klass) or "" if klass is not None else ""
    missing = [p for p in sig.parameters
               if p not in IGNORED_PARAMS
               and not _mentions(doc, p) and not _mentions(class_doc, p)]
    assert not missing, \
        f"{qualname} docstring does not mention parameter(s) {missing}"
