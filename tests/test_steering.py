"""Hetero partition steering, judged end-to-end (carried-forward ROADMAP
satellite): ``optimize_partition`` with ``rerun_strategies=True`` on a
heterogeneous machine re-judges every accepted move with the full strategy
sweep, and the simulator's verdict over that accepted-move sequence never
degrades — the model-guided moves are vindicated by the ground-truth
judge, not just by the model that proposed them.

The configuration (skewed initial partition, step=32, seed=0) is a pinned
golden: it accepts several moves on the Lassen-like preset, so the
monotonicity claim is exercised on real re-judgments rather than a
trivially empty verdict list.
"""
import numpy as np
import pytest

from repro.comm.strategies import best_strategy, strategies_for
from repro.net.machine import lassen_machine
from repro.sparse import poisson_3d
from repro.sparse.optimize import optimize_partition
from repro.sparse.partition import RowPartition, spmv_comm_pattern

REL_TOL = 1e-9


@pytest.fixture(scope="module")
def steered():
    machine = lassen_machine((2, 2, 2))
    A = poisson_3d(10)
    P = 16
    weights = np.linspace(3.0, 1.0, P)
    weights /= weights.sum()
    starts = np.concatenate(
        [[0], np.cumsum(np.round(weights * A.n_rows))]).astype(np.int64)
    starts[-1] = A.n_rows
    part = RowPartition(starts)
    result = optimize_partition(A, machine, part=part, moves=128, step=32,
                                seed=0, rerun_strategies=True)
    return machine, A, part, result


def test_accepted_moves_are_rejudged_by_the_full_hetero_sweep(steered):
    machine, _, _, result = steered
    assert result.n_accepted >= 2           # the pin is not vacuous
    assert len(result.verdicts) == result.n_accepted
    want = set(strategies_for(machine))
    assert "host_staged" in want and "device_direct" in want
    for _, verdict in result.verdicts:
        assert set(verdict.sim) == want     # judged by the hetero sweep
        assert set(verdict.model) == want


def test_rejudging_never_degrades_the_simulator_verdict(steered):
    _, _, _, result = steered
    best_sim = [min(v.sim.values()) for _, v in result.verdicts]
    for earlier, later in zip(best_sim, best_sim[1:]):
        assert later <= earlier * (1.0 + REL_TOL)


def test_final_partition_beats_initial_under_the_simulator(steered):
    machine, A, part, result = steered
    initial = best_strategy(spmv_comm_pattern(A, part).bind(machine), seed=0)
    final = best_strategy(result.pattern.bind(machine), seed=0)
    assert (min(final.sim.values())
            <= min(initial.sim.values()) * (1.0 + REL_TOL))
    # and the model's accepted-move trace really did lower the model cost
    assert result.cost <= result.initial_cost
