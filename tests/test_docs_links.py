"""Documentation checks: every internal link in the markdown docs resolves.

Covers relative file links (``[x](DESIGN.md)``, ``[x](docs/api.md)``) and
GitHub-style heading anchors (``[x](DESIGN.md#7-...)``) in README.md,
DESIGN.md and docs/*.md.  External (http/https) links are not fetched.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOCS = sorted([ROOT / "README.md", ROOT / "DESIGN.md",
               *(ROOT / "docs").glob("*.md")])

_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, strip punctuation, spaces->dashes."""
    h = re.sub(r"`([^`]*)`", r"\1", heading)      # unwrap code spans
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)                # drop punctuation (incl. §)
    return h.replace(" ", "-")


def _anchors(md: Path) -> set:
    return {_github_slug(m.group(1)) for m in _HEADING.finditer(md.read_text())}


def _links(md: Path):
    for m in _LINK.finditer(md.read_text()):
        target = m.group(1)
        if not target.startswith(("http://", "https://", "mailto:")):
            yield target


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: str(p.relative_to(ROOT)))
def test_internal_links_resolve(doc):
    assert doc.exists()
    for target in _links(doc):
        path_part, _, anchor = target.partition("#")
        dest = doc if not path_part else (doc.parent / path_part).resolve()
        assert dest.exists(), f"{doc.name}: broken link -> {target}"
        if anchor:
            assert anchor in _anchors(dest), \
                f"{doc.name}: dangling anchor -> {target}"


def test_docs_exist():
    for p in (ROOT / "README.md", ROOT / "DESIGN.md", ROOT / "docs" / "api.md"):
        assert p.exists(), p
