"""The heterogeneous-node machine model (Lockhart et al. 2022 scenario).

Four layers of certification:

* **geometry** — ``MachineSpec.locality`` classifies intra-device /
  cross-device / network pairs per the machine's configured network path,
  and the device maps validate their shape invariants;
* **rails** — multi-rail injection divides a node's active senders across
  its NICs (``ceil(ppn / n_rails)`` contend per rail), with ``rails=1``
  bit-identical to the pre-rail formula;
* **strategies** — the GPU-aware rewrites conserve payload, keep every
  phase role in its locality lane (copies are self-messages at the ``h2d``
  class, staged inter traffic carries the ``host_staged`` override), and
  are gated to machines that support them;
* **crossover** — on the Lassen-like preset ``device_direct`` wins small
  message counts and ``host_staged`` wins large ones, with the simulator
  agreeing with the model at both ends (the acceptance contract), while the
  Frontier-like preset — NICs on the GPUs — never leaves the direct path.
"""
import numpy as np
import pytest

from repro.comm import (CommPhase, DeltaStack, GPU_STRATEGIES, PhaseStack,
                        STRATEGIES, best_strategy, delivered_payload,
                        injected_payload, rewrite, strategies_for,
                        transport_times)
from repro.core import lassen, phase_cost_many
from repro.core.models import message_time, phase_cost_phase
from repro.net import (blue_waters_machine, frontier_machine, lassen_machine,
                       tpu_v5e_machine, simulate_many)

LASSEN = lassen_machine((2, 2, 2))
FRONTIER = frontier_machine((2, 2, 1))
HETERO = [LASSEN, FRONTIER]


def _random_phase(machine, n, seed, size_lo=256, size_hi=8192):
    rng = np.random.default_rng(seed)
    P = machine.n_procs
    src = rng.integers(0, P, n)
    dst = (src + rng.integers(1, P, n)) % P
    size = rng.integers(size_lo, size_hi, n).astype(float)
    return CommPhase.build(machine, src, dst, size, n_procs=P)


# ------------------------------------------------------ geometry ------------
def test_locality_classifies_device_pairs():
    m = LASSEN                      # 4 devices x 2 ranks, 8 ppn
    names = m.params.locality_names
    assert names.index("intra_device") == 0
    assert names.index("cross_device") == 1
    # rank pairs: same device, same node cross-device, cross-node
    a = np.array([0, 0, 0, 8])
    b = np.array([1, 2, 9, 17])
    want = np.array([0,                          # ranks 0,1 share device 0
                     1,                          # rank 2 is device 1
                     names.index("device_direct"),   # nodes 0 vs 1
                     names.index("device_direct")])  # nodes 1 vs 2
    np.testing.assert_array_equal(m.locality(a, b), want)
    assert np.array_equal(m.device_of(np.array([0, 1, 2, 9])),
                          np.array([0, 0, 1, 4]))


def test_locality_honors_network_path():
    staged = lassen_machine((2, 1, 1), network_path="host_staged")
    direct = lassen_machine((2, 1, 1), network_path="device_direct")
    hs = staged.params.class_index("host_staged")
    dd = direct.params.class_index("device_direct")
    assert staged.locality([0], [8])[0] == hs
    assert direct.locality([0], [8])[0] == dd
    # both classes traverse the network
    nl = staged.params.network_locality
    assert hs >= nl and dd >= nl


def test_machine_spec_validates_device_shape():
    import dataclasses
    with pytest.raises(ValueError, match="procs_per_device >= 1"):
        dataclasses.replace(LASSEN, procs_per_device=0)
    with pytest.raises(ValueError, match="must equal"):
        dataclasses.replace(LASSEN, procs_per_node=10)
    with pytest.raises(ValueError, match="no device endpoints"):
        blue_waters_machine((2, 1, 1)).device_of([0])


def test_class_index_and_has_class():
    p = lassen()
    assert p.locality_names[p.class_index("h2d")] == "h2d"
    assert p.has_class("device_direct")
    assert not p.has_class("inter_node")
    with pytest.raises(ValueError, match="not a locality class"):
        p.class_index("inter_node")


def test_loc_override_validates_and_broadcasts():
    scalar = CommPhase.build(LASSEN, [0, 1], [9, 10], [64.0, 64.0],
                             n_procs=64, loc=2)
    np.testing.assert_array_equal(scalar.loc, [2, 2])
    assert not scalar.is_net.any()            # h2d is below network_locality
    with pytest.raises(ValueError, match="loc override out of range"):
        CommPhase.build(LASSEN, [0], [9], [64.0], n_procs=64, loc=7)


# ------------------------------------------------------ rails ---------------
def test_rails_divide_active_senders_per_nic():
    alpha, Rb, RN = 1e-6, 1e9, 4e9
    size = np.full(8, 1 << 20, dtype=float)
    ppn = np.full(8, 8.0)
    is_net = np.ones(8, dtype=bool)
    one = transport_times(size, alpha, Rb, RN, ppn, is_net)
    two = transport_times(size, alpha, Rb, RN, ppn, is_net, rails=2)
    # 8 senders on 1 rail: eff=8, rate=min(4e9, 8e9); on 2 rails: eff=4
    np.testing.assert_allclose(one, alpha + 8 * size / 4e9)
    np.testing.assert_allclose(two, alpha + 4 * size / 4e9)
    # ceil division: 3 senders on 2 rails -> 2 contend on the fuller NIC
    three = transport_times(size, alpha, Rb, RN, np.full(8, 3.0), is_net,
                            rails=2)
    np.testing.assert_allclose(three, alpha + 2 * size / np.minimum(4e9, 2e9))


def test_rails_one_is_bit_identical_to_prerail_formula():
    rng = np.random.default_rng(3)
    size = rng.integers(8, 1 << 20, 100).astype(float)
    ppn = rng.integers(1, 16, 100).astype(float)
    is_net = rng.random(100) < 0.7
    alpha = rng.random(100) * 1e-6
    Rb = rng.random(100) * 1e10 + 1e8
    RN = np.where(rng.random(100) < 0.5, np.inf, 6.6e9)
    want_eff = np.where(is_net, np.maximum(ppn, 1.0), 1.0)
    want = alpha + want_eff * size / np.minimum(RN, want_eff * Rb)
    got = transport_times(size, alpha, Rb, RN, ppn, is_net, rails=1)
    assert np.array_equal(got, want)


def test_model_ladder_prices_rails_on_lassen():
    """message_time on a hetero machine uses ceil(ppn / n_rails) senders."""
    m = LASSEN
    p = m.params
    dd = p.class_index("device_direct")
    size = np.array([1 << 20], dtype=float)
    t = message_time(p, size, np.array([dd]), ppn=np.array([8.0]))
    eff = np.ceil(8.0 / p.n_rails)            # dual rail -> 4 per NIC
    proto = p.protocol_of(size)[0]
    want = p.alpha[dd, proto] + eff * size[0] / min(p.RN[dd, proto],
                                                    eff * p.Rb[dd, proto])
    assert t[0] == pytest.approx(want, rel=1e-12)


# ------------------------------------------------------ class axis ----------
@pytest.mark.parametrize("machine", HETERO, ids=lambda m: m.name)
def test_stacked_class_bytes_bit_identical(machine):
    phases = [_random_phase(machine, n, 11 + n) for n in (0, 1, 200, 40)]
    # include override classes via a staged rewrite's phases
    phases += list(rewrite(_random_phase(machine, 150, 5),
                           "host_staged").phases)
    stack = PhaseStack.build(phases)
    got = stack.class_bytes()
    assert got.shape == (len(phases), machine.params.n_locality)
    for i, ph in enumerate(phases):
        assert np.array_equal(got[i], ph.class_bytes())


# ------------------------------------------------------ strategies ----------
@pytest.mark.parametrize("machine", HETERO, ids=lambda m: m.name)
@pytest.mark.parametrize("strategy", GPU_STRATEGIES)
@pytest.mark.parametrize("seed", [0, 1])
def test_gpu_strategy_payload_conservation(machine, strategy, seed):
    phase = _random_phase(machine, 400, seed)
    plan = rewrite(phase, strategy)
    P = phase.n_procs
    np.testing.assert_allclose(
        injected_payload(plan),
        np.bincount(phase.src, weights=phase.size, minlength=P))
    np.testing.assert_allclose(
        delivered_payload(plan),
        np.bincount(phase.dst, weights=phase.size, minlength=P))


@pytest.mark.parametrize("machine", HETERO, ids=lambda m: m.name)
def test_host_staged_roles_stay_in_their_lane(machine):
    p = machine.params
    plan = rewrite(_random_phase(machine, 500, 7), "host_staged")
    assert "d2h" in plan.roles and "h2d" in plan.roles
    for ph, role in zip(plan.phases, plan.roles):
        dst_node = np.asarray(machine.node_of(ph.dst))
        if role in ("d2h", "h2d"):            # coalesced self-copies
            assert np.array_equal(ph.src, ph.dst)
            assert (ph.loc == p.class_index("h2d")).all()
            assert not ph.is_net.any()
        elif role == "inter":                 # staged network path, cross-node
            assert (ph.loc == p.class_index("host_staged")).all()
            assert ph.is_net.all()
            assert (ph.send_node != dst_node).all()
        else:                                 # local / gather / scatter
            assert (ph.send_node == dst_node).all()


@pytest.mark.parametrize("machine", HETERO, ids=lambda m: m.name)
def test_device_direct_roles_stay_in_their_lane(machine):
    p = machine.params
    plan = rewrite(_random_phase(machine, 500, 9), "device_direct")
    assert plan.phase_by_role("inter") is not None
    for ph, role in zip(plan.phases, plan.roles):
        if role == "inter":
            assert (ph.loc == p.class_index("device_direct")).all()
            assert (ph.send_node
                    != np.asarray(machine.node_of(ph.dst))).all()
            # leaders inject: one sender per device, spread across the node
            assert (ph.src % machine.procs_per_device == 0).all()
            assert (ph.dst % machine.procs_per_device == 0).all()
        elif role in ("gather", "scatter"):   # never leave the device
            assert np.array_equal(np.asarray(machine.device_of(ph.src)),
                                  np.asarray(machine.device_of(ph.dst)))


def test_device_direct_gather_empty_with_one_rank_per_device():
    """On Frontier every rank is its own device leader: no gather/scatter."""
    plan = rewrite(_random_phase(FRONTIER, 300, 13), "device_direct")
    assert "gather" not in plan.roles
    assert "scatter" not in plan.roles


def test_gpu_strategies_gated_to_hetero_machines():
    bw_phase = CommPhase.build(blue_waters_machine((2, 1, 1)),
                               [0], [16], [1024.0], n_procs=32)
    for strategy in GPU_STRATEGIES:
        with pytest.raises(ValueError, match="heterogeneous machine"):
            rewrite(bw_phase, strategy)
    assert strategies_for(blue_waters_machine((2, 1, 1))) == STRATEGIES
    assert strategies_for(tpu_v5e_machine((4, 4))) == STRATEGIES
    for m in HETERO:
        assert strategies_for(m) == STRATEGIES + GPU_STRATEGIES


def test_best_strategy_sweeps_gpu_strategies_by_default():
    v = best_strategy(_random_phase(LASSEN, 200, 17), seed=0)
    assert set(v.model) == set(STRATEGIES + GPU_STRATEGIES)
    assert set(v.sim) == set(v.model)


def test_intra_node_phase_degenerates_to_identity():
    src = np.arange(0, 4)
    dst = src + 4                     # same node (8 ppn), other devices
    phase = CommPhase.build(LASSEN, src, dst, np.full(4, 64.0), n_procs=64)
    for s in GPU_STRATEGIES:
        plan = rewrite(phase, s)
        assert plan.roles == ("standard",)
        assert plan.phases == (phase,)


def test_pingpong_pair_demands_the_configured_network_path():
    """Asking for a network-path sweep the machine is not configured with
    must raise, not silently measure the other path's rate class."""
    from repro.net.pingpong import _pair_for, pingpong_sweep
    staged = lassen_machine((2, 1, 1), network_path="host_staged")
    assert _pair_for(staged, "host_staged") == (0, 8)
    with pytest.raises(ValueError, match="network path"):
        _pair_for(staged, "device_direct")
    with pytest.raises(ValueError, match="network path"):
        pingpong_sweep(LASSEN, "host_staged", [1024], reps=1, noise=0.0)
    with pytest.raises(ValueError, match="not a locality class"):
        _pair_for(blue_waters_machine((2, 1, 1)), "host_staged")
    with pytest.raises(ValueError, match="intra-device"):
        _pair_for(FRONTIER, "intra_device")     # 1 rank per GCD
    # a staged-path sweep on the right preset actually runs
    times = pingpong_sweep(staged, "host_staged", [256, 65536], reps=1,
                           noise=0.0)
    assert (times > 0).all()


# ------------------------------------------------------ arenas --------------
def test_delta_stack_rejects_loc_overridden_phases():
    plan = rewrite(_random_phase(LASSEN, 200, 19), "host_staged")
    staged = plan.phase_by_role("inter")
    with pytest.raises(ValueError, match="machine-classified"):
        DeltaStack.from_phases([staged])


@pytest.mark.parametrize("machine", HETERO, ids=lambda m: m.name)
def test_overridden_phases_ride_the_stack_bit_identically(machine):
    """Staged phases (explicit class overrides) obey the stack contract."""
    plan = rewrite(_random_phase(machine, 400, 21), "host_staged")
    phases = list(plan.phases)
    got = phase_cost_many(PhaseStack.build(phases))
    want = [phase_cost_phase(ph) for ph in phases]
    assert got == want


# ------------------------------------------------------ the crossover -------
def _verdict_at(machine, n, seed=42):
    phase = _random_phase(machine, n, seed)
    return best_strategy(phase, seed=0, strategies=GPU_STRATEGIES)


def test_lassen_host_staged_device_direct_crossover():
    """The acceptance contract: device_direct wins small message counts (no
    copy overhead), host_staged wins large ones (multi-rail host NIC
    bandwidth beats the GPUDirect read rate), and the simulator agrees with
    the model at both ends of the sweep."""
    counts = (8, 32, 128, 512, 2048)
    verdicts = [_verdict_at(LASSEN, n) for n in counts]
    sim_winners = [v.sim_winner for v in verdicts]
    # both strategies win somewhere, direct -> staged as counts grow
    assert sim_winners[0] == "device_direct"
    assert sim_winners[-1] == "host_staged"
    flips = sum(a != b for a, b in zip(sim_winners, sim_winners[1:]))
    assert flips == 1                 # one clean crossover, no flapping
    for v in verdicts:                # the model predicts every verdict
        assert v.agree
    # real margins at the endpoints, on both sides of the inferential gap
    first, last = verdicts[0], verdicts[-1]
    assert first.sim["device_direct"] < 0.8 * first.sim["host_staged"]
    assert first.model["device_direct"] < 0.8 * first.model["host_staged"]
    assert last.sim["host_staged"] < 0.9 * last.sim["device_direct"]
    # the closed-form model compresses the margin (gamma n^2 upper bound on
    # both candidates) but must still rank staged clearly ahead
    assert last.model["host_staged"] < 0.95 * last.model["device_direct"]


def test_frontier_stays_on_the_direct_path():
    """NICs hang off the GPUs on the Frontier-like preset: staging through
    host never wins, small or large."""
    for n in (16, 1024):
        v = _verdict_at(FRONTIER, n)
        assert v.sim_winner == "device_direct"
        assert v.agree


def test_simulator_prices_staged_sequences():
    """End-to-end: a staged plan's phases simulate without special cases —
    copies contribute transport but neither network bytes nor contention."""
    plan = rewrite(_random_phase(LASSEN, 300, 23), "host_staged")
    results = simulate_many(list(plan.phases))
    for res, role in zip(results, plan.roles):
        if role in ("d2h", "h2d"):
            assert res.total_net_bytes == 0.0
            assert res.contention == 0.0
            assert res.transport > 0.0


def test_device_direct_leader_queue_is_device_local():
    """Golden pin: device_direct leaders see *device-local* in-degrees.

    On Lassen (ppn=8, 4 devices x 2 ranks) a dense node0 -> node1 exchange
    rewritten to device_direct must give the gather leader an in-degree of
    procs_per_device - 1 = 1 (its device sibling), NOT procs_per_node - 1 = 7,
    and the inter leader an in-degree equal to devices_per_node = 4 (one
    coalesced message per sending device).  The queue ladder then prices the
    leader at gamma * n^2 with that device-local n."""
    machine = lassen_machine()
    p = machine.params
    ppn = machine.procs_per_node
    ppd = machine.procs_per_device
    ndev = machine.devices_per_node
    rr = np.arange(ppn)
    src = np.repeat(rr, ppn)
    dst = ppn + np.tile(rr, ppn)                   # every node-0 rank -> node 1
    phase = CommPhase.build(machine, src, dst,
                            np.full(src.size, 4096.0), n_procs=2 * ppn)
    plan = rewrite(phase, "device_direct")

    gather = plan.phase_by_role("gather")
    inter = plan.phase_by_role("inter")
    scatter = plan.phase_by_role("scatter")
    assert gather.max_msgs_per_proc() == ppd - 1 == 1
    assert gather.max_msgs_per_proc() < ppn - 1     # never the node-wide fan-in
    assert inter.max_msgs_per_proc() == ndev == 4
    assert scatter.max_msgs_per_proc() == ppd - 1

    # gamma * n^2 with the device-local n, exactly
    assert phase_cost_phase(gather, level="queue").queue == \
        pytest.approx(p.gamma * (ppd - 1) ** 2, rel=1e-12)
    assert phase_cost_phase(inter, level="queue").queue == \
        pytest.approx(p.gamma * ndev ** 2, rel=1e-12)
