"""The paper's workflow, end to end: build an AMG hierarchy, extract the
per-level communication patterns, price them with the model ladder, and
compare against the mechanistic simulator ("measured").

    PYTHONPATH=src python examples/comm_model_amg.py
"""
import numpy as np

from repro.core import model_ladder, MODEL_LEVELS
from repro.core.report import format_table
from repro.net import blue_waters_machine, simulate_phase
from repro.sparse import (elasticity_like_3d, build_hierarchy, RowPartition,
                          spmv_comm_pattern)


def main():
    A = elasticity_like_3d(12)
    levels = build_hierarchy(A)
    machine = blue_waters_machine((4, 2, 2))
    print(f"elasticity-like operator: {A.shape[0]} dof, {A.nnz} nnz, "
          f"{len(levels)} AMG levels\n")

    rows = []
    rng = np.random.default_rng(0)
    for li, lvl in enumerate(levels):
        n_procs = min(512, max(lvl.A.n_rows // 2, 2))
        part = RowPartition.balanced(lvl.A.n_rows, n_procs)
        cp = spmv_comm_pattern(lvl.A, part)
        if cp.n_msgs == 0:
            continue
        arrival = {int(p): rng.permutation(np.nonzero(cp.dst == p)[0])
                   for p in np.unique(cp.dst)}
        meas = simulate_phase(machine, cp.src, cp.dst, cp.size,
                              arrival_order=arrival).time
        lad = model_ladder(machine.params, cp.src, cp.dst, cp.size,
                           machine.locality(cp.src, cp.dst),
                           node_of=machine.node_of,
                           n_torus_nodes=machine.torus.size,
                           torus_ndim=3,
                           procs_per_torus_node=machine.procs_per_torus_node,
                           n_procs=cp.n_procs)
        row = {"level": li, "rows": lvl.A.n_rows,
               "msgs/proc": cp.max_msgs_per_proc(), "measured": meas}
        for lvlname in MODEL_LEVELS:
            row[lvlname] = lad[lvlname].total
        rows.append(row)
    print(format_table(rows, title="SpMV per AMG level: measured vs model "
                                   "ladder (seconds)"))
    print("\nReading: 'node_aware' (transport only) under-predicts the "
          "message-heavy levels;\n'queue' adds the paper's gamma*n^2 term; "
          "'contention' brackets from above (Sec. 5).")


if __name__ == "__main__":
    main()
