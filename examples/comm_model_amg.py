"""The paper's workflow, end to end: build an AMG hierarchy, extract the
per-level communication patterns, bind them to a machine as CommPhases, price
the whole hierarchy with the model ladder in one batched call, and compare
against the mechanistic simulator ("measured").

Then the node-aware strategy sweep (the NAPSpMV question): for every level,
rewrite the halo exchange as standard / two_step / three_step sequences,
let the model ladder predict the winner, and check the simulator's verdict.

Then the model *steers*: a boundary-shift local search per level
(optimize_partition), with every candidate priced incrementally through the
DeltaStack arena instead of rebuilt from scratch.

Finally the heterogeneous-node question (Lockhart et al. 2022): on a GPU
machine, should aggregated traffic stage through host memory and the host
NICs (``host_staged``) or go GPU-NIC direct (``device_direct``)?  The same
model/simulator pair sweeps the two paths on the Lassen-like preset and
surfaces the crossover as message counts grow.

    PYTHONPATH=src python examples/comm_model_amg.py
"""
import numpy as np

from repro.comm import CommPhase, GPU_STRATEGIES, STRATEGIES, best_strategy
from repro.core import model_ladder_many, MODEL_LEVELS
from repro.core.report import format_table
from repro.net import (blue_waters_machine, frontier_machine, lassen_machine,
                       simulate_many)
from repro.sparse import (elasticity_like_3d, build_hierarchy, RowPartition,
                          optimize_partition, spmv_comm_pattern)


def main():
    A = elasticity_like_3d(12)
    levels = build_hierarchy(A)
    machine = blue_waters_machine((4, 2, 2))
    print(f"elasticity-like operator: {A.shape[0]} dof, {A.nnz} nnz, "
          f"{len(levels)} AMG levels\n")

    # one CommPhase per level: locality / protocol / routing endpoints /
    # active-sender counts are computed once and shared by both sides
    tagged = []
    for li, lvl in enumerate(levels):
        n_procs = min(512, max(lvl.A.n_rows // 2, 2))
        part = RowPartition.balanced(lvl.A.n_rows, n_procs)
        cp = spmv_comm_pattern(lvl.A, part)
        if cp.n_msgs == 0:
            continue
        tagged.append((li, lvl, cp.bind(machine)))
    phases = [ph for _, _, ph in tagged]

    rng = np.random.default_rng(0)
    arrivals = [ph.random_arrival_order(rng) for ph in phases]
    results = simulate_many(phases, arrival_orders=arrivals)
    ladders = model_ladder_many(phases)

    rows = []
    for (li, lvl, ph), res, lad in zip(tagged, results, ladders):
        row = {"level": li, "rows": lvl.A.n_rows,
               "msgs/proc": ph.max_msgs_per_proc(), "measured": res.time}
        for lvlname in MODEL_LEVELS:
            row[lvlname] = lad[lvlname].total
        rows.append(row)
    print(format_table(rows, title="SpMV per AMG level: measured vs model "
                                   "ladder (seconds)"))
    print("\nReading: 'node_aware' (transport only) under-predicts the "
          "message-heavy levels;\n'queue' adds the paper's gamma*n^2 term; "
          "'contention' brackets from above (Sec. 5).")

    # -- node-aware strategy sweep: which levels should aggregate? ----------
    srows = []
    for (li, lvl, ph) in tagged:
        v = best_strategy(ph, seed=0)
        row = {"level": li, "msgs": ph.n_msgs,
               "inter_msgs": v.plans["standard"].inter_node_msgs}
        for s in STRATEGIES:
            row[f"model_{s}"] = v.model[s]
            row[f"sim_{s}"] = v.sim[s]
        row["model_pick"] = v.model_winner
        row["sim_pick"] = v.sim_winner
        row["agree"] = "yes" if v.agree else "NO"
        srows.append(row)
    print()
    print(format_table(
        srows,
        columns=["level", "msgs", "inter_msgs",
                 *(f"model_{s}" for s in STRATEGIES),
                 *(f"sim_{s}" for s in STRATEGIES),
                 "model_pick", "sim_pick", "agree"],
        title="Per-level strategy sweep: model-predicted winner vs simulator "
              "verdict (seconds)"))
    flipped = [r["level"] for r in srows if r["sim_pick"] != "standard"]
    print(f"\nLevels where aggregation wins (as in the NAPSpMV results): "
          f"{flipped or 'none'}.")
    print("Message-heavy levels flip to an aggregated strategy (fewer, "
          "larger inter-node\nmessages: less alpha, less queue search, "
          "rendezvous bandwidth); coarse levels\nwith little traffic keep "
          "the standard strategy.")

    # -- model-guided partition optimization (the DeltaStack scenario) ------
    orows = []
    for (li, lvl, ph) in tagged:
        res = optimize_partition(lvl.A, machine, n_procs=ph.n_procs,
                                 moves=48, seed=0)
        orows.append({"level": li, "procs": ph.n_procs,
                      "cost_before": res.initial_cost,
                      "cost_after": res.cost,
                      "accepted": f"{res.n_accepted}/{len(res.moves)}",
                      "improvement": f"{res.improvement:.1%}"})
    print()
    print(format_table(
        orows,
        title="Model-guided partition search per level: 48 boundary-shift "
              "moves, each candidate\npriced incrementally (DeltaStack) at "
              "the 'contention' ladder level (seconds)"))
    print("\nEvery candidate costs O(changed messages) instead of a full "
          "pattern-extraction\n+ rebind + re-price pass; accepted moves "
          "shave modeled cost by trading rows\nbetween adjacent processes "
          "(see DESIGN.md §9 and benchmarks/bench_delta.py).")

    # -- heterogeneous nodes: host-staged vs GPU-direct (Lockhart 2022) -----
    gpu = lassen_machine((2, 2, 2))
    grows = []
    for n in (8, 32, 128, 512, 2048):
        rng = np.random.default_rng(42)
        P = gpu.n_procs
        src = rng.integers(0, P, n)
        dst = (src + rng.integers(1, P, n)) % P
        size = rng.integers(256, 8192, n).astype(float)
        phase = CommPhase.build(gpu, src, dst, size, n_procs=P)
        v = best_strategy(phase, seed=0, strategies=GPU_STRATEGIES)
        grows.append({"msgs": n,
                      **{f"model_{s}": v.model[s] for s in GPU_STRATEGIES},
                      **{f"sim_{s}": v.sim[s] for s in GPU_STRATEGIES},
                      "model_pick": v.model_winner, "sim_pick": v.sim_winner,
                      "agree": "yes" if v.agree else "NO"})
    print()
    print(format_table(
        grows,
        title="Lassen-like nodes (4 GPUs, dual-rail host NICs): host_staged "
              "vs device_direct\nas message counts grow (seconds)"))
    print("\nFew messages: GPU-NIC direct wins (no d2h/h2d copy phases). "
          "Many messages:\nstaging through host wins (node-level aggregation "
          "rides the full dual-rail host\nNIC bandwidth; early-GPUDirect "
          "rendezvous reads cannot keep up).  The model\npredicts the "
          "simulator's winner at every point — strategy selection remains a\n"
          "prediction across the paper's inferential gap.")
    fr = frontier_machine((2, 2, 1))
    rng = np.random.default_rng(42)
    P = fr.n_procs
    src = rng.integers(0, P, 2048)
    dst = (src + rng.integers(1, P, 2048)) % P
    vf = best_strategy(CommPhase.build(
        fr, src, dst, rng.integers(256, 8192, 2048).astype(float),
        n_procs=P), seed=0, strategies=GPU_STRATEGIES)
    print(f"\nFrontier-like nodes (NIC per GCD pair): sim picks "
          f"{vf.sim_winner} even at 2048 messages —\nwith the NICs on the "
          f"GPUs there is nothing to gain from staging through host.")


if __name__ == "__main__":
    main()
