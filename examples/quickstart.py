"""Quickstart: build a small LM, train a few steps, decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.nn import init_params, init_cache, decode_step
from repro.train import Trainer, TrainConfig, AdamWConfig

ARCH = "tinyllama-1.1b"


def main():
    cfg = get_smoke_config(ARCH)
    print(f"arch={ARCH} (reduced): {cfg.n_params()/1e6:.1f}M params")

    data = SyntheticTokens(cfg.vocab_size, batch=8, seq_len=64)
    trainer = Trainer(cfg, TrainConfig(steps=20, ckpt_every=100,
                                       ckpt_dir="/tmp/repro_quickstart",
                                       log_every=5),
                      AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20))
    out = trainer.run(data)
    print("loss:", [f"{h['loss']:.3f}" for h in out["history"]])

    # greedy decode from the trained params
    params = out["params"]
    cache = init_cache(cfg, 1, 32)
    tok = jnp.asarray([1], jnp.int32)
    toks = []
    for i in range(8):
        logits, cache = decode_step(params, cfg, cache, tok, i)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(int(tok[0]))
    print("greedy continuation:", toks)


if __name__ == "__main__":
    main()
