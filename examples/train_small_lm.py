"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_small_lm.py --steps 300

Uses the real mamba2-130m architecture (134M params) at short sequence
length so the run completes on CPU; on a pod the same Trainer takes the full
config + production mesh.  Checkpoints + resume + watchdog are all active —
kill it mid-run and rerun to see it resume.
"""
import argparse

from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.train import Trainer, TrainConfig, AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_small_lm")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m")
    print(f"mamba2-130m: {cfg.n_params()/1e6:.0f}M params, "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")
    data = SyntheticTokens(cfg.vocab_size, batch=args.batch, seq_len=args.seq)
    trainer = Trainer(
        cfg,
        TrainConfig(steps=args.steps, ckpt_every=100, ckpt_dir=args.ckpt_dir,
                    log_every=20),
        AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps))
    out = trainer.run(data)
    hist = out["history"]
    print(f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} over "
          f"{args.steps} steps; stragglers: {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
