"""Executing the winning strategy, end to end (DESIGN.md §14).

The rest of the examples *price* strategies; this one runs them.  Lower
each strategy rewrite of an irregular exchange to integral payload units
and edge-colored ``ppermute`` rounds, replay the schedule with the serial
numpy oracle, then calibrate a parameter table from recorded sweeps and
check the fitted model ranks the strategies exactly like the ground-truth
table.  With jax installed the same schedules also execute for real on a
forced 8-device host mesh (``XLA_FLAGS`` is set below, before jax loads),
bit-identical to the oracle, with a measured-vs-predicted table.

    PYTHONPATH=src python examples/comm_exec.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.comm import CommPhase
from repro.comm.strategies import strategies_for
from repro.exec import (build_schedule, calibrate, lassen_8, ordering,
                        predicted_costs, record_sweeps, reference_delivered,
                        run_reference)


def main():
    m = lassen_8()
    rng = np.random.default_rng(3)
    n = 96
    src = rng.integers(0, 8, n)
    dst = (src + rng.integers(1, 8, n)) % 8
    size = rng.integers(256, 8192, n).astype(float)
    phase = CommPhase.build(m, src, dst, size, n_procs=8)
    print(f"{m.name}-like host preset: {n} messages, "
          f"{phase.size.sum() / 1024:.0f} KiB total\n")

    # -- lowering: every strategy -> units, rounds, bit-identity ----------
    print(f"{'strategy':>14} {'units':>6} {'phases':>7} {'rounds':>7} "
          f"{'naive rounds':>13}   oracle")
    for strat in strategies_for(m):
        sched = build_schedule(phase, strat)
        naive = build_schedule(phase, strat, coloring="per_message")
        ok = np.array_equal(run_reference(sched), reference_delivered(sched))
        print(f"{strat:>14} {sched.n_units:>6} {len(sched.phases):>7} "
              f"{sched.n_rounds:>7} {naive.n_rounds:>13}   "
              f"{'bit-identical' if ok else 'MISMATCH'}")

    # -- calibration: fitted table reproduces the strategy ordering -------
    fit = calibrate(record_sweeps(m), m.params)
    truth = predicted_costs(phase)
    fitted = predicted_costs(phase, params=fit.params)
    print(f"\ncalibrated from recorded sweeps: n_rails={fit.n_rails} "
          f"(truth {m.params.n_rails}), classes {sorted(fit.fitted_classes)}")
    print(f"{'strategy':>14} {'truth s':>12} {'fitted s':>12}")
    for strat in ordering(truth):
        print(f"{strat:>14} {truth[strat]:>12.3e} {fitted[strat]:>12.3e}")
    agree = ordering(fitted) == ordering(truth)
    print(f"fitted-model ordering {'==' if agree else '!='} ground truth")

    # -- execution: the same schedules on a real 8-device host mesh -------
    try:
        import jax
    except ImportError:
        print("\n(jax not installed — skipping the mesh execution)")
        return
    if len(jax.devices()) < 8:
        print("\n(fewer than 8 devices — skipping the mesh execution)")
        return
    from repro.exec import execute, time_schedule
    print(f"\nexecuting on {len(jax.devices())} host devices "
          f"(shard_map + ppermute):")
    print(f"{'strategy':>14} {'measured us':>12} {'model s':>12}   payloads")
    for strat in strategies_for(m):
        sched = build_schedule(phase, strat)
        delivered, _ = execute(sched)
        ok = np.array_equal(delivered, run_reference(sched))
        meas = time_schedule(sched, reps=3, warmup=1)
        print(f"{strat:>14} {meas.median_s * 1e6:>12.0f} "
              f"{truth[strat]:>12.3e}   "
              f"{'bit-identical' if ok else 'MISMATCH'}")


if __name__ == "__main__":
    main()
