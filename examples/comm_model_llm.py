"""Price the repo's own LLM traffic with the paper's comm model, end to end.

The model stack (``repro.nn`` + ``repro.parallel``) *generates* irregular
point-to-point communication; the comm stack (``repro.comm`` +
``repro.core``) *prices* it.  This example connects them through
``repro.workloads``:

1. Derive real traffic shapes, numpy-only: the MoE expert-parallel
   all-to-all of qwen3-moe / deepseek-moe (seeded token-routing histograms
   lowered to the ``ep_a2a`` two-exchange schedule, capacity clipping
   included), llama3's TP ring collectives, and a GPipe stage-boundary
   exchange.
2. Sweep every scenario on every machine preset (lassen / frontier GPU
   nodes + the paper's Blue Waters CPU baseline) through ONE
   ``best_strategy_many`` arena.
3. Print the winner table: which node-aware / GPU-aware strategy the model
   predicts per phase, and whether the simulator's verdict agrees (it
   should — ``tests/test_workloads_golden.py`` pins this exact table).
4. Close the steering loop through the production service: optimize a
   sparse-operator partition on lassen (``optimize_partition`` with
   ``rerun_strategies=True``), then re-price the initial -> optimized
   traffic drift incrementally with ``StrategyService.reprice`` — the
   verdict must come back non-degraded (``tests/test_service_soak.py``
   pins this flow).

    PYTHONPATH=src python examples/comm_model_llm.py
"""
import numpy as np

from repro.configs import get_config
from repro.workloads import (DEFAULT_SCENARIOS, moe_a2a_pattern, sweep,
                             winner_table)


def main():
    # -- the raw shapes: what one MoE layer actually puts on the wire -------
    cfg = get_config("qwen3-moe-30b-a3b")
    pat = moe_a2a_pattern(cfg, n_ranks=64, tokens_per_rank=256, seed=0)
    pair = pat.dispatch.size
    print(f"{cfg.name}: 64 ranks x 256 tokens, E={cfg.n_experts} "
          f"top-{cfg.n_experts_active}, capacity {pat.capacity}/expert "
          f"-> {pat.dispatch.n_msgs} dispatch messages, "
          f"{pat.dispatch.total_bytes / 1e6:.1f} MB")
    print(f"per-pair size spread: {pair.min() / 1e3:.1f} KB .. "
          f"{pair.max() / 1e3:.1f} KB (median {np.median(pair) / 1e3:.1f} KB)"
          f" — irregular, not a collective schedule; "
          f"{pat.dropped_tokens} assignments clipped at capacity\n")

    # -- the sweep: every scenario x machine in one arena -------------------
    rows = sweep(DEFAULT_SCENARIOS)
    print(winner_table(rows))

    agree = sum(r.agree for r in rows)
    print(f"\nModel predicts the simulator's winner in {agree}/{len(rows)} "
          "cells.")
    print("Reading: on lassen (dual-rail host NICs) the dense MoE "
          "all-to-alls stage through\nhost memory (host_staged) and the "
          "bulk TP/pipeline volume aggregates (three_step);\non frontier "
          "(GPU-side NICs) and the CPU baseline the minimal-message shapes "
          "keep\nthe standard strategy, with combine-side aggregation "
          "winning where the reversed\nhistogram concentrates traffic.  "
          "This is the paper's thesis on the repo's own\ntraffic: strategy "
          "choice is machine x shape, and the model predicts it.\n")

    # -- the steering loop: optimizer drift through the service -------------
    steer_drift()


def steer_drift():
    """Optimize a partition on lassen, then reprice the traffic drift
    incrementally through the production service."""
    from repro.net import lassen_machine
    from repro.serve import StrategyService
    from repro.sparse import (RowPartition, optimize_partition, poisson_3d,
                              spmv_comm_pattern)

    machine = lassen_machine((2, 2, 2))
    A, n_procs = poisson_3d(6), 16
    res = optimize_partition(A, machine, n_procs=n_procs, moves=32, seed=0,
                             rerun_strategies=True)
    print(f"steering: poisson_3d(6) on lassen, {len(res.moves)} moves, "
          f"{res.n_accepted} accepted, modeled cost "
          f"{res.initial_cost * 1e6:.1f} -> {res.cost * 1e6:.1f} us "
          f"({res.improvement:.2%} better); "
          f"{len(res.verdicts)} per-move strategy verdicts")

    svc = StrategyService(machine, backend="numpy")
    initial = spmv_comm_pattern(A, RowPartition.balanced(A.n_rows, n_procs))
    out = svc.reprice(initial, res.pattern)
    assert out.ok and not out.degraded, out.error
    print(f"service reprice (initial -> optimized drift): "
          f"model winner {out.verdict.model_winner}, "
          f"sim winner {out.verdict.sim_winner}, "
          f"degraded={out.degraded}, cached={out.cached}")
    again = svc.reprice(initial, res.pattern)
    print(f"repeat reprice served from the fingerprint cache: "
          f"cached={again.cached}, winners unchanged="
          f"{again.verdict.sim_winner == out.verdict.sim_winner}")


if __name__ == "__main__":
    main()
