"""Batched serving demo: submit more requests than slots, watch continuous
refill.

    PYTHONPATH=src python examples/serve_batch.py --arch hymba-1.5b
"""
import argparse
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.nn import init_params
from repro.serve import ServeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b",
                    help="any assigned arch id (smoke-sized)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, 0)
    eng = ServeEngine(cfg, params, batch_slots=args.slots, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        req = Request(uid=uid,
                      prompt=rng.integers(1, cfg.vocab_size, 4).tolist(),
                      max_new_tokens=6)
        reqs.append(req)
        eng.submit(req)
    t0 = time.perf_counter()
    eng.run_until_done(max_ticks=500)
    dt = time.perf_counter() - t0
    for r in reqs:
        print(f"req {r.uid}: {r.prompt} -> {r.output}")
    n = sum(len(r.output) for r in reqs)
    print(f"{n} tokens / {dt:.2f}s = {n/dt:.1f} tok/s on "
          f"{args.slots} slots ({args.arch})")


if __name__ == "__main__":
    main()
