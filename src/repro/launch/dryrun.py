"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The FIRST two lines below must run before any other import — jax locks the
device count on first initialization.  512 placeholder host devices exist
ONLY inside this entry point; tests and benchmarks see the real device count.

Per cell we record:
  * ``memory_analysis()`` — proves the program fits per-device HBM;
  * ``cost_analysis()``   — per-device FLOPs / bytes for §Roofline;
  * the collective table parsed from the compiled HLO, decomposed to p2p
    messages and priced BOTH naively (bytes/link-bw) and with the paper's
    node-aware max-rate + queue + contention model.

Artifacts are JSON files under artifacts/dryrun/, resumable (existing cells
are skipped unless --force).
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, get_config, SHAPES, cell_applicable)  # noqa: E402
from repro.core import parse_collectives, collective_summary, price_step  # noqa: E402
from repro.core.decompose import PodGeometry  # noqa: E402
from repro.core.params import tpu_v5e  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (make_train_step, make_prefill_step,  # noqa: E402
                                make_serve_step, input_specs,
                                abstract_opt_state)
from repro.nn.model import abstract_params  # noqa: E402
from repro.parallel.sharding import (make_mesh_plan, param_pspecs,  # noqa: E402
                                     batch_pspecs, cache_pspecs, shardings,
                                     zero1_pspecs)
from repro.parallel import context as pctx  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def cell_path(arch: str, shape: str, mesh_name: str, out_dir: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")


def _compile_one(cfg, shape, mesh, plan, seq_shard=True, q_chunk=1024,
                 unroll=False, microbatch_override=None):
    """Lower + compile one program; return (compiled, lower_s, compile_s)."""
    params_abs = abstract_params(cfg)
    # FSDP for >20B-param cells: in training, TP-sharded weights + grads
    # alone exceed HBM; for 72B-class decode, TP=16 weights eat most of HBM,
    # so big-model serving uses the weight-gathered (batch-amortized) layout
    # too.  Small/medium models keep TP-only for serving latency.
    fsdp = (cfg.n_params() > 20e9 if shape.kind == "train"
            else cfg.n_params() > 15e9)
    pspecs = param_pspecs(cfg, plan, fsdp=fsdp)
    p_sh = shardings(pspecs, mesh)
    ctx = pctx.ShardingContext(mesh=mesh, dp_axes=plan.dp_axes,
                               seq_shard=seq_shard, q_chunk=q_chunk,
                               unroll_loops=unroll)
    t0 = time.time()
    with mesh, pctx.use(ctx):
        if shape.kind == "train":
            microbatches = microbatch_override or (
                16 if cfg.n_params() > 50e9
                else 4 if (cfg.n_params() > 20e9 or cfg.is_moe)
                else 2 if cfg.cross_attention else 1)
            step = make_train_step(cfg, unroll=unroll,
                                   microbatches=microbatches)
            opt_abs = abstract_opt_state(params_abs)
            mom_sh = shardings(zero1_pspecs(pspecs, cfg, plan), mesh)  # ZeRO-1
            opt_sh = {"m": mom_sh, "v": mom_sh, "step": NamedSharding(mesh, P())}
            batch = input_specs(cfg, shape)["batch"]
            b_sh = shardings(batch_pspecs(plan, batch), mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                             out_shardings=(p_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, unroll=unroll)
            batch = input_specs(cfg, shape)["batch"]
            b_sh = shardings(batch_pspecs(plan, batch), mesh)
            # explicit output shardings: without them GSPMD may replicate
            # the emitted KV cache over the model axis (L x B x S x KH x hd
            # at 32k context does not fit replicated)
            out_struct = jax.eval_shape(step, params_abs, batch)
            logits_s, cache_s = out_struct
            cache_out_sh = shardings(cache_pspecs(plan, cache_s), mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, cache_out_sh))
            lowered = jitted.lower(params_abs, batch)
        else:  # decode
            # decode lowers UNROLLED: no while-loop double-buffering of the
            # KV cache, and cost_analysis flops are exact without calibration
            step = make_serve_step(cfg, unroll=True)
            spec = input_specs(cfg, shape)
            c_sh = shardings(cache_pspecs(plan, spec["cache"]), mesh)
            tok_sh = shardings(batch_pspecs(plan, spec["token"]), mesh)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, c_sh, tok_sh,
                                           NamedSharding(mesh, P())),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, spec["cache"], spec["token"],
                                   spec["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               seq_shard: bool = True, q_chunk: int = 1024,
               calibrate: bool = True, cfg_overrides: dict | None = None,
               mesh_shape: tuple[int, int] | None = None,
               microbatch_override: int | None = None):
    """Lower + compile one cell.  Returns the artifact dict.

    ``calibrate``: additionally compile the same cell with 2 and 4 scanned
    layers; the delta gives exact XLA-accounted per-layer FLOPs/bytes
    (cost_analysis counts while bodies once, so the full-depth numbers must
    be reconstructed as entry + L * per-layer).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if (shape.kind == "decode" and cfg.n_params() > 50e9
            and not cfg_overrides):
        # production serving default for 72B-class: int8 KV cache
        cfg = _dc.replace(cfg, kv_quant=True)
    ok, why = cell_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind, "seq_len": shape.seq_len,
            "global_batch": shape.global_batch,
            "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params()}
    if not ok:
        return {**base, "status": "skipped", "reason": why}

    if mesh_shape is not None:
        from .mesh import make_mesh
        mesh = make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_mesh_plan(mesh)
    n_scanned = cfg.n_layers - cfg.first_dense_layers

    if shape.kind == "prefill":
        q_chunk = min(q_chunk, 512)   # 32k-seq score blocks at half size
    compiled, t_lower, t_compile = _compile_one(
        cfg, shape, mesh, plan, seq_shard, q_chunk,
        microbatch_override=microbatch_override)
    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    ops = parse_collectives(text, default_trip_count=n_scanned)
    geom = PodGeometry(n_pods=2 if multi_pod else 1)
    comm = price_step(ops, geom, tpu_v5e())

    flops_corr = bytes_corr = None
    if calibrate and n_scanned > 4 and shape.kind != "decode":
        small = {}
        for L in (2, 4):
            c2 = _dc.replace(cfg, n_layers=L + cfg.first_dense_layers,
                             encoder_layers=min(cfg.encoder_layers, L))
            comp, _, _ = _compile_one(c2, shape, mesh, plan, seq_shard,
                                      q_chunk, unroll=True)
            cst = comp.cost_analysis()
            small[L] = (cst.get("flops", 0.0), cst.get("bytes accessed", 0.0))
        per_layer_f = (small[4][0] - small[2][0]) / 2.0
        per_layer_b = (small[4][1] - small[2][1]) / 2.0
        enc_corr = 0
        if cfg.encoder_layers:
            enc_corr = cfg.encoder_layers - min(cfg.encoder_layers, 2)
        flops_corr = small[2][0] + (n_scanned - 2 + enc_corr) * per_layer_f
        bytes_corr = small[2][1] + (n_scanned - 2 + enc_corr) * per_layer_b

    art = {
        **base,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "seq_shard": seq_shard,
        "q_chunk": q_chunk,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device_raw": cost.get("flops", 0.0),
            "bytes_per_device_raw": cost.get("bytes accessed", 0.0),
            "flops_per_device": flops_corr or cost.get("flops", 0.0),
            "bytes_per_device": bytes_corr or cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        "collectives": collective_summary(ops),
        "comm_model": comm.as_dict(),
        "scan_trip_count": n_scanned,
    }
    # trim the per-op list (can be long) to the essentials
    art["comm_model"]["ops"] = [
        {k: o[k] for k in ("kind", "count", "payload_bytes", "naive_time",
                           "transport", "queue", "contention")}
        for o in art["comm_model"]["ops"]]
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="disable Megatron-SP residual sequence sharding")
    ap.add_argument("--q-chunk", type=int, default=1024,
                    help="query-chunk size for blockwise attention (0=off)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the 2/4-layer flops calibration compiles")
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix (for variant runs)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = n_cached = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = ("pod2x16x16" if mp else "pod16x16") + args.tag
                path = cell_path(arch, shape, mesh_name, args.out)
                if os.path.exists(path) and not args.force:
                    prev = json.load(open(path))
                    if prev.get("status") in ("ok", "skipped"):
                        n_cached += 1
                        continue
                t0 = time.time()
                try:
                    art = lower_cell(arch, shape, mp,
                                     seq_shard=not args.no_seq_shard,
                                     q_chunk=args.q_chunk,
                                     calibrate=not args.no_calibrate)
                    art["mesh"] = mesh_name
                except Exception as e:  # noqa: BLE001
                    art = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "failed", "error": str(e),
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(art, f, indent=1, default=float)
                st = art["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "failed"
                msg = ""
                if st == "ok":
                    peak = art["memory"]["peak_bytes"] / 2**30
                    msg = (f"peak={peak:.2f}GiB "
                           f"flops/dev={art['cost']['flops_per_device']:.3e} "
                           f"compile={art['compile_s']}s")
                elif st == "failed":
                    msg = art["error"][:160]
                print(f"[{time.strftime('%H:%M:%S')}] {arch} x {shape} x "
                      f"{mesh_name}: {st} {msg} ({time.time()-t0:.1f}s)",
                      flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail} cached={n_cached}")


if __name__ == "__main__":
    main()
