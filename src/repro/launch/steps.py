"""Step functions (train / prefill / serve) + abstract input specs per cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — exactly what
``jax.jit(...).lower()`` needs for the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.nn.config import ArchConfig
from repro.nn import model as M
from repro.train.optim import AdamWConfig, init_opt_state, adamw_update

PyTree = Any


# ------------------------------------------------------------- steps --------
def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig | None = None,
                    remat: bool = True, unroll: bool = False,
                    microbatches: int = 1):
    """Build the jittable train step (loss + grad + AdamW).

    ``microbatches > 1`` scans over gradient-accumulation slices — per-device
    activation memory scales down by the slice count (how the >20B cells fit
    v5e HBM) at the cost of re-running the collective schedule per slice.
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = M.lm_loss(p, cfg, batch, remat=remat,
                                      unroll=unroll)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def body(acc, b):
                (l, m), g = grads_of(params, b)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / microbatches,
                    acc, (l, g))
                return acc, m
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), ms = jax.lax.scan(body, zero, mb)
            metrics = jax.tree.map(lambda a: a[-1], ms)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state,
                                                      opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, unroll: bool = False):
    def prefill_step(params, batch):
        return M.prefill(params, cfg,
                         tokens=batch.get("tokens"),
                         embeds=batch.get("embeds"),
                         enc_frames=batch.get("frames"),
                         unroll=unroll)
    return prefill_step


def make_serve_step(cfg: ArchConfig, unroll: bool = False):
    def serve_step(params, cache, token, pos):
        return M.decode_step(params, cfg, cache, token, pos, unroll=unroll)
    return serve_step


# ------------------------------------------------------- abstract inputs ----
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Abstract model inputs for one (arch x shape) cell.

    train/prefill: the batch dict.  decode: {"cache", "token", "pos"} with the
    KV cache sized to the cell's seq_len.
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            batch = {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
                     "positions": _sds((B, S, 3), jnp.int32)}
            if shape.kind == "train":
                batch["targets"] = _sds((B, S), jnp.int32)
        else:
            batch = {"tokens": _sds((B, S), jnp.int32)}
            if cfg.family == "audio":
                batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                       jnp.bfloat16)
        return {"batch": batch}
    # decode: one new token against a seq_len cache
    cache = M.abstract_cache(cfg, B, S)
    return {"cache": cache,
            "token": _sds((B,), jnp.int32),
            "pos": _sds((), jnp.int32)}


def abstract_opt_state(params_abstract: PyTree) -> PyTree:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"m": jax.tree.map(f32, params_abstract),
            "v": jax.tree.map(f32, params_abstract),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}
