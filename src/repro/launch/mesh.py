"""Production meshes.  A FUNCTION (not module-level constant): importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg) only exist in
    newer jax releases than the pinned toolchain ships; when present we ask
    for ``Auto`` on every axis (the pre-AxisType default), otherwise we omit
    the kwarg entirely.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))
