"""Production meshes.  A FUNCTION (not module-level constant): importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg) only exist in
    newer jax releases than the pinned toolchain ships; when present we ask
    for ``Auto`` on every axis (the pre-AxisType default), otherwise we omit
    the kwarg entirely.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_rank_mesh(n_ranks: int):
    """1-D ``("rank",)`` mesh over the first ``n_ranks`` local devices — the
    execution mesh :mod:`repro.exec` lowers strategy schedules onto (one
    mesh rank per simulated MPI rank).  Raises ``ValueError`` when fewer
    than ``n_ranks`` devices exist; tests force an 8-device host mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    import numpy as np
    devices = jax.devices()
    if len(devices) < n_ranks:
        raise ValueError(
            f"make_rank_mesh({n_ranks}) needs {n_ranks} devices but only "
            f"{len(devices)} exist; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<n> before "
            "importing jax to fake a host mesh")
    return jax.sharding.Mesh(np.asarray(devices[:n_ranks]), ("rank",))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))
