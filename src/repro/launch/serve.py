"""Serving driver: batched requests through the slot engine (CPU-runnable).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.nn import init_params
from repro.serve import ServeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, 0)
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.integers(2, 8))
        req = Request(uid=uid,
                      prompt=rng.integers(1, cfg.vocab_size, plen).tolist(),
                      max_new_tokens=args.max_new)
        reqs.append(req)
        eng.submit(req)
    t0 = time.perf_counter()
    eng.run_until_done(max_ticks=2000)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.output) for r in reqs)
    for r in reqs:
        print(f"req {r.uid}: prompt={r.prompt} -> {r.output}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s, {args.slots} slots)")


if __name__ == "__main__":
    main()
