"""Generate the §Roofline table (markdown) from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]

Per (arch x shape x mesh): the three roofline terms in seconds, the dominant
term, MODEL_FLOPS/HLO_FLOPS, and the collective term priced both naively and
with the paper's model.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.core.params import V5E_PEAK_FLOPS_BF16, V5E_HBM_BW

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def analyze(a: dict) -> dict:
    flops = a["cost"]["flops_per_device"]
    byts = a["cost"]["bytes_per_device"]
    cm = a["comm_model"]
    compute = flops / V5E_PEAK_FLOPS_BF16
    memory = byts / V5E_HBM_BW
    coll = cm["model_time"]
    dom = max((compute, "compute"), (memory, "memory"), (coll, "collective"))[1]
    tokens = (a["global_batch"] * a["seq_len"] if a["kind"] != "decode"
              else a["global_batch"])
    mult = 6 if a["kind"] == "train" else 2
    chips = 512 if "2x16x16" in a["mesh"] else 256
    model_flops = mult * a["n_active_params"] * tokens / chips
    total = compute + memory + coll
    return {
        "arch": a["arch"], "shape": a["shape"], "mesh": a["mesh"],
        "compute_s": compute, "memory_s": memory,
        "coll_naive_s": cm["naive_time"], "coll_bienz_s": coll,
        "queue_s": cm["queue"], "contention_s": cm["contention"],
        "dominant": dom,
        "model/hlo": model_flops / flops if flops else 0.0,
        "roofline_frac": max(compute, memory) / total if total else 0.0,
        "peak_gib": a["memory"]["peak_bytes"] / 2**30,
        "fits": a["memory"]["peak_bytes"] < 15.5 * 2**30,
    }


def load(mesh_filter: str | None = None, art_dir: str | None = None):
    rows, skips = [], []
    for f in sorted(glob.glob(os.path.join(art_dir or ART, "*.json"))):
        a = json.load(open(f))
        if mesh_filter and mesh_filter not in a.get("mesh", ""):
            continue
        if a.get("status") == "ok":
            rows.append(analyze(a))
        elif a.get("status") == "skipped":
            skips.append((a["arch"], a["shape"], a["mesh"], a["reason"]))
    return rows, skips


def to_markdown(rows, skips) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | coll_naive_s | "
           "coll_bienz_s | dominant | 6ND/HLO | frac | peak GiB | fits |")
    sep = "|" + "---|" * 12
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['coll_naive_s']:.3e} | {r['coll_bienz_s']:.3e} "
            f"| {r['dominant']} | {r['model/hlo']:.2f} "
            f"| {r['roofline_frac']:.2f} | {r['peak_gib']:.1f} "
            f"| {'y' if r['fits'] else 'N'} |")
    if skips:
        lines.append("")
        lines.append("Skipped cells (documented in DESIGN.md "
                     "§Arch-applicability):")
        for (a, s, m, why) in skips:
            lines.append(f"* {a} x {s} x {m}: {why[:100]}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows, skips = load(args.mesh)
    md = to_markdown(rows, skips)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
