"""End-to-end training driver (CPU-runnable at reduced scale).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128

On a real pod this is the per-host entry point: same Trainer, production
config, mesh from ``make_production_mesh()``, data shard from the host id.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticTokens
from repro.train import Trainer, TrainConfig, AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    data = SyntheticTokens(cfg.vocab_size, batch=args.batch, seq_len=args.seq,
                           family=cfg.family, d_model=cfg.d_model,
                           encoder_seq=cfg.encoder_seq)
    trainer = Trainer(
        cfg,
        TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir, log_every=5,
                    microbatches=args.microbatches),
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps))
    out = trainer.run(data)
    for row in out["history"]:
        print(json.dumps(row))
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
