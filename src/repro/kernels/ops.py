"""Public jit'd wrappers for the Pallas kernels (shape checks + dispatch).

``interpret=True`` (Python-on-CPU execution of the kernel body) is how the
kernels are validated in this container; on TPU hardware the same calls run
compiled with ``interpret=False``.
"""
from __future__ import annotations

import jax.numpy as jnp

from .flash_attention import flash_attention
from .ssd import ssd_intra_chunk
from .spmv_ell import spmv_block_ell, csr_to_block_ell

__all__ = ["flash_attention", "ssd_intra_chunk", "spmv_block_ell",
           "csr_to_block_ell", "mha_flash"]


def mha_flash(q, k, v, causal=True, block_q=128, block_k=128,
              interpret=False):
    """Shape-checked flash attention entry point."""
    assert q.ndim == 4 and k.ndim == 4 and v.ndim == 4
    assert k.shape == v.shape
    assert q.shape[0] == k.shape[0] and q.shape[1] == k.shape[1]
    assert q.shape[3] == k.shape[3]
    assert q.shape[2] % k.shape[2] == 0, "H must be a multiple of KH"
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)
