"""Flash attention (block-wise online softmax) as a Pallas TPU kernel.

The train/prefill compute hot-spot.  Grid: (batch, q_heads, nq, nk) with the
KV-block index innermost; running max / denominator / accumulator live in
VMEM scratch across the nk dimension and the output tile is finalized on the
last KV block.  GQA is handled in the K/V BlockSpec index maps (query head h
reads KV head ``h // rep``) — KV tensors are never materialized per-q-head.

Block shapes are MXU-aligned (multiples of 128 on the matmul dims); the
f32 scratch working set per program is
``block_q*(d + block_k + 2)`` floats ~ 128*(128+128+2)*4 B ~ 132 KiB,
comfortably inside a v5e core's ~16 MiB VMEM alongside the Q/K/V tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]                             # [bq, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                          # [bq, bk]
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(p, v)  # [bq, d]

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B, S, H, D]; k/v: [B, S, KH, D] -> [B, S, H, D].

    H must be a multiple of KH (GQA); S must be a multiple of the block
    sizes.  ``interpret=True`` runs the kernel body in Python on CPU (how it
    is validated in this container); on TPU pass False.
    """
    B, S, H, D = q.shape
    KH = k.shape[2]
    assert H % KH == 0, (H, KH)
    rep = H // KH
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (D ** 0.5)

    qt = q.transpose(0, 2, 1, 3)     # [B, H, S, D]
    kt = k.transpose(0, 2, 1, 3)     # [B, KH, S, D]
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
