"""Accelerator backends for the PhaseStack segmented passes.

The stacked sweep engine (:mod:`repro.comm.stack`) reduces per-message
quantities to per-(phase, process) / per-(phase, link) aggregates with
segmented sums/maxima over packed integer keys, and replays receive-queue
walks with a batched lock-step Fenwick sweep.  This module provides the
device implementations of all three:

``backend='jax'``
    ``jax.ops.segment_sum`` / ``segment_max`` under ``jax.jit`` and a jitted
    ``lax.fori_loop`` Fenwick walk (:func:`queue_walk`) — the scalable
    path: O(total messages) scatter work, the whole queue sweep one device
    program with no host round-trip between rounds.
``backend='pallas'``
    Fused Pallas kernels.  :func:`fused_segment_reduce` tiles the message
    stream into ``_CHUNK``-wide grid steps and scatter-accumulates each
    chunk into the full padded output row kept resident across the grid
    (the flash-attention accumulate idiom) — sums and maxima in one launch,
    O(messages) work, so there is no one-hot work ceiling and no size
    reroute.  The queue walk wraps the same lock-step Fenwick rounds in a
    single Pallas program.  On hosts without a TPU/GPU the kernels run in
    interpret mode (parity, not speed).
``backend='auto'`` (the resolved form of ``backend=None``)
    The autotuned default: picks numpy below the measured numpy/jax
    crossover size and jax at/above it (:func:`autotune_crossover`).

numpy is the bit-identity reference and the silent fallback when jax is
absent (:func:`resolve_backend` warns once for explicit device requests).
Backend parity for the float reductions is *allclose*, not bit-equal (the
device paths run float32); the queue walk is integer work and bit-equal on
every backend.

Robustness (DESIGN.md §12): every device call here runs inside
:func:`device_guard` — a named fault-injection site
(:mod:`repro.comm.faults`) plus the graceful-degradation policy: any
backend failure falls back to the numpy reference, warns once, and is
recorded in :class:`repro.comm.health.BackendHealth`, which quarantines a
backend after repeated consecutive failures.  The optional
``REPRO_STACK_VERIFY`` post-kernel check (``finite`` | ``parity``) detects
silent NaN/mismatch in device outputs and triggers the same fallback.  The
autotune probe is bounded by a cooperative timeout with
retry-and-backoff, and its disk cache tolerates corruption and read-only
directories.

This module imports jax lazily so that importing it — and everything in
:mod:`repro.comm` — stays numpy-only.
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

from repro.comm import faults
from repro.comm.health import get_health

BACKENDS = ("numpy", "jax", "pallas", "auto")

_CHUNK = 512        # messages per fused-kernel grid step
_LANE = 128         # lane tile: device output rows pad to multiples of this
_SEG_BLOCK = _LANE  # historical alias (the retired one-hot kernel's block)


def have_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:  # pragma: no cover - environment-dependent
        return False


def resolve_backend(backend: str | None = None,
                    n_values: int | None = None) -> str:
    """Resolve a backend request to a concrete backend name.

    ``None`` means the *autotuned default* (``'auto'``).  ``'auto'`` picks
    numpy below the measured numpy/jax crossover size and jax at/above it;
    pass ``n_values`` (the reduction's input length) to collapse it to a
    concrete choice here — without ``n_values`` the string ``'auto'`` is
    returned for the caller to resolve per call.  Explicit ``'jax'`` /
    ``'pallas'`` requests fall back to numpy with a warning (once per
    process, via the resettable :class:`repro.comm.health.BackendHealth`
    registry) when jax is not importable or the backend is quarantined
    after repeated failures; ``'auto'`` falls back silently (it is a
    default, not a request).
    """
    if backend is None:
        backend = "auto"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown stack backend {backend!r}; expected one of {BACKENDS}")
    if backend != "numpy" and not have_jax():
        if backend != "auto":
            get_health().warn_once(
                f"nojax:{backend}",
                f"stack backend {backend!r} requested but jax is not "
                "importable; falling back to numpy")
        return "numpy"
    if backend == "auto" and n_values is not None:
        backend = "numpy" if n_values < autotune_crossover() else "jax"
    if backend in ("jax", "pallas") and get_health().is_quarantined(backend):
        get_health().warn_once(
            f"resolve-quarantined:{backend}",
            f"stack backend {backend!r} is quarantined after repeated "
            "failures; resolving to numpy (BackendHealth.reset() restores)")
        return "numpy"
    return backend


# -- graceful degradation around device calls --------------------------------

#: Allowed ``REPRO_STACK_VERIFY`` values: ``''`` (off), ``finite`` (reject
#: non-finite device outputs), ``parity`` (compare device outputs against
#: the numpy reference, allclose).
VERIFY_MODES = ("", "finite", "parity")


class BackendVerifyError(RuntimeError):
    """A device output failed the ``REPRO_STACK_VERIFY`` post-kernel check."""


def verify_mode() -> str:
    """The active post-kernel check, from ``REPRO_STACK_VERIFY``.

    ``finite`` rejects NaN/inf in device outputs; ``parity`` recomputes the
    numpy reference and rejects non-allclose outputs.  Either rejection is
    a :class:`BackendVerifyError`, which the degradation policy treats like
    any other backend failure (fallback + health event).  An unknown value
    raises ``ValueError`` naming the allowed modes.
    """
    mode = os.environ.get("REPRO_STACK_VERIFY", "")
    if mode not in VERIFY_MODES:
        raise ValueError(
            f"unknown REPRO_STACK_VERIFY value {mode!r}; allowed values: "
            f"{VERIFY_MODES}")
    return mode


def _leaves(value):
    return value if isinstance(value, tuple) else (value,)


def _check_finite(value) -> None:
    for leaf in _leaves(value):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating) and not np.isfinite(arr).all():
            raise BackendVerifyError(
                "device output contains non-finite values "
                "(REPRO_STACK_VERIFY=finite)")


def _check_parity(value, ref) -> None:
    for got, want in zip(_leaves(value), _leaves(ref)):
        g = np.asarray(got)
        w = np.asarray(want)
        if np.issubdtype(g.dtype, np.integer) and \
                np.issubdtype(w.dtype, np.integer):
            # integer device outputs are bit-equal by contract; allclose
            # would let a +1 shift on large values slide under rtol
            ok = g.shape == w.shape and (g == w).all()
        else:
            ok = np.allclose(g.astype(np.float64), w.astype(np.float64),
                             rtol=1e-4, atol=1e-6, equal_nan=False)
        if not ok:
            raise BackendVerifyError(
                "device output does not match the numpy reference "
                "(REPRO_STACK_VERIFY=parity)")


def device_guard(site: str, backend: str, device_fn, numpy_fn):
    """Run one device-backend call under the full degradation contract.

    ``device_fn`` (no arguments) performs the device work; ``numpy_fn`` (no
    arguments) computes the bit-identity numpy reference.  In order:

    1. a quarantined ``backend`` skips the device path entirely and returns
       ``numpy_fn()`` (the quarantine was announced when it was imposed);
    2. the :mod:`repro.comm.faults` injection site ``site`` may raise
       (``raise`` / ``timeout`` modes) or poison the device output
       (``nan`` / ``corrupt`` modes);
    3. the ``REPRO_STACK_VERIFY`` post-kernel check, when enabled, rejects
       non-finite (``finite``) or non-matching (``parity``) device outputs;
    4. *any* failure in 2-3 — or in the device computation itself — is
       recorded in :class:`repro.comm.health.BackendHealth` (warn-once,
       streak accounting, quarantine after repeated failures) and the call
       returns ``numpy_fn()`` instead of raising.

    A successful device call records a success (clearing the backend's
    failure streak) and returns the device output.
    """
    health = get_health()
    if health.is_quarantined(backend):
        return numpy_fn()
    try:
        faults.fail_point(site)
        out = faults.poison(site, device_fn())
        mode = verify_mode()
        if mode == "finite":
            _check_finite(out)
        elif mode == "parity":
            ref = numpy_fn()
            _check_parity(out, ref)
    except Exception as e:  # noqa: BLE001 - degradation catches everything
        health.record_failure(backend, site, e)
        return numpy_fn()
    health.record_success(backend)
    return out


# -- autotuned numpy/jax crossover -------------------------------------------

#: probe sizes for the crossover search (geometric, covers the realistic
#: arena range on both CPU-only and accelerator hosts)
_PROBE_SIZES = (1 << 13, 1 << 15, 1 << 17, 1 << 19)
_PROBE_SEGMENTS = 256

_crossover: float | None = None


def _probe_tag() -> str:
    """Cache key tying a persisted probe to the software/device stack."""
    parts = [np.__version__]
    try:
        import jax
        parts += [jax.__version__, jax.default_backend()]
    except Exception:  # pragma: no cover - environment-dependent
        parts.append("nojax")
    return "/".join(parts)


def _best_time(fn, reps: int = 3) -> float:
    fn()                                              # warm (jit, caches)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _probe_pair(n: int) -> tuple[float, float]:
    """(numpy, jax) best-of times for one packed-key segment sum of ``n``."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    ids = rng.integers(0, _PROBE_SEGMENTS, size=n)
    vals = rng.random(n)
    t_np = _best_time(
        lambda: np.bincount(ids, weights=vals, minlength=_PROBE_SEGMENTS))
    seg_sum, _ = _jax_segment_ops()
    d_vals = jax.device_put(jnp.asarray(vals, jnp.float32))
    d_ids = jax.device_put(jnp.asarray(ids, jnp.int32))
    t_jax = _best_time(
        lambda: seg_sum(d_vals, d_ids, _PROBE_SEGMENTS).block_until_ready())
    return t_np, t_jax


#: Live-probe hardening: per-size retry attempts, base backoff seconds
#: (doubling per retry), and the cooperative probe deadline (seconds,
#: override with ``REPRO_STACK_PROBE_TIMEOUT``).
_PROBE_RETRIES = 3
_PROBE_BACKOFF = 0.05
_PROBE_TIMEOUT = 60.0


def _read_probe_cache(path: str, tag: str) -> float | None:
    """The cached crossover at ``path``, or None when the cache is absent,
    unreadable, corrupt, or tagged for a different software stack (a
    corrupt cache is recorded as a health event and reprobed, never
    trusted and never fatal)."""
    if not os.path.exists(path):
        return None
    try:
        faults.fail_point("autotune.cache_read")
        with open(path) as fh:
            raw = faults.poison("autotune.cache_read", fh.read())
        rec = json.loads(raw)
        if rec.get("tag") == tag:
            return float(rec["crossover"])
    except (OSError, ValueError, KeyError, TypeError) as e:
        get_health().record_failure("disk-cache", "autotune.cache_read", e)
    return None


def _write_probe_cache(path: str, tag: str, cross: float) -> None:
    """Persist a probe result; a read-only/failing cache directory is a
    recorded health event, not an error (the probe result still serves the
    process from the in-memory memo)."""
    try:
        faults.fail_point("autotune.cache_write")
        with open(path, "w") as fh:
            json.dump({"tag": tag, "crossover": cross,
                       "sizes": list(_PROBE_SIZES)}, fh)
    except OSError as e:
        get_health().record_failure("disk-cache", "autotune.cache_write", e)


def _probe_crossover() -> float:
    """Run the live probe under a cooperative deadline with per-size
    retry-and-backoff; degrades to ``inf`` (numpy always) when the probe
    keeps failing or the deadline passes — a strategy-service query must
    never hang or crash on a misbehaving probe."""
    deadline = time.monotonic() + float(
        os.environ.get("REPRO_STACK_PROBE_TIMEOUT", _PROBE_TIMEOUT))
    for n in _PROBE_SIZES:
        for attempt in range(_PROBE_RETRIES):
            try:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"autotune probe deadline exceeded before size {n}")
                faults.fail_point("autotune.probe")
                t_np, t_jax = _probe_pair(n)
            except TimeoutError as e:
                # the deadline is global: no point retrying or probing on
                get_health().record_failure("autotune", "autotune.probe", e)
                return float("inf")
            except Exception as e:  # noqa: BLE001 - degradation
                get_health().record_failure("autotune", "autotune.probe", e)
                if attempt + 1 == _PROBE_RETRIES:
                    return float("inf")
                time.sleep(_PROBE_BACKOFF * 2 ** attempt)
            else:
                if t_jax < t_np:
                    return float(n)
                break                      # this size settled: next size
    return float("inf")


def autotune_crossover(refresh: bool = False) -> float:
    """The measured input size where the jitted jax segment reduction starts
    beating numpy's ``bincount`` (``float('inf')`` when it never does — e.g.
    CPU-only jax, or jax absent).

    Resolution order: in-process memo -> ``REPRO_STACK_AUTOTUNE`` env
    override (a number, ``inf`` allowed) -> on-disk probe cache (the path in
    ``REPRO_STACK_AUTOTUNE_CACHE``, ignored — with a recorded health event —
    when corrupt or when its software tag no longer matches) -> a live probe
    over ``_PROBE_SIZES`` with device-resident inputs (first size where jax
    wins).  ``refresh=True`` forces a new probe and rewrites the disk cache.
    The probe costs a few jit compiles once per process; pin the env var to
    skip it entirely.

    Hardened for service use: the probe runs under a cooperative deadline
    (``REPRO_STACK_PROBE_TIMEOUT`` seconds) with retry-and-backoff per
    size, and every failure path — probe timeout, corrupt cache, read-only
    cache directory — degrades to a usable crossover (``inf`` = numpy)
    instead of raising.
    """
    global _crossover
    if _crossover is not None and not refresh:
        return _crossover
    env = os.environ.get("REPRO_STACK_AUTOTUNE")
    if env is not None and not refresh:
        _crossover = float(env)
        return _crossover
    path = os.environ.get("REPRO_STACK_AUTOTUNE_CACHE")
    tag = _probe_tag()
    if path and not refresh:
        cached = _read_probe_cache(path, tag)
        if cached is not None:
            _crossover = cached
            return _crossover
    if not have_jax():
        _crossover = float("inf")
        return _crossover
    cross = _probe_crossover()
    _crossover = cross
    if path:
        _write_probe_cache(path, tag, cross)
    return cross


# -- jitted segment reductions ----------------------------------------------

@functools.cache
def _jax_segment_ops():
    import jax

    @functools.partial(jax.jit, static_argnames=("n_seg",))
    def seg_sum(vals, ids, n_seg):
        return jax.ops.segment_sum(vals, ids, num_segments=n_seg)

    @functools.partial(jax.jit, static_argnames=("n_seg",))
    def seg_max(vals, ids, n_seg):
        return jax.ops.segment_max(vals, ids, num_segments=n_seg)

    return seg_sum, seg_max


def _as_device(a, dtype):
    """``a`` as a device array: jax arrays pass through untouched (already
    resident), anything else is converted once."""
    import jax
    import jax.numpy as jnp
    if isinstance(a, jax.Array):
        return a
    return jnp.asarray(np.asarray(a), dtype=dtype)


def _size_of(a) -> int:
    return int(a.size) if hasattr(a, "size") else len(a)


# -- fused Pallas segment reduce ---------------------------------------------

def _fused_segreduce_kernel(ids_ref, vals_ref, sum_ref, max_ref):
    """One grid step: scatter-accumulate chunk ``c`` into the resident row.

    The output blocks map to ``(0, 0)`` on every step, so they stay resident
    in VMEM across the whole grid while each step's ``(1, _CHUNK)`` message
    tile streams through — sums and maxima in the same pass.  Padded lanes
    carry the sink segment id (the last padded column) and neutral values,
    so no masking is needed.
    """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        max_ref[...] = jnp.full_like(max_ref, -jnp.inf)

    ids = ids_ref[0, :]
    vals = vals_ref[0, :]
    sum_ref[0, :] = sum_ref[0, :].at[ids].add(vals)
    max_ref[0, :] = max_ref[0, :].at[ids].max(vals)


@functools.cache
def _pallas_segreduce(n_pad: int, s_pad: int):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    return pl.pallas_call(
        _fused_segreduce_kernel,
        grid=(n_pad // _CHUNK,),
        in_specs=[
            pl.BlockSpec((1, _CHUNK), lambda c: (0, c)),
            pl.BlockSpec((1, _CHUNK), lambda c: (0, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, s_pad), lambda c: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda c: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, s_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, s_pad), jnp.float32),
        ],
        interpret=jax.default_backend() == "cpu",
    )


def fused_segment_reduce(values, seg_ids,
                         n_seg: int) -> tuple[np.ndarray, np.ndarray]:
    """One fused Pallas launch -> ``(segment sums, segment maxima)``.

    Replaces the retired one-hot membership kernel: each grid step
    scatter-accumulates one message chunk into the full padded output row
    resident in VMEM, so the work is O(messages) — any arena size runs in
    one launch and ``PALLAS_ONE_HOT_LIMIT`` rerouting is gone.  Padding to
    ``s_pad = roundup(n_seg + 1, _LANE)`` guarantees a sink column for the
    padded message lanes.  Empty segments report sum 0 and max 0 (the
    contention reduction's inputs are non-negative byte counts).

    Kernel failures degrade to the numpy reference pair via
    :func:`device_guard` (site ``kernel.segment_reduce``).
    """
    values = np.asarray(values)
    seg_ids = np.asarray(seg_ids)

    def device_fn():
        import jax.numpy as jnp

        n = values.size
        n_pad = max(_CHUNK, -(-n // _CHUNK) * _CHUNK)
        s_pad = max(_LANE, -(-(n_seg + 1) // _LANE) * _LANE)
        ids = np.full((1, n_pad), s_pad - 1, dtype=np.int32)
        ids[0, :n] = seg_ids
        vals = np.zeros((1, n_pad), dtype=np.float32)
        vals[0, :n] = values
        s, mx = _pallas_segreduce(n_pad, s_pad)(jnp.asarray(ids),
                                                jnp.asarray(vals))
        sums = np.asarray(s)[0, :n_seg].astype(np.float64)
        maxs = np.asarray(mx)[0, :n_seg].astype(np.float64)
        maxs[np.isneginf(maxs)] = 0.0                 # empty segments
        return sums, maxs

    return device_guard(
        "kernel.segment_reduce", "pallas", device_fn,
        lambda: (_segment_sum_numpy(values, seg_ids, n_seg),
                 _segment_max_numpy(values, seg_ids, n_seg)))


# -- public segment reductions -----------------------------------------------

def _segment_sum_numpy(values, seg_ids, n_seg: int) -> np.ndarray:
    """The bit-identity numpy reference for :func:`segment_sum` (also the
    degradation fallback for the device backends)."""
    return np.bincount(np.asarray(seg_ids, dtype=np.int64),
                       weights=np.asarray(values, dtype=np.float64),
                       minlength=n_seg)


def _segment_max_numpy(values, seg_ids, n_seg: int) -> np.ndarray:
    """The bit-identity numpy reference for :func:`segment_max`."""
    out = np.zeros(n_seg)
    np.maximum.at(out, np.asarray(seg_ids, dtype=np.int64),
                  np.asarray(values, dtype=np.float64))
    return out


def segment_sum(values, seg_ids, n_seg: int,
                backend: str | None = None) -> np.ndarray:
    """Sum ``values`` into ``n_seg`` bins by ``seg_ids`` on the chosen
    backend (``None``/``'auto'`` = the autotuned default).  Device inputs
    (jax arrays) stay resident on the jax path; the reduced dense result is
    returned on the host.  Device-backend failures degrade to the numpy
    reference via :func:`device_guard` (site ``kernel.segment_reduce``)."""
    if backend in (None, "auto"):
        backend = resolve_backend("auto", n_values=_size_of(seg_ids))
    if backend == "numpy":
        return _segment_sum_numpy(values, seg_ids, n_seg)
    if backend == "pallas":
        return fused_segment_reduce(values, seg_ids, n_seg)[0]

    def device_fn():
        import jax.numpy as jnp
        seg_sum, _ = _jax_segment_ops()
        return np.asarray(seg_sum(_as_device(values, jnp.float32),
                                  _as_device(seg_ids, jnp.int32), n_seg),
                          dtype=np.float64)

    return device_guard("kernel.segment_reduce", backend, device_fn,
                        lambda: _segment_sum_numpy(values, seg_ids, n_seg))


def segment_max(values, seg_ids, n_seg: int,
                backend: str | None = None) -> np.ndarray:
    """Per-segment maximum (0.0 for empty segments, matching the stacked
    contention reduction where all inputs are non-negative byte counts).
    Device-backend failures degrade to the numpy reference via
    :func:`device_guard` (site ``kernel.segment_reduce``)."""
    if backend in (None, "auto"):
        backend = resolve_backend("auto", n_values=_size_of(seg_ids))
    if backend == "numpy":
        return _segment_max_numpy(values, seg_ids, n_seg)
    if backend == "pallas":
        return fused_segment_reduce(values, seg_ids, n_seg)[1]

    def device_fn():
        import jax.numpy as jnp
        _, seg_max = _jax_segment_ops()
        out = np.asarray(seg_max(_as_device(values, jnp.float32),
                                 _as_device(seg_ids, jnp.int32), n_seg),
                         dtype=np.float64)
        out[np.isneginf(out)] = 0.0
        return out

    return device_guard("kernel.segment_reduce", backend, device_fn,
                        lambda: _segment_max_numpy(values, seg_ids, n_seg))


# -- device Fenwick queue walk -----------------------------------------------

def _queue_layout(posted, arrival, bounds):
    """Host-side layout for the lock-step Fenwick sweep (mirrors the numpy
    reference in :func:`repro.comm.primitives.batched_queue_traversal_steps`
    exactly: same private-tree packing, same initial tree contents)."""
    from repro.comm.primitives import segmented_arange

    posted = np.asarray(posted, dtype=np.int64)
    arrival = np.asarray(arrival, dtype=np.int64)
    bounds = np.asarray(bounds, dtype=np.int64)
    N = int(posted.size)
    starts = bounds[:-1]
    counts = np.diff(bounds)
    region_of = np.repeat(np.arange(counts.size), counts)
    start_of = starts[region_of]
    pos = np.empty(N, dtype=np.int64)
    pos[start_of + posted] = np.arange(N) - start_of
    b = pos[start_of + arrival]                       # slot of j-th arrival
    span = np.ones(counts.size, dtype=np.int64)
    while (span < counts).any():
        span = np.where(span < counts, span * 2, span)
    blk = span + 1
    toff = np.concatenate([[0], np.cumsum(blk)])
    tree = np.zeros(toff[-1] + 1, dtype=np.int64)     # +1: shared sink
    li = segmented_arange(blk)
    c_rep = np.repeat(counts, blk)
    lo = li - (li & -li)
    tree[:-1] = np.minimum(li, c_rep) - np.minimum(lo, c_rep)
    depth = int(span.max(initial=1)).bit_length()
    rounds = int(counts.max(initial=0))
    return tree, b, starts, counts, toff[:-1], span, depth, rounds


@functools.cache
def _jax_queue_walk(depth: int):
    """Jitted lock-step Fenwick sweep: all rounds in one ``fori_loop``, no
    host round-trip between rounds.  ``depth`` (the per-round chain length)
    is static and unrolled; shapes retrace per arena layout."""
    import jax
    import jax.numpy as jnp

    def walk(tree, b, starts, counts, toff, span, rounds):
        sink = tree.shape[0] - 1
        steps0 = jnp.zeros(b.shape, dtype=tree.dtype)

        def round_body(j, state):
            tree, steps = state
            mask = counts > j
            s = jnp.where(mask, starts + j, 0)
            p = jnp.where(mask, b[s] + 1, 0)
            # prefix: maskless gathers (a chain that reaches 0 keeps
            # reading its region's always-zero root)
            i = p
            acc = jnp.zeros_like(p)
            for _ in range(depth):
                acc = acc + tree[toff + i]
                i = i - (i & -i)
            steps = steps.at[s].add(jnp.where(mask, acc, 0))
            # removal: chains past the region span (and inactive regions)
            # park at the shared sink slot, which is never read
            i = p
            bound = jnp.where(mask, span, -1)
            idx = jnp.where(mask, toff + i, sink)
            delta = jnp.where(mask, -1, 0).astype(tree.dtype)
            for _ in range(depth):
                tree = tree.at[idx].add(delta)
                i = i + (i & -i)
                idx = jnp.where(i > bound, sink, toff + i)
            return tree, steps

        _, steps = jax.lax.fori_loop(0, rounds, round_body, (tree, steps0))
        return steps

    return jax.jit(walk)


def _queue_walk_pallas_kernel(tree_ref, b_ref, starts_ref, counts_ref,
                              toff_ref, span_ref, steps_ref, *,
                              depth: int, rounds: int):
    """The same lock-step rounds as :func:`_jax_queue_walk`, fused into one
    Pallas program: every tree/arrival array resident for the whole sweep."""
    import jax
    import jax.numpy as jnp

    tree = tree_ref[0, :]
    b = b_ref[0, :]
    starts = starts_ref[0, :]
    counts = counts_ref[0, :]
    toff = toff_ref[0, :]
    span = span_ref[0, :]
    sink = tree.shape[0] - 1
    steps0 = jnp.zeros(b.shape, dtype=tree.dtype)

    def round_body(j, state):
        tree, steps = state
        mask = counts > j
        s = jnp.where(mask, starts + j, 0)
        p = jnp.where(mask, b[s] + 1, 0)
        i = p
        acc = jnp.zeros_like(p)
        for _ in range(depth):
            acc = acc + tree[toff + i]
            i = i - (i & -i)
        steps = steps.at[s].add(jnp.where(mask, acc, 0))
        i = p
        bound = jnp.where(mask, span, -1)
        idx = jnp.where(mask, toff + i, sink)
        delta = jnp.where(mask, -1, 0).astype(tree.dtype)
        for _ in range(depth):
            tree = tree.at[idx].add(delta)
            i = i + (i & -i)
            idx = jnp.where(i > bound, sink, toff + i)
        return tree, steps

    _, steps = jax.lax.fori_loop(0, rounds, round_body, (tree, steps0))
    steps_ref[0, :] = steps


@functools.cache
def _pallas_queue_walk(n_pad: int, r_pad: int, t_pad: int, depth: int,
                       rounds: int):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def row(w):
        return pl.BlockSpec((1, w), lambda i: (0, 0))

    return pl.pallas_call(
        functools.partial(_queue_walk_pallas_kernel, depth=depth,
                          rounds=rounds),
        grid=(1,),
        in_specs=[row(t_pad), row(n_pad), row(r_pad), row(r_pad),
                  row(r_pad), row(r_pad)],
        out_specs=row(n_pad),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
        interpret=jax.default_backend() == "cpu",
    )


def _pad_row(a, width, fill, dtype=np.int32):
    out = np.full((1, width), fill, dtype=dtype)
    out[0, :a.size] = a
    return out


def queue_walk(posted, arrival, bounds, backend: str | None = None) -> np.ndarray:
    """Batched receive-queue walk lengths on the chosen backend.

    Same contract as
    :func:`repro.comm.primitives.batched_queue_traversal_steps` (region
    ``r`` owns slots ``bounds[r]:bounds[r+1]`` of ``posted``/``arrival``;
    returns per-arrival steps in the same layout).  The walk is integer
    work, so every backend is bit-equal to the numpy reference — the device
    paths just run all rounds in one program instead of one host-synced
    array pass per round.  Index arithmetic runs in int32 on device
    (arenas beyond 2^31 - 1 queue slots must use numpy).  Device-backend
    failures degrade to the numpy reference via :func:`device_guard`
    (site ``kernel.queue_walk``) — bit-identically, since the walk is
    integer work.
    """
    if backend in (None, "auto"):
        backend = resolve_backend("auto", n_values=_size_of(posted))
    else:
        backend = resolve_backend(backend)

    def numpy_fn():
        from repro.comm.primitives import batched_queue_traversal_steps
        return batched_queue_traversal_steps(posted, arrival, bounds)

    if backend == "numpy":
        return numpy_fn()

    tree, b, starts, counts, toff, span, depth, rounds = _queue_layout(
        posted, arrival, bounds)
    N = int(b.size)
    if N == 0 or rounds == 0:
        return np.zeros(N, dtype=np.int64)
    if tree.size - 1 >= np.iinfo(np.int32).max:       # pragma: no cover
        return numpy_fn()

    def device_fn():
        import jax.numpy as jnp
        if backend == "jax":
            walk = _jax_queue_walk(depth)
            steps = walk(jnp.asarray(tree, jnp.int32),
                         jnp.asarray(b, jnp.int32),
                         jnp.asarray(starts, jnp.int32),
                         jnp.asarray(counts, jnp.int32),
                         jnp.asarray(toff, jnp.int32),
                         jnp.asarray(span, jnp.int32), rounds)
            return np.asarray(steps, dtype=np.int64)
        # pallas: pad every row to a lane multiple; padded regions have
        # count 0 (never active) and padded chains park at the shared sink
        def up(n):
            return max(_LANE, -(-n // _LANE) * _LANE)

        n_pad, r_pad, t_pad = up(N), up(int(counts.size)), up(int(tree.size))
        call = _pallas_queue_walk(n_pad, r_pad, t_pad, depth, rounds)
        steps = call(_pad_row(tree, t_pad, 0), _pad_row(b, n_pad, 0),
                     _pad_row(starts, r_pad, 0), _pad_row(counts, r_pad, 0),
                     _pad_row(toff, r_pad, 0), _pad_row(span, r_pad, 0))
        return np.asarray(steps)[0, :N].astype(np.int64)

    return device_guard("kernel.queue_walk", backend, device_fn, numpy_fn)


# -- deprecated one-hot era shims --------------------------------------------

#: Deprecated: the retired one-hot kernel's work ceiling.  The fused
#: scatter-accumulate kernel is O(messages), so no limit applies; the
#: constant is kept (with :func:`pallas_within_limit`) so external callers
#: written against the old reroute logic keep working.
PALLAS_ONE_HOT_LIMIT = 1 << 24


def pallas_within_limit(n_values: int, n_seg: int) -> bool:
    """Deprecated: always True.

    The one-hot Pallas kernel this guarded was replaced by the fused
    scatter-accumulate kernel (:func:`fused_segment_reduce`), which is
    O(messages) — there is no work ceiling and no jax reroute.  Warns once
    per process (via the resettable
    :class:`repro.comm.health.BackendHealth` registry), then delegates to
    the new behaviour (every size is within limit).
    """
    get_health().warn_once(
        "kernels.one_hot_deprecated",
        "pallas_within_limit/PALLAS_ONE_HOT_LIMIT are deprecated: the "
        "one-hot kernel was replaced by a fused scatter-accumulate "
        "kernel with no size limit; the pallas backend now handles "
        "every request directly", category=DeprecationWarning, stacklevel=3)
    return True
