"""Optional JAX / Pallas backends for the PhaseStack segmented reductions.

The stacked sweep engine (:mod:`repro.comm.stack`) reduces per-message
quantities to per-(phase, process) / per-(phase, link) aggregates with two
primitives: segmented sum and segmented max over packed integer keys.  This
module provides accelerator implementations of exactly those two:

``backend='jax'``
    ``jax.ops.segment_sum`` / ``segment_max`` under ``jax.jit`` — the
    scalable path (scatter-add, O(total messages)).
``backend='pallas'``
    A Pallas segment-reduce kernel: the message stream is chunked, each
    ``(segment-block, chunk)`` grid step builds the chunk's one-hot
    membership matrix against its 128-wide segment block and reduces it on
    the MXU (``values @ one_hot`` for sums, a masked row-max for maxima),
    accumulating across chunks in the resident output block — the
    flash-attention accumulate idiom.  O(messages x segments) work: it is
    the MXU-shaped demonstration/parity backend, not the scalable one, so
    requests whose padded one-hot work exceeds ``PALLAS_ONE_HOT_LIMIT``
    reroute to the jitted jax path (:func:`pallas_within_limit`).

numpy is the default everywhere and the silent fallback when jax is absent
(:func:`resolve_backend` warns once).  Backend parity is *allclose*, not
bit-equal: the accelerator paths run float32 (tests pin the tolerance).

This module imports jax lazily so that importing it — and everything in
:mod:`repro.comm` — stays numpy-only.
"""
from __future__ import annotations

import functools
import warnings

import numpy as np

BACKENDS = ("numpy", "jax", "pallas")

_CHUNK = 512        # messages per grid step
_SEG_BLOCK = 128    # segments per output block (one lane tile)

#: Ceiling on the Pallas kernel's total one-hot work, in (padded message,
#: padded segment) cells.  The kernel is O(messages x segments) — every grid
#: step materializes a (_CHUNK, _SEG_BLOCK) membership matrix, and interpret
#: mode (CPU) buffers far more than that — so a large sweep arena would both
#: crawl and blow up memory.  Above this limit the request silently reroutes
#: to the scalable jitted ``segment_sum``/``segment_max`` path (O(messages)
#: scatter-add); numpy fallback behaviour is unchanged.
PALLAS_ONE_HOT_LIMIT = 1 << 24


def pallas_within_limit(n_values: int, n_seg: int) -> bool:
    """Would the Pallas one-hot kernel stay under ``PALLAS_ONE_HOT_LIMIT``?

    Uses the *padded* extents (chunk/segment-block multiples), i.e. exactly
    the cell count the kernel would sweep.
    """
    n_pad = max(_CHUNK, -(-n_values // _CHUNK) * _CHUNK)
    s_pad = max(_SEG_BLOCK, -(-n_seg // _SEG_BLOCK) * _SEG_BLOCK)
    return n_pad * s_pad <= PALLAS_ONE_HOT_LIMIT


def have_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:  # pragma: no cover - environment-dependent
        return False


def resolve_backend(backend: str) -> str:
    """Validate a backend name; fall back to numpy (with a warning) when the
    accelerator stack is unavailable."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown stack backend {backend!r}; expected one of {BACKENDS}")
    if backend != "numpy" and not have_jax():
        warnings.warn(f"stack backend {backend!r} requested but jax is not "
                      "importable; falling back to numpy", RuntimeWarning,
                      stacklevel=2)
        return "numpy"
    return backend


# -- jitted segment reductions ----------------------------------------------

@functools.cache
def _jax_segment_ops():
    import jax

    @functools.partial(jax.jit, static_argnames=("n_seg",))
    def seg_sum(vals, ids, n_seg):
        return jax.ops.segment_sum(vals, ids, num_segments=n_seg)

    @functools.partial(jax.jit, static_argnames=("n_seg",))
    def seg_max(vals, ids, n_seg):
        return jax.ops.segment_max(vals, ids, num_segments=n_seg)

    return seg_sum, seg_max


# -- Pallas segment-reduce kernel --------------------------------------------

def _segreduce_kernel(ids_ref, vals_ref, out_ref, *, op: str):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    sb, c = pl.program_id(0), pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        fill = 0.0 if op == "sum" else -jnp.inf
        out_ref[...] = jnp.full_like(out_ref, fill)

    ids = ids_ref[0, :]                                   # [M]
    vals = vals_ref[0, :]                                 # [M]
    m, s = ids.shape[0], out_ref.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, s), 1) + sb * s
    member = ids[:, None] == cols                         # [M, S] one-hot
    if op == "sum":
        out_ref[...] += jnp.dot(vals[None, :],
                                member.astype(vals.dtype))
    else:
        part = jnp.max(jnp.where(member, vals[:, None], -jnp.inf),
                       axis=0)                            # [S]
        out_ref[...] = jnp.maximum(out_ref[...], part[None, :])


@functools.cache
def _pallas_segreduce(n_pad: int, s_pad: int, op: str):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    grid = (s_pad // _SEG_BLOCK, n_pad // _CHUNK)
    return pl.pallas_call(
        functools.partial(_segreduce_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, _CHUNK), lambda sb, c: (0, c)),
            pl.BlockSpec((1, _CHUNK), lambda sb, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, _SEG_BLOCK), lambda sb, c: (0, sb)),
        out_shape=jax.ShapeDtypeStruct((1, s_pad), jnp.float32),
        interpret=jax.default_backend() == "cpu",
    )


def _pallas_reduce(values, seg_ids, n_seg: int, op: str) -> np.ndarray:
    import jax.numpy as jnp

    n = values.size
    n_pad = max(_CHUNK, -(-n // _CHUNK) * _CHUNK)
    s_pad = max(_SEG_BLOCK, -(-n_seg // _SEG_BLOCK) * _SEG_BLOCK)
    ids = np.full((1, n_pad), -1, dtype=np.int32)         # -1 matches no block
    ids[0, :n] = seg_ids
    vals = np.zeros((1, n_pad), dtype=np.float32)
    vals[0, :n] = values
    out = _pallas_segreduce(n_pad, s_pad, op)(jnp.asarray(ids),
                                              jnp.asarray(vals))
    out = np.asarray(out)[0, :n_seg].astype(np.float64)
    if op == "max":
        out[np.isneginf(out)] = 0.0                       # empty segments
    return out


# -- public entry points -----------------------------------------------------

def segment_sum(values, seg_ids, n_seg: int, backend: str = "numpy") -> np.ndarray:
    """Sum ``values`` into ``n_seg`` bins by ``seg_ids`` on the chosen backend."""
    values = np.asarray(values, dtype=np.float64)
    seg_ids = np.asarray(seg_ids, dtype=np.int64)
    if backend == "numpy":
        return np.bincount(seg_ids, weights=values, minlength=n_seg)
    if backend == "pallas" and pallas_within_limit(values.size, n_seg):
        return _pallas_reduce(values, seg_ids, n_seg, "sum")
    import jax.numpy as jnp
    seg_sum, _ = _jax_segment_ops()
    return np.asarray(seg_sum(jnp.asarray(values, jnp.float32),
                              jnp.asarray(seg_ids), n_seg), dtype=np.float64)


def segment_max(values, seg_ids, n_seg: int, backend: str = "numpy") -> np.ndarray:
    """Per-segment maximum (0.0 for empty segments, matching the stacked
    contention reduction where all inputs are non-negative byte counts)."""
    values = np.asarray(values, dtype=np.float64)
    seg_ids = np.asarray(seg_ids, dtype=np.int64)
    if backend == "numpy":
        out = np.zeros(n_seg)
        np.maximum.at(out, seg_ids, values)
        return out
    if backend == "pallas" and pallas_within_limit(values.size, n_seg):
        return _pallas_reduce(values, seg_ids, n_seg, "max")
    import jax.numpy as jnp
    _, seg_max = _jax_segment_ops()
    out = np.asarray(seg_max(jnp.asarray(values, jnp.float32),
                             jnp.asarray(seg_ids), n_seg), dtype=np.float64)
    out[np.isneginf(out)] = 0.0
    return out
