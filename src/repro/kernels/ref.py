"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: [B,S,H,D], k/v: [B,S,KH,D] -> [B,S,H,D] (exact softmax attention)."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    rep = H // KH
    qg = q.reshape(B, S, KH, rep, D)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def ssd_intra_chunk_ref(dtx, Bm, Cm, cumA):
    """dtx: [G,q,p], Bm/Cm: [G,q,n], cumA: [G,q,1] -> (y [G,q,p], S [G,n,p])."""
    q = dtx.shape[1]
    cum = cumA[..., 0]                                    # [G, q]
    cb = jnp.einsum("gin,gjn->gij", Cm, Bm)
    ln = cum[:, :, None] - cum[:, None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    ln = jnp.where(mask[None], ln, NEG_INF)
    scores = cb * jnp.exp(ln)
    y = jnp.einsum("gij,gjp->gip", scores, dtx)
    seg = jnp.exp(cum[:, -1:] - cum)                      # [G, q]
    s = jnp.einsum("gjn,gj,gjp->gnp", Bm, seg, dtx)
    return y, s


def spmv_block_ell_ref(blocks, cols, x):
    """blocks: [nbr,max_bpr,bs,bs], cols: [nbr,max_bpr], x: [ncb*bs]."""
    nbr, max_bpr, bs, _ = blocks.shape
    xb = x.reshape(-1, bs)
    gathered = xb[cols]                                   # [nbr, max_bpr, bs]
    y = jnp.einsum("rsij,rsj->ri", blocks.astype(jnp.float32),
                   gathered.astype(jnp.float32))
    return y.reshape(nbr * bs).astype(x.dtype)
