"""Mamba2 SSD intra-chunk kernel (Pallas TPU).

The SSD block decomposition's compute hot-spot is the within-chunk part:
for each (batch, chunk, head) program,

    scores[i,j] = (C_i . B_j) * exp(cumA_i - cumA_j)   for i >= j
    y[i]        = sum_j scores[i,j] * dtx[j]           [q, p]
    S_c         = sum_j exp(cumA_last - cumA_j) B_j dtx_j^T   [n, p]

Both matmuls are MXU-shaped ([q,n]x[n,q] and [q,q]x[q,p] with q=n=128,
p=64); the whole working set (~250 KiB f32) sits in VMEM.  The inter-chunk
recurrence (tiny state updates) stays in JAX.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_kernel(dtx_ref, b_ref, c_ref, a_ref, y_ref, s_ref, *, q: int):
    dtx = dtx_ref[0].astype(jnp.float32)        # [q, p]
    Bm = b_ref[0].astype(jnp.float32)           # [q, n]
    Cm = c_ref[0].astype(jnp.float32)           # [q, n]
    cumA = a_ref[0].astype(jnp.float32)         # [q, 1]

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # [q, q]
    ln_decay = cumA - cumA.T                                     # [q, q] i-j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    ln_decay = jnp.where(ii >= jj, ln_decay, NEG_INF)
    scores = cb * jnp.exp(ln_decay)
    y_ref[0] = jax.lax.dot(scores, dtx).astype(y_ref.dtype)      # [q, p]

    seg = jnp.exp(cumA[-1:, :] - cumA)                           # [q, 1]
    bw = Bm * seg                                                # [q, n]
    s_ref[0] = jax.lax.dot_general(
        bw, dtx, (((0,), (0,)), ((), ()))).astype(s_ref.dtype)   # [n, p]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(dtx, Bm, Cm, cumA, interpret: bool = False):
    """Batched intra-chunk SSD.

    dtx: [G, q, p] (dt_j * x_j, f32); Bm/Cm: [G, q, n]; cumA: [G, q, 1]
    (inclusive cumulative log-decay).  G = batch*chunks*heads, flattened by
    the caller.  Returns (y_intra [G, q, p], S_c [G, n, p]).
    """
    G, q, p = dtx.shape
    n = Bm.shape[-1]
    y, s = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, q, 1), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, n, p), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, q, p), jnp.float32),
            jax.ShapeDtypeStruct((G, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(dtx, Bm, Cm, cumA)
    return y, s
