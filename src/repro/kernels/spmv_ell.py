"""Block-ELL SpMV as a Pallas TPU kernel — the paper's SpMV hot-spot,
adapted to TPU.

Hardware adaptation (DESIGN.md §2): a CUDA CSR SpMV is a scalar-gather
kernel, which the TPU's systolic MXU cannot exploit.  The TPU-native layout
is *block*-sparse ELL: rows grouped into bs-row blocks, each block row
holding up to ``max_bpr`` dense bs x bs blocks plus their block-column ids.
Each grid step does one bs x bs MXU matmul; the needed x-block is selected
by a scalar-prefetch index map (cols are prefetched to SMEM before the grid
runs, so the x BlockSpec can depend on them).  Padding slots point at block
column 0 with zero data — they contribute nothing.

For AMG matrices, bs=8..32 matches the 3-dof node blocks well (see
benchmarks/bench_kernels.py for the density trade-off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_kernel(cols_ref, blocks_ref, x_ref, y_ref, acc, *, nslots: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a = blocks_ref[0, 0].astype(jnp.float32)        # [bs, bs]
    xb = x_ref[0].astype(jnp.float32)               # [bs, 1]
    acc[...] += jax.lax.dot(a, xb)

    @pl.when(s == nslots - 1)
    def _done():
        y_ref[0] = acc[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_block_ell(blocks, cols, x, interpret: bool = False):
    """y = A @ x with A in block-ELL form.

    blocks: [nbr, max_bpr, bs, bs]; cols: [nbr, max_bpr] int32 block-column
    ids; x: [ncb * bs].  Returns y: [nbr * bs].
    """
    nbr, max_bpr, bs, _ = blocks.shape
    x2 = x.reshape(-1, bs, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nbr, max_bpr),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda r, s, cols: (r, s, 0, 0)),
            pl.BlockSpec((1, bs, 1), lambda r, s, cols: (cols[r, s], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, 1), lambda r, s, cols: (r, 0, 0)),
        scratch_shapes=[pltpu.VMEM((bs, 1), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_spmv_kernel, nslots=max_bpr),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nbr, bs, 1), x.dtype),
        interpret=interpret,
    )(cols, blocks, x2)
    return y.reshape(nbr * bs)


# ------------------------------------------------- host-side conversion -----
def csr_to_block_ell(csr, bs: int = 8):
    """Convert a repro.sparse CSR matrix to padded block-ELL arrays."""
    n, m = csr.shape
    nbr = -(-n // bs)
    ncb = -(-m // bs)
    rows = np.repeat(np.arange(n), np.diff(csr.indptr))
    br = rows // bs
    bc = csr.indices // bs
    # unique block coordinates
    key = br * ncb + bc
    uniq = np.unique(key)
    ub, uc = uniq // ncb, uniq % ncb
    counts = np.bincount(ub, minlength=nbr)
    max_bpr = int(counts.max()) if counts.size else 1
    blocks = np.zeros((nbr, max_bpr, bs, bs), dtype=np.float32)
    cols = np.zeros((nbr, max_bpr), dtype=np.int32)
    slot_of = {}
    next_slot = np.zeros(nbr, dtype=np.int64)
    for b_, c_ in zip(ub, uc):
        s = next_slot[b_]
        slot_of[(b_, c_)] = s
        cols[b_, s] = c_
        next_slot[b_] += 1
    # scatter entries
    for r, c, v in zip(rows, csr.indices, csr.data):
        b_, c_ = r // bs, c // bs
        s = slot_of[(b_, c_)]
        blocks[b_, s, r % bs, c % bs] = v
    return jnp.asarray(blocks), jnp.asarray(cols), max_bpr
