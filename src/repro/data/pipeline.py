"""Deterministic synthetic token pipeline with per-host sharding, prefetch,
and fault re-dispatch.

Determinism contract: batch(step, shard) is a pure function of
(seed, step, shard) — so a restarted or re-meshed job replays the exact same
token stream, and a dead host's shards can be recomputed by any survivor
(``shard_assignment``).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


def shard_assignment(n_shards: int, alive_hosts: list[int]) -> dict[int, list[int]]:
    """Round-robin shard ownership over the alive hosts (straggler/failure
    re-dispatch).  Deterministic: every survivor computes the same map."""
    alive = sorted(alive_hosts)
    out: dict[int, list[int]] = {h: [] for h in alive}
    for s in range(n_shards):
        out[alive[s % len(alive)]].append(s)
    return out


class SyntheticTokens:
    """Deterministic LM token batches.

    Yields dicts matching the model's batch contract for the arch family.
    """

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 n_shards: int = 1, shard: int = 0, seed: int = 0,
                 prefetch: int = 2, family: str = "dense",
                 d_model: int = 0, encoder_seq: int = 0):
        assert batch % n_shards == 0
        self.vocab = vocab_size
        self.local_batch = batch // n_shards
        self.seq = seq_len
        self.shard = shard
        self.n_shards = n_shards
        self.seed = seed
        self.family = family
        self.d_model = d_model
        self.encoder_seq = encoder_seq
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = 0
        self._thread: threading.Thread | None = None

    # -- pure batch function --------------------------------------------------
    def batch_at(self, step: int, shard: int | None = None) -> dict:
        shard = self.shard if shard is None else shard
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        b = {"tokens": rng.integers(
            1, self.vocab, (self.local_batch, self.seq)).astype(np.int32)}
        if self.family == "vlm":
            b = {"embeds": rng.standard_normal(
                     (self.local_batch, self.seq, self.d_model)
                 ).astype(np.float32),
                 "positions": np.broadcast_to(
                     np.arange(self.seq, dtype=np.int32)[None, :, None],
                     (self.local_batch, self.seq, 3)).copy(),
                 "targets": rng.integers(
                     1, self.vocab,
                     (self.local_batch, self.seq)).astype(np.int32)}
        elif self.family == "audio":
            b["frames"] = rng.standard_normal(
                (self.local_batch, self.encoder_seq, self.d_model)
            ).astype(np.float32)
        return b

    # -- prefetching iterator -------------------------------------------------
    def _producer(self):
        step = self._step
        while True:
            self._q.put((step, self.batch_at(step)))
            step += 1

    def __iter__(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._producer, daemon=True)
            self._thread.start()
        return self

    def __next__(self):
        step, batch = self._q.get()
        self._step = step + 1
        return batch
