from .pipeline import SyntheticTokens, shard_assignment

__all__ = ["SyntheticTokens", "shard_assignment"]
