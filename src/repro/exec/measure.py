"""Timed schedule runs and measured-vs-predicted strategy orderings.

:func:`time_schedule` runs a lowered schedule on the forced multi-device
host mesh with warmup iterations followed by ``reps`` timed runs, reporting
the **median** (warmup + median-of-k: compilation lands in warmup, the
median rejects scheduler outliers).  :func:`measure_strategies` sweeps
every strategy of a phase through lower + time; :func:`predicted_costs`
prices the same strategies' pricing plans through the model ladder —
optionally with a *fitted* parameter table from
:mod:`repro.exec.calibrate` — and :func:`ordering` /
:func:`pairwise_agreement` turn both cost dicts into comparable rankings.

Only the timing functions touch jax (lazily); the prediction/agreement
half is numpy-only so the docs and benches can rank strategies without a
device runtime.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.comm.phase import CommPhase
from repro.comm.strategies import rewrite, strategies_for

from .plan import UNIT_BYTES, ExecSchedule, build_schedule


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One timed schedule: ``median_s`` over ``times_s`` (the individual
    timed runs, post-warmup), plus the schedule's round count ``n_rounds``
    for overhead normalization."""

    median_s: float
    times_s: tuple
    n_rounds: int


def time_schedule(schedule: ExecSchedule, *, mesh=None, reps: int = 5,
                  warmup: int = 2) -> Measurement:
    """Time ``schedule`` on the JAX path: ``warmup`` untimed runs (the
    first compiles), then ``reps`` timed runs, median reported.  ``mesh``
    as in :func:`repro.exec.lower.build_executor`."""
    from .lower import build_executor
    run = build_executor(schedule, mesh=mesh)
    for _ in range(max(1, warmup)):
        run()
    times = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    return Measurement(median_s=float(np.median(times)),
                       times_s=tuple(times), n_rounds=schedule.n_rounds)


def launch_overhead(phase: CommPhase, *, mesh=None, reps: int = 5,
                    warmup: int = 2) -> float:
    """The fixed cost of launching a lowered schedule, in seconds: the
    median time of the ``standard`` schedule of an *empty* exchange bound
    to ``phase``'s machine (same rank count, zero messages — all launch,
    no transport).  ``mesh`` / ``reps`` / ``warmup`` as in
    :func:`time_schedule`."""
    empty = CommPhase.build(phase.machine, [], [], [],
                            n_procs=phase.n_procs)
    sched = build_schedule(empty, "standard")
    return time_schedule(sched, mesh=mesh, reps=reps, warmup=warmup).median_s


def measure_strategies(phase: CommPhase, strategies=None, *,
                       unit_bytes: float = UNIT_BYTES,
                       coloring: str = "greedy", mesh=None, reps: int = 5,
                       warmup: int = 2) -> dict:
    """Lower and time every strategy of ``phase``: returns ``{strategy:
    (ExecSchedule, Measurement)}``.  ``strategies`` defaults to
    :func:`repro.comm.strategies.strategies_for` the phase's machine;
    ``unit_bytes`` / ``coloring`` feed the planner and ``mesh`` / ``reps``
    / ``warmup`` feed :func:`time_schedule`."""
    names = (strategies if strategies is not None
             else strategies_for(phase.machine))
    out = {}
    for name in names:
        sched = build_schedule(phase, name, unit_bytes=unit_bytes,
                               coloring=coloring)
        out[name] = (sched, time_schedule(sched, mesh=mesh, reps=reps,
                                          warmup=warmup))
    return out


def predicted_costs(phase: CommPhase, strategies=None, *,
                    level: str = "contention", params=None) -> dict:
    """Model-ladder cost per strategy of ``phase`` at ladder ``level``:
    ``{strategy: predicted_seconds}``.  ``params`` substitutes a fitted
    table (:func:`repro.exec.calibrate.calibrate`) for the machine's ground
    truth — the calibrated-model side of the measured-vs-predicted
    comparison; ``strategies`` as in :func:`measure_strategies`."""
    from repro.core.models import sequence_cost
    names = (strategies if strategies is not None
             else strategies_for(phase.machine))
    return {name: float(sequence_cost(rewrite(phase, name).phases,
                                      level=level, params=params).total)
            for name in names}


def ordering(costs: dict) -> tuple:
    """Strategy names of the ``costs`` dict, cheapest first (ties broken by
    name for determinism)."""
    return tuple(sorted(costs, key=lambda k: (costs[k], k)))


def pairwise_agreement(a: dict, b: dict) -> float:
    """Fraction of strategy pairs ranked in the same order by cost dicts
    ``a`` and ``b`` (1.0 = identical orderings; keys must match).  This is
    the ordering-agreement statistic ``bench_exec`` reports."""
    if set(a) != set(b):
        raise ValueError(f"orderings cover different strategies: "
                         f"{sorted(a)} vs {sorted(b)}")
    names = sorted(a)
    same = total = 0
    for i, x in enumerate(names):
        for y in names[i + 1:]:
            total += 1
            same += (a[x] < a[y]) == (b[x] < b[y])
    return 1.0 if total == 0 else same / total
