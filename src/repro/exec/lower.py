"""Lower an :class:`~repro.exec.plan.ExecSchedule` to a jitted JAX program.

The program is one ``jax.jit`` around one
:func:`repro.parallel._jax_compat.shard_map` over a 1-D ``("rank",)`` mesh
(:func:`repro.launch.mesh.make_rank_mesh`): every simulated MPI rank owns
one mesh device, its row of the holding/delivered buffers, and its rows of
each round's index tables.  Per round the body gathers the rank's ``pack``
slots from its holding buffer, moves them with a single static
:func:`~repro.parallel._jax_compat.ppermute` (the round's permutation is
baked in at trace time — rounds unroll, no dynamic control flow), and
scatter-adds the received slots into the holding (``stage``) and delivered
(``final``) buffers.  Padding flows through the sink column, which both
sides index for unused slots, so junk never aliases a real unit; the sink
is trimmed before returning.

Payloads are int32 and scatter-adds touch disjoint real columns, so the
result is bit-identical to the serial numpy walk of the same tables
(:func:`repro.exec.reference.run_reference`) — the oracle
:mod:`tests.test_exec` pins on the forced 8-device host mesh.

jax is imported lazily inside the functions: importing this module (for
docs and docstring coverage) needs numpy only.
"""
from __future__ import annotations

import numpy as np

from .plan import ExecSchedule


def initial_buffers(schedule: ExecSchedule) -> tuple[np.ndarray, np.ndarray]:
    """The executor's starting ``(hold, deliv)`` int32 buffers for
    ``schedule``, each ``(n_procs, n_units + 1)`` with the sink column last:
    every unit's payload sits in its origin rank's holding row, and units
    already at home (origin == destination) are pre-delivered."""
    P, U = schedule.n_procs, schedule.n_units
    units = np.arange(U)
    hold = np.zeros((P, U + 1), dtype=np.int32)
    deliv = np.zeros((P, U + 1), dtype=np.int32)
    hold[schedule.unit_src, units] = schedule.payload
    at_home = schedule.unit_src == schedule.unit_dst
    deliv[schedule.unit_dst[at_home], units[at_home]] = \
        schedule.payload[at_home]
    return hold, deliv


def build_executor(schedule: ExecSchedule, mesh=None):
    """Compile ``schedule`` into a zero-argument callable returning the
    delivered ``(n_procs, n_units)`` int32 matrix (host numpy, sink
    trimmed).

    ``mesh`` is the 1-D ``("rank",)`` mesh to run on, defaulting to
    :func:`repro.launch.mesh.make_rank_mesh` over the schedule's rank
    count.  The callable re-runs the jitted program on each invocation
    (compilation is cached by jax), which is what
    :func:`repro.exec.measure.time_schedule` times.
    """
    import jax

    from repro.launch.mesh import make_rank_mesh
    from repro.parallel._jax_compat import ppermute, shard_map

    if mesh is None:
        mesh = make_rank_mesh(schedule.n_procs)
    hold0, deliv0 = initial_buffers(schedule)

    perms = []
    tables = []
    for phase in schedule.phases:
        for rnd in phase.rounds:
            perms.append(tuple((int(s), int(d)) for s, d in rnd.perm))
            tables.append((np.asarray(rnd.pack, dtype=np.int32),
                           np.asarray(rnd.stage, dtype=np.int32),
                           np.asarray(rnd.final, dtype=np.int32)))
    tables = tuple(tables)

    def step(hold, deliv, round_tables):
        h, dv = hold[0], deliv[0]
        for perm, (pack, stage, final) in zip(perms, round_tables):
            send = h[pack[0]]
            recv = ppermute(send, "rank", perm)
            h = h.at[stage[0]].add(recv)
            dv = dv.at[final[0]].add(recv)
        return dv[None]

    spec = jax.sharding.PartitionSpec("rank")
    args = (hold0, deliv0, tables)
    in_specs = jax.tree_util.tree_map(lambda _: spec, args)
    fn = jax.jit(shard_map(step, mesh=mesh, in_specs=in_specs,
                           out_specs=spec))

    def run() -> np.ndarray:
        out = jax.block_until_ready(fn(*args))
        return np.asarray(out)[:, :schedule.n_units]

    return run


def execute(schedule: ExecSchedule, mesh=None,
            digest_backend: str | None = None):
    """Run ``schedule`` once on the JAX path and return ``(delivered,
    digest)``: the delivered int32 matrix and its per-rank payload totals
    reduced through the fused segment kernels
    (:func:`repro.exec.reference.delivered_digest`, device-backed when
    ``digest_backend`` is ``'jax'``/``'pallas'``).  ``mesh`` as in
    :func:`build_executor`."""
    from .reference import delivered_digest
    delivered = build_executor(schedule, mesh=mesh)()
    return delivered, delivered_digest(delivered, schedule,
                                       backend=digest_backend)
