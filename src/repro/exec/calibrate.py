"""Fitted parameter tables from recorded ping-pong sweeps.

The model-vs-measured comparison is only honest if the model side does not
peek at the simulator's ground-truth rate tables.  This module closes that
loop the way the paper does: *record* the measurement suite once
(:func:`record_sweeps` — per-locality ping-pong size sweeps over **both**
network paths, plus the ppn saturation sweep per path), optionally ship it
as JSON (:meth:`SweepRecord.to_json`), and *fit* a fresh
:class:`~repro.core.params.CommParams` from the record alone
(:func:`calibrate`): per-class (alpha, R_b) tables via
:func:`repro.core.fitting.fit_node_aware_table`, the rail count via
:func:`repro.core.fitting.fit_rails`, and the per-rail injection cap R_N
via the rails-exact :func:`repro.core.fitting.fit_RN_rails`.

Two conventions to know when reading fitted numbers:

* the simulator charges one queue step per received message, so a
  single-message ping-pong pays ``alpha + gamma``; the fitted alpha
  *absorbs* gamma.  That is a feature, not a bias — every model prediction
  made with fitted params prices that same per-message step implicitly,
  and gamma/delta themselves keep their base values (they need the
  dedicated high-volume/contention harnesses, out of scope here).
* network-path kinds are measured on a machine *rebuilt* with that path
  (``cross_node_locality`` repointed), mirroring how a real calibration
  run re-launches the benchmark with a different transport setting.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.comm.phase import CommPhase
from repro.core.fitting import (fit_node_aware_table, fit_rails,
                                fit_RN_rails)
from repro.core.params import PROTOCOL_NAMES, CommParams
from repro.net.machine import MachineSpec
from repro.net.pingpong import pingpong_sweep, ppn_sweep
from repro.net.simulator import simulate

#: Default ping-pong size grid: two sizes per protocol regime or better
#: under the default thresholds (short <= 512 < eager <= 8192 < rend).
DEFAULT_SIZES = (64.0, 256.0, 1024.0, 4096.0,
                 16384.0, 65536.0, 262144.0, 1048576.0)

#: Default ppn-sweep message size: deep in the rendezvous regime so the
#: injection cap binds early (the staircase fit needs saturation).
PPN_SIZE = float(1 << 20)


@dataclasses.dataclass(frozen=True)
class SweepRecord:
    """One machine's recorded measurement suite.

    ``pingpong[kind]`` holds the ping-pong times for ``sizes`` (one entry
    per locality-class kind, network paths measured on the matching
    rebuilt machine); ``ppn[kind]`` holds the ``(ks, times)`` saturation
    sweep at ``ppn_size`` bytes per network-path kind; ``machine`` is the
    preset name the record came from.
    """

    machine: str
    sizes: np.ndarray
    pingpong: dict
    ppn_size: float
    ppn: dict

    def to_json(self) -> str:
        """Serialize the record to a JSON string (arrays as lists) — the
        on-disk form a real calibration run would ship."""
        return json.dumps({
            "machine": self.machine,
            "sizes": np.asarray(self.sizes).tolist(),
            "pingpong": {k: np.asarray(v).tolist()
                         for k, v in self.pingpong.items()},
            "ppn_size": self.ppn_size,
            "ppn": {k: [np.asarray(ks).tolist(), np.asarray(ts).tolist()]
                    for k, (ks, ts) in self.ppn.items()},
        })

    @classmethod
    def from_json(cls, text: str) -> "SweepRecord":
        """Rebuild a record from its :meth:`to_json` string ``text``."""
        d = json.loads(text)
        return cls(machine=d["machine"],
                   sizes=np.asarray(d["sizes"], dtype=np.float64),
                   pingpong={k: np.asarray(v, dtype=np.float64)
                             for k, v in d["pingpong"].items()},
                   ppn_size=float(d["ppn_size"]),
                   ppn={k: (np.asarray(ks, dtype=np.float64),
                            np.asarray(ts, dtype=np.float64))
                        for k, (ks, ts) in d["ppn"].items()})


def _with_network_path(machine: MachineSpec, kind: str) -> MachineSpec:
    """``machine`` rebuilt so cross-node pairs are born with class ``kind``
    (identity when already configured that way)."""
    want = machine.params.class_index(kind)
    if machine.cross_node_locality == want:
        return machine
    return dataclasses.replace(machine, cross_node_locality=want)


def sweep_kinds(machine: MachineSpec) -> tuple[tuple[str, ...],
                                               tuple[str, ...]]:
    """The measurable locality kinds of ``machine`` as
    ``(pingpong_kinds, network_kinds)``: device classes plus both network
    paths on heterogeneous machines, the socket/node/network split on
    classic CPU machines.  ``network_kinds`` additionally get the ppn
    saturation sweep."""
    if machine.devices_per_node:
        kinds = []
        if machine.procs_per_device >= 2:
            kinds.append("intra_device")
        kinds.append("cross_device")
        net = tuple(k for k in ("host_staged", "device_direct")
                    if machine.params.has_class(k))
        return tuple(kinds) + ("h2d",) + net, net
    kinds = []
    if machine.sockets_per_node > 1:
        kinds += ["intra_socket", "intra_node"]
    return tuple(kinds) + ("inter_node",), ("inter_node",)


def _h2d_sweep(machine: MachineSpec, sizes, noise: float,
               seed: int) -> np.ndarray:
    """Host<->device copy sweep: one coalesced self-copy per size at the
    ``h2d`` rate class (the staging phases of ``host_staged`` price the
    same way)."""
    loc = machine.params.class_index("h2d")
    rng = np.random.default_rng(seed)
    out = []
    for s in sizes:
        ph = CommPhase.build(machine, [0], [0], [float(s)], loc=loc)
        out.append(simulate(ph, rng=rng, noise=noise).time)
    return np.asarray(out)


def record_sweeps(machine: MachineSpec, sizes=DEFAULT_SIZES,
                  ppn_size: float = PPN_SIZE, reps: int = 1,
                  noise: float = 0.0, seed: int = 0) -> SweepRecord:
    """Run the full measurement suite on ``machine`` and return the
    :class:`SweepRecord`.

    ``sizes`` is the ping-pong size grid (``DEFAULT_SIZES`` spans every
    protocol regime), ``ppn_size`` the saturation-sweep message size,
    ``reps`` / ``noise`` / ``seed`` the per-measurement averaging count,
    multiplicative noise level and RNG seed passed through to
    :func:`repro.net.pingpong.pingpong_sweep` /
    :func:`repro.net.pingpong.ppn_sweep` (noiseless by default: the
    round-trip tests demand exact recovery).
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    kinds, net_kinds = sweep_kinds(machine)
    pingpong, ppn = {}, {}
    for kind in kinds:
        if kind == "h2d":
            pingpong[kind] = _h2d_sweep(machine, sizes, noise, seed)
            continue
        var = (_with_network_path(machine, kind)
               if kind in net_kinds else machine)
        pingpong[kind] = pingpong_sweep(var, kind, sizes, reps=reps,
                                        noise=noise, seed=seed)
    for kind in net_kinds:
        var = _with_network_path(machine, kind)
        ppn[kind] = ppn_sweep(var, ppn_size, noise=noise, seed=seed)
    return SweepRecord(machine=machine.name, sizes=sizes, pingpong=pingpong,
                       ppn_size=float(ppn_size), ppn=ppn)


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """A fitted table and its provenance: ``params`` is the fitted
    :class:`~repro.core.params.CommParams` (drive model predictions with
    it), ``n_rails`` the recovered rail count, ``rails_by_class`` the
    per-network-kind staircase fits it was reconciled from, and
    ``fitted_classes`` the locality kinds whose (alpha, R_b) rows came
    from the record (untouched rows keep the base table's values)."""

    params: CommParams
    n_rails: int
    rails_by_class: dict
    fitted_classes: tuple


def calibrate(record: SweepRecord, base: CommParams) -> CalibrationResult:
    """Fit a parameter table from ``record`` alone.

    ``base`` supplies the table *shape* (locality classes, protocol
    thresholds) and the values of anything the record cannot see (gamma,
    delta, unmeasured classes); every measured kind's (alpha, R_b) row,
    the rail count and the per-rail R_N cap are replaced by fits.  The
    fitted alpha absorbs the simulator's per-message queue step (see the
    module docstring); R_N is fitted for the rendezvous row of each
    network kind via :func:`repro.core.fitting.fit_RN_rails`, staying at
    the base value (usually ``inf``) elsewhere.
    """
    alpha = np.array(base.alpha, dtype=np.float64)
    Rb = np.array(base.Rb, dtype=np.float64)
    RN = np.array(base.RN, dtype=np.float64)

    table = fit_node_aware_table(
        {k: (record.sizes, v) for k, v in record.pingpong.items()}, base)
    for kind, fits in table.items():
        li = base.class_index(kind)
        for proto, (a, rb) in fits.items():
            pi = PROTOCOL_NAMES.index(proto)
            alpha[li, pi] = a
            Rb[li, pi] = rb

    rails_by_class = {kind: fit_rails(ks, ts)
                      for kind, (ks, ts) in record.ppn.items()}
    n_rails = (int(round(float(np.median(list(rails_by_class.values())))))
               if rails_by_class else base.n_rails)

    for kind, (ks, ts) in record.ppn.items():
        li = base.class_index(kind)
        pi = int(base.protocol_of(np.asarray([record.ppn_size]))[0])
        RN[li, pi] = fit_RN_rails(ks, ts, record.ppn_size,
                                  alpha[li, pi], Rb[li, pi], rails=n_rails)

    fitted = base.replace(alpha=alpha, Rb=Rb, RN=RN, n_rails=n_rails)
    return CalibrationResult(params=fitted, n_rails=n_rails,
                             rails_by_class=rails_by_class,
                             fitted_classes=tuple(sorted(table)))
