"""Host-scale machine presets: the four shipped machines shrunk to 8 ranks.

The stock presets put 8+ ranks on every node, so a forced 8-device host
mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) would never
cross a node boundary and every strategy would degenerate to the identity.
These variants keep each preset's *rate tables* (the ground-truth
``CommParams``) and relative geometry — two nodes, device structure where
the original has one — but shrink ``procs_per_node`` to 4 so 8 ranks span
2 nodes and every strategy rewrite produces real gather/inter/scatter
traffic the executors can run end-to-end.
"""
from __future__ import annotations

from repro.core.params import blue_waters, frontier, lassen, tpu_v5e
from repro.core.topology import TorusTopology
from repro.net.machine import MachineSpec

#: Ranks every host-scale preset spans (the forced host-mesh device count).
HOST_PROCS = 8


def blue_waters_8() -> MachineSpec:
    """Blue Waters at host scale: 2 nodes x 4 ranks on a 2-Gemini line,
    2 sockets per node, stock :func:`repro.core.params.blue_waters` rates."""
    return MachineSpec(
        name="blue_waters_8",
        params=blue_waters(),
        torus=TorusTopology((2, 1, 1), wrap=False),
        nodes_per_torus_node=1,
        procs_per_node=4,
        sockets_per_node=2,
        link_bw=9.4e9,
    )


def tpu_v5e_8() -> MachineSpec:
    """TPU v5e at host scale: 8 chips (2 hosts x 4 chips) on a wrapped
    4x2 ICI torus, stock :func:`repro.core.params.tpu_v5e` rates."""
    return MachineSpec(
        name="tpu_v5e_8",
        params=tpu_v5e(),
        torus=TorusTopology((4, 2), wrap=True),
        nodes_per_torus_node=1,
        procs_per_node=4,
        sockets_per_node=1,
        link_bw=50e9,
        torus_over_procs=True,
        cross_node_locality=1,
    )


def lassen_8(network_path: str = "device_direct") -> MachineSpec:
    """Lassen at host scale: 2 nodes x (2 devices x 2 ranks), dual-rail
    stock :func:`repro.core.params.lassen` rates; ``network_path`` picks
    the cross-node class exactly as in
    :func:`repro.net.machine.lassen_machine`."""
    params = lassen()
    return MachineSpec(
        name="lassen_8",
        params=params,
        torus=TorusTopology((2, 1, 1), wrap=False),
        nodes_per_torus_node=1,
        procs_per_node=4,
        sockets_per_node=2,
        link_bw=12.5e9,
        cross_node_locality=params.class_index(network_path),
        devices_per_node=2,
        procs_per_device=2,
    )


def frontier_8(network_path: str = "device_direct") -> MachineSpec:
    """Frontier at host scale: 2 nodes x (4 GCDs x 1 rank), stock
    :func:`repro.core.params.frontier` rates; ``network_path`` as in
    :func:`repro.net.machine.frontier_machine`."""
    params = frontier()
    return MachineSpec(
        name="frontier_8",
        params=params,
        torus=TorusTopology((2, 1, 1), wrap=False),
        nodes_per_torus_node=1,
        procs_per_node=4,
        sockets_per_node=1,
        link_bw=25e9,
        cross_node_locality=params.class_index(network_path),
        devices_per_node=4,
        procs_per_device=1,
    )


def host_machines() -> dict[str, MachineSpec]:
    """All four host-scale presets, name -> fresh
    :class:`~repro.net.machine.MachineSpec` instance."""
    return {m.name: m for m in (blue_waters_8(), tpu_v5e_8(),
                                lassen_8(), frontier_8())}
