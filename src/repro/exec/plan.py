"""Lower a strategy rewrite to an executable message-passing schedule.

The pricing layer (:mod:`repro.comm.strategies`) rewrites a bound
:class:`~repro.comm.CommPhase` into a sequence of phases whose *sizes* are
what the model and simulator consume — aggregated and (for the split
strategies) divided into fractional per-injector shares.  Fractions price
correctly but cannot be *executed* byte-exactly, so the planner here works
in **integral payload units**: each original message becomes
``ceil(size / unit_bytes)`` (>= 1) tagged int32 words, and every unit takes
the integer-rank route that mirrors its strategy's rewrite semantics:

``standard`` / ``local``
    origin -> destination, one hop.
``two_step``
    origin -> sender-node leader -> receiver-node leader -> destination.
``three_step`` / ``host_staged``
    unit ``j`` of a message rides injector slot ``j mod k`` (``k`` = ranks
    available on both end nodes, exactly the rewrite's share fan-out):
    origin -> sender-node rank ``k_j`` -> receiver-node rank ``k_j`` ->
    destination.  ``host_staged`` additionally records the ``d2h`` / ``h2d``
    coalesced self-copy phases (zero data motion across ranks — rounds are
    empty, the copy cost lives in the pricing plan).
``device_direct``
    origin -> its device leader -> the destination's device leader ->
    destination.

Hops whose endpoints coincide collapse, so a node leader's own payload
needs no gather message — the same dedup the rewrites apply.  Within each
phase the unit hops are grouped into messages per (holder, next-holder)
pair and the messages are edge-colored into **rounds**: a round is one
static ``ppermute`` permutation (each rank sends to at most one peer and
receives from at most one peer), the collective step the JAX executor
(:mod:`repro.exec.lower`) replays verbatim.  The numpy reference executor
(:mod:`repro.exec.reference`) walks the identical rounds serially, which is
what makes bit-identity a meaningful oracle: both executors consume *the
same* schedule, only the transport differs.

Every schedule self-checks at build time: units flow origin -> destination
through the recorded hops (flow conservation), and the lowered (role, src,
dst) pair set is a subset of the pricing plan's rewritten message rows
(:meth:`repro.comm.strategies.StrategyPlan.schedule`) — the planner can
never invent traffic the model did not price.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.comm.phase import CommPhase
from repro.comm.primitives import segmented_arange
from repro.comm.strategies import (ROLES, StrategyPlan, _avail, _remote_mask,
                                   rewrite)

#: Default payload-unit granularity (bytes per int32 tracer unit).  Small
#: enough that multi-unit messages exercise the k-way injector fan-out on
#: realistic sizes, large enough to keep unit counts modest.
UNIT_BYTES = 512.0

#: Round-construction policies: ``greedy`` edge-colors each phase's messages
#: into few permutation rounds; ``per_message`` gives every message its own
#: round (the naive one-``ppermute``-per-message baseline the perf gate
#: compares against).
COLORINGS = ("greedy", "per_message")

_PAYLOAD_MOD = 2147483647


def units_for(size, unit_bytes: float = UNIT_BYTES) -> np.ndarray:
    """Payload units per message: ``ceil(size / unit_bytes)`` with a floor
    of one, so zero- and sub-unit-``size`` messages still carry a traceable
    payload unit."""
    size = np.asarray(size, dtype=np.float64).ravel()
    return np.maximum(1, np.ceil(size / float(unit_bytes))).astype(np.int64)


def synth_payload(unit_msg) -> np.ndarray:
    """Deterministic nonzero int32 payload per unit: a multiplicative hash
    of the unit index and its owning message id ``unit_msg``, so a dropped,
    duplicated or misrouted unit always changes the delivered matrix."""
    unit_msg = np.asarray(unit_msg, dtype=np.int64).ravel()
    u = np.arange(unit_msg.size, dtype=np.int64)
    return ((u * 2654435761 + unit_msg * 40503 + 97) % _PAYLOAD_MOD
            + 1).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class ExecRound:
    """One collective step: a static ``ppermute`` permutation plus its
    gather/scatter index tables.

    ``perm`` is the (sender, receiver) pair tuple (each rank appears at most
    once per side).  ``pack[p, w]`` is the unit id rank ``p`` loads into
    send slot ``w``; on arrival the receiver scatters slot ``w`` into its
    holding buffer at ``stage[p, w]`` (unit still in transit) or into its
    delivered buffer at ``final[p, w]`` (unit at its destination).  Unused
    slots point at the sink column (index ``n_units``), whose junk flow is
    discarded — padding never aliases a real unit.
    """

    perm: tuple
    pack: np.ndarray
    stage: np.ndarray
    final: np.ndarray

    @property
    def width(self) -> int:
        return int(self.pack.shape[1])


@dataclasses.dataclass(frozen=True)
class ExecPhase:
    """One lowered phase: the strategy role, the per-(src, dst) message
    grouping, and the permutation rounds that move it.

    ``msg_src[i] -> msg_dst[i]`` carries ``msg_units[i]`` payload units.
    Copy roles (``d2h`` / ``h2d``) hold coalesced self-messages and no
    rounds — they stage payload in place, moving nothing across ranks.
    """

    role: str
    msg_src: np.ndarray
    msg_dst: np.ndarray
    msg_units: np.ndarray
    rounds: tuple

    @property
    def n_msgs(self) -> int:
        return int(self.msg_src.size)

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)


@dataclasses.dataclass(frozen=True)
class ExecSchedule:
    """An executable lowering of one strategy applied to one phase.

    ``payload[u]`` is the int32 word unit ``u`` carries from rank
    ``unit_src[u]`` to rank ``unit_dst[u]`` on behalf of original message
    ``unit_msg[u]``; ``phases`` are the lowered :class:`ExecPhase` steps in
    execution order and ``plan`` is the pricing-side
    :class:`~repro.comm.strategies.StrategyPlan` the schedule was lowered
    from (the model prices ``plan``, the executors run ``phases`` — the
    measured-vs-predicted comparison joins the two).  ``unit_bytes`` and
    ``coloring`` record the planner knobs that produced it.
    """

    strategy: str
    n_procs: int
    unit_bytes: float
    coloring: str
    payload: np.ndarray
    unit_src: np.ndarray
    unit_dst: np.ndarray
    unit_msg: np.ndarray
    phases: tuple
    plan: StrategyPlan

    @property
    def n_units(self) -> int:
        return int(self.payload.size)

    @property
    def n_rounds(self) -> int:
        return sum(ph.n_rounds for ph in self.phases)

    @property
    def n_msgs(self) -> int:
        return sum(ph.n_msgs for ph in self.phases)


def _color_rounds(msg_src, msg_dst, coloring: str) -> list:
    """Greedy edge coloring: place each message in the first round where its
    sender and receiver are both free (each rank sends/receives at most once
    per round)."""
    if coloring == "per_message":
        return [[i] for i in range(msg_src.size)]
    rounds: list = []
    for i in range(msg_src.size):
        s, d = int(msg_src[i]), int(msg_dst[i])
        for senders, receivers, members in rounds:
            if s not in senders and d not in receivers:
                senders.add(s)
                receivers.add(d)
                members.append(i)
                break
        else:
            rounds.append(({s}, {d}, [i]))
    return [members for _, _, members in rounds]


def _movement_phase(role, frm, to, uid, unit_dst, n_procs, sink, coloring):
    """Group one hop set into messages and color them into rounds; None when
    every hop collapses (endpoints equal) or the set is empty."""
    move = frm != to
    frm, to, uid = frm[move], to[move], uid[move]
    if frm.size == 0:
        return None
    order = np.argsort(frm * np.int64(n_procs) + to, kind="stable")
    frm, to, uid = frm[order], to[order], uid[order]
    key = frm * np.int64(n_procs) + to
    _, starts, counts = np.unique(key, return_index=True, return_counts=True)
    msg_src, msg_dst = frm[starts], to[starts]

    rounds = []
    for members in _color_rounds(msg_src, msg_dst, coloring):
        width = int(max(counts[i] for i in members))
        pack = np.full((n_procs, width), sink, dtype=np.int32)
        stage = np.full((n_procs, width), sink, dtype=np.int32)
        final = np.full((n_procs, width), sink, dtype=np.int32)
        perm = []
        for i in members:
            s, d = int(msg_src[i]), int(msg_dst[i])
            ids = uid[starts[i]:starts[i] + counts[i]]
            w = ids.size
            pack[s, :w] = ids
            at_dest = unit_dst[ids] == d
            final[d, :w][at_dest] = ids[at_dest]
            stage[d, :w][~at_dest] = ids[~at_dest]
            perm.append((s, d))
        rounds.append(ExecRound(perm=tuple(perm), pack=pack, stage=stage,
                                final=final))
    return ExecPhase(role=role, msg_src=msg_src, msg_dst=msg_dst,
                     msg_units=counts.astype(np.int64), rounds=tuple(rounds))


def _copy_phase(role, ranks, uid) -> ExecPhase:
    """A ``d2h``/``h2d`` staging phase: one coalesced self-copy per rank,
    zero rounds (nothing crosses a rank boundary)."""
    uranks, counts = np.unique(ranks, return_counts=True)
    return ExecPhase(role=role, msg_src=uranks, msg_dst=uranks,
                     msg_units=counts.astype(np.int64), rounds=())


def build_schedule(phase: CommPhase, strategy: str, *,
                   unit_bytes: float = UNIT_BYTES,
                   coloring: str = "greedy") -> ExecSchedule:
    """Lower ``strategy`` applied to the bound ``phase`` into an
    :class:`ExecSchedule`.

    ``unit_bytes`` sets the payload-unit granularity (module default
    ``UNIT_BYTES``); ``coloring`` picks the round policy from ``COLORINGS``.
    The returned schedule is self-checked: units are flow-conserved through
    the recorded hops and the lowered pair set is a subset of the pricing
    plan's (:func:`pairs_subset_of_plan`).
    """
    if coloring not in COLORINGS:
        raise ValueError(f"unknown coloring {coloring!r}; "
                         f"expected one of {COLORINGS}")
    m, P = phase.machine, phase.n_procs
    plan = rewrite(phase, strategy)
    u = units_for(phase.size, unit_bytes)
    msg = np.repeat(np.arange(phase.n_msgs), u)
    unit_src = phase.src[msg].astype(np.int64)
    unit_dst = phase.dst[msg].astype(np.int64)
    uid = np.arange(msg.size)
    payload = synth_payload(msg)
    sink = msg.size

    # hop groups in execution order; degenerate rewrites (no remote traffic)
    # lower exactly like ``standard``, mirroring the pricing side
    degenerate = plan.roles == ("standard",)
    groups: list = []
    if strategy == "standard" or degenerate:
        groups.append(("standard", unit_src, unit_dst, uid))
    else:
        remote = _remote_mask(phase)[msg]
        groups.append(("local", unit_src[~remote], unit_dst[~remote],
                       uid[~remote]))
        rs, rd, ru = unit_src[remote], unit_dst[remote], uid[remote]
        if strategy == "device_direct":
            ppd = np.int64(m.procs_per_device)
            inj = (rs // ppd) * ppd
            rinj = (rd // ppd) * ppd
        else:
            ppn = np.int64(m.procs_per_node)
            sn = np.asarray(m.node_of(rs), dtype=np.int64)
            dn = np.asarray(m.node_of(rd), dtype=np.int64)
            if strategy == "two_step":
                slot = np.zeros(rs.size, dtype=np.int64)
            else:
                j = segmented_arange(u)[remote]     # unit index in message
                slot = j % np.minimum(_avail(m, sn, P), _avail(m, dn, P))
            inj = sn * ppn + slot
            rinj = dn * ppn + slot
        if strategy == "host_staged":
            groups.append(("d2h", rs, rs, ru))
        groups.append(("gather", rs, inj, ru))
        groups.append(("inter", inj, rinj, ru))
        groups.append(("scatter", rinj, rd, ru))
        if strategy == "host_staged":
            groups.append(("h2d", rd, rd, ru))

    # flow conservation: every unit walks origin -> destination through the
    # recorded hops, each hop leaving from the unit's current holder
    holder = unit_src.copy()
    for role, frm, to, gid in groups:
        if role in ("d2h", "h2d"):
            continue
        mov = frm != to
        if not np.array_equal(holder[gid[mov]], frm[mov]):
            raise ValueError(f"flow violation lowering {strategy!r}: "
                             f"{role} hop leaves from a non-holder rank")
        holder[gid[mov]] = to[mov]
    if not np.array_equal(holder, unit_dst):
        raise ValueError(f"flow violation lowering {strategy!r}: "
                         "units do not end at their destinations")

    phases = []
    for role, frm, to, gid in groups:
        if role in ("d2h", "h2d"):
            ph = _copy_phase(role, frm, gid) if frm.size else None
        else:
            ph = _movement_phase(role, frm, to, gid, unit_dst, P, sink,
                                 coloring)
        if ph is not None:
            phases.append(ph)

    schedule = ExecSchedule(strategy=strategy, n_procs=P,
                            unit_bytes=float(unit_bytes), coloring=coloring,
                            payload=payload, unit_src=unit_src,
                            unit_dst=unit_dst, unit_msg=msg.astype(np.int64),
                            phases=tuple(phases), plan=plan)
    if not pairs_subset_of_plan(schedule):
        raise ValueError(f"lowering {strategy!r} produced a (role, src, dst) "
                         "pair its pricing plan does not carry")
    return schedule


def pairs_subset_of_plan(schedule: ExecSchedule) -> bool:
    """True when every (role, src, dst) message of ``schedule``'s lowered
    phases appears among its pricing plan's rewritten rows
    (:meth:`repro.comm.strategies.StrategyPlan.schedule`) — the integral
    unit routing must never invent traffic the model did not price.  The
    sets coincide exactly when every remote message carries at least ``k``
    units; with fewer, the lowered set is a strict subset (unused injector
    slots send nothing)."""
    rows = schedule.plan.schedule()
    plan_pairs = set(zip(rows["role"].tolist(), rows["src"].tolist(),
                         rows["dst"].tolist()))
    for ph in schedule.phases:
        role = ROLES.index(ph.role)
        for s, d in zip(ph.msg_src.tolist(), ph.msg_dst.tolist()):
            if (role, s, d) not in plan_pairs:
                return False
    return True
