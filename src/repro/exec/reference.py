"""Serial numpy reference executor: the bit-identity oracle for lowered
schedules.

Two independent answers for "what payload does each rank end up holding":

* :func:`reference_delivered` — the *semantic* oracle.  It ignores the
  schedule's routing entirely and places every unit's payload directly at
  its destination: the answer any correct exchange must produce.
* :func:`run_reference` — the *operational* oracle.  It walks the
  schedule's phases and rounds serially with plain Python loops, consuming
  the same ``pack`` / ``stage`` / ``final`` index tables the JAX executor
  (:mod:`repro.exec.lower`) feeds to ``ppermute`` — so a schedule bug
  (mis-colored round, wrong table entry) makes *both* executors disagree
  with :func:`reference_delivered`, while a lowering/transport bug makes
  the JAX path disagree with this one.

Payloads are int32 and accumulation is addition of disjoint contributions,
so equality is exact (``==``), never approximate.
"""
from __future__ import annotations

import numpy as np

from .plan import ExecSchedule


def reference_delivered(schedule: ExecSchedule) -> np.ndarray:
    """The semantic delivery oracle for ``schedule``: an ``(n_procs,
    n_units)`` int32 matrix with every unit's payload placed directly at its
    destination rank, no routing involved."""
    out = np.zeros((schedule.n_procs, schedule.n_units), dtype=np.int32)
    out[schedule.unit_dst, np.arange(schedule.n_units)] = schedule.payload
    return out


def run_reference(schedule: ExecSchedule) -> np.ndarray:
    """Execute ``schedule`` serially in numpy and return the delivered
    ``(n_procs, n_units)`` matrix.

    Walks every phase's rounds in order; for each ``(sender, receiver)``
    pair of a round's permutation the sender's ``pack`` row is read from its
    holding buffer and scattered through the receiver's ``stage`` /
    ``final`` rows — exactly the dataflow the JAX executor runs as one
    ``ppermute`` per round.  The padded sink column is carried and trimmed
    like the device path carries it.
    """
    P, U = schedule.n_procs, schedule.n_units
    hold = np.zeros((P, U + 1), dtype=np.int32)
    deliv = np.zeros((P, U + 1), dtype=np.int32)
    units = np.arange(U)
    hold[schedule.unit_src, units] = schedule.payload
    at_home = schedule.unit_src == schedule.unit_dst
    deliv[schedule.unit_dst[at_home], units[at_home]] = \
        schedule.payload[at_home]

    for phase in schedule.phases:
        for rnd in phase.rounds:
            arrivals = []                       # snapshot: sends are posted
            for s, d in rnd.perm:               # before any receive lands
                arrivals.append((d, hold[s, rnd.pack[s]]))
            for d, recv in arrivals:
                np.add.at(hold[d], rnd.stage[d], recv)
                np.add.at(deliv[d], rnd.final[d], recv)
            hold[:, U] = 0                      # discard sink junk
            deliv[:, U] = 0
    return deliv[:, :U]


def delivered_digest(delivered: np.ndarray, schedule: ExecSchedule,
                     backend: str | None = None) -> np.ndarray:
    """Per-rank delivered-payload totals of a ``delivered`` matrix, reduced
    through the fused segment kernels
    (:func:`repro.kernels.comm_stack.segment_sum`) — the on-device
    aggregation path when ``backend`` is ``'jax'``/``'pallas'``, the numpy
    reference otherwise.  For a correct execution of ``schedule`` this
    equals ``segment_sum(payload, unit_dst, n_procs)``."""
    from repro.kernels.comm_stack import segment_sum
    units = np.arange(schedule.n_units)
    values = np.asarray(delivered)[schedule.unit_dst, units]
    return segment_sum(values.astype(np.float64), schedule.unit_dst,
                       schedule.n_procs, backend=backend)
