"""Strategy execution: lower rewrites to runnable JAX schedules and close
the measured-vs-predicted loop (DESIGN.md §14).

Pipeline: :func:`~repro.exec.plan.build_schedule` lowers one strategy of a
bound phase to permutation rounds (:mod:`repro.exec.plan`); the serial
numpy executor replays them as the bit-identity oracle
(:mod:`repro.exec.reference`); the jitted ``shard_map`` + ``ppermute``
program runs them on a device mesh (:mod:`repro.exec.lower`); timed runs
and ordering comparisons live in :mod:`repro.exec.measure`; fitted
parameter tables from recorded sweeps in :mod:`repro.exec.calibrate`; and
:mod:`repro.exec.presets` ships 8-rank host-scale machines for the forced
host mesh.  Everything imports without jax — only actually *running* a
lowered schedule needs it.
"""
from .calibrate import (CalibrationResult, SweepRecord, calibrate,
                        record_sweeps)
from .lower import build_executor, execute
from .measure import (Measurement, launch_overhead, measure_strategies,
                      ordering, pairwise_agreement, predicted_costs,
                      time_schedule)
from .plan import (COLORINGS, UNIT_BYTES, ExecPhase, ExecRound, ExecSchedule,
                   build_schedule, pairs_subset_of_plan, synth_payload,
                   units_for)
from .presets import (HOST_PROCS, blue_waters_8, frontier_8, host_machines,
                      lassen_8, tpu_v5e_8)
from .reference import delivered_digest, reference_delivered, run_reference

__all__ = [
    "COLORINGS", "UNIT_BYTES", "ExecPhase", "ExecRound", "ExecSchedule",
    "build_schedule", "pairs_subset_of_plan", "synth_payload", "units_for",
    "reference_delivered", "run_reference", "delivered_digest",
    "build_executor", "execute",
    "Measurement", "time_schedule", "launch_overhead", "measure_strategies",
    "predicted_costs", "ordering", "pairwise_agreement",
    "SweepRecord", "CalibrationResult", "record_sweeps", "calibrate",
    "HOST_PROCS", "blue_waters_8", "tpu_v5e_8", "lassen_8", "frontier_8",
    "host_machines",
]
