"""Sharded checkpointing with atomic commits, async writes, content hashes,
resume-from-latest and elastic (re-mesh) restore."""
from .checkpoint import (save_checkpoint, load_checkpoint, latest_step,
                         CheckpointManager)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "CheckpointManager"]
