"""Sharded checkpointing: atomic commits, async writes, content hashes,
resume-from-latest, and elastic restore onto a different mesh.

Layout per step:
    <dir>/step_<N>.tmp/          (written)
    <dir>/step_<N>/              (atomic rename on commit)
        manifest.json            tree structure, shapes, dtypes, crc32s
        <flat_key>.npy           one file per leaf

On a real multi-host pod each host writes only the shards it owns (the
manifest records the sharding); in this single-process container leaves are
materialized whole.  Elastic restore re-``device_put``s with the *target*
mesh's shardings, so a checkpoint taken on 16x16 reloads onto 8x16 or
2x16x16 unchanged — the re-mesh test in tests/test_ckpt.py exercises this.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(tree):
    # jax.tree_util spelling: jax.tree.flatten_with_path only exists in
    # newer jax releases than the pinned toolchain ships
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory: str, step: int, tree, wait: bool = True
                    ) -> threading.Thread:
    """Write a checkpoint; atomic commit via rename.

    ``wait=False`` returns immediately and writes in a background thread
    (async checkpointing — training continues while the previous step
    serializes).
    """
    leaves, _ = _flatten(tree)

    def to_np(v):
        a = np.asarray(v)
        if a.dtype.kind == "V":      # bfloat16 etc: store as f32 (lossless up)
            a = np.asarray(jax.numpy.asarray(v, jax.numpy.float32))
        return a

    host = {k: to_np(v) for k, v in leaves.items()}

    def _write():
        tmp = os.path.join(directory, f"step_{step}.tmp")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][key] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if wait:
        t.join()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like_tree,
                    shardings=None, verify: bool = True):
    """Load a checkpoint into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding for the *target* mesh
    (elastic restore); leaves are device_put with them.
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    sh_leaves = _flatten(shardings)[0] if shardings is not None else None
    out = {}
    for key, like in leaves.items():
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {key}: "
                              f"crc {crc} != {meta['crc32']}")
        val = jax.numpy.asarray(arr).astype(like.dtype)
        if sh_leaves is not None:
            val = jax.device_put(val, sh_leaves[key])
        out[key] = val
    ordered = [out[k] for k in _flatten(like_tree)[0]]
    return jax.tree.unflatten(treedef, ordered)


@dataclasses.dataclass
class CheckpointManager:
    """Keep-last-k manager with async writes and resume support."""

    directory: str
    keep: int = 3
    _pending: threading.Thread | None = None

    def save(self, step: int, tree, wait: bool = False):
        os.makedirs(self.directory, exist_ok=True)
        if self._pending is not None:
            self._pending.join()         # one outstanding async write max
        self._pending = save_checkpoint(self.directory, step, tree, wait=wait)
        if wait:
            self._gc()
        return self._pending

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        self._gc()

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(s for s in (int(d.split("_")[1])
                                   for d in os.listdir(self.directory)
                                   if d.startswith("step_")
                                   and not d.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def restore_latest(self, like_tree, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, like_tree,
                                     shardings)
