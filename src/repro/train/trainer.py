"""Training loop: jitted step, checkpoint/resume, straggler watchdog.

Fault-tolerance model (single-process simulation of the multi-host recipe):

* checkpoint every ``ckpt_every`` steps, asynchronously; on (re)start the
  trainer resumes from the latest complete checkpoint — a crashed run replays
  identically because the data pipeline is a pure function of (seed, step).
* the straggler watchdog compares each step's wall time against an SLA —
  either a modeled step time (the paper's performance model, when provided)
  or a running median x tolerance — and records offenders; on a real pod
  this signal drives re-dispatch of the slow host's data shards
  (:func:`repro.data.shard_assignment`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.nn.config import ArchConfig
from repro.nn.model import init_params
from .optim import AdamWConfig, init_opt_state


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0
    microbatches: int = 1
    sla_seconds: float | None = None   # modeled step time (perf model)
    sla_tolerance: float = 3.0


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainConfig,
                 opt_cfg: AdamWConfig | None = None,
                 step_hook: Callable[[int], None] | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.step_hook = step_hook       # test hook (e.g. straggler injection)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        # lazy import: launch.steps imports repro.train.optim (package cycle)
        from repro.launch.steps import make_train_step
        self._step_fn = jax.jit(make_train_step(
            cfg, self.opt_cfg, microbatches=tcfg.microbatches))
        self.stragglers: list[tuple[int, float]] = []
        self.history: list[dict[str, float]] = []

    # ------------------------------------------------------------------ run --
    def init_state(self):
        params = init_params(self.cfg, self.tcfg.seed)
        return params, init_opt_state(params)

    def run(self, data_iter, params=None, opt_state=None) -> dict[str, Any]:
        if params is None:
            params, opt_state = self.init_state()
        start = 0
        restored = self.ckpt.restore_latest({"params": params,
                                             "opt": opt_state})
        if restored[0] is not None:
            start = restored[0]
            params, opt_state = restored[1]["params"], restored[1]["opt"]

        times: list[float] = []
        it = iter(data_iter)
        for step in range(start, self.tcfg.steps):
            batch = next(it) if not hasattr(data_iter, "batch_at") \
                else data_iter.batch_at(step)
            t0 = time.perf_counter()
            if self.step_hook:
                self.step_hook(step)
            params, opt_state, metrics = self._step_fn(params, opt_state,
                                                       batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            self._watchdog(step, dt, times)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                self.history.append(
                    {"step": step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "sec": dt})
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": params, "opt": opt_state})
        self.ckpt.save(self.tcfg.steps, {"params": params, "opt": opt_state},
                       wait=True)
        return {"params": params, "opt_state": opt_state,
                "history": self.history, "stragglers": self.stragglers}

    # ------------------------------------------------------------- watchdog --
    def _watchdog(self, step: int, dt: float, times: list[float]):
        sla = self.tcfg.sla_seconds
        if sla is None and len(times) >= 5:
            sla = float(np.median(times[-20:]))
        if sla is not None and dt > self.tcfg.sla_tolerance * sla:
            self.stragglers.append((step, dt))
