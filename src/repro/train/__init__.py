from .optim import AdamWConfig, init_opt_state, adamw_update, schedule
from .trainer import Trainer, TrainConfig

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "schedule",
           "Trainer", "TrainConfig"]
