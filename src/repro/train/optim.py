"""AdamW with f32 moments over bf16 parameters + cosine LR schedule.

The optimizer state shards exactly like the parameters (same tree shapes), so
``train_step`` lowers with optimizer sharding for free; a ZeRO-1 variant
(moments sharded over the data axis) is provided as an optimization lever.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params: PyTree, grads: PyTree, state: PyTree,
                 cfg: AdamWConfig) -> tuple[PyTree, PyTree, dict]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn, "lr": lr}
