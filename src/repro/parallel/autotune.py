"""Model-driven layout autotuning — the paper's model as a decision procedure.

For a given (arch x shape), enumerate candidate layouts (mesh factorization,
sequence sharding, attention chunk, FSDP), lower + compile each, decompose
the compiled collectives to p2p messages, and rank by the node-aware
max-rate + queue + contention step time (plus the compute/memory roofline
terms so communication wins don't get chosen when they blow the other
budgets).

This mirrors the paper's conclusions loop: the model tells you WHETHER a
schedule is message-count-bound (queue), link-share-bound (contention) or
bandwidth-bound, and the tuner picks the layout that moves the dominant
term.  Run through ``launch/autotune.py`` (needs the 512-device dry-run env).
"""
from __future__ import annotations

import dataclasses

from repro.core import parse_collectives, price_step
from repro.core.decompose import PodGeometry
from repro.core.params import (tpu_v5e, V5E_PEAK_FLOPS_BF16, V5E_HBM_BW)


@dataclasses.dataclass(frozen=True)
class LayoutCandidate:
    name: str
    mesh_shape: tuple[int, ...]       # (data, model) or (pod, data, model)
    seq_shard: bool = True
    q_chunk: int = 1024
    fsdp: bool | None = None          # None = dryrun default rule


@dataclasses.dataclass
class LayoutScore:
    candidate: LayoutCandidate
    compute_s: float
    memory_s: float
    comm_naive_s: float
    comm_model_s: float
    queue_s: float
    contention_s: float
    peak_gib: float
    fits: bool

    @property
    def step_model_s(self) -> float:
        """Modeled step time: max(compute, memory) + modeled communication."""
        return max(self.compute_s, self.memory_s) + self.comm_model_s


def score_compiled(compiled, n_layers: int, multi_pod: bool,
                   flops_per_device: float | None = None,
                   bytes_per_device: float | None = None) -> dict:
    """Roofline + Bienz terms from a compiled executable."""
    cost = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    flops = flops_per_device if flops_per_device is not None \
        else cost.get("flops", 0.0)
    byts = bytes_per_device if bytes_per_device is not None \
        else cost.get("bytes accessed", 0.0)
    ops = parse_collectives(compiled.as_text(), default_trip_count=n_layers)
    comm = price_step(ops, PodGeometry(n_pods=2 if multi_pod else 1),
                      tpu_v5e())
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return {
        "compute_s": flops / V5E_PEAK_FLOPS_BF16,
        "memory_s": byts / V5E_HBM_BW,
        "comm_naive_s": comm.naive_time,
        "comm_model_s": comm.model_time,
        "queue_s": comm.queue,
        "contention_s": comm.contention,
        "peak_gib": peak / 2**30,
        "fits": peak < 15.5 * 2**30,
    }


def rank(scores: list[LayoutScore]) -> list[LayoutScore]:
    """Feasible layouts first, by modeled step time."""
    return sorted(scores, key=lambda s: (not s.fits, s.step_model_s))
