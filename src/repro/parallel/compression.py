"""Gradient compression: int8 quantized all-reduce with error feedback.

The DP gradient reduce dominates wire bytes at scale; quantizing to int8
with per-block scales cuts them 4x (bf16) / 8x (f32).  Error feedback keeps
the *accumulated* quantization error bounded, preserving convergence
(Karimireddy et al., 2019).

``compressed_psum`` runs inside shard_map: each device quantizes its local
shard, the int8 payload is summed (as int32 — no overflow below ~2^23
participants), and the result is dequantized with the globally-maxed scale.
The error-feedback residual is returned for the caller to carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._jax_compat import pcast, shard_map


def quantize_int8(x, scale):
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q


def compressed_psum(x, axis_name: str, error: jnp.ndarray | None = None):
    """int8 + error-feedback psum over ``axis_name`` (call inside shard_map).

    Returns (mean-reduced value, new error-feedback residual).
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    # one scale per device-shard, maxed across the axis so dequant agrees
    local_max = jnp.max(jnp.abs(xf))
    gmax = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = quantize_int8(xf, scale)
    new_error = xf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / n.astype(jnp.float32), new_error


def dp_grads_compressed(loss_fn, params, batch, mesh,
                        axis_name: str = "data", errors=None):
    """Data-parallel gradients with int8+EF compressed all-reduce.

    ``loss_fn(params, batch) -> scalar`` computed on each device's batch
    shard inside shard_map; per-shard grads are reduced with
    :func:`compressed_psum`.  Returns (mean grads, new error pytree).
    The uncompressed reference is ``jax.grad`` of the mean loss.
    """
    n_dev = mesh.shape[axis_name]
    if errors is None:
        # per-device EF residuals, stacked on a leading device axis
        errors = jax.tree.map(
            lambda g: jnp.zeros((n_dev,) + g.shape, jnp.float32), params)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), jax.tree.map(lambda _: P(axis_name), batch),
                  jax.tree.map(lambda _: P(axis_name), errors)),
        out_specs=(P(), jax.tree.map(lambda _: P(axis_name), errors)))
    def _grads(p, b, e):
        # grad w.r.t. a *varying* copy of the params: differentiating the
        # replicated input directly would insert an implicit psum (transpose
        # of replication), defeating quantize-before-reduce.
        p_local = jax.tree.map(
            lambda a: pcast(a, (axis_name,), to="varying"), p)
        g = jax.grad(loss_fn)(p_local, b)
        flat_g, td = jax.tree.flatten(g)
        flat_e, _ = jax.tree.flatten(e)
        outs = [compressed_psum(gl, axis_name, el[0])
                for gl, el in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(td, [o[0] for o in outs]),
                jax.tree.unflatten(td, [o[1][None] for o in outs]))

    return _grads(params, batch, errors)
