"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

Baseline layout (what the dry-run lowers):

* **DP** over ``("pod", "data")`` (or ``("data",)`` single-pod): batch dims.
* **TP** over ``"model"``: attention head projections, MLP hidden, vocab.
* **EP** over ``"model"``: MoE expert dimension (experts are co-sharded with
  TP — the standard "experts replace MLP shards" layout).
* **SP** over ``"model"`` for decode KV caches: the *sequence* dimension of
  the cache is sharded (flash-decoding style), so GQA archs with fewer KV
  heads than the TP degree still scale; XLA inserts the partial-softmax
  reductions automatically.

Every rule degrades to replication when a dimension is not divisible by the
axis size (e.g. whisper's 51865 vocab), so all 10 archs lower on the same
mesh.  These specs are the *baseline* the §Perf hillclimbs improve on.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.config import ArchConfig
from repro.nn.model import param_shapes, cache_shapes, _names

MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    mesh: Mesh
    dp_axes: tuple[str, ...]      # ("pod", "data") or ("data",)
    model_axis: str = MODEL_AXIS

    @property
    def dp_size(self) -> int:
        return int(jax.numpy.prod(
            jax.numpy.asarray([self.mesh.shape[a] for a in self.dp_axes])))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    def dp_spec_for(self, batch: int):
        """Largest prefix of dp axes that divides ``batch`` (1 -> None)."""
        axes = []
        rem = batch
        for a in self.dp_axes:
            s = self.mesh.shape[a]
            if rem % s == 0 and rem >= s:
                axes.append(a)
                rem //= s
            else:
                break
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]


def make_mesh_plan(mesh: Mesh) -> MeshPlan:
    dp = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    return MeshPlan(mesh=mesh, dp_axes=dp)


# ------------------------------------------------------------- params -------
def _param_rule(names: tuple, shape: tuple, cfg: ArchConfig, tp: int):
    """PartitionSpec for one parameter leaf (names = path, shape incl. [L])."""
    name = names[-1]
    group = names[-2] if len(names) >= 2 else ""
    nd = len(shape)

    def last_dim_tp():
        specs = [None] * nd
        if shape[-1] % tp == 0:
            specs[-1] = MODEL_AXIS
        return P(*specs)

    def dim_tp(axis_from_end: int):
        specs = [None] * nd
        if shape[nd - axis_from_end] % tp == 0:
            specs[nd - axis_from_end] = MODEL_AXIS
        return P(*specs)

    if name == "embed":
        return P(MODEL_AXIS, None) if shape[0] % tp == 0 else P(None, None)
    if name == "lm_head":
        return P(None, MODEL_AXIS) if shape[1] % tp == 0 else P(None, None)
    if name == "frontend_proj":
        return last_dim_tp()
    if name in ("scale", "bias", "q_norm", "k_norm", "A_log", "D", "dt_bias",
                "norm", "conv_w", "conv_b", "router"):
        return P(*([None] * nd))
    if group in ("attn", "xattn"):
        if name in ("wq", "wk", "wv"):
            return last_dim_tp()        # column-parallel
        if name == "wo":
            return dim_tp(2)            # row-parallel
    if group == "moe":
        if name in ("w1", "w2", "w3"):
            # [L, E, d, f] / [L, E, f, d]: shard experts (EP == TP axis)
            specs = [None] * nd
            if shape[1] % tp == 0:
                specs[1] = MODEL_AXIS
            return P(*specs)
        if name.startswith("shared_"):
            return last_dim_tp() if name in ("shared_w1", "shared_w3") else dim_tp(2)
    if group == "mlp":
        if name in ("w1", "w3"):
            return last_dim_tp()
        if name == "w2":
            return dim_tp(2)
    if group == "ssm":
        if name == "in_proj":
            return last_dim_tp()
        if name == "out_proj":
            return dim_tp(2)
    return P(*([None] * nd))


def _add_data_sharding(spec: P, shape: tuple, plan: MeshPlan,
                       skip_leading: bool = True) -> P:
    """Shard one replicated dim over the data axes (ZeRO / FSDP style).

    Prefers a non-leading dim (so per-layer gathers happen inside the layer
    scan, not on the whole stacked stack).  Uses the innermost data axis
    ("data", not "pod") — DCN-crossing weight gathers would be pathological.
    """
    axis = plan.dp_axes[-1]
    size = plan.mesh.shape[axis]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if axis in parts:                 # already data-sharded (FSDP + ZeRO-1)
        return spec
    start = 1 if (skip_leading and len(shape) > 1) else 0
    for i in range(start, len(shape)):
        if parts[i] is None and shape[i] % size == 0 and shape[i] >= size:
            parts[i] = axis
            return P(*parts)
    return spec


def param_pspecs(cfg: ArchConfig, plan: MeshPlan, fsdp: bool = False):
    """Pytree of PartitionSpec matching ``param_shapes(cfg)``.

    ``fsdp=True`` additionally shards every parameter over the data axis
    (ZeRO-3 style) — used for >20B-parameter training cells where even
    TP-sharded bf16 weights + grads exceed HBM.
    """
    shapes = param_shapes(cfg)
    tp = plan.model_size

    def rule(p, sh):
        spec = _param_rule(_names(p), sh, cfg, tp)
        if fsdp:
            spec = _add_data_sharding(spec, sh, plan)
        return spec

    return jax.tree_util.tree_map_with_path(
        rule, shapes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, int) for i in x))


def zero1_pspecs(param_specs, cfg: ArchConfig, plan: MeshPlan):
    """Optimizer-moment specs: parameter specs + data-axis sharding (ZeRO-1)."""
    shapes = param_shapes(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda p, sh: _add_data_sharding(_lookup(param_specs, p), sh, plan),
        shapes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, int) for i in x))


def _lookup(tree, path):
    node = tree
    for k in path:
        node = node[getattr(k, "key", getattr(k, "idx", None))]
    return node


# -------------------------------------------------------------- batch -------
def batch_pspecs(plan: MeshPlan, batch_tree):
    """PartitionSpecs matching an actual batch dict (ShapeDtypeStructs ok).

    Every leading dim is treated as batch (DP-sharded when divisible);
    remaining dims replicated.
    """
    def rule(leaf):
        if len(leaf.shape) == 0:
            return P()
        dp = plan.dp_spec_for(leaf.shape[0])
        return P(dp, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(rule, batch_tree)


def cache_pspecs(plan: MeshPlan, cache_tree):
    """Decode-cache specs: batch over DP, sequence over the model axis (SP)."""
    tp = plan.model_size

    def rule(path, leaf):
        name = _names(path)[-1]
        sh = leaf.shape
        dp = plan.dp_spec_for(sh[1]) if len(sh) > 1 else None
        if name in ("k", "v"):
            # [L, B, S, KH, hd]: shard a dim whose update index is static so
            # the per-token dynamic_update_slice stays shard-local — KV heads
            # first, head_dim second (partial-score psum); sharding the
            # sequence dim would make GSPMD replicate the cache on every
            # update ("involuntary full rematerialization").
            if sh[3] % tp == 0:
                return P(None, dp, None, MODEL_AXIS, None)
            if sh[4] % tp == 0:
                return P(None, dp, None, None, MODEL_AXIS)
            seq_ax = MODEL_AXIS if sh[2] % tp == 0 else None
            return P(None, dp, seq_ax, None, None)
        if name in ("k_scale", "v_scale"):
            if sh[3] % tp == 0:
                return P(None, dp, None, MODEL_AXIS)
            return P(None, dp, None, None)
        if name == "conv":
            return P(None, dp, None, None)
        if name == "ssd":
            # [L, B, H, N, P]: shard heads when divisible
            h_ax = MODEL_AXIS if sh[2] % tp == 0 else None
            return P(None, dp, h_ax, None, None)
        if name == "enc_out":
            return P(None, dp, None, None)
        return P(*([None] * len(sh)))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def shardings(tree_of_specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
