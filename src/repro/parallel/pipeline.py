"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

Multi-pod note: inter-pod DCN bandwidth is far below ICI, so the pod axis is
the natural pipeline boundary — each pod holds a contiguous stage of layers
and only [microbatch, seq, d_model] activations cross the DCN per tick,
instead of per-layer collectives.  The schedule is plain GPipe: M
microbatches flow through S stages in M + S - 1 ticks via
``collective_permute`` (ppermute); bubble ticks compute on garbage and are
masked out.

``gpipe`` is generic over a ``stage_fn(stage_params, x) -> y`` with matching
x/y shapes (transformer blocks).  The dry-run exposes it as a variant config;
tests validate numerically on a fake multi-device mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._jax_compat import pcast, shard_map


def stack_stages(layer_params, n_stages: int):
    """[L, ...] stacked layer params -> [S, L/S, ...] stage-major params."""
    def f(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree.map(f, layer_params)


def gpipe(stage_fn, stage_params, microbatches, mesh, axis: str = "pod"):
    """Run microbatches through pipeline stages laid out on ``axis``.

    stage_fn: (per-stage params, x [mb, ...]) -> y [mb, ...]
    stage_params: pytree with leading stage dim S == mesh.shape[axis]
    microbatches: [M, mb, ...] (replicated input)
    Returns [M, mb, ...] outputs of the last stage (replicated).
    """
    S = mesh.shape[axis]
    M = microbatches.shape[0]
    perm = [(i, (i + 1) % S) for i in range(S)]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
        out_specs=P())
    def _run(params_local, mb):
        p = jax.tree.map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        is_first = (s == 0)
        is_last = (s == S - 1)

        def tick(t, state):
            carry, outs = state
            recv = jax.lax.ppermute(carry, axis, perm)
            feed_idx = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(is_first, mb[feed_idx], recv)
            y = stage_fn(p, x_in)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            valid = jnp.logical_and(is_last, t >= S - 1)
            outs = jnp.where(valid, outs.at[out_idx].set(y), outs)
            return y, outs

        carry0 = pcast(jnp.zeros_like(mb[0]), (axis,), to="varying")
        outs0 = pcast(jnp.zeros_like(mb), (axis,), to="varying")
        _, outs = jax.lax.fori_loop(0, M + S - 1, tick, (carry0, outs0))
        # broadcast the last stage's outputs to every stage
        outs = jnp.where(is_last, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return _run(stage_params, microbatches)
