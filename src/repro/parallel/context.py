"""Ambient sharding context for model-internal layout constraints.

The model code is mesh-agnostic; when a ShardingContext is active (the
launcher/dry-run sets it), blocks apply ``with_sharding_constraint`` at
layer boundaries:

* residual stream [B, S, d] -> P(dp, "model", None)  (Megatron-style sequence
  sharding: XLA then lowers TP all-reduces into reduce-scatter/all-gather
  pairs and per-device activation memory drops by the TP degree);
* q-chunked attention bound (keeps S^2 score blocks off HBM).

This is the *production default*; the §Perf baselines toggle these off to
quantify their effect.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingContext:
    mesh: Mesh
    dp_axes: tuple[str, ...]
    model_axis: str = "model"
    seq_shard: bool = True          # sequence-shard residual stream
    q_chunk: int = 1024             # query-chunked attention block size
    unroll_loops: bool = False      # unroll inner scans (flops calibration)

    def residual_sharding(self, batch: int, seq: int):
        """NamedSharding for [B, S, d] residuals, or None if not applicable."""
        if not self.seq_shard:
            return None
        tp = self.mesh.shape[self.model_axis]
        if seq % tp != 0:
            return None
        dp = _dp_spec(self.mesh, self.dp_axes, batch)
        return NamedSharding(self.mesh, P(dp, self.model_axis, None))


def _dp_spec(mesh, dp_axes, batch: int):
    axes = []
    rem = batch
    for a in dp_axes:
        s = mesh.shape[a]
        if rem % s == 0 and rem >= s:
            axes.append(a)
            rem //= s
        else:
            break
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def current() -> ShardingContext | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use(ctx: ShardingContext | None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ctx
    try:
        yield
    finally:
        _STATE.ctx = prev
