"""Expert parallelism with explicit all-to-all (the optimized MoE path).

The baseline MoE (:mod:`repro.nn.moe`) builds a global [E, C, d] capacity
buffer under pjit; GSPMD lowers the scatter/gather around the
expert-sharded matmuls into all-gathers whose message pattern the paper's
queue-search term punishes (many strided transfers).  This module is the
classic alternative: shard_map over the expert axis with two
``jax.lax.all_to_all`` exchanges — each chip sends exactly one message per
peer per direction, the minimal-message-count schedule the paper's model
favors.

Semantics match moe_ffn with per-device capacity (tokens over device
capacity are dropped); tests compare against the reference with generous
capacity so no drops occur.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._jax_compat import shard_map

from repro.nn.config import ArchConfig


def _local_dispatch(xf, logits, cfg: ArchConfig, E_total: int, C: int):
    """Route local tokens into a per-expert capacity buffer [E_total, C, d]."""
    T, d = xf.shape
    K = cfg.n_experts_active
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    eflat = idx.reshape(-1)
    gflat = gate_vals.reshape(-1)
    order = jnp.argsort(eflat)
    e_sorted = eflat[order]
    tok_sorted = order // K
    counts = jnp.zeros(E_total, dtype=jnp.int32).at[eflat].add(1)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - offsets[e_sorted]
    keep = rank < C
    se = jnp.where(keep, e_sorted, 0)
    sc = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E_total, C, d), dtype=xf.dtype)
    buf = buf.at[se, sc].add(jnp.where(keep[:, None], xf[tok_sorted], 0)
                             .astype(xf.dtype))
    return buf, (se, sc, keep, tok_sorted, gflat, order)


def moe_ffn_ep(x, p, cfg: ArchConfig, mesh, axis_name: str = "model"):
    """MoE layer with explicit expert-parallel all-to-all.

    x: [B, S, d] (replicated over the expert axis); expert weights sharded
    on their leading E dim over ``axis_name``.  Returns [B, S, d].
    """
    M = mesh.shape[axis_name]
    E = cfg.n_experts
    assert E % M == 0

    # out is numerically replicated (every rank combines the same expert
    # outputs after the reverse all-to-all) but the replication is not
    # statically inferable -> check_vma=False.
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(), check_vma=False,
    )
    def _run(xl, router, w1, w3, w2):
        B, S, d = xl.shape
        T = B * S
        xf = xl.reshape(T, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router.astype(jnp.float32))
        C = max(8, int(T * cfg.n_experts_active * cfg.capacity_factor // E)
                + 1)
        buf, route = _local_dispatch(xf, logits, cfg, E, C)
        # [E, C, d] -> [M, E_l, C, d] -> a2a -> [E_l, M*C, d]
        E_l = E // M
        buf = buf.reshape(M, E_l, C, d)
        buf = jax.lax.all_to_all(buf, axis_name, split_axis=0, concat_axis=0,
                                 tiled=False)
        # leading axis now gathers every peer's slots for MY experts
        buf = buf.reshape(M, E_l, C, d).transpose(1, 0, 2, 3) \
                 .reshape(E_l, M * C, d)
        gate = jnp.einsum("ecd,edf->ecf", buf, w1)
        up = jnp.einsum("ecd,edf->ecf", buf, w3)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(buf.dtype) * up
        out = jnp.einsum("ecf,efd->ecd", h, w2)
        # reverse a2a: [E_l, M*C, d] -> [M, E_l, C, d] -> [E, C, d] local view
        out = out.reshape(E_l, M, C, d).transpose(1, 0, 2, 3)
        out = jax.lax.all_to_all(out, axis_name, split_axis=0, concat_axis=0,
                                 tiled=False)
        out = out.reshape(E, C, d)
        se, sc, keep, tok_sorted, gflat, order = route
        gathered = out[se, sc]
        contrib = jnp.where(keep[:, None],
                            gathered * gflat[order][:, None].astype(xl.dtype),
                            0)
        y = jnp.zeros((T, d), dtype=xl.dtype).at[tok_sorted].add(contrib)
        return y.reshape(B, S, d)

    return _run(x, p["router"], p["w1"], p["w3"], p["w2"])
