"""Distribution runtime: sharding rules, gradient compression, pipeline,
model-driven layout autotuning."""
from .sharding import (MeshPlan, make_mesh_plan, param_pspecs, batch_pspecs,
                       cache_pspecs, shardings)

__all__ = ["MeshPlan", "make_mesh_plan", "param_pspecs", "batch_pspecs",
           "cache_pspecs", "shardings"]
