"""jax API compat for the pinned toolchain.

``jax.shard_map`` (top-level, with the ``check_vma`` kwarg) only exists in
newer jax releases; the pinned toolchain ships the experimental spelling
with ``check_rep``.  Everything in :mod:`repro.parallel` goes through this
wrapper so call sites read like current jax.
"""
from __future__ import annotations

import jax


def pcast(x, axis_names, to: str = "varying"):
    """``jax.lax.pcast`` where it exists; identity on pre-vma jax, whose
    shard_map has no varying-axis typing (and hence nothing to cast)."""
    fn = getattr(jax.lax, "pcast", None)
    if fn is not None:
        return fn(x, axis_names, to=to)
    return x


def ppermute(x, axis_name, perm):
    """``jax.lax.ppermute`` of ``x`` along ``axis_name`` with the static
    source->destination pair list ``perm``.  Thin passthrough so collective
    call sites (the :mod:`repro.exec` schedule executor) import collectives
    from one place, like :func:`shard_map`; an empty ``perm`` is the
    fill-with-zeros permutation jax defines (no pair sends to anyone)."""
    return jax.lax.ppermute(x, axis_name, perm)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    new = getattr(jax, "shard_map", None)
    if new is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as old
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
