"""Deterministic fault injection for the comm stack's device/backend sites.

Every device-backend call in the pricing stack passes through a **named
injection site**: the fused segment reduction and queue walk in
:mod:`repro.kernels.comm_stack`, the device column shipping in
:meth:`repro.comm.PhaseStack._dev`, and the autotune live probe and disk
cache.  This module arms those sites: an armed site can *raise*, *time out*,
*NaN-poison* its output, or *corrupt* it — deterministically (no randomness,
an optional fire-count), so a CI chaos run reproduces exactly.

Sites (:data:`SITES`):

==========================  =================================================
``kernel.segment_reduce``   jitted/Pallas segment sum/max reductions
``kernel.queue_walk``       the device Fenwick queue sweep
``stack.device_store``      arena column shipping to the device
``autotune.probe``          the live numpy/jax crossover probe
``autotune.cache_read``     autotune disk-cache read
``autotune.cache_write``    autotune disk-cache write
``serve.cache_read``        strategy-service arena-cache read
``serve.cache_write``       strategy-service arena-cache write
``serve.deadline``          strategy-service per-request deadline check
==========================  =================================================

Modes (:data:`MODES`): ``raise`` (an :class:`InjectedFault`), ``timeout``
(an :class:`InjectedTimeout`, an ``OSError``/``TimeoutError`` so cache and
probe paths see a realistic failure type), ``nan`` (float outputs filled
with NaN — pair with ``REPRO_STACK_VERIFY=finite`` to detect it), and
``corrupt`` (numeric outputs shifted off their true values, strings/bytes
garbled — pair with ``REPRO_STACK_VERIFY=parity``).

Arming a site, two equivalent ways:

* the :func:`inject` context manager (tests)::

      with inject("kernel.segment_reduce", "raise"):
          ...  # every fused reduction degrades to numpy inside the block

* the ``REPRO_FAULT_INJECT`` env var (CI chaos runs): a comma-separated
  list of ``site:mode`` or ``site:mode:times`` entries, where ``site`` may
  be a glob (``kernel.*:raise,autotune.probe:timeout:1``).

Instrumented code calls :func:`fail_point` (raises for armed raise/timeout
specs) and :func:`poison` (transforms outputs for armed nan/corrupt specs);
both are no-ops when nothing matches, so the instrumentation costs one dict
probe per *device call* (never per message).  The graceful-degradation
wrappers around each site catch what fires, record it in
:class:`repro.comm.health.BackendHealth`, and fall back to the numpy
reference — see DESIGN.md §12.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import os

import numpy as np

__all__ = ["SITES", "MODES", "FaultSpec", "InjectedFault", "InjectedTimeout",
           "inject", "fail_point", "poison", "active_specs", "any_armed",
           "ENV_VAR"]

#: Named injection sites wrapping every device-backend call.
SITES = (
    "kernel.segment_reduce",
    "kernel.queue_walk",
    "stack.device_store",
    "autotune.probe",
    "autotune.cache_read",
    "autotune.cache_write",
    "serve.cache_read",
    "serve.cache_write",
    "serve.deadline",
)

#: Injection modes: raise / timeout fire at :func:`fail_point`, nan /
#: corrupt transform outputs at :func:`poison`.
MODES = ("raise", "timeout", "nan", "corrupt")

#: Env var holding the process-wide fault plan (CI chaos runs):
#: ``site:mode[:times]`` entries, comma-separated; ``site`` may be a glob.
ENV_VAR = "REPRO_FAULT_INJECT"


class InjectedFault(RuntimeError):
    """A deterministic injected backend failure (mode ``raise``)."""


class InjectedTimeout(InjectedFault, TimeoutError):
    """An injected timeout (mode ``timeout``).

    Also a ``TimeoutError`` (hence ``OSError``), so the disk-cache and
    probe paths — which guard against real I/O failures — see the same
    exception family a genuine timeout would produce.
    """


@dataclasses.dataclass
class FaultSpec:
    """One armed fault: ``mode`` at every site matching ``site``.

    ``site`` is an exact name or an ``fnmatch`` glob; ``times`` caps how
    often the spec fires (None = every time); ``fired`` counts firings —
    the :func:`inject` context manager yields the spec so tests can assert
    exactly how many times the fault triggered.
    """

    site: str
    mode: str
    times: int | None = None
    fired: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; "
                             f"expected one of {MODES}")
        if self.times is not None and self.times < 1:
            raise ValueError(f"times must be >= 1, got {self.times}")

    def matches(self, site: str) -> bool:
        """Whether this spec covers ``site`` (exact or glob match)."""
        return self.site == site or fnmatch.fnmatchcase(site, self.site)

    @property
    def armed(self) -> bool:
        """Whether the spec can still fire (``times`` not exhausted)."""
        return self.times is None or self.fired < self.times

    def fire(self) -> None:
        """Count one firing."""
        self.fired += 1


# context-manager-armed specs, innermost last (fires before env specs)
_stack: list[FaultSpec] = []
# parsed env plans, keyed by the raw env string (the env can change
# between calls — monkeypatched tests — so the parse is keyed, not frozen)
_env_cache: dict[str, tuple[FaultSpec, ...]] = {}


def _parse_env(raw: str) -> tuple[FaultSpec, ...]:
    specs = []
    for entry in raw.split(","):
        entry = entry.strip()
        if not entry:
            continue
        parts = entry.split(":")
        if len(parts) not in (2, 3):
            raise ValueError(
                f"bad {ENV_VAR} entry {entry!r}; expected site:mode or "
                "site:mode:times")
        times = int(parts[2]) if len(parts) == 3 else None
        specs.append(FaultSpec(site=parts[0], mode=parts[1], times=times))
    return tuple(specs)


def _env_specs() -> tuple[FaultSpec, ...]:
    raw = os.environ.get(ENV_VAR, "")
    if not raw:
        return ()
    if raw not in _env_cache:
        _env_cache.clear()                    # one plan per process at a time
        _env_cache[raw] = _parse_env(raw)
    return _env_cache[raw]


def active_specs() -> tuple[FaultSpec, ...]:
    """Every armed spec, innermost context first, then the env plan."""
    return tuple(s for s in (*reversed(_stack), *_env_specs()) if s.armed)


def any_armed() -> bool:
    """Whether any fault spec is currently armed (context or env)."""
    return bool(active_specs())


def _match(site: str, modes: tuple[str, ...]) -> FaultSpec | None:
    for spec in active_specs():
        if spec.mode in modes and spec.matches(site):
            return spec
    return None


@contextlib.contextmanager
def inject(site: str, mode: str = "raise", times: int | None = None):
    """Arm ``mode`` at every site matching ``site`` for the block.

    ``site`` is an exact name from :data:`SITES` or an ``fnmatch`` glob;
    ``times`` caps how often the spec fires (None = every time).  Yields
    the armed :class:`FaultSpec` (inspect ``spec.fired`` afterwards).
    Nested injections stack; the innermost matching spec fires first.
    """
    spec = FaultSpec(site=site, mode=mode, times=times)
    _stack.append(spec)
    try:
        yield spec
    finally:
        _stack.remove(spec)


def fail_point(site: str) -> None:
    """The raise/timeout trigger, called on entry to an instrumented site.

    Raises :class:`InjectedFault` / :class:`InjectedTimeout` when an armed
    ``raise`` / ``timeout`` spec matches ``site``; otherwise a no-op.
    """
    spec = _match(site, ("raise", "timeout"))
    if spec is None:
        return
    spec.fire()
    if spec.mode == "timeout":
        raise InjectedTimeout(f"injected timeout at {site}")
    raise InjectedFault(f"injected failure at {site}")


def _poison_value(value, mode: str):
    if isinstance(value, tuple):
        return tuple(_poison_value(v, mode) for v in value)
    if isinstance(value, (str, bytes)):
        junk = "\x00corrupt\x00" if isinstance(value, str) else b"\x00corrupt\x00"
        return junk + value
    arr = np.asarray(value)
    if mode == "nan":
        if np.issubdtype(arr.dtype, np.floating):
            return np.full_like(arr, np.nan)
        # integer outputs cannot hold NaN, and shifting them instead would
        # make nan-mode undetectable by REPRO_STACK_VERIFY=finite (which
        # only inspects float leaves): nan leaves non-float outputs intact,
        # corrupt is the integer-corruption mode
        return value
    # corrupt: shift every element detectably off its true value — a
    # relative bump for floats (the parity check is allclose-based, so an
    # absolute +1 would vanish against large magnitudes) and +1 for
    # integers (parity compares integer outputs exactly)
    if np.issubdtype(arr.dtype, np.floating):
        return arr * 1.01 + 1.0
    return arr + np.ones_like(arr)


def poison(site: str, value):
    """The output-poisoning trigger, called on an instrumented site's result.

    When an armed ``nan`` / ``corrupt`` spec matches ``site``, returns a
    poisoned copy of ``value`` (tuples poison element-wise; float arrays are
    NaN-filled under ``nan``, which leaves integer outputs intact — only
    ``finite``-detectable damage; ``corrupt`` shifts numeric outputs off
    their true values and garbles strings/bytes).  Otherwise returns
    ``value`` unchanged.  Poisoned *device* outputs are what the
    ``REPRO_STACK_VERIFY`` post-kernel checks exist to catch.
    """
    spec = _match(site, ("nan", "corrupt"))
    if spec is None:
        return value
    spec.fire()
    return _poison_value(value, spec.mode)
