"""Backend-agnostic array namespace ("xp") for the comm engine.

The segmented arena passes are written against a small numpy-compatible
surface (``asarray`` / ``where`` / ``minimum`` / ``ceil`` / arithmetic).
This module maps a resolved backend name to the module implementing that
surface — ``numpy`` itself, or ``jax.numpy`` for the device backends — so
:func:`repro.comm.primitives.transport_times` and the stack's pricing path
run unchanged under either, without per-call host<->device conversion.

Contract: with ``xp is numpy`` the engine's bit-identity guarantee holds
(same ops, same accumulation order, float64).  With ``xp is jax.numpy``
arrays stay device-resident end to end and results are float32-allclose.
"""
from __future__ import annotations

import numpy as np

#: backend names served by :func:`get_xp` with a device namespace
JAX_BACKENDS = ("jax", "pallas")


def get_xp(backend: str | None):
    """The array namespace for a *resolved* backend name.

    ``None`` / ``"numpy"`` -> :mod:`numpy`; ``"jax"`` / ``"pallas"`` ->
    :mod:`jax.numpy` (imported lazily — tier-1 environments without jax
    never pay the import).  ``"auto"`` is not accepted here: resolve it
    first (:func:`repro.kernels.comm_stack.resolve_backend`).
    """
    if backend is None or backend == "numpy":
        return np
    if backend in JAX_BACKENDS:
        import jax.numpy as jnp
        return jnp
    raise ValueError(f"no array namespace for backend {backend!r}; "
                     f"expected 'numpy' or one of {JAX_BACKENDS}")


def float_dtype(xp):
    """The working float dtype under ``xp``: float64 on numpy (bit-identity
    contract), float32 on the device namespaces (allclose contract)."""
    return np.float64 if xp is np else xp.float32


def is_device_array(a) -> bool:
    """True when ``a`` lives on a device backend (a jax Array)."""
    return type(a).__module__.split(".")[0] == "jaxlib" or \
        type(a).__module__.split(".")[0] == "jax"


def to_numpy(a) -> np.ndarray:
    """Materialise ``a`` on the host as a numpy array (no-op for numpy)."""
    return np.asarray(a)
