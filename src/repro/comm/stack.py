"""PhaseStack: one ragged arena for a whole sweep of CommPhases.

PR 1 removed the per-message Python loops *inside* a phase; this module
removes the per-phase loop *around* them — the third and last layer of the
vectorization ladder (messages -> phases -> sweeps).  A
:class:`PhaseStack` concatenates N bound :class:`~repro.comm.CommPhase`
objects (all bound to the *same* machine) into flat per-message arrays plus
``phase_id`` / ``offsets``, and evaluates every sweep quantity in one
segmented pass:

* per-(phase, process) transport sums and receive counts via a packed-key
  ``bincount`` (``phase_id * proc_span + proc``), reshaped dense and reduced
  per row;
* per-(phase, receiver) receive-queue traversal steps via one global
  :func:`~repro.comm.primitives.grouped_queue_steps` Fenwick sweep — all
  receivers of all phases advance in lock-step;
* link contention via a single phase-tagged routing expansion: one
  ``route_link_ids`` call for every network message of every phase, grouped
  by packed ``(phase, link, source)`` keys.

Bit-identity contract: with the default numpy backend every aggregate equals
the per-phase loop result *bit for bit*.  Packed-key ``bincount`` accumulates
weights in array order, which restricted to one phase is exactly the order
the per-phase ``bincount`` used; maxima are order-independent.  The one
reduction where numpy's algorithm depends on layout — ``ndarray.sum()``'s
pairwise summation over a phase's masked sizes — is computed per phase on
the identical contiguous slice of the stacked mask (:meth:`masked_phase_sums`,
O(n_phases) trivial slice-sums; all per-message work stays in the single
pass).

Device backends (``backend='jax' | 'pallas' | 'auto'``, or the
``REPRO_STACK_BACKEND`` env var) route the packed-key transport/contention
reductions and the Fenwick queue sweep through
:mod:`repro.kernels.comm_stack`, with the hot per-message columns cached
device-resident on first use (one transfer per arena, not per call) and the
message pricing itself run under the backend's array namespace
(:mod:`repro.comm.xp`).  ``'auto'`` is the autotuned default: it collapses
per call to numpy below the measured numpy/jax crossover size and to jax
at/above it.  numpy remains the default and the fallback; float backend
results are allclose (not bit-equal, the device path runs float32) while
queue steps are integer work and bit-equal everywhere.

Arenas can also be built *streaming* (:meth:`PhaseStack.build_streaming`):
phases from any iterable are appended through fixed-size buffers and the
stacked phase tuple is rebuilt as zero-copy views into the arena —
bit-identical to monolithic :meth:`PhaseStack.build` without ever holding
all source phases in RAM.

Layering: numpy-only, below both consumers.  Pricing formulas stay where
they live today — :mod:`repro.core.models` turns these aggregates into
``CostBreakdown`` rows, :mod:`repro.net.simulator` into ``PhaseResult`` rows.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any

import numpy as np

from . import faults
from .guard import ArenaOverflowError
from .health import get_health
from .phase import CommPhase
from .primitives import (flat_orders, group_by_receiver,
                         grouped_queue_steps, transport_times)
from .primitives import active_senders_per_node

__all__ = ["PhaseStack", "StackSimArrays", "as_stack", "STACK_BACKENDS"]

#: Allowed values for the ``backend`` kwarg and the ``REPRO_STACK_BACKEND``
#: env var.  Mirrors ``repro.kernels.comm_stack.BACKENDS`` — duplicated here
#: so eager validation never has to import the (jax-adjacent) kernels module.
#: ``'auto'`` is the autotuned default: numpy below the measured numpy/jax
#: crossover size, jax at/above it, resolved per call.
STACK_BACKENDS = ("numpy", "jax", "pallas", "auto")


def as_stack(phases) -> "PhaseStack | None":
    """A PhaseStack for the sweep, or None when the per-phase loop is the
    right path (fewer than two phases, unbound arrays, mixed machines).

    The one stack-or-fallback policy shared by every batched entry point
    (:func:`repro.core.models.phase_cost_many`,
    :func:`repro.net.simulator.simulate_many`): an already-built stack
    passes through, a same-machine sweep of two or more bound phases is
    stacked, anything else signals the caller to loop phase by phase.
    """
    if isinstance(phases, PhaseStack):
        return phases
    if len(phases) < 2:
        return None
    m = getattr(phases[0], "machine", None)
    if m is None or any(getattr(ph, "machine", None) is not m
                        for ph in phases):
        return None
    return PhaseStack.build(phases)


#: Per-message arrays concatenated into the arena, in CommPhase field order.
_ARENA_FIELDS = ("src", "dst", "size", "loc", "proto", "is_net", "send_node",
                 "torus_src", "torus_dst", "active_ppn")


@dataclasses.dataclass(frozen=True)
class StackSimArrays:
    """Raw per-phase simulator aggregates (priced by ``repro.net.simulator``)."""

    transport: np.ndarray            # [N] max over procs of send-side sums
    per_proc: list[np.ndarray]       # per-phase send-side transport sums
    qsteps: list[np.ndarray]         # per-phase queue traversal steps
    max_link: np.ndarray             # [N] hottest contended-link bytes
    net_bytes: np.ndarray            # [N] total network bytes


@dataclasses.dataclass(frozen=True, eq=False)
class PhaseStack:
    """N CommPhases concatenated into one ragged arena (same machine)."""

    machine: Any                     # shared MachineSpec (duck-typed)
    phases: tuple[CommPhase, ...]
    offsets: np.ndarray              # [N+1] message offsets into the arena
    n_procs: np.ndarray              # [N] per-phase process counts
    src: np.ndarray                  # [total] — concatenated CommPhase arrays
    dst: np.ndarray
    size: np.ndarray
    loc: np.ndarray
    proto: np.ndarray
    is_net: np.ndarray
    send_node: np.ndarray
    torus_src: np.ndarray
    torus_dst: np.ndarray
    active_ppn: np.ndarray
    phase_id: np.ndarray             # [total] owning phase of each message

    @classmethod
    def build(cls, phases) -> "PhaseStack":
        """Concatenate bound phases into one arena.

        Every phase must be bound to the *same* machine object: the arena
        caches machine-derived arrays, and mixing machines would silently
        price messages with the wrong parameter tables.
        """
        phases = tuple(phases)
        for ph in phases:
            if not isinstance(ph, CommPhase):
                raise TypeError(
                    f"PhaseStack stacks bound CommPhases, got {type(ph).__name__}")
        machine = phases[0].machine if phases else None
        for ph in phases:
            if ph.machine is not machine:
                raise ValueError(
                    "mixed machines: every phase in a PhaseStack must be "
                    "bound to the same machine object (rebind with "
                    "CommPhase.build / CommPattern.bind first)")
        counts = np.asarray([ph.n_msgs for ph in phases], dtype=np.int64)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        cat = {f: (np.concatenate([getattr(ph, f) for ph in phases])
                   if phases else np.zeros(0))
               for f in _ARENA_FIELDS}
        return cls(
            machine=machine, phases=phases, offsets=offsets,
            n_procs=np.asarray([ph.n_procs for ph in phases], dtype=np.int64),
            phase_id=np.repeat(np.arange(len(phases), dtype=np.int64), counts),
            **cat)

    @classmethod
    def build_streaming(cls, phases, chunk_msgs: int = 1 << 16) -> "PhaseStack":
        """Stream bound phases into an arena through fixed-size buffers.

        ``phases`` is any *iterable* of bound CommPhases — a generator is
        the point: each phase can be produced, copied into the staging
        buffer and dropped before the next one exists, so arena setup never
        needs all source phases in RAM at once.  Per-message columns are
        appended into ``chunk_msgs``-sized staging buffers; a full buffer is
        sealed into a chunk block, and each column is concatenated exactly
        once at the end.  Peak extra memory is one chunk plus the sealed
        blocks (which together are the arena), instead of every source
        phase's arrays *plus* the arena.

        The stacked ``phases`` tuple is rebuilt as zero-copy views: each
        entry is a CommPhase whose arrays are slices of the arena columns.
        The result is **bit-identical** to monolithic :meth:`build` for
        every chunk size — a concatenation of chunk blocks is the same
        array as a concatenation of per-phase columns, and every derived
        aggregate reduces the same arena.
        """
        chunk_msgs = int(chunk_msgs)
        if chunk_msgs < 1:
            raise ValueError(f"chunk_msgs must be >= 1, got {chunk_msgs}")
        machine = None
        counts: list[int] = []
        n_procs: list[int] = []
        overridden: list[bool] = []
        dtypes: dict[str, Any] = {}
        blocks: dict[str, list] = {f: [] for f in _ARENA_FIELDS}
        buf: dict[str, np.ndarray] = {}
        fill = 0

        def seal():
            nonlocal fill
            if fill:
                for f in _ARENA_FIELDS:
                    blocks[f].append(buf[f][:fill].copy())
            fill = 0

        for ph in phases:
            if not isinstance(ph, CommPhase):
                raise TypeError(
                    f"PhaseStack stacks bound CommPhases, got {type(ph).__name__}")
            if not counts:
                machine = ph.machine
                dtypes = {f: getattr(ph, f).dtype for f in _ARENA_FIELDS}
            elif ph.machine is not machine:
                raise ValueError(
                    "mixed machines: every phase in a PhaseStack must be "
                    "bound to the same machine object (rebind with "
                    "CommPhase.build / CommPattern.bind first)")
            counts.append(ph.n_msgs)
            n_procs.append(ph.n_procs)
            overridden.append(ph.loc_overridden)
            if not buf and ph.n_msgs:
                buf = {f: np.empty(chunk_msgs, dtype=dtypes[f])
                       for f in _ARENA_FIELDS}
            taken = 0
            while taken < ph.n_msgs:
                step = min(chunk_msgs - fill, ph.n_msgs - taken)
                for f in _ARENA_FIELDS:
                    buf[f][fill:fill + step] = \
                        getattr(ph, f)[taken:taken + step]
                fill += step
                taken += step
                if fill == chunk_msgs:
                    seal()
        seal()
        counts_a = np.asarray(counts, dtype=np.int64)
        offsets = np.concatenate([[0], np.cumsum(counts_a)]).astype(np.int64)
        cat = {f: (np.concatenate(blocks[f]) if blocks[f]
                   else np.zeros(0, dtype=dtypes[f]) if dtypes
                   else np.zeros(0))
               for f in _ARENA_FIELDS}
        views = tuple(
            CommPhase(machine=machine, n_procs=int(n_procs[i]),
                      loc_overridden=bool(overridden[i]),
                      **{f: cat[f][offsets[i]:offsets[i + 1]]
                         for f in _ARENA_FIELDS})
            for i in range(len(counts)))
        return cls(
            machine=machine, phases=views, offsets=offsets,
            n_procs=np.asarray(n_procs, dtype=np.int64),
            phase_id=np.repeat(np.arange(len(counts), dtype=np.int64),
                               counts_a),
            **cat)

    # -- basic stats --------------------------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self.phases)

    @property
    def total_msgs(self) -> int:
        return int(self.offsets[-1]) if self.offsets.size else 0

    def __len__(self) -> int:
        return self.n_phases

    def __iter__(self):
        return iter(self.phases)

    # cached_property writes straight to __dict__, bypassing the frozen
    # dataclass __setattr__ — all of these are derived state, computed once
    # per stack and reused by every sweep over it (ladder levels, strategy
    # candidates, repeated simulations).
    @functools.cached_property
    def proc_span(self) -> int:
        """Column span of the dense per-(phase, process) layouts."""
        return int(max(self.n_procs.max(initial=0),
                       self.src.max(initial=-1) + 1,
                       self.dst.max(initial=-1) + 1, 1))

    @functools.cached_property
    def _src_key(self) -> np.ndarray:
        """Packed (phase, sender) key of every message."""
        return self.phase_id * self.proc_span + self.src

    @functools.cached_property
    def _dst_key(self) -> np.ndarray:
        """Packed (phase, receiver) key of every message."""
        return self.phase_id * self.proc_span + self.dst

    @functools.cached_property
    def _recv_counts(self) -> np.ndarray:
        """Dense [n_phases, proc_span] receive counts (level-independent)."""
        return np.bincount(self._dst_key,
                           minlength=self.n_phases * self.proc_span).reshape(
            self.n_phases, self.proc_span)

    @functools.cached_property
    def _receiver_groups(self) -> tuple[np.ndarray, np.ndarray]:
        """Stable grouping of messages by packed (phase, receiver) slot."""
        return group_by_receiver(self._dst_key,
                                 self.n_phases * self.proc_span)

    @functools.cached_property
    def _net_bytes(self) -> np.ndarray:
        """Per-phase network bytes under the machine's own locality tables."""
        return self.masked_phase_sums(self.size, self.is_net)

    @functools.cached_property
    def _class_bytes(self) -> np.ndarray:
        """Dense [n_phases, n_locality] byte sums by locality class — the
        packed-key bincount with the *class* axis in place of the process
        axis.  Restricted to one phase the accumulation order is the
        per-phase ``CommPhase.class_bytes`` order, so rows are bit-identical
        to the loop."""
        L = self.machine.params.n_locality
        return np.bincount(self.phase_id * L + self.loc, weights=self.size,
                           minlength=self.n_phases * L).reshape(
            self.n_phases, L)

    def class_bytes(self) -> np.ndarray:
        """Per-phase payload bytes per locality class ([n_phases,
        n_locality]) — one packed-key pass over the arena, row ``i``
        bit-identical to ``phases[i].class_bytes()``.  The class-axis view
        the hetero benches and examples report (how much traffic rides each
        rate-table row)."""
        return self._class_bytes

    @functools.cached_property
    def _machine_tables(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(alpha, Rb, RN) indexed per message with the machine's own
        parameter tables — shared by the simulator and every node-aware
        ladder level priced against the ground truth."""
        p = self.machine.params
        return (p.alpha[self.loc, self.proto], p.Rb[self.loc, self.proto],
                p.RN[self.loc, self.proto])

    @functools.cached_property
    def _machine_t_msg(self) -> np.ndarray:
        """Max-rate transport time of every message under the machine's own
        tables — the quantity the simulator and the node-aware ladder levels
        both price (identical inputs, so one cached pass serves both)."""
        alpha, Rb, RN = self._machine_tables
        return transport_times(self.size, alpha, Rb, RN, self.active_ppn,
                               self.is_net,
                               rails=self.machine.params.n_rails)

    @functools.cached_property
    def _machine_transport(self) -> np.ndarray:
        """Dense per-(phase, process) sums of :attr:`_machine_t_msg`.

        Pinned to the numpy backend (not ``None``): the cache must stay
        bit-exact even when ``REPRO_STACK_BACKEND`` selects an accelerator.
        """
        return self._phase_proc_sums(self._machine_t_msg, self._src_key,
                                     backend="numpy")

    @functools.cached_property
    def _ladder_cache(self) -> dict:
        """Dense transport matrices per (node_aware, use_maxrate) flag pair,
        for pricing against the machine's own tables (numpy backend).  Like
        every cached property here these are pure functions of the arena:
        binding once and sweeping many times — fitting loops, strategy scans,
        repeated ladders — amortizes the message-pricing passes away."""
        return {}

    # -- backend resolution --------------------------------------------------
    @staticmethod
    def _backend(backend):
        """Resolve a backend name to ('numpy', None) or (name, kernels mod).

        Validation is eager and happens *here*, before any reduction runs:
        an unknown name — whether passed as the ``backend`` kwarg or set in
        the ``REPRO_STACK_BACKEND`` env var — raises a ``ValueError`` naming
        the allowed values and where the bad name came from, instead of
        failing deep inside a segmented pass.
        """
        source = "the backend argument"
        if backend is None:
            backend = os.environ.get("REPRO_STACK_BACKEND", "numpy")
            source = "the REPRO_STACK_BACKEND environment variable"
        if backend not in STACK_BACKENDS:
            raise ValueError(
                f"unknown stack backend {backend!r} (from {source}); "
                f"allowed values: {STACK_BACKENDS}")
        if backend == "numpy":
            return "numpy", None
        from repro.kernels import comm_stack   # lazy: keeps comm numpy-only
        backend = comm_stack.resolve_backend(backend)
        return backend, (None if backend == "numpy" else comm_stack)

    def _resolved_backend(self, backend):
        """Like :meth:`_backend`, with ``'auto'`` collapsed for this arena.

        The autotuned default resolves against the arena's message count:
        numpy below the measured numpy/jax crossover size (the exact numpy
        paths and caches, bit-identical), jax at/above it
        (:func:`repro.kernels.comm_stack.autotune_crossover`).  The choice
        is memoized per arena — ``total_msgs`` is immutable and the
        crossover is a process-wide constant, so re-resolving on every
        reduction pass would only add dispatch overhead to the small-arena
        path the autotuner exists to protect.
        """
        name, mod = self._backend(backend)
        if name == "auto":
            cached = self.__dict__.get("_auto_choice")
            if cached is None:
                cached = mod.resolve_backend("auto", n_values=self.total_msgs)
                self.__dict__["_auto_choice"] = cached
            name = cached
            if name == "numpy":
                mod = None
        return name, mod

    # -- device-resident columns --------------------------------------------
    @functools.cached_property
    def _device_store(self) -> dict:
        """Device (jax) copies of arena columns, by attribute name — filled
        lazily by :meth:`_dev`, so a device-backed sweep transfers each hot
        column once per arena instead of once per call."""
        return {}

    def _dev(self, name):
        """The named per-message column as a cached device array (float64
        columns go over as float32, int64 keys as int32 — the device
        contract is allclose/float32 for floats and exact for keys).

        An arena whose keys exceed int32 raises the typed
        :class:`repro.comm.guard.ArenaOverflowError` — callers inside the
        degradation contract (:meth:`cost_arrays` / :meth:`sim_arrays`)
        catch it and price the arena on the numpy path with a warn-once
        instead of crashing the sweep.
        """
        store = self._device_store
        if name not in store:
            import jax.numpy as jnp
            faults.fail_point("stack.device_store")
            a = np.asarray(getattr(self, name))
            if a.dtype == np.float64:
                a = a.astype(np.float32)
            elif a.dtype == np.int64:
                if a.size and (a.max() >= 2 ** 31 or a.min() < -2 ** 31):
                    raise ArenaOverflowError(
                        f"arena column {name!r} exceeds int32 range; such "
                        "arenas price on the numpy backend")
                a = a.astype(np.int32)
            store[name] = jnp.asarray(a)
        return store[name]

    # -- segmented reductions -----------------------------------------------
    def _phase_proc_sums(self, values, key, backend=None) -> np.ndarray:
        """Dense [n_phases, proc_span] sums of ``values`` by a packed
        (phase, process) key (``_src_key`` / ``_dst_key``)."""
        n = self.n_phases * self.proc_span
        backend, mod = self._resolved_backend(backend)
        if mod is None:
            dense = np.bincount(key, weights=values, minlength=n)
        else:
            dense = mod.segment_sum(values, key, n, backend=backend)
        return dense.reshape(self.n_phases, self.proc_span)

    def masked_phase_sums(self, values, mask) -> np.ndarray:
        """Per-phase ``values[mask].sum()`` with the loop path's exact
        floating-point result: each phase's masked elements form a contiguous
        slice of the stacked mask selection, and ``ndarray.sum()`` on that
        slice replays the identical pairwise-summation tree.  O(n_phases)
        trivial slice-sums; the selection itself is one vectorized pass."""
        picked = np.asarray(values)[mask]
        pid = self.phase_id[mask]
        bounds = np.searchsorted(pid, np.arange(self.n_phases + 1))
        return np.asarray([picked[bounds[i]:bounds[i + 1]].sum()
                           for i in range(self.n_phases)])

    # -- model-side aggregates ----------------------------------------------
    def cost_arrays(self, params=None, *, node_aware: bool = True,
                    use_maxrate: bool = True, with_queue: bool = True,
                    with_net_bytes: bool = True, backend=None):
        """Aggregates behind the model ladder, one segmented pass each.

        Returns ``(transport[N], max_recv[N], net_bytes[N])``: the worst
        per-process send-side transport sum, the worst per-process receive
        count (0s when ``with_queue=False``) and the total network-class
        bytes (0s when ``with_net_bytes=False``) of every phase.  ``params``
        substitutes a fitted table for the machine's own; ``node_aware`` /
        ``use_maxrate`` select the ladder rung's transport formula;
        ``backend`` routes the pricing and segmented reductions through
        :mod:`repro.kernels.comm_stack` (``'jax'``/``'pallas'`` run
        device-resident off the cached column store; ``'auto'`` picks
        numpy or jax per call at the autotuned crossover size).
        :func:`repro.core.models.phase_cost_many` prices them into
        ``CostBreakdown`` rows bit-identical to the per-phase loop.
        """
        N = self.n_phases
        zeros = np.zeros(N)
        if N == 0 or self.total_msgs == 0:
            return zeros, zeros.copy(), zeros.copy()
        m = self.machine
        p = params if params is not None else m.params
        same_net = p.network_locality == m.params.network_locality
        backend_name, mod = self._resolved_backend(backend)
        flags = (node_aware, use_maxrate)
        cacheable = p is m.params and backend_name == "numpy"
        if cacheable and flags in self._ladder_cache:
            dense = self._ladder_cache[flags]
        else:
            if node_aware and use_maxrate and cacheable:
                # ground-truth node-aware pricing: the pass shared with the
                # simulator (identical inputs, identical result)
                dense = self._machine_transport
            else:
                # device path: columns cached resident, tables indexed and
                # the formula priced on device, one transfer of the reduced
                # dense matrix back.  A device failure (None) degrades to
                # the numpy pricing path — the sweep never crashes on a
                # backend fault (DESIGN.md §12).
                dense = (self._device_dense_guarded(
                             p, node_aware, use_maxrate, backend_name, mod,
                             same_net)
                         if mod is not None else None)
                if dense is None:
                    dense = self._numpy_dense_for(p, node_aware, use_maxrate,
                                                  same_net)
            if cacheable:
                self._ladder_cache[flags] = dense
        transport = dense.max(axis=1)
        max_recv = (self._recv_counts.max(axis=1).astype(np.float64)
                    if with_queue else zeros.copy())
        if not with_net_bytes:
            net_bytes = zeros.copy()
        elif node_aware and same_net:
            net_bytes = self._net_bytes        # cached machine classification
        elif node_aware:
            net_bytes = self.masked_phase_sums(self.size,
                                               self.loc >= p.network_locality)
        else:                                  # every message is network-class
            net_bytes = self.masked_phase_sums(
                self.size, np.ones(self.total_msgs, dtype=bool))
        return np.asarray(transport, dtype=np.float64), max_recv, net_bytes

    def _active_ppn_for(self, params) -> np.ndarray:
        """Cached active-sender counts, or a stacked recompute when an
        override params table reclassifies localities (the per-(phase, node)
        grouping rides on phase-offset node ids)."""
        if params.network_locality == self.machine.params.network_locality:
            return self.active_ppn
        node_span = int(self.send_node.max(initial=-1)) + 1
        return active_senders_per_node(
            self.src, self.phase_id * node_span + self.send_node,
            self.loc >= params.network_locality)

    def _numpy_cost_dense(self, p, node_aware, use_maxrate,
                          same_net) -> np.ndarray:
        """The ladder transport matrix priced on the host — the bit-identity
        numpy reference the device path degrades to."""
        m = self.machine
        # protocol classes depend on size thresholds only: the
        # machine-table classification is already cached
        proto = self.proto if p is m.params else p.protocol_of(self.size)
        if node_aware:
            if p is m.params:
                alpha, Rb, RN = self._machine_tables
            else:
                alpha = p.alpha[self.loc, proto]
                Rb = p.Rb[self.loc, proto]
                RN = p.RN[self.loc, proto] if use_maxrate else None
            is_net = (self.is_net if same_net
                      else self.loc >= p.network_locality)
        else:
            # loc collapses to the network class: index the table
            # rows by protocol only (== full_like(loc, nl) indexing)
            nl = p.network_locality
            alpha = p.alpha[nl][proto]
            Rb = p.Rb[nl][proto]
            RN = p.RN[nl][proto] if use_maxrate else None
            is_net = np.ones(self.total_msgs, dtype=bool)
        if use_maxrate:
            t_msg = transport_times(self.size, alpha, Rb, RN,
                                    self._active_ppn_for(p), is_net,
                                    rails=p.n_rails)
        else:
            t_msg = transport_times(self.size, alpha, Rb, None, 1.0,
                                    False, use_maxrate=False)
        return self._phase_proc_sums(t_msg, self._src_key, backend="numpy")

    def _numpy_dense_for(self, p, node_aware, use_maxrate,
                         same_net) -> np.ndarray:
        """The numpy reference dense matrix for a ladder configuration —
        the cached machine pass when it applies, the host pricing path
        otherwise.  Both the degradation fallback and the
        ``REPRO_STACK_VERIFY=parity`` reference for the device pricing."""
        if node_aware and use_maxrate and p is self.machine.params:
            return self._machine_transport
        return self._numpy_cost_dense(p, node_aware, use_maxrate, same_net)

    def _device_dense_guarded(self, p, node_aware, use_maxrate, backend_name,
                              mod, same_net) -> np.ndarray | None:
        """:meth:`_device_cost_dense` under the degradation contract.

        The ``stack.device_store`` injection site covers the whole device
        pricing pass (column shipping via :meth:`_dev` has its own
        fail-point inside).  Any failure — an injected fault, an
        :class:`repro.comm.guard.ArenaOverflowError` from an oversized
        arena, a compile error, a ``REPRO_STACK_VERIFY`` rejection — is
        recorded in :class:`repro.comm.health.BackendHealth` (warn-once,
        quarantine accounting) and returns None; the caller prices on the
        numpy path instead.
        """
        from repro.kernels import comm_stack as cs
        health = get_health()
        if health.is_quarantined(backend_name):
            return None
        try:
            dense = faults.poison(
                "stack.device_store",
                self._device_cost_dense(p, node_aware, use_maxrate,
                                        backend_name, mod, same_net))
            mode = cs.verify_mode()
            if mode == "finite":
                cs._check_finite(dense)
            elif mode == "parity":
                cs._check_parity(dense, self._numpy_dense_for(
                    p, node_aware, use_maxrate, same_net))
        except Exception as e:  # noqa: BLE001 - degradation catches all
            health.record_failure(backend_name, "stack.device_store", e)
            return None
        health.record_success(backend_name)
        return dense

    def _device_cost_dense(self, p, node_aware, use_maxrate, backend_name,
                           mod, same_net) -> np.ndarray:
        """Ladder transport matrix priced end-to-end on device.

        The cached device columns (:meth:`_dev`) supply the per-message
        inputs, the (tiny) locality x protocol parameter tables are shipped
        once and indexed on device, :func:`transport_times` runs under the
        backend's array namespace and the packed-key reduction consumes the
        device values directly — the only host transfer per call is the
        reduced dense ``[n_phases, proc_span]`` matrix.
        """
        import jax.numpy as jnp

        from .xp import get_xp
        xp = get_xp(backend_name)
        m = self.machine
        proto = (self._dev("proto") if p is m.params
                 else jnp.asarray(p.protocol_of(self.size).astype(np.int32)))
        at = jnp.asarray(np.asarray(p.alpha, dtype=np.float32))
        rb = jnp.asarray(np.asarray(p.Rb, dtype=np.float32))
        rn = jnp.asarray(np.asarray(p.RN, dtype=np.float32))
        if node_aware:
            loc = self._dev("loc")
            alpha, Rb, RN = at[loc, proto], rb[loc, proto], rn[loc, proto]
            is_net = (self._dev("is_net") if same_net
                      else loc >= p.network_locality)
        else:
            nl = p.network_locality
            alpha, Rb, RN = at[nl, proto], rb[nl, proto], rn[nl, proto]
            is_net = jnp.ones(self.total_msgs, dtype=bool)
        if use_maxrate:
            if p.network_locality == m.params.network_locality:
                ppn = self._dev("active_ppn")
            else:
                ppn = jnp.asarray(
                    self._active_ppn_for(p).astype(np.float32))
            t_msg = transport_times(self._dev("size"), alpha, Rb, RN, ppn,
                                    is_net, rails=p.n_rails, xp=xp)
        else:
            t_msg = transport_times(self._dev("size"), alpha, Rb, None, 1.0,
                                    False, use_maxrate=False, xp=xp)
        n = self.n_phases * self.proc_span
        dense = mod.segment_sum(t_msg, self._dev("_src_key"), n,
                                backend=backend_name)
        return dense.reshape(self.n_phases, self.proc_span)

    # -- per-rail byte counters ---------------------------------------------
    def rail_bytes(self, n_rails: int | None = None) -> np.ndarray:
        """Dense ``[n_phases, n_rails]`` injected network bytes per NIC rail.

        The measurement-side counter behind multi-rail fitting
        (:func:`repro.core.fitting.fit_rails`): each network-class message —
        the same selection the routing expansion routes — is charged to its
        sender's rail ``src % n_rails``, the static round-robin NIC binding
        the max-rate rail model assumes.  One packed-key bincount
        (``phase * n_rails + rail``).  ``n_rails`` defaults to the machine
        table's own ``CommParams.n_rails``.
        """
        r = int(n_rails) if n_rails is not None else \
            int(self.machine.params.n_rails)
        if r < 1:
            raise ValueError(f"n_rails must be >= 1, got {r}")
        key = self.phase_id * r + self.src % r
        w = np.where(self.is_net, self.size, 0.0)
        return np.bincount(key, weights=w,
                           minlength=self.n_phases * r).reshape(
            self.n_phases, r)

    # -- receive-queue accounting -------------------------------------------
    def queue_steps_many(self, recv_post_orders=None,
                         arrival_orders=None, backend=None) -> np.ndarray:
        """Dense [n_phases, proc_span] exact queue traversal-step totals.

        ``recv_post_orders[i]`` / ``arrival_orders[i]`` are phase ``i``'s
        per-receiver order dicts (phase-local message indices, exactly what
        :meth:`CommPhase.queue_steps` takes).  All phases' custom receivers
        run in ONE lock-step Fenwick sweep: the rounds needed are the *max*
        messages-per-receiver over the whole stack, not the per-phase sum.
        ``backend`` selects where the sweep runs — the device walk
        (:func:`repro.kernels.comm_stack.queue_walk`) executes all rounds in
        one fused program and, being integer work, is *bit-equal* to numpy.
        """
        P = self.proc_span
        backend_name, _ = self._resolved_backend(backend)
        qsteps = grouped_queue_steps(
            self._dst_key, self.n_phases * P,
            recv_post_order=self._flatten_orders(recv_post_orders),
            arrival_order=self._flatten_orders(arrival_orders),
            groups=self._receiver_groups,
            describe=lambda s: f"receiver {s % P} of phase {s // P}",
            backend=backend_name)
        return qsteps.reshape(self.n_phases, P)

    def _flatten_orders(self, per_phase):
        """Merge per-phase order specs (dicts or flat ``(slots, lens, ids)``
        tuples of phase-local values) into one stack-wide flat spec: slots
        become packed ``(phase, receiver)`` keys, ids become arena indices.
        Pure array concatenation — no per-receiver work for flat inputs."""
        if per_phase is None:
            return None
        P = self.proc_span
        slot_parts, len_parts, id_parts = [], [], []
        for i, d in enumerate(per_phase):
            flat = flat_orders(d)
            if flat is None:
                continue
            slots, lens, ids = flat
            if slots.size and (slots[0] < 0 or slots[-1] >= P):
                keep = (slots >= 0) & (slots < P)   # mirror per-phase filter
                sel = np.repeat(keep, lens)
                slots, lens, ids = slots[keep], lens[keep], ids[sel]
            slot_parts.append(i * P + slots)
            len_parts.append(lens)
            id_parts.append(ids + self.offsets[i])
        if not slot_parts:
            return None
        return (np.concatenate(slot_parts), np.concatenate(len_parts),
                np.concatenate(id_parts))

    # -- link contention ----------------------------------------------------
    @functools.cached_property
    def _link_contention(self) -> tuple[np.ndarray, np.ndarray]:
        """Cached numpy-backend :meth:`link_contention_many` — the routing
        expansion depends only on the arena, never on receive orders, so
        repeated simulations of a bound stack reuse it.  Pinned to numpy so
        ``REPRO_STACK_BACKEND`` cannot poison the bit-exact cache."""
        return self._compute_link_contention("numpy")

    def link_contention_many(self, backend=None):
        """(hottest contended-link bytes, total network bytes) per phase;
        ``backend`` selects the reduction backend (numpy default, cached).

        One phase-tagged routing expansion: every inter-torus-unit network
        message of every phase is routed dimension-ordered in a single
        ``route_link_ids`` call, grouped by packed ``(phase, link, source)``
        keys.  Per ``(phase, link)``, bytes beyond the largest single-source
        contribution count as contention, exactly like
        :meth:`CommPhase.link_contention` — and bit-identically so: within a
        phase the packed keys sort and accumulate in the per-phase order.
        """
        backend_name, _ = self._resolved_backend(backend)
        if backend_name == "numpy":
            return self._link_contention
        return self._compute_link_contention(backend_name)

    def _compute_link_contention(self, backend):
        net_bytes = self._net_bytes
        out = np.zeros(self.n_phases)
        sel = self.is_net & (self.torus_src != self.torus_dst)
        if not sel.any():
            return out, net_bytes
        torus = self.machine.torus
        tsrc = self.torus_src[sel]
        pid = self.phase_id[sel]
        midx, link = torus.route_link_ids(tsrc, self.torus_dst[sel])
        if link.size == 0:
            return out, net_bytes
        w = self.size[sel][midx]
        src_span = np.int64(max(torus.size, int(tsrc.max()) + 1))
        link_span = np.int64(torus.link_slots)
        if self.n_phases * int(link_span) * int(src_span) >= 2 ** 62:
            raise ValueError(
                "packed (phase, link, source) key would overflow int64; "
                "split the sweep into smaller stacks")
        key = (pid[midx] * link_span + link) * src_span + tsrc[midx]
        uk, inv = np.unique(key, return_inverse=True)
        per_src = np.bincount(inv, weights=w)     # bytes/(phase, link, source)
        pair = uk // src_span                     # (phase, link) runs
        starts = np.nonzero(np.r_[True, pair[1:] != pair[:-1]])[0]
        backend, mod = self._resolved_backend(backend)
        if mod is None:
            totals = np.add.reduceat(per_src, starts)
            largest = np.maximum.reduceat(per_src, starts)
        else:
            lens = np.diff(np.r_[starts, per_src.size])
            seg = np.repeat(np.arange(starts.size), lens)
            if backend == "pallas":
                # the contention reduction needs both aggregates: one fused
                # launch returns (sums, maxima) together
                totals, largest = mod.fused_segment_reduce(per_src, seg,
                                                           starts.size)
            else:
                totals = mod.segment_sum(per_src, seg, starts.size,
                                         backend=backend)
                largest = mod.segment_max(per_src, seg, starts.size,
                                          backend=backend)
        run_phase = (pair[starts] // link_span).astype(np.int64)
        np.maximum.at(out, run_phase, totals - largest)
        return out, net_bytes

    # -- simulator-side aggregates ------------------------------------------
    def sim_arrays(self, recv_post_orders=None, arrival_orders=None,
                   backend=None) -> StackSimArrays:
        """Raw simulator aggregates for the whole stack, one pass each.

        ``recv_post_orders[i]`` / ``arrival_orders[i]`` are phase ``i``'s
        receive-order specs (as in :meth:`queue_steps_many`); ``backend``
        selects the reduction backend.
        :func:`repro.net.simulator.simulate_many` prices them into
        ``PhaseResult`` rows bit-identical to per-phase :func:`simulate`
        (numpy backend); phases with zero messages get the empty per-proc
        arrays the per-phase early return produces.
        """
        if self.n_phases == 0:
            z = np.zeros(0)
            return StackSimArrays(z, [], [], z.copy(), z.copy())
        backend_name, mod = self._resolved_backend(backend)
        if backend_name == "numpy":
            dense = self._machine_transport    # cached, shared with the model
        else:
            # device failures degrade to the cached numpy machine pass
            # (bit-identical) instead of crashing the simulation
            dense = self._device_dense_guarded(self.machine.params, True,
                                               True, backend_name, mod, True)
            if dense is None:
                dense = self._machine_transport
        qdense = self.queue_steps_many(recv_post_orders, arrival_orders,
                                       backend=backend_name)
        max_link, net_bytes = self.link_contention_many(backend=backend_name)
        counts = np.diff(self.offsets)
        empty_f = np.zeros(0)
        empty_i = np.zeros(0, dtype=qdense.dtype)
        per_proc = [dense[i, :self.n_procs[i]].copy() if counts[i] else empty_f
                    for i in range(self.n_phases)]
        qsteps = [qdense[i, :self.n_procs[i]].copy() if counts[i] else empty_i
                  for i in range(self.n_phases)]
        return StackSimArrays(
            transport=np.asarray(dense.max(axis=1), dtype=np.float64),
            per_proc=per_proc, qsteps=qsteps,
            max_link=max_link, net_bytes=net_bytes)
