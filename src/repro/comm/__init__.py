"""Unified vectorized communication-phase engine.

One abstraction — :class:`CommPhase` — binds a point-to-point message set
(src, dst, size) to a machine once, caching per-message locality, protocol
class, torus endpoints and active-senders-per-node.  Both sides of the
paper's inferential gap consume it: the closed-form model ladder
(:func:`repro.core.models.phase_cost_many`) and the mechanistic event
simulator (:func:`repro.net.simulator.simulate`).  The shared hot-path math
lives in :mod:`repro.comm.primitives` (numpy-only, below both consumers).

:mod:`repro.comm.strategies` builds on the same engine: node-aware
communication strategies (``standard`` / ``two_step`` / ``three_step``) are
pure phase -> phase-sequence rewrites, so both consumers price every
strategy with zero new cost code; :func:`best_strategy` sweeps them and
returns the model's predicted winner plus the simulator's verdict.

:mod:`repro.comm.stack` lifts the engine from phases to *sweeps*: a
:class:`PhaseStack` concatenates a whole sweep of same-machine phases into
one ragged arena and evaluates every quantity in one segmented pass —
bit-identical to the per-phase loop, with an optional JAX/Pallas backend
for the reductions (:mod:`repro.kernels.comm_stack`).  The batched entry
points (``phase_cost_many`` / ``model_ladder_many`` / ``simulate_many`` /
``best_strategy``) ride it automatically.

:mod:`repro.comm.delta` lifts sweeps to *search*: a :class:`DeltaStack`
wraps the same arena and re-prices ``apply(removed, added)`` mutations at
O(changed) cost — bit-identical to a fresh build — so model-guided local
search (:func:`repro.sparse.optimize_partition`) pays per move only for
what the move touched.

The robustness layer (DESIGN.md §12) rides underneath all of it:
:mod:`repro.comm.guard` is the typed input-validation layer (the
:class:`PatternError` hierarchy), :mod:`repro.comm.faults` the
deterministic fault-injection framework over every device-backend site,
and :mod:`repro.comm.health` the per-process :class:`BackendHealth`
ledger (degradation events, quarantine, the resettable warn-once
registry) that the graceful-fallback policy reports to.

See ``docs/api.md`` for the public API reference and DESIGN.md §1/§7/§8/§9
for the architecture.
"""
from .guard import (PatternError, MessageSizeError, RankError,
                    ArenaOverflowError, validate_messages, validate_phase)
from .faults import (FaultSpec, InjectedFault, InjectedTimeout, inject,
                     SITES as FAULT_SITES, MODES as FAULT_MODES)
from .health import (BackendHealth, CircuitBreaker, HealthEvent, get_health,
                     reset_health)
from .phase import CommPhase
from .primitives import (active_senders_per_node, transport_times,
                         per_proc_sums, group_by_receiver, sum_by_pairs,
                         segmented_arange, grouped_queue_steps,
                         queue_traversal_steps,
                         batched_queue_traversal_steps)
from .stack import PhaseStack, StackSimArrays, STACK_BACKENDS
from .delta import (ARENA_TYPES, DeltaStack, message_delta,
                    pattern_fingerprint, phase_fingerprint)
from .strategies import (STRATEGIES, GPU_STRATEGIES, StrategyPlan,
                         StrategyVerdict, strategies_for,
                         standard, two_step, three_step, host_staged,
                         device_direct, rewrite,
                         injected_payload, delivered_payload, best_strategy,
                         best_strategy_many)

__all__ = [
    "CommPhase", "PhaseStack", "StackSimArrays", "STACK_BACKENDS",
    "DeltaStack", "ARENA_TYPES",
    "message_delta", "pattern_fingerprint", "phase_fingerprint",
    "active_senders_per_node", "transport_times", "per_proc_sums",
    "group_by_receiver", "sum_by_pairs", "segmented_arange",
    "grouped_queue_steps",
    "queue_traversal_steps", "batched_queue_traversal_steps",
    "STRATEGIES", "GPU_STRATEGIES", "StrategyPlan", "StrategyVerdict",
    "strategies_for",
    "standard", "two_step", "three_step", "host_staged", "device_direct",
    "rewrite",
    "injected_payload", "delivered_payload", "best_strategy",
    "best_strategy_many",
    "PatternError", "MessageSizeError", "RankError", "ArenaOverflowError",
    "validate_messages", "validate_phase",
    "FaultSpec", "InjectedFault", "InjectedTimeout", "inject",
    "FAULT_SITES", "FAULT_MODES",
    "BackendHealth", "CircuitBreaker", "HealthEvent", "get_health",
    "reset_health",
]
