"""Unified vectorized communication-phase engine.

One abstraction — :class:`CommPhase` — binds a point-to-point message set
(src, dst, size) to a machine once, caching per-message locality, protocol
class, torus endpoints and active-senders-per-node.  Both sides of the
paper's inferential gap consume it: the closed-form model ladder
(:func:`repro.core.models.phase_cost_many`) and the mechanistic event
simulator (:func:`repro.net.simulator.simulate`).  The shared hot-path math
lives in :mod:`repro.comm.primitives` (numpy-only, below both consumers).
"""
from .phase import CommPhase
from .primitives import (active_senders_per_node, transport_times,
                         per_proc_sums, group_by_receiver,
                         queue_traversal_steps, batched_queue_traversal_steps)

__all__ = [
    "CommPhase",
    "active_senders_per_node", "transport_times", "per_proc_sums",
    "group_by_receiver", "queue_traversal_steps",
    "batched_queue_traversal_steps",
]
