"""CommPhase: one point-to-point communication phase bound to a machine.

The paper evaluates every phase (a set of messages that are all posted, then
all completed — an SpMV halo exchange, one direction of a HighVolumePingPong)
twice: with the closed-form model ladder and with the mechanistic simulator.
Both need the same derived quantities — per-message locality class, protocol
class, sender node / torus-unit ids, and the number of actively-sending
processes per node.  ``CommPhase`` computes all of them once, vectorized, at
construction; :func:`repro.core.models.phase_cost_many` and
:func:`repro.net.simulator.simulate` are thin layers over these cached arrays.

The machine argument is duck-typed (anything with ``params``, ``torus``,
``locality``, ``node_of``, ``torus_node_of`` — i.e.
:class:`repro.net.MachineSpec`), which keeps this module numpy-only and below
both consumers in the import layering.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np

from .primitives import group_by_receiver, grouped_queue_steps
from .primitives import active_senders_per_node


@dataclasses.dataclass(frozen=True, eq=False)
class CommPhase:
    """A message set (src, dst, size) with machine-derived arrays cached."""

    machine: Any                 # MachineSpec (duck-typed)
    src: np.ndarray              # [n_msgs] sending process
    dst: np.ndarray              # [n_msgs] receiving process
    size: np.ndarray             # [n_msgs] bytes
    n_procs: int
    loc: np.ndarray              # [n_msgs] locality class
    proto: np.ndarray            # [n_msgs] protocol class
    is_net: np.ndarray           # [n_msgs] traverses the network
    send_node: np.ndarray        # [n_msgs] sender's node
    torus_src: np.ndarray        # [n_msgs] sender's torus unit
    torus_dst: np.ndarray        # [n_msgs] receiver's torus unit
    active_ppn: np.ndarray       # [n_msgs] active senders on sender's node
    loc_overridden: bool = False  # built with an explicit class override

    @classmethod
    def build(cls, machine, src, dst, size, n_procs: int | None = None,
              loc=None, validate: bool = False) -> "CommPhase":
        """Bind a message set ``(src, dst, size)`` to ``machine``.

        Computes every derived per-message array (locality, protocol,
        ``is_net``, sender node, torus endpoints, active-senders-per-node)
        once, vectorized.  ``n_procs`` fixes the process count (default: the
        largest endpoint + 1).  ``loc`` overrides the machine's locality
        classification with an explicit class index (scalar or per-message
        array) — how the GPU-aware strategy rewrites mark staged phases
        (``h2d`` copies, ``host_staged`` inter-node traffic) whose class is
        a *routing decision*, not a pair geometry; everything downstream
        (protocol, ``is_net``, injection accounting, pricing) follows the
        override.

        ``validate=True`` runs the typed input-validation layer
        (:func:`repro.comm.guard.validate_messages`) first: NaN / negative
        sizes, out-of-range or non-integral ranks, and int32-overflow
        arenas raise a precise :class:`repro.comm.guard.PatternError`
        subclass before any derived array is computed.
        """
        if validate:
            from .guard import validate_messages
            # validate the raveled raw inputs: the int64/float64 casts below
            # would silently truncate NaN ranks and mask length mismatches
            validate_messages(np.asarray(src).ravel(),
                              np.asarray(dst).ravel(),
                              np.asarray(size).ravel(), n_procs=n_procs,
                              where="CommPhase.build")
        src = np.asarray(src, dtype=np.int64).ravel()
        dst = np.asarray(dst, dtype=np.int64).ravel()
        size = np.asarray(size, dtype=np.float64).ravel()
        params = machine.params
        overridden = loc is not None
        if loc is None:
            loc = np.asarray(machine.locality(src, dst), dtype=np.int64)
        else:
            loc = np.broadcast_to(np.asarray(loc, dtype=np.int64),
                                  src.shape).copy()
            if loc.size and not (0 <= loc.min()
                                 and loc.max() < params.n_locality):
                raise ValueError(
                    f"loc override out of range for a table with "
                    f"{params.n_locality} locality classes")
        proto = params.protocol_of(size)
        is_net = loc >= params.network_locality
        send_node = np.asarray(machine.node_of(src), dtype=np.int64)
        if n_procs is None:
            n_procs = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
        return cls(
            machine=machine, src=src, dst=dst, size=size, n_procs=int(n_procs),
            loc=loc, proto=proto, is_net=is_net, send_node=send_node,
            torus_src=np.asarray(machine.torus_node_of(src), dtype=np.int64),
            torus_dst=np.asarray(machine.torus_node_of(dst), dtype=np.int64),
            active_ppn=active_senders_per_node(src, send_node, is_net),
            loc_overridden=overridden,
        )

    # -- basic stats --------------------------------------------------------
    @property
    def n_msgs(self) -> int:
        return int(self.src.size)

    @property
    def total_bytes(self) -> float:
        return float(self.size.sum())

    @property
    def net_bytes(self) -> float:
        return float(self.size[self.is_net].sum())

    def recv_counts(self) -> np.ndarray:
        """Messages received per process (``[n_procs]`` counts)."""
        return np.bincount(self.dst, minlength=self.n_procs)

    def max_msgs_per_proc(self) -> int:
        """Worst per-process receive count (the queue model's ``n``)."""
        if self.n_msgs == 0:
            return 0
        return int(self.recv_counts().max())

    def class_bytes(self) -> np.ndarray:
        """Payload bytes per locality class (``[n_locality]``).

        The class axis of the phase: how much traffic rides each rate-table
        row (intra-device vs staged vs device-direct on a hetero machine).
        ``PhaseStack.class_bytes`` is the stacked equivalent.
        """
        return np.bincount(self.loc, weights=self.size,
                           minlength=self.machine.params.n_locality)

    # -- receive-queue accounting -------------------------------------------
    @functools.cached_property
    def _receiver_groups(self) -> tuple[np.ndarray, np.ndarray]:
        # cached_property writes straight to __dict__, bypassing the frozen
        # dataclass __setattr__ — the grouping is derived state like the rest
        return group_by_receiver(self.dst, self.n_procs)

    def receiver_groups(self) -> tuple[np.ndarray, np.ndarray]:
        """(order, bounds): message indices grouped by receiving process."""
        return self._receiver_groups

    def queue_steps(self, recv_post_order=None, arrival_order=None) -> np.ndarray:
        """Exact per-process receive-queue traversal-step totals.

        ``recv_post_order[p]`` / ``arrival_order[p]``: permutations of the
        message indices destined to ``p``, giving the order receives are
        posted and envelopes arrive.  Default is array order for both (best
        case: every arrival matches the queue head, n steps total); receivers
        with a custom order pay the exact Fenwick walk, batched across all of
        them in one sweep (:func:`repro.comm.primitives.grouped_queue_steps`,
        which the stacked sweep path shares with ``(phase, receiver)`` slots).
        """
        if self.n_msgs == 0:
            return np.zeros(self.n_procs, dtype=np.int64)
        return grouped_queue_steps(self.dst, self.n_procs,
                                   recv_post_order=recv_post_order,
                                   arrival_order=arrival_order,
                                   groups=self.receiver_groups())

    def random_arrival_flat(self, rng: np.random.Generator
                            ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Random envelope-arrival permutations in the flat ``(slots, lens,
        ids)`` form of :func:`repro.comm.primitives.flat_orders` (the paper's
        Sec.-5 irregular regime: matches land at ~n^2/3 queue positions).

        One shuffle for the whole phase: iid uniform keys per message, one
        lexsort by (receiver, key) — a uniform random permutation within
        every receiver segment, with no per-receiver generator calls or
        array slicing.  :meth:`random_arrival_order` packages the same
        permutations (same rng stream) as a per-receiver dict.
        """
        z = np.zeros(0, dtype=np.int64)
        if self.n_msgs == 0:
            return z, z.copy(), z.copy()
        keys = rng.random(self.n_msgs)
        perm = np.lexsort((keys, self.dst))       # grouped by receiver,
        dst_sorted = self.dst[perm]               # random within each group
        starts = np.nonzero(np.r_[True, dst_sorted[1:] != dst_sorted[:-1]])[0]
        lens = np.diff(np.r_[starts, dst_sorted.size])
        return dst_sorted[starts], lens, perm

    def random_arrival_order(self, rng: np.random.Generator) -> dict[int, np.ndarray]:
        """Dict view of :meth:`random_arrival_flat` (receiver -> permutation),
        drawn from the same ``rng`` stream."""
        slots, lens, perm = self.random_arrival_flat(rng)
        return {int(s): ids
                for s, ids in zip(slots, np.split(perm, np.cumsum(lens)[:-1]))}

    # -- link contention ----------------------------------------------------
    def link_contention(self) -> tuple[float, float]:
        """(hottest contended-link bytes, total network bytes).

        Routes every inter-torus-unit network message dimension-ordered over
        the machine torus in one vectorized expansion.  A single unit's flows
        over one link are already bounded by its injection cap R_N, so only
        bytes *beyond the largest single-source contribution* on a link count
        as contention (multiple units funneling into it, as in the paper's
        Fig. 6 G1-G2 link).
        """
        net_bytes = self.net_bytes
        sel = self.is_net & (self.torus_src != self.torus_dst)
        if not sel.any():
            return 0.0, net_bytes
        torus = self.machine.torus
        tsrc = self.torus_src[sel]
        midx, link = torus.route_link_ids(tsrc, self.torus_dst[sel])
        if link.size == 0:
            return 0.0, net_bytes
        w = self.size[sel][midx]
        # span must cover every source id: on torus_over_procs machines a
        # process id can exceed the torus size, and a too-small span would
        # bleed source bits into the link field
        span = np.int64(max(torus.size, int(tsrc.max()) + 1))
        key = link * span + tsrc[midx]
        uk, inv = np.unique(key, return_inverse=True)
        per_src = np.bincount(inv, weights=w)     # bytes per (link, source)
        pair_link = uk // span
        starts = np.nonzero(np.r_[True, pair_link[1:] != pair_link[:-1]])[0]
        totals = np.add.reduceat(per_src, starts)
        largest = np.maximum.reduceat(per_src, starts)
        return float((totals - largest).max(initial=0.0)), net_bytes
