"""Node-aware communication strategies: pure phase -> phase-sequence rewrites.

The paper's node-aware model explains *why* aggregating inter-node traffic
helps; its successors (Lockhart et al., Collom et al.) turn the insight into
concrete multi-step strategies.  This module makes those strategies
first-class: a strategy is a **rewrite** that transforms one bound
:class:`~repro.comm.CommPhase` into a *sequence* of CommPhases carrying the
same payload along a different route.  Because each step is itself an
ordinary CommPhase, the existing cost code prices every strategy unchanged —
the model ladder via :func:`repro.core.models.sequence_cost` and the event
simulator via :func:`repro.net.simulator.simulate_sequence` simply sum the
steps.

Strategies (``STRATEGIES``):

``standard``
    Identity: every message travels directly, one phase.
``two_step``
    Node-aware aggregation.  Each node designates a leader (its lowest
    process).  Sequence: **gather** (every process ships its off-node payload
    to its node leader, intra-node), **inter** (one aggregated message per
    (send-node, recv-node) pair, leader to leader), **scatter** (the
    receiving leader forwards each final destination its payload,
    intra-node).  Original intra-node messages ride in a ``local`` phase.
``three_step``
    As ``two_step``, but the aggregated inter-node traffic of every node
    pair is dedup-split into ``k`` equal shares injected by ``k`` distinct
    processes on the sender node (``k`` = processes available on both ends),
    spreading the node's injection load so the max-rate cap ``R_N`` — rather
    than a single process's ``R_b`` — bounds throughput.  The gather/scatter
    phases fan shares across the same ``k`` ranks.

All rewrites are built from the engine's ``np.unique``/``bincount`` idiom
(:func:`repro.comm.primitives.sum_by_pairs`,
:func:`repro.comm.primitives.segmented_arange`) — no per-message Python
loops.  "Off-node" means the sender's and receiver's *nodes* differ, which
coincides with the machine's network locality classes on both shipped
machines (Blue Waters and TPU v5e).

Layering: the rewrites are numpy-only and sit below both consumers, like the
rest of :mod:`repro.comm`.  :func:`best_strategy` is the one function that
reaches *up* to the model ladder and the simulator; it imports them lazily
inside the call so the package layering stays acyclic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .phase import CommPhase
from .primitives import segmented_arange, sum_by_pairs
from .stack import as_stack

STRATEGIES = ("standard", "two_step", "three_step")

#: Phase roles, in execution order, as they appear in ``StrategyPlan.roles``.
ROLES = ("standard", "local", "gather", "inter", "scatter")


@dataclasses.dataclass(frozen=True)
class StrategyPlan:
    """A strategy applied to one phase: the rewritten phase sequence.

    ``phases[i]`` plays role ``roles[i]`` (see ``ROLES``).  A ``standard``
    role marks an unrewritten phase (the identity strategy, or a rewrite of
    a phase with no inter-node traffic, where every strategy degenerates to
    the identity).
    """

    strategy: str
    original: CommPhase
    phases: tuple[CommPhase, ...]
    roles: tuple[str, ...]

    @property
    def n_phases(self) -> int:
        return len(self.phases)

    @property
    def total_msgs(self) -> int:
        return sum(ph.n_msgs for ph in self.phases)

    @property
    def inter_node_msgs(self) -> int:
        """Messages that cross a node boundary, summed over the sequence."""
        return sum(int(_remote_mask(ph).sum()) for ph in self.phases)

    def phase_by_role(self, role: str) -> CommPhase | None:
        for ph, r in zip(self.phases, self.roles):
            if r == role:
                return ph
        return None

    def inter_node_pair_bytes(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(send_node, recv_node, bytes) actually crossing node boundaries.

        Invariant under every rewrite (payload conservation): aggregation
        changes message *counts* and *sizes*, never which node owes how many
        payload bytes to which node.
        """
        sn, dn, sz = [], [], []
        for ph in self.phases:
            rem = _remote_mask(ph)
            if rem.any():
                sn.append(ph.send_node[rem])
                dn.append(np.asarray(ph.machine.node_of(ph.dst[rem]),
                                     dtype=np.int64))
                sz.append(ph.size[rem])
        if not sn:
            z = np.zeros(0, dtype=np.int64)
            return z, z, np.zeros(0)
        return sum_by_pairs(np.concatenate(sn), np.concatenate(dn),
                            np.concatenate(sz))


def _remote_mask(phase: CommPhase) -> np.ndarray:
    """Messages whose sender and receiver live on different nodes."""
    dst_node = np.asarray(phase.machine.node_of(phase.dst), dtype=np.int64)
    return phase.send_node != dst_node


def _avail(machine, nodes: np.ndarray, n_procs: int) -> np.ndarray:
    """Processes of each node that exist within the phase's process range.

    A phase may span fewer processes than the machine hosts (a coarse AMG
    level on a big partition); shares are only fanned across ranks that are
    actually in ``[0, n_procs)``.  Every node that appears in the phase hosts
    at least its leader, so the result is always >= 1.
    """
    ppn = machine.procs_per_node
    return np.minimum(np.int64(ppn), n_procs - nodes * np.int64(ppn))


def _build(machine, parts, n_procs: int) -> tuple[tuple[CommPhase, ...],
                                                  tuple[str, ...]]:
    phases, roles = [], []
    for role, src, dst, size in parts:
        if len(src):
            phases.append(CommPhase.build(machine, src, dst, size,
                                          n_procs=n_procs))
            roles.append(role)
    return tuple(phases), tuple(roles)


def standard(phase: CommPhase) -> StrategyPlan:
    """Identity strategy: the phase as given, in a one-phase sequence."""
    return StrategyPlan("standard", phase, (phase,), ("standard",))


def two_step(phase: CommPhase) -> StrategyPlan:
    """Gather -> one inter-node message per node pair -> scatter."""
    return _aggregated(phase, "two_step", split=False)


def three_step(phase: CommPhase) -> StrategyPlan:
    """Two-step with each node pair's traffic split across k injectors."""
    return _aggregated(phase, "three_step", split=True)


def _aggregated(phase: CommPhase, name: str, split: bool) -> StrategyPlan:
    m, P = phase.machine, phase.n_procs
    ppn = np.int64(m.procs_per_node)
    remote = _remote_mask(phase)
    if not remote.any():            # nothing to aggregate: identity
        return StrategyPlan(name, phase, (phase,), ("standard",))

    parts = [("local", phase.src[~remote], phase.dst[~remote],
              phase.size[~remote])]
    rs, rd, rsz = phase.src[remote], phase.dst[remote], phase.size[remote]
    rsn = phase.send_node[remote]
    rdn = np.asarray(m.node_of(rd), dtype=np.int64)

    # shares per message: 1 (leader only) or k = procs available on both ends
    if split:
        k = np.minimum(_avail(m, rsn, P), _avail(m, rdn, P))
    else:
        k = np.ones(rs.size, dtype=np.int64)
    rep = np.repeat(np.arange(rs.size), k)      # message id of each share
    rank = segmented_arange(k)                  # injector rank of each share
    share = rsz[rep] / k[rep]

    # gather: origin -> the k injector ranks on its own node (equal shares;
    # the share an injector originates itself needs no message)
    g_src, g_dst = rs[rep], rsn[rep] * ppn + rank
    keep = g_src != g_dst
    parts.append(("gather", *sum_by_pairs(g_src[keep], g_dst[keep],
                                          share[keep])))

    # inter: aggregate payload per (send node, recv node), then one message
    # per injector rank r: (S, r) -> (D, r)
    Sn, Dn, B = sum_by_pairs(rsn, rdn, rsz)
    if split:
        kp = np.minimum(_avail(m, Sn, P), _avail(m, Dn, P))
    else:
        kp = np.ones(Sn.size, dtype=np.int64)
    prep = np.repeat(np.arange(Sn.size), kp)
    prank = segmented_arange(kp)
    parts.append(("inter", Sn[prep] * ppn + prank, Dn[prep] * ppn + prank,
                  B[prep] / kp[prep]))

    # scatter: the k receiving ranks on the destination node forward each
    # final destination its shares (a rank's own share needs no message)
    s_src, s_dst = rdn[rep] * ppn + rank, rd[rep]
    keep = s_src != s_dst
    parts.append(("scatter", *sum_by_pairs(s_src[keep], s_dst[keep],
                                           share[keep])))

    phases, roles = _build(m, parts, P)
    return StrategyPlan(name, phase, phases, roles)


_REWRITES = {"standard": standard, "two_step": two_step,
             "three_step": three_step}


def rewrite(phase: CommPhase, strategy: str) -> StrategyPlan:
    """Apply one named strategy rewrite to a bound phase."""
    try:
        fn = _REWRITES[strategy]
    except KeyError:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"expected one of {STRATEGIES}") from None
    return fn(phase)


# -- payload-conservation accessors -----------------------------------------
#
# Both are flow identities over the rewritten message arrays alone (no use of
# the original payload), so tests can compare them against the original phase
# to certify a rewrite delivers exactly what was sent.

def injected_payload(plan: StrategyPlan) -> np.ndarray:
    """Per-process payload bytes *originated*, reconstructed from the plan.

    An injector's inter-phase sends equal its gather-phase receipts plus the
    shares it originated itself, so ``local + gather + inter - gather_recv``
    telescopes back to the original per-source payload.
    """
    P = plan.original.n_procs
    out = np.zeros(P)
    for ph, role in zip(plan.phases, plan.roles):
        if role in ("standard", "local", "gather", "inter"):
            out += np.bincount(ph.src, weights=ph.size, minlength=P)
        if role == "gather":
            out -= np.bincount(ph.dst, weights=ph.size, minlength=P)
    return out


def delivered_payload(plan: StrategyPlan) -> np.ndarray:
    """Per-process payload bytes *finally delivered* (mirror identity:
    ``local + scatter + inter - scatter_sent``)."""
    P = plan.original.n_procs
    out = np.zeros(P)
    for ph, role in zip(plan.phases, plan.roles):
        if role in ("standard", "local", "scatter", "inter"):
            out += np.bincount(ph.dst, weights=ph.size, minlength=P)
        if role == "scatter":
            out -= np.bincount(ph.src, weights=ph.size, minlength=P)
    return out


# -- the strategy sweep ------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StrategyVerdict:
    """Every strategy priced by the model ladder and judged by the simulator.

    ``model[s]`` is the model-ladder total (at the requested level) summed
    over strategy ``s``'s phase sequence; ``sim[s]`` is the simulator's.  The
    *predicted* winner comes from the model alone — the simulator's verdict
    is the ground truth the prediction is scored against, across the same
    inferential gap the paper has between model and machine.
    """

    plans: dict[str, StrategyPlan]
    model: dict[str, float]
    sim: dict[str, float]
    model_winner: str
    sim_winner: str

    @property
    def agree(self) -> bool:
        return self.model_winner == self.sim_winner


def best_strategy(pattern, machine=None, *, strategies=STRATEGIES,
                  level: str = "contention", arrival: str = "random",
                  seed: int = 0, params=None) -> StrategyVerdict:
    """Sweep strategies over one phase; return the model's pick and the
    simulator's verdict.

    ``pattern`` is a :class:`repro.sparse.CommPattern` (bound to ``machine``)
    or an already-bound :class:`CommPhase`.  ``arrival='random'`` drives the
    simulator with the paper's Sec.-5 irregular regime (random envelope
    arrival, seeded); ``'posted'`` uses best-case in-order arrival.  The
    model prices phases at ladder ``level``; ``params`` substitutes a fitted
    parameter table for the machine's ground truth on the model side only.

    The whole candidate set — every strategy's phase sequence — is priced in
    one stacked model pass and one stacked simulator pass: this is the
    one-pattern case of :func:`best_strategy_many`.
    """
    return best_strategy_many([pattern], machine, strategies=strategies,
                              level=level, arrival=arrival, seed=seed,
                              params=params)[0]


def best_strategy_many(patterns, machine=None, *, strategies=STRATEGIES,
                       level: str = "contention", arrival: str = "random",
                       seed: int = 0, params=None) -> list[StrategyVerdict]:
    """:func:`best_strategy` for a whole sweep of patterns in ONE arena.

    Every (pattern, strategy) candidate's phase sequence is rewritten and
    concatenated into a single :class:`~repro.comm.PhaseStack`, then the
    model ladder and the simulator each price the entire candidate set in
    one segmented pass — the strategy-sweep analogue of
    :func:`repro.core.models.phase_cost_many`.  Results are element-wise
    identical to ``[best_strategy(p, ...) for p in patterns]`` (each
    candidate keeps its own seeded arrival stream); only the number of
    arena walks changes.
    """
    if arrival not in ("random", "posted"):
        raise ValueError(f"unknown arrival regime {arrival!r}; "
                         "expected 'random' or 'posted'")
    from repro.core.models import phase_cost_many
    from repro.net.simulator import simulate_many

    phases = []
    for pat in patterns:
        if hasattr(pat, "bind"):
            if machine is None:
                raise ValueError("a CommPattern needs a machine to bind to")
            phases.append(pat.bind(machine))
        elif machine is not None and machine is not pat.machine:
            phases.append(CommPhase.build(machine, pat.src, pat.dst,
                                          pat.size, n_procs=pat.n_procs))
        else:
            phases.append(pat)

    plan_rows, spans, all_phases, all_arrivals = [], [], [], []
    for phase in phases:
        plans, row_spans = {}, {}
        for name in strategies:
            plan = rewrite(phase, name)
            rng = np.random.default_rng(seed)
            plans[name] = plan
            row_spans[name] = slice(len(all_phases),
                                    len(all_phases) + plan.n_phases)
            all_phases.extend(plan.phases)
            all_arrivals.extend([ph.random_arrival_flat(rng)
                                 for ph in plan.phases]
                                if arrival == "random"
                                else [None] * plan.n_phases)
        plan_rows.append(plans)
        spans.append(row_spans)
    # one shared arena for both passes; mixed-machine candidate sets (bound
    # phases from different machines) fall back to the per-phase loop, same
    # policy as every batched entry point
    stack = as_stack(all_phases)
    if stack is None:
        stack = all_phases
    costs = phase_cost_many(stack, level=level, params=params)
    sims = simulate_many(stack, arrival_orders=all_arrivals)
    out = []
    for plans, row_spans in zip(plan_rows, spans):
        model = {name: sum(c.total for c in costs[row_spans[name]])
                 for name in plans}
        sim = {name: sum(r.time for r in sims[row_spans[name]])
               for name in plans}
        out.append(StrategyVerdict(
            plans=plans, model=model, sim=sim,
            model_winner=min(model, key=model.get),
            sim_winner=min(sim, key=sim.get)))
    return out
