"""Node-aware communication strategies: pure phase -> phase-sequence rewrites.

The paper's node-aware model explains *why* aggregating inter-node traffic
helps; its successors (Lockhart et al., Collom et al.) turn the insight into
concrete multi-step strategies.  This module makes those strategies
first-class: a strategy is a **rewrite** that transforms one bound
:class:`~repro.comm.CommPhase` into a *sequence* of CommPhases carrying the
same payload along a different route.  Because each step is itself an
ordinary CommPhase, the existing cost code prices every strategy unchanged —
the model ladder via :func:`repro.core.models.sequence_cost` and the event
simulator via :func:`repro.net.simulator.simulate_sequence` simply sum the
steps.

Strategies (``STRATEGIES``):

``standard``
    Identity: every message travels directly, one phase.
``two_step``
    Node-aware aggregation.  Each node designates a leader (its lowest
    process).  Sequence: **gather** (every process ships its off-node payload
    to its node leader, intra-node), **inter** (one aggregated message per
    (send-node, recv-node) pair, leader to leader), **scatter** (the
    receiving leader forwards each final destination its payload,
    intra-node).  Original intra-node messages ride in a ``local`` phase.
``three_step``
    As ``two_step``, but the aggregated inter-node traffic of every node
    pair is dedup-split into ``k`` equal shares injected by ``k`` distinct
    processes on the sender node (``k`` = processes available on both ends),
    spreading the node's injection load so the max-rate cap ``R_N`` — rather
    than a single process's ``R_b`` — bounds throughput.  The gather/scatter
    phases fan shares across the same ``k`` ranks.

GPU-aware strategies (``GPU_STRATEGIES``, heterogeneous machines only —
Lockhart et al. 2022's comparison):

``host_staged``
    Copy-to-host aggregation: each off-node payload is staged to host memory
    (a ``d2h`` copy phase, one coalesced self-copy per sending process at the
    ``h2d`` rate class), node-aggregated and k-way split like ``three_step``,
    sent over the *host* NIC path (the inter phase carries an explicit
    ``host_staged`` class override), scattered, and copied back device-side
    (the ``h2d`` phase).  Pays two copy phases, rides the full multi-rail
    host NIC bandwidth.
``device_direct``
    Per-device 3-step: each device's traffic is gathered to its device
    leader (intra-device), aggregated per (send-device, recv-device) pair,
    and injected GPU-NIC direct (``device_direct`` class) — every node's
    devices become its injectors.  No copies, but the device-direct network
    rates bound throughput.

On-node share movement inside both GPU strategies is machine-classified
(intra-device / cross-device), a deliberate simplification — the copy phases
carry the staging cost.  ``strategies_for(machine)`` returns the sweep set a
machine supports (the GPU pair requires device endpoints and the staged rate
classes); ``best_strategy``/``best_strategy_many`` default to it.

All rewrites are built from the engine's ``np.unique``/``bincount`` idiom
(:func:`repro.comm.primitives.sum_by_pairs`,
:func:`repro.comm.primitives.segmented_arange`) — no per-message Python
loops.  "Off-node" means the sender's and receiver's *nodes* differ, which
coincides with the machine's network locality classes on both shipped
machines (Blue Waters and TPU v5e).

Layering: the rewrites are numpy-only and sit below both consumers, like the
rest of :mod:`repro.comm`.  :func:`best_strategy` is the one function that
reaches *up* to the model ladder and the simulator; it imports them lazily
inside the call so the package layering stays acyclic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .phase import CommPhase
from .primitives import segmented_arange, sum_by_pairs
from .stack import as_stack

STRATEGIES = ("standard", "two_step", "three_step")

#: Heterogeneous-machine strategies (Lockhart's host-staged vs GPU-direct).
GPU_STRATEGIES = ("host_staged", "device_direct")

#: Phase roles, in execution order, as they appear in ``StrategyPlan.roles``.
#: ``d2h`` / ``h2d`` are the staging copy phases (coalesced per-process
#: self-copies at the ``h2d`` rate class) of the ``host_staged`` strategy.
ROLES = ("standard", "local", "d2h", "gather", "inter", "scatter", "h2d")

#: Row dtype of :meth:`StrategyPlan.schedule`: one row per rewritten message.
SCHEDULE_DTYPE = np.dtype([("phase", np.int32), ("role", np.int32),
                           ("src", np.int64), ("dst", np.int64),
                           ("size", np.float64)])


def strategies_for(machine) -> tuple[str, ...]:
    """The strategy names worth sweeping on ``machine``: the three node-aware
    CPU strategies everywhere, plus ``GPU_STRATEGIES`` when the machine has
    device endpoints and its rate table carries the staged classes."""
    p = machine.params
    if getattr(machine, "devices_per_node", 0) and all(
            p.has_class(c) for c in ("h2d", "host_staged", "device_direct")):
        return STRATEGIES + GPU_STRATEGIES
    return STRATEGIES


def _require_hetero(machine, name: str) -> None:
    """GPU-aware rewrites need device endpoints and the staged rate classes."""
    if name not in strategies_for(machine):
        raise ValueError(
            f"the {name!r} strategy needs a heterogeneous machine (device "
            f"endpoints plus h2d/host_staged/device_direct rate classes); "
            f"{getattr(machine, 'name', machine)!r} has "
            f"{machine.params.locality_names}")


@dataclasses.dataclass(frozen=True)
class StrategyPlan:
    """A strategy applied to one phase: the rewritten phase sequence.

    ``phases[i]`` plays role ``roles[i]`` (see ``ROLES``).  A ``standard``
    role marks an unrewritten phase (the identity strategy, or a rewrite of
    a phase with no inter-node traffic, where every strategy degenerates to
    the identity).
    """

    strategy: str
    original: CommPhase
    phases: tuple[CommPhase, ...]
    roles: tuple[str, ...]

    @property
    def n_phases(self) -> int:
        return len(self.phases)

    @property
    def total_msgs(self) -> int:
        return sum(ph.n_msgs for ph in self.phases)

    @property
    def inter_node_msgs(self) -> int:
        """Messages that cross a node boundary, summed over the sequence."""
        return sum(int(_remote_mask(ph).sum()) for ph in self.phases)

    def phase_by_role(self, role: str) -> CommPhase | None:
        """The first phase playing ``role`` (see ``ROLES``), or None."""
        for ph, r in zip(self.phases, self.roles):
            if r == role:
                return ph
        return None

    def schedule(self) -> np.ndarray:
        """The plan's executable message schedule, one structured row per
        rewritten message (dtype ``SCHEDULE_DTYPE``): ``phase`` indexes into
        ``phases``, ``role`` into ``ROLES``, and ``src`` / ``dst`` / ``size``
        are the message endpoints and payload bytes.  This is the contract
        the execution layer (:mod:`repro.exec`) lowers from — a lowered
        schedule's per-role (src, dst) pair set must be a subset of these
        rows (see ``repro.exec.plan.pairs_subset_of_plan``)."""
        out = np.empty(self.total_msgs, dtype=SCHEDULE_DTYPE)
        at = 0
        for i, (ph, role) in enumerate(zip(self.phases, self.roles)):
            rows = out[at:at + ph.n_msgs]
            rows["phase"] = i
            rows["role"] = ROLES.index(role)
            rows["src"] = ph.src
            rows["dst"] = ph.dst
            rows["size"] = ph.size
            at += ph.n_msgs
        return out

    def inter_node_pair_bytes(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(send_node, recv_node, bytes) actually crossing node boundaries.

        Invariant under every rewrite (payload conservation): aggregation
        changes message *counts* and *sizes*, never which node owes how many
        payload bytes to which node.
        """
        sn, dn, sz = [], [], []
        for ph in self.phases:
            rem = _remote_mask(ph)
            if rem.any():
                sn.append(ph.send_node[rem])
                dn.append(np.asarray(ph.machine.node_of(ph.dst[rem]),
                                     dtype=np.int64))
                sz.append(ph.size[rem])
        if not sn:
            z = np.zeros(0, dtype=np.int64)
            return z, z, np.zeros(0)
        return sum_by_pairs(np.concatenate(sn), np.concatenate(dn),
                            np.concatenate(sz))


def _remote_mask(phase: CommPhase) -> np.ndarray:
    """Messages whose sender and receiver live on different nodes."""
    dst_node = np.asarray(phase.machine.node_of(phase.dst), dtype=np.int64)
    return phase.send_node != dst_node


def _avail(machine, nodes: np.ndarray, n_procs: int) -> np.ndarray:
    """Processes of each node that exist within the phase's process range.

    A phase may span fewer processes than the machine hosts (a coarse AMG
    level on a big partition); shares are only fanned across ranks that are
    actually in ``[0, n_procs)``.  Every node that appears in the phase hosts
    at least its leader, so the result is always >= 1.
    """
    ppn = machine.procs_per_node
    return np.minimum(np.int64(ppn), n_procs - nodes * np.int64(ppn))


def _build(machine, parts, n_procs: int) -> tuple[tuple[CommPhase, ...],
                                                  tuple[str, ...]]:
    phases, roles = [], []
    for part in parts:
        role, src, dst, size = part[:4]
        loc = part[4] if len(part) > 4 else None    # explicit class override
        if len(src):
            phases.append(CommPhase.build(machine, src, dst, size,
                                          n_procs=n_procs, loc=loc))
            roles.append(role)
    return tuple(phases), tuple(roles)


def standard(phase: CommPhase) -> StrategyPlan:
    """Identity strategy: the phase as given, in a one-phase sequence."""
    return StrategyPlan("standard", phase, (phase,), ("standard",))


def two_step(phase: CommPhase) -> StrategyPlan:
    """Node-aware aggregation of one bound phase: gather -> one inter-node
    message per node pair -> scatter."""
    return _aggregated(phase, "two_step", split=False)


def three_step(phase: CommPhase) -> StrategyPlan:
    """Two-step of one bound phase with each node pair's traffic split
    across k injectors."""
    return _aggregated(phase, "three_step", split=True)


def host_staged(phase: CommPhase) -> StrategyPlan:
    """Copy-to-host aggregation of one bound phase (hetero machines only):
    d2h copies -> node-level k-way-split aggregation over the *host* NIC
    path -> h2d copies on the receiving side."""
    _require_hetero(phase.machine, "host_staged")
    return _aggregated(phase, "host_staged", split=True, staged=True)


def _aggregated(phase: CommPhase, name: str, split: bool,
                staged: bool = False) -> StrategyPlan:
    m, P = phase.machine, phase.n_procs
    ppn = np.int64(m.procs_per_node)
    remote = _remote_mask(phase)
    if not remote.any():            # nothing to aggregate: identity
        return StrategyPlan(name, phase, (phase,), ("standard",))

    parts = [("local", phase.src[~remote], phase.dst[~remote],
              phase.size[~remote])]
    rs, rd, rsz = phase.src[remote], phase.dst[remote], phase.size[remote]
    rsn = phase.send_node[remote]
    rdn = np.asarray(m.node_of(rd), dtype=np.int64)

    inter_loc = None
    if staged:
        # the staging decision, as explicit class overrides: each process
        # coalesces its off-node payload into one host<->device copy, and
        # the aggregated traffic rides the host NIC path
        h2d = m.params.class_index("h2d")
        inter_loc = m.params.class_index("host_staged")
        parts.append(("d2h", *sum_by_pairs(rs, rs, rsz), h2d))

    # shares per message: 1 (leader only) or k = procs available on both ends
    if split:
        k = np.minimum(_avail(m, rsn, P), _avail(m, rdn, P))
    else:
        k = np.ones(rs.size, dtype=np.int64)
    rep = np.repeat(np.arange(rs.size), k)      # message id of each share
    rank = segmented_arange(k)                  # injector rank of each share
    share = rsz[rep] / k[rep]

    # gather: origin -> the k injector ranks on its own node (equal shares;
    # the share an injector originates itself needs no message)
    g_src, g_dst = rs[rep], rsn[rep] * ppn + rank
    keep = g_src != g_dst
    parts.append(("gather", *sum_by_pairs(g_src[keep], g_dst[keep],
                                          share[keep])))

    # inter: aggregate payload per (send node, recv node), then one message
    # per injector rank r: (S, r) -> (D, r)
    Sn, Dn, B = sum_by_pairs(rsn, rdn, rsz)
    if split:
        kp = np.minimum(_avail(m, Sn, P), _avail(m, Dn, P))
    else:
        kp = np.ones(Sn.size, dtype=np.int64)
    prep = np.repeat(np.arange(Sn.size), kp)
    prank = segmented_arange(kp)
    parts.append(("inter", Sn[prep] * ppn + prank, Dn[prep] * ppn + prank,
                  B[prep] / kp[prep], inter_loc))

    # scatter: the k receiving ranks on the destination node forward each
    # final destination its shares (a rank's own share needs no message)
    s_src, s_dst = rdn[rep] * ppn + rank, rd[rep]
    keep = s_src != s_dst
    parts.append(("scatter", *sum_by_pairs(s_src[keep], s_dst[keep],
                                           share[keep])))

    if staged:
        parts.append(("h2d", *sum_by_pairs(rd, rd, rsz), h2d))

    phases, roles = _build(m, parts, P)
    return StrategyPlan(name, phase, phases, roles)


def device_direct(phase: CommPhase) -> StrategyPlan:
    """Per-device 3-step of one bound phase (hetero machines only): gather
    to device leaders -> one GPU-NIC-direct message per (send-device,
    recv-device) pair -> scatter.  Every node's devices are its injectors;
    no host staging, so no copy phases."""
    m, P = phase.machine, phase.n_procs
    _require_hetero(m, "device_direct")
    ppd = np.int64(m.procs_per_device)
    dd = m.params.class_index("device_direct")
    remote = _remote_mask(phase)
    if not remote.any():            # nothing to aggregate: identity
        return StrategyPlan("device_direct", phase, (phase,), ("standard",))

    parts = [("local", phase.src[~remote], phase.dst[~remote],
              phase.size[~remote])]
    rs, rd, rsz = phase.src[remote], phase.dst[remote], phase.size[remote]
    rsd = rs // ppd                 # global device of origin / destination
    rdd = rd // ppd

    # gather: origin -> its device leader (the device's lowest rank; the
    # leader's own payload needs no message).  Intra-device traffic.
    g_src, g_dst = rs, rsd * ppd
    keep = g_src != g_dst
    parts.append(("gather", *sum_by_pairs(g_src[keep], g_dst[keep],
                                          rsz[keep])))

    # inter: one aggregated leader-to-leader message per (send-device,
    # recv-device) pair, explicitly on the device-direct network path
    # (remote pairs always cross nodes, so the override is consistent with
    # pair geometry even when the machine's default path is host_staged)
    Sd, Dd, B = sum_by_pairs(rsd, rdd, rsz)
    parts.append(("inter", Sd * ppd, Dd * ppd, B, dd))

    # scatter: the receiving device leader forwards each final destination
    # its payload (a leader's own payload needs no message)
    s_src, s_dst = rdd * ppd, rd
    keep = s_src != s_dst
    parts.append(("scatter", *sum_by_pairs(s_src[keep], s_dst[keep],
                                           rsz[keep])))

    phases, roles = _build(m, parts, P)
    return StrategyPlan("device_direct", phase, phases, roles)


_REWRITES = {"standard": standard, "two_step": two_step,
             "three_step": three_step,
             "host_staged": host_staged, "device_direct": device_direct}


def rewrite(phase: CommPhase, strategy: str) -> StrategyPlan:
    """Apply one named ``strategy`` rewrite to a bound ``phase``."""
    try:
        fn = _REWRITES[strategy]
    except KeyError:
        raise ValueError(f"unknown strategy {strategy!r}; expected one of "
                         f"{STRATEGIES + GPU_STRATEGIES}") from None
    return fn(phase)


# -- payload-conservation accessors -----------------------------------------
#
# Both are flow identities over the rewritten message arrays alone (no use of
# the original payload), so tests can compare them against the original phase
# to certify a rewrite delivers exactly what was sent.

def injected_payload(plan: StrategyPlan) -> np.ndarray:
    """Per-process payload bytes *originated*, reconstructed from the plan.

    An injector's inter-phase sends equal its gather-phase receipts plus the
    shares it originated itself, so ``local + gather + inter - gather_recv``
    telescopes back to the original per-source payload.
    """
    P = plan.original.n_procs
    out = np.zeros(P)
    for ph, role in zip(plan.phases, plan.roles):
        if role in ("standard", "local", "gather", "inter"):
            out += np.bincount(ph.src, weights=ph.size, minlength=P)
        if role == "gather":
            out -= np.bincount(ph.dst, weights=ph.size, minlength=P)
    return out


def delivered_payload(plan: StrategyPlan) -> np.ndarray:
    """Per-process payload bytes *finally delivered* by ``plan`` (mirror
    identity: ``local + scatter + inter - scatter_sent``)."""
    P = plan.original.n_procs
    out = np.zeros(P)
    for ph, role in zip(plan.phases, plan.roles):
        if role in ("standard", "local", "scatter", "inter"):
            out += np.bincount(ph.dst, weights=ph.size, minlength=P)
        if role == "scatter":
            out -= np.bincount(ph.src, weights=ph.size, minlength=P)
    return out


# -- the strategy sweep ------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StrategyVerdict:
    """Every strategy priced by the model ladder and judged by the simulator.

    ``model[s]`` is the model-ladder total (at the requested level) summed
    over strategy ``s``'s phase sequence; ``sim[s]`` is the simulator's.  The
    *predicted* winner comes from the model alone — the simulator's verdict
    is the ground truth the prediction is scored against, across the same
    inferential gap the paper has between model and machine.

    ``degraded`` marks a verdict priced under the degradation policy: some
    backend call failed and fell back to the numpy reference during this
    sweep (the triggering events are in
    :func:`repro.comm.health.get_health`'s ledger).  The numbers are still
    correct — the fallback is the bit-identity reference — but the device
    path did not serve them.
    """

    plans: dict[str, StrategyPlan]
    model: dict[str, float]
    sim: dict[str, float]
    model_winner: str
    sim_winner: str
    degraded: bool = False

    @property
    def agree(self) -> bool:
        return self.model_winner == self.sim_winner


def best_strategy(pattern, machine=None, *, strategies=None,
                  level: str = "contention", arrival: str = "random",
                  seed: int = 0, params=None, backend=None,
                  validate: bool = False) -> StrategyVerdict:
    """Sweep strategies over one phase; return the model's pick and the
    simulator's verdict.

    ``pattern`` is a :class:`repro.sparse.CommPattern` (bound to ``machine``)
    or an already-bound :class:`CommPhase`.  ``strategies`` defaults to
    :func:`strategies_for` the bound machine — the three node-aware
    strategies, plus the GPU-aware pair on heterogeneous machines.
    ``arrival='random'`` drives the simulator with the paper's Sec.-5
    irregular regime (random envelope arrival, from a generator seeded with
    ``seed`` per candidate); ``'posted'`` uses best-case in-order arrival.
    The model prices phases at ladder ``level``; ``params`` substitutes a
    fitted parameter table for the machine's ground truth on the model side
    only.  ``backend`` routes the stacked passes through a device backend;
    ``validate=True`` runs the typed validation layer over the pattern
    first (see :func:`best_strategy_many` for both).

    The whole candidate set — every strategy's phase sequence — is priced in
    one stacked model pass and one stacked simulator pass: this is the
    one-pattern case of :func:`best_strategy_many`.
    """
    return best_strategy_many([pattern], machine, strategies=strategies,
                              level=level, arrival=arrival, seed=seed,
                              params=params, backend=backend,
                              validate=validate)[0]


def _machine_groups(phases) -> list[list[int]]:
    """Partition ``phases`` indices by machine identity, first-seen order.

    Each group's phases share one machine, so each can stack into its own
    arena; the groups together cover every index exactly once.
    """
    groups: dict[int, list[int]] = {}
    for i, ph in enumerate(phases):
        groups.setdefault(id(ph.machine), []).append(i)
    return list(groups.values())


def best_strategy_many(patterns, machine=None, *, strategies=None,
                       level: str = "contention", arrival: str = "random",
                       seed: int = 0, params=None, backend=None,
                       validate: bool = False) -> list[StrategyVerdict]:
    """:func:`best_strategy` for a whole sweep of ``patterns`` in ONE arena
    (same ``machine`` / ``strategies`` / ``level`` / ``arrival`` / ``seed``
    / ``params`` arguments).

    Every (pattern, strategy) candidate's phase sequence is rewritten and
    concatenated into a single :class:`~repro.comm.PhaseStack`, then the
    model ladder and the simulator each price the entire candidate set in
    one segmented pass — the strategy-sweep analogue of
    :func:`repro.core.models.phase_cost_many`.  Already-bound phases from
    *different* machines (a cross-machine scenario sweep, e.g.
    :func:`repro.workloads.sweep`) are also one arena call: the candidate
    set is partitioned by machine and stacked per machine group.  Results
    are element-wise identical to ``[best_strategy(p, ...) for p in
    patterns]`` (each candidate keeps its own seeded arrival stream); only
    the number of arena walks changes.

    Hardening (DESIGN.md §12): ``validate=True`` runs the typed validation
    layer over every pattern before anything is rewritten
    (:func:`repro.comm.guard.validate_messages` — precise
    :class:`~repro.comm.guard.PatternError` subclasses).  ``backend``
    routes the stacked passes through a device backend; every device site
    already degrades to numpy on failure, and should the pricing passes
    still raise on a non-numpy backend, the sweep is retried once on
    ``backend='numpy'``.  Verdicts priced under any fallback carry
    ``degraded=True`` with the events recorded in
    :func:`repro.comm.health.get_health`.
    """
    if arrival not in ("random", "posted"):
        raise ValueError(f"unknown arrival regime {arrival!r}; "
                         "expected 'random' or 'posted'")
    from repro.core.models import phase_cost_many
    from repro.net.simulator import simulate_many
    from .health import get_health

    phases = []
    for pat in patterns:
        if hasattr(pat, "bind"):
            if machine is None:
                raise ValueError("a CommPattern needs a machine to bind to")
            phases.append(pat.bind(machine, validate=validate))
        elif machine is not None and machine is not pat.machine:
            phases.append(CommPhase.build(machine, pat.src, pat.dst,
                                          pat.size, n_procs=pat.n_procs,
                                          validate=validate))
        else:
            if validate:
                from .guard import validate_phase
                validate_phase(pat)
            phases.append(pat)

    plan_rows, spans, all_phases, all_arrivals = [], [], [], []
    for phase in phases:
        plans, row_spans = {}, {}
        names = (strategies if strategies is not None
                 else strategies_for(phase.machine))
        for name in names:
            plan = rewrite(phase, name)
            rng = np.random.default_rng(seed)
            plans[name] = plan
            row_spans[name] = slice(len(all_phases),
                                    len(all_phases) + plan.n_phases)
            all_phases.extend(plan.phases)
            all_arrivals.extend([ph.random_arrival_flat(rng)
                                 for ph in plan.phases]
                                if arrival == "random"
                                else [None] * plan.n_phases)
        plan_rows.append(plans)
        spans.append(row_spans)

    health = get_health()
    events_before = health.n_events

    def _price(be):
        # one shared arena for both passes; a mixed-machine candidate set
        # (bound phases from different machines — a cross-machine scenario
        # sweep) is partitioned by machine and runs one arena per machine
        # group, results scattered back in place (bit-identical to one arena
        # by the PhaseStack contract: segmented passes never mix rows across
        # phases)
        stack = as_stack(all_phases)
        if stack is not None:
            costs = phase_cost_many(stack, level=level, params=params,
                                    backend=be)
            sims = simulate_many(stack, arrival_orders=all_arrivals,
                                 backend=be)
            return costs, sims
        costs = [None] * len(all_phases)
        sims = [None] * len(all_phases)
        for idx in _machine_groups(all_phases):
            sub = [all_phases[i] for i in idx]
            sub_stack = as_stack(sub)
            if sub_stack is None:       # single phase / degenerate group
                sub_stack = sub
            sub_costs = phase_cost_many(sub_stack, level=level,
                                        params=params, backend=be)
            sub_sims = simulate_many(
                sub_stack, arrival_orders=[all_arrivals[i] for i in idx],
                backend=be)
            for i, c, r in zip(idx, sub_costs, sub_sims):
                costs[i] = c
                sims[i] = r
        return costs, sims

    try:
        costs, sims = _price(backend)
    except Exception as e:  # noqa: BLE001 - serve-layer degradation
        if backend == "numpy":
            raise       # the reference path itself failed: a real error
        # backend=None may still resolve to a device backend through the
        # REPRO_STACK_BACKEND env default, so the numpy retry applies to it
        # too; a genuine input error re-raises from the retry unchanged
        health.record_failure(str(backend), "strategies.best_strategy_many",
                              e)
        costs, sims = _price("numpy")

    degraded = health.n_events > events_before
    out = []
    for plans, row_spans in zip(plan_rows, spans):
        model = {name: sum(c.total for c in costs[row_spans[name]])
                 for name in plans}
        sim = {name: sum(r.time for r in sims[row_spans[name]])
               for name in plans}
        out.append(StrategyVerdict(
            plans=plans, model=model, sim=sim,
            model_winner=min(model, key=model.get),
            sim_winner=min(sim, key=sim.get), degraded=degraded))
    return out
