"""Backend health accounting: degradation events, quarantine, warn-once.

The comm stack degrades gracefully: when a device backend (jax / Pallas)
fails — an injected fault, a compile error, an int32-overflow arena, a
verify-mode mismatch — the failing call falls back to the numpy bit-identity
reference and *records the event here* instead of crashing the sweep.  This
module is the per-process ledger of those events:

* :class:`BackendHealth` keeps a **bounded** event ring (a week-long soak
  cannot grow memory without bound: the newest ``max_events`` events are
  retained, older ones are dropped with :attr:`BackendHealth.dropped_events`
  counting the loss; :attr:`BackendHealth.n_events` stays the monotone
  total, so snapshot-and-compare degradation probes keep working across a
  wrap), per-backend consecutive-failure streaks, and a quarantine set: a
  backend that fails ``quarantine_after`` times in a row is quarantined —
  subsequent requests for it resolve straight to numpy without
  re-attempting the device path — until :meth:`BackendHealth.reset` (or a
  recorded success, which clears the streak but not an existing
  quarantine).
* The same object owns the process's **resettable warn-once registry**
  (:meth:`BackendHealth.warn_once`): every "warn once per process" message
  in the stack (backend fallbacks, the deprecated one-hot shim) goes through
  it, so tests can reset warning state instead of poking module globals.
* :class:`CircuitBreaker` is the *service-path* failure policy
  (:class:`repro.serve.StrategyService`), replacing the stack's one-shot
  quarantine counter one level up: repeated failures **open** the breaker
  (requests route straight to numpy), an open breaker **half-opens** after
  ``reset_after`` seconds letting exactly one probe through, and the
  probe's outcome closes or re-opens it.  Per-backend breakers live on the
  ledger (:meth:`BackendHealth.breaker_for`) so :func:`reset_health`
  clears them with everything else.

One process-wide instance is served by :func:`get_health`;
:func:`reset_health` restores it to a clean slate (the autouse pytest
fixture in ``tests/conftest.py`` does this around every test).

Layering: stdlib-only (no numpy, no jax), importable from everywhere —
:mod:`repro.kernels.comm_stack` and :mod:`repro.comm.stack` both report
here.  See DESIGN.md §12 for the failure-handling contract.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import os
import threading
import time
import warnings

__all__ = ["HealthEvent", "BackendHealth", "CircuitBreaker", "get_health",
           "reset_health", "DEFAULT_QUARANTINE_AFTER", "DEFAULT_MAX_EVENTS",
           "BREAKER_STATES"]

#: Consecutive failures of one backend before it is quarantined (override
#: per process with the ``REPRO_STACK_QUARANTINE`` env var; ``0`` disables
#: quarantine entirely — every call re-attempts the device path).
DEFAULT_QUARANTINE_AFTER = 3

#: Retained-event cap of the ledger ring (override per process with the
#: ``REPRO_HEALTH_MAX_EVENTS`` env var).  Older events beyond the cap are
#: dropped and counted, never silently lost.
DEFAULT_MAX_EVENTS = 4096

#: The circuit-breaker state machine: ``closed`` (requests flow),
#: ``open`` (requests shed to numpy), ``half_open`` (one probe in flight).
BREAKER_STATES = ("closed", "open", "half_open")


class CircuitBreaker:
    """Per-backend circuit breaker for the service request path.

    The stack's quarantine counter is one-shot: once a backend trips it,
    only :func:`reset_health` re-arms the device path.  A long-lived
    service needs the full state machine instead — transient failures must
    not permanently degrade throughput:

    * ``closed`` — requests flow to the backend; ``fail_threshold``
      *consecutive* failures (any success resets the count) **open** it;
    * ``open`` — :meth:`allow` answers False (route the query straight to
      numpy) until ``reset_after`` seconds have passed, then the breaker
      **half-opens**;
    * ``half_open`` — exactly one caller gets True (the probe); its
      :meth:`record_success` closes the breaker, its :meth:`record_failure`
      re-opens it for another ``reset_after`` window.

    ``backend`` names the guarded backend (labels and warn-once keys);
    ``clock`` is injectable (monotonic seconds) so tests drive transitions
    without sleeping.  Thread-safe; state transitions to ``open`` are
    surfaced once per breaker through the owning ledger's warn-once
    registry when the breaker was created by
    :meth:`BackendHealth.breaker_for`.
    """

    def __init__(self, backend: str, *, fail_threshold: int = 3,
                 reset_after: float = 30.0, clock=time.monotonic,
                 _health: "BackendHealth | None" = None):
        if fail_threshold < 1:
            raise ValueError(
                f"fail_threshold must be >= 1, got {fail_threshold}")
        if reset_after < 0:
            raise ValueError(f"reset_after must be >= 0, got {reset_after}")
        self.backend = backend
        self.fail_threshold = int(fail_threshold)
        self.reset_after = float(reset_after)
        self._clock = clock
        self._health = _health
        self._lock = threading.Lock()
        self._state = "closed"
        self._failures = 0
        self._opened_at = 0.0
        self._n_opens = 0
        self._n_shed = 0

    @property
    def state(self) -> str:
        """Current state (one of :data:`BREAKER_STATES`); an expired
        ``open`` window reads as ``open`` until the next :meth:`allow`
        half-opens it."""
        with self._lock:
            return self._state

    @property
    def n_opens(self) -> int:
        """How many times the breaker has opened since construction."""
        with self._lock:
            return self._n_opens

    @property
    def n_shed(self) -> int:
        """How many :meth:`allow` calls answered False (requests routed
        around the backend) since construction."""
        with self._lock:
            return self._n_shed

    def allow(self) -> bool:
        """Whether the next request may try the guarded backend.

        ``closed`` → True.  ``open`` → False until ``reset_after`` seconds
        since opening, then the breaker half-opens and this call (only)
        gets True as the probe.  ``half_open`` → False: one probe is
        already in flight.
        """
        with self._lock:
            if self._state == "closed":
                return True
            if (self._state == "open"
                    and self._clock() - self._opened_at >= self.reset_after):
                self._state = "half_open"
                return True
            self._n_shed += 1
            return False

    def record_success(self) -> None:
        """A guarded call succeeded: close the breaker, clear the streak."""
        with self._lock:
            self._state = "closed"
            self._failures = 0

    def record_failure(self) -> None:
        """A guarded call failed: bump the streak; at ``fail_threshold``
        consecutive failures (or any half-open probe failure) the breaker
        opens for ``reset_after`` seconds."""
        with self._lock:
            self._failures += 1
            opening = (self._state == "half_open"
                       or (self._state == "closed"
                           and self._failures >= self.fail_threshold))
            if opening:
                self._state = "open"
                self._opened_at = self._clock()
                self._n_opens += 1
        if opening and self._health is not None:
            self._health.warn_once(
                f"breaker:{self.backend}",
                f"circuit breaker for backend {self.backend!r} opened after "
                f"repeated failures; service queries route to numpy and a "
                f"half-open probe re-tries the backend after "
                f"{self.reset_after:g}s")

    def reset(self) -> None:
        """Force the breaker back to ``closed`` with a clear streak."""
        with self._lock:
            self._state = "closed"
            self._failures = 0


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One recorded degradation: ``backend`` failed at injection ``site``.

    ``error`` is the triggering exception's ``repr`` (the exception object
    itself is not retained — events outlive their tracebacks); ``seq`` is a
    process-wide monotone sequence number, so event ordering is total even
    across interleaved arenas.
    """

    seq: int
    backend: str
    site: str
    error: str

    def __str__(self) -> str:
        return f"[{self.seq}] {self.backend} failed at {self.site}: {self.error}"


class BackendHealth:
    """Per-process backend failure ledger + quarantine + warn-once registry.

    Thread-safe (one lock around all mutation).  ``quarantine_after=None``
    reads the ``REPRO_STACK_QUARANTINE`` env var (default
    :data:`DEFAULT_QUARANTINE_AFTER`); ``0`` disables quarantine.
    ``max_events=None`` reads ``REPRO_HEALTH_MAX_EVENTS`` (default
    :data:`DEFAULT_MAX_EVENTS`); the ledger retains at most that many
    events (newest win), counting what it drops in
    :attr:`dropped_events` — a week-long soak stays bounded while the
    monotone :attr:`n_events` keeps snapshot-compare probes exact.
    """

    def __init__(self, quarantine_after: int | None = None,
                 max_events: int | None = None):
        if quarantine_after is None:
            quarantine_after = int(os.environ.get(
                "REPRO_STACK_QUARANTINE", DEFAULT_QUARANTINE_AFTER))
        if quarantine_after < 0:
            raise ValueError(
                f"quarantine_after must be >= 0, got {quarantine_after}")
        if max_events is None:
            max_events = int(os.environ.get(
                "REPRO_HEALTH_MAX_EVENTS", DEFAULT_MAX_EVENTS))
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self.quarantine_after = quarantine_after
        self.max_events = max_events
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self._events: collections.deque[HealthEvent] = collections.deque(
            maxlen=max_events)
        self._total = 0
        self._dropped = 0
        self._streak: dict[str, int] = {}
        self._quarantined: set[str] = set()
        self._warned: set[str] = set()
        self._breakers: dict[str, CircuitBreaker] = {}

    # -- event accounting ----------------------------------------------------
    def record_failure(self, backend: str, site: str,
                       error: BaseException | str) -> HealthEvent:
        """Record one backend failure at a named injection ``site``.

        ``error`` is the triggering exception (or a plain string), kept as
        its ``repr`` on the event.  Bumps ``backend``'s
        consecutive-failure streak and quarantines it
        when the streak reaches ``quarantine_after``; warns once per
        (backend, site) pair so a million-message sweep degrades with one
        line of noise, not one per call.  Returns the recorded event.
        """
        err = error if isinstance(error, str) else repr(error)
        with self._lock:
            ev = HealthEvent(seq=next(self._seq), backend=backend, site=site,
                             error=err)
            if len(self._events) == self._events.maxlen:
                self._dropped += 1      # deque drops the oldest on append
            self._events.append(ev)
            self._total += 1
            streak = self._streak.get(backend, 0) + 1
            self._streak[backend] = streak
            newly_quarantined = (self.quarantine_after
                                 and streak >= self.quarantine_after
                                 and backend not in self._quarantined)
            if newly_quarantined:
                self._quarantined.add(backend)
        self.warn_once(
            f"fallback:{backend}:{site}",
            f"backend {backend!r} failed at {site} ({err}); falling back to "
            "the numpy reference for this and further failures at this site")
        if newly_quarantined:
            self.warn_once(
                f"quarantine:{backend}",
                f"backend {backend!r} quarantined after {streak} consecutive "
                "failures; requests resolve to numpy until "
                "BackendHealth.reset()")
        return ev

    def record_success(self, backend: str) -> None:
        """Record a successful device call: clears ``backend``'s
        consecutive-failure streak (an existing quarantine stays until
        :meth:`reset` — a quarantined backend is not re-attempted, so a
        success can only come from an explicit direct call)."""
        with self._lock:
            self._streak[backend] = 0

    def is_quarantined(self, backend: str) -> bool:
        """Whether ``backend`` is quarantined (resolve it to numpy)."""
        with self._lock:
            return backend in self._quarantined

    def breaker_for(self, backend: str, *, fail_threshold: int = 3,
                    reset_after: float = 30.0,
                    clock=time.monotonic) -> CircuitBreaker:
        """The per-``backend`` :class:`CircuitBreaker`, created on first use.

        ``fail_threshold`` / ``reset_after`` / ``clock`` configure a breaker
        being created and are ignored for an existing one (first caller
        wins — one policy per backend per process).  Breakers created here
        report open transitions through :meth:`warn_once` and are cleared
        by :meth:`reset`.
        """
        with self._lock:
            br = self._breakers.get(backend)
            if br is None:
                br = CircuitBreaker(backend, fail_threshold=fail_threshold,
                                    reset_after=reset_after, clock=clock,
                                    _health=self)
                self._breakers[backend] = br
            return br

    # -- inspection ----------------------------------------------------------
    @property
    def events(self) -> tuple[HealthEvent, ...]:
        """The retained degradation events, in sequence order (the newest
        ``max_events``; see :attr:`dropped_events` for what the ring shed)."""
        with self._lock:
            return tuple(self._events)

    @property
    def n_events(self) -> int:
        """Monotone count of every event ever recorded since the last
        :meth:`reset` — including events the bounded ring has since dropped
        (cheap degradation probe: snapshot it before a call, compare
        after; a ring wrap can never mask a new failure)."""
        with self._lock:
            return self._total

    @property
    def dropped_events(self) -> int:
        """How many events the bounded ring has dropped since the last
        :meth:`reset` (``n_events - len(events)``)."""
        with self._lock:
            return self._dropped

    def failure_streak(self, backend: str) -> int:
        """Current consecutive-failure count for ``backend``."""
        with self._lock:
            return self._streak.get(backend, 0)

    def events_for(self, backend: str | None = None,
                   site: str | None = None) -> tuple[HealthEvent, ...]:
        """Events filtered by ``backend`` and/or ``site`` (None = any)."""
        with self._lock:
            return tuple(ev for ev in self._events
                         if (backend is None or ev.backend == backend)
                         and (site is None or ev.site == site))

    # -- warn-once registry --------------------------------------------------
    def warn_once(self, key: str, message: str,
                  category: type[Warning] = RuntimeWarning,
                  stacklevel: int = 3) -> bool:
        """Issue ``message`` as a warning the first time ``key`` is seen.

        The resettable replacement for module-level ``_warned_*`` globals:
        ``category`` and ``stacklevel`` pass through to ``warnings.warn``;
        returns True when the warning was actually issued.  :meth:`reset`
        clears the seen-set (the pytest autouse fixture relies on this to
        stop warn-once state leaking across tests).
        """
        with self._lock:
            if key in self._warned:
                return False
            self._warned.add(key)
        warnings.warn(message, category, stacklevel=stacklevel)
        return True

    def warned(self, key: str) -> bool:
        """Whether warn-once ``key`` has fired since the last reset."""
        with self._lock:
            return key in self._warned

    # -- lifecycle -----------------------------------------------------------
    def reset(self) -> None:
        """Clear events (and the dropped counter), streaks, quarantines,
        circuit breakers and warn-once state."""
        with self._lock:
            self._events.clear()
            self._total = 0
            self._dropped = 0
            self._streak.clear()
            self._quarantined.clear()
            self._warned.clear()
            self._breakers.clear()


_health = BackendHealth()


def get_health() -> BackendHealth:
    """The process-wide :class:`BackendHealth` ledger."""
    return _health


def reset_health() -> None:
    """Reset the process-wide ledger (events, quarantines, warn-once)."""
    _health.reset()
