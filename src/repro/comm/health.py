"""Backend health accounting: degradation events, quarantine, warn-once.

The comm stack degrades gracefully: when a device backend (jax / Pallas)
fails — an injected fault, a compile error, an int32-overflow arena, a
verify-mode mismatch — the failing call falls back to the numpy bit-identity
reference and *records the event here* instead of crashing the sweep.  This
module is the per-process ledger of those events:

* :class:`BackendHealth` keeps an append-only event list, per-backend
  consecutive-failure streaks, and a quarantine set: a backend that fails
  ``quarantine_after`` times in a row is quarantined — subsequent requests
  for it resolve straight to numpy without re-attempting the device path —
  until :meth:`BackendHealth.reset` (or a recorded success, which clears the
  streak but not an existing quarantine).
* The same object owns the process's **resettable warn-once registry**
  (:meth:`BackendHealth.warn_once`): every "warn once per process" message
  in the stack (backend fallbacks, the deprecated one-hot shim) goes through
  it, so tests can reset warning state instead of poking module globals.

One process-wide instance is served by :func:`get_health`;
:func:`reset_health` restores it to a clean slate (the autouse pytest
fixture in ``tests/conftest.py`` does this around every test).

Layering: stdlib-only (no numpy, no jax), importable from everywhere —
:mod:`repro.kernels.comm_stack` and :mod:`repro.comm.stack` both report
here.  See DESIGN.md §12 for the failure-handling contract.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import warnings

__all__ = ["HealthEvent", "BackendHealth", "get_health", "reset_health",
           "DEFAULT_QUARANTINE_AFTER"]

#: Consecutive failures of one backend before it is quarantined (override
#: per process with the ``REPRO_STACK_QUARANTINE`` env var; ``0`` disables
#: quarantine entirely — every call re-attempts the device path).
DEFAULT_QUARANTINE_AFTER = 3


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One recorded degradation: ``backend`` failed at injection ``site``.

    ``error`` is the triggering exception's ``repr`` (the exception object
    itself is not retained — events outlive their tracebacks); ``seq`` is a
    process-wide monotone sequence number, so event ordering is total even
    across interleaved arenas.
    """

    seq: int
    backend: str
    site: str
    error: str

    def __str__(self) -> str:
        return f"[{self.seq}] {self.backend} failed at {self.site}: {self.error}"


class BackendHealth:
    """Per-process backend failure ledger + quarantine + warn-once registry.

    Thread-safe (one lock around all mutation).  ``quarantine_after=None``
    reads the ``REPRO_STACK_QUARANTINE`` env var (default
    :data:`DEFAULT_QUARANTINE_AFTER`); ``0`` disables quarantine.
    """

    def __init__(self, quarantine_after: int | None = None):
        if quarantine_after is None:
            quarantine_after = int(os.environ.get(
                "REPRO_STACK_QUARANTINE", DEFAULT_QUARANTINE_AFTER))
        if quarantine_after < 0:
            raise ValueError(
                f"quarantine_after must be >= 0, got {quarantine_after}")
        self.quarantine_after = quarantine_after
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self._events: list[HealthEvent] = []
        self._streak: dict[str, int] = {}
        self._quarantined: set[str] = set()
        self._warned: set[str] = set()

    # -- event accounting ----------------------------------------------------
    def record_failure(self, backend: str, site: str,
                       error: BaseException | str) -> HealthEvent:
        """Record one backend failure at a named injection ``site``.

        ``error`` is the triggering exception (or a plain string), kept as
        its ``repr`` on the event.  Bumps ``backend``'s
        consecutive-failure streak and quarantines it
        when the streak reaches ``quarantine_after``; warns once per
        (backend, site) pair so a million-message sweep degrades with one
        line of noise, not one per call.  Returns the recorded event.
        """
        err = error if isinstance(error, str) else repr(error)
        with self._lock:
            ev = HealthEvent(seq=next(self._seq), backend=backend, site=site,
                             error=err)
            self._events.append(ev)
            streak = self._streak.get(backend, 0) + 1
            self._streak[backend] = streak
            newly_quarantined = (self.quarantine_after
                                 and streak >= self.quarantine_after
                                 and backend not in self._quarantined)
            if newly_quarantined:
                self._quarantined.add(backend)
        self.warn_once(
            f"fallback:{backend}:{site}",
            f"backend {backend!r} failed at {site} ({err}); falling back to "
            "the numpy reference for this and further failures at this site")
        if newly_quarantined:
            self.warn_once(
                f"quarantine:{backend}",
                f"backend {backend!r} quarantined after {streak} consecutive "
                "failures; requests resolve to numpy until "
                "BackendHealth.reset()")
        return ev

    def record_success(self, backend: str) -> None:
        """Record a successful device call: clears ``backend``'s
        consecutive-failure streak (an existing quarantine stays until
        :meth:`reset` — a quarantined backend is not re-attempted, so a
        success can only come from an explicit direct call)."""
        with self._lock:
            self._streak[backend] = 0

    def is_quarantined(self, backend: str) -> bool:
        """Whether ``backend`` is quarantined (resolve it to numpy)."""
        with self._lock:
            return backend in self._quarantined

    # -- inspection ----------------------------------------------------------
    @property
    def events(self) -> tuple[HealthEvent, ...]:
        """Every recorded degradation event, in sequence order."""
        with self._lock:
            return tuple(self._events)

    @property
    def n_events(self) -> int:
        """Number of recorded events (cheap degradation probe: snapshot it
        before a call, compare after)."""
        with self._lock:
            return len(self._events)

    def failure_streak(self, backend: str) -> int:
        """Current consecutive-failure count for ``backend``."""
        with self._lock:
            return self._streak.get(backend, 0)

    def events_for(self, backend: str | None = None,
                   site: str | None = None) -> tuple[HealthEvent, ...]:
        """Events filtered by ``backend`` and/or ``site`` (None = any)."""
        with self._lock:
            return tuple(ev for ev in self._events
                         if (backend is None or ev.backend == backend)
                         and (site is None or ev.site == site))

    # -- warn-once registry --------------------------------------------------
    def warn_once(self, key: str, message: str,
                  category: type[Warning] = RuntimeWarning,
                  stacklevel: int = 3) -> bool:
        """Issue ``message`` as a warning the first time ``key`` is seen.

        The resettable replacement for module-level ``_warned_*`` globals:
        ``category`` and ``stacklevel`` pass through to ``warnings.warn``;
        returns True when the warning was actually issued.  :meth:`reset`
        clears the seen-set (the pytest autouse fixture relies on this to
        stop warn-once state leaking across tests).
        """
        with self._lock:
            if key in self._warned:
                return False
            self._warned.add(key)
        warnings.warn(message, category, stacklevel=stacklevel)
        return True

    def warned(self, key: str) -> bool:
        """Whether warn-once ``key`` has fired since the last reset."""
        with self._lock:
            return key in self._warned

    # -- lifecycle -----------------------------------------------------------
    def reset(self) -> None:
        """Clear events, streaks, quarantines and warn-once state."""
        with self._lock:
            self._events.clear()
            self._streak.clear()
            self._quarantined.clear()
            self._warned.clear()


_health = BackendHealth()


def get_health() -> BackendHealth:
    """The process-wide :class:`BackendHealth` ledger."""
    return _health


def reset_health() -> None:
    """Reset the process-wide ledger (events, quarantines, warn-once)."""
    _health.reset()
