"""Vectorized primitives shared by the model ladder and the event simulator.

Both sides of the paper's model/measurement gap — the closed-form models in
:mod:`repro.core.models` and the mechanistic simulator in
:mod:`repro.net.simulator` — need the same per-phase quantities: how many
processes on each node are actively injecting into the network, what the
max-rate transport time of each message is, and how many receive-queue slots
each envelope walks.  This module computes all of them with array ops
(``np.unique`` / ``bincount`` / batched Fenwick rounds) so neither consumer
keeps a per-message Python loop.

Imports numpy only: it sits *below* both ``repro.core`` and ``repro.net`` in
the layering, so either package can build on it without import cycles.
"""
from __future__ import annotations

import numpy as np


# -- active senders per node -------------------------------------------------

def active_senders_per_node(src, node, is_net) -> np.ndarray:
    """Per-message count of actively-communicating processes on the sender's node.

    A process is *active* on its node if it sends at least one network-class
    message; every network message then contends with its node's active-sender
    count for injection bandwidth (the max-rate mechanism).  Non-network
    messages get 1.  Computed via ``np.unique`` over (node, sender) pairs —
    no dict-of-sets walk.
    """
    src = np.asarray(src, dtype=np.int64)
    node = np.asarray(node, dtype=np.int64)
    is_net = np.asarray(is_net, dtype=bool)
    ppn = np.ones(src.shape, dtype=np.float64)
    if src.size == 0 or not is_net.any():
        return ppn
    nd, sp = node[is_net], src[is_net]
    span = np.int64(sp.max()) + 1
    pair_node = np.unique(nd * span + sp) // span     # distinct (node, sender)
    nodes_u, senders = np.unique(pair_node, return_counts=True)
    ppn[is_net] = senders[np.searchsorted(nodes_u, nd)]
    return ppn


# -- max-rate message pricing ------------------------------------------------

def transport_times(size, alpha, Rb, RN, ppn, is_net,
                    use_maxrate: bool = True) -> np.ndarray:
    """Per-message transport time under the (node-aware) max-rate model.

    ``alpha``/``Rb``/``RN`` are the already-indexed per-message parameter
    arrays (locality x protocol lookup done by the caller, which owns the
    table layout).  Only network-class messages (``is_net``) contend for the
    node injection cap ``RN``; with ``use_maxrate=False`` the cap is ignored
    (pure postal model).
    """
    size = np.asarray(size, dtype=np.float64)
    if not use_maxrate:
        return alpha + size / Rb
    eff = np.where(np.asarray(is_net, dtype=bool),
                   np.maximum(np.asarray(ppn, dtype=np.float64), 1.0), 1.0)
    rate = np.minimum(RN, eff * Rb)
    return alpha + eff * size / rate


def per_proc_sums(idx, values, n: int) -> np.ndarray:
    """Sum ``values`` into ``n`` bins by ``idx`` (send-side transport sums)."""
    return np.bincount(np.asarray(idx, dtype=np.int64),
                       weights=np.asarray(values, dtype=np.float64),
                       minlength=n)


def sum_by_pairs(a, b, w) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Aggregate weights ``w`` over distinct ``(a, b)`` pairs.

    Returns ``(ua, ub, sums)`` sorted by ``(a, b)``; ``sums[i]`` is the total
    weight of pair ``(ua[i], ub[i])``.  This is the engine's one aggregation
    idiom (``np.unique`` on a packed key + ``bincount`` on the inverse) — the
    strategy rewrites build every gather/inter/scatter message set with it.
    ``a`` and ``b`` must be non-negative integers.
    """
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    if a.size == 0:
        return a, b, w
    span = np.int64(b.max()) + 1
    uk, inv = np.unique(a * span + b, return_inverse=True)
    sums = np.bincount(inv, weights=w)
    return (uk // span).astype(np.int64), (uk % span).astype(np.int64), sums


def segmented_arange(counts) -> np.ndarray:
    """``[0..counts[0]), [0..counts[1]), ...`` concatenated (one arange per
    segment, no Python loop) — the rank index of each expanded element within
    its segment, used to fan a message out across ``counts[i]`` peers."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total) - np.repeat(offsets, counts)


def group_by_receiver(dst, n_procs: int) -> tuple[np.ndarray, np.ndarray]:
    """Stable grouping of message indices by destination process.

    Returns ``(order, bounds)``: ``order[bounds[p]:bounds[p+1]]`` are the
    indices of messages destined to process ``p``, in posting (array) order.
    """
    dst = np.asarray(dst, dtype=np.int64)
    order = np.argsort(dst, kind="stable")
    bounds = np.searchsorted(dst[order], np.arange(n_procs + 1))
    return order, bounds


# -- receive-queue walk ------------------------------------------------------

class _Fenwick:
    """Binary indexed tree over n slots holding 0/1 'still unmatched' flags."""

    def __init__(self, n: int):
        self.n = n
        idx = np.arange(n + 1, dtype=np.int64)
        self.t = idx & -idx          # prefix tree of all-ones
        self.t[0] = 0

    def _add(self, i: int, v: int) -> None:
        while i <= self.n:
            self.t[i] += v
            i += i & -i

    def prefix(self, i: int) -> int:
        s = 0
        while i > 0:
            s += self.t[i]
            i -= i & -i
        return int(s)

    def remove(self, i: int) -> None:
        self._add(i, -1)


def queue_traversal_steps(posted_order, arrival_order) -> np.ndarray:
    """Exact queue-walk lengths for one receiving process (reference Fenwick).

    ``posted_order[k]`` = message id posted k-th; ``arrival_order[j]`` =
    message id of the j-th arriving envelope.  Returns steps per arrival: the
    1-based position of the match in the still-unmatched posted queue —
    exactly what CrayMPI's linear receive-queue search pays.

    This is the scalar per-process reference; the simulator uses
    :func:`batched_queue_traversal_steps` across all receivers at once.
    """
    posted_order = np.asarray(posted_order)
    n = len(posted_order)
    pos = np.empty(n, dtype=np.int64)
    pos[posted_order] = np.arange(n)
    fen = _Fenwick(n)
    steps = np.empty(n, dtype=np.int64)
    for j, mid in enumerate(np.asarray(arrival_order)):
        p = int(pos[mid]) + 1               # 1-based slot
        steps[j] = fen.prefix(p)            # unmatched entries at/before slot
        fen.remove(p)
    return steps


def _prefix_many(tree: np.ndarray, i: np.ndarray) -> np.ndarray:
    """Fenwick prefix sums for an array of 1-based indices."""
    i = np.array(i, dtype=np.int64, copy=True)
    out = np.zeros(i.shape, dtype=np.int64)
    while True:
        m = i > 0
        if not m.any():
            return out
        im = i[m]
        out[m] += tree[im]
        i[m] = im - (im & -im)


def _add_many(tree: np.ndarray, i: np.ndarray, v: int) -> None:
    """Fenwick point updates for an array of distinct 1-based indices."""
    n = tree.size - 1
    i = np.array(i, dtype=np.int64, copy=True)
    while True:
        m = i <= n
        if not m.any():
            return
        im = i[m]
        np.add.at(tree, im, v)              # ancestors may collide across slots
        i[m] = im + (im & -im)


def batched_queue_traversal_steps(posted, arrival, bounds) -> np.ndarray:
    """Queue-walk lengths for many receiving processes in one Fenwick sweep.

    Region ``r`` (one receiver) occupies slots ``bounds[r]:bounds[r+1]`` of
    the concatenated ``posted`` / ``arrival`` arrays, which hold region-local
    message indices.  Returns per-arrival steps in the same layout — equal to
    stacking :func:`queue_traversal_steps` per region, but all regions advance
    in lock-step: one round per arrival *depth*, each round a vectorized
    prefix/remove over every still-active receiver.  Python-level work is
    O(max msgs-per-receiver * log N) instead of O(total messages).
    """
    posted = np.asarray(posted, dtype=np.int64)
    arrival = np.asarray(arrival, dtype=np.int64)
    bounds = np.asarray(bounds, dtype=np.int64)
    N = int(posted.size)
    steps = np.zeros(N, dtype=np.int64)
    if N == 0:
        return steps
    starts = bounds[:-1]
    counts = np.diff(bounds)
    region_of = np.repeat(np.arange(counts.size), counts)
    start_of = starts[region_of]
    pos = np.empty(N, dtype=np.int64)                 # local id -> local slot
    pos[start_of + posted] = np.arange(N) - start_of
    idx = np.arange(N + 1, dtype=np.int64)
    tree = idx & -idx                                 # all-ones Fenwick
    tree[0] = 0
    regions = np.nonzero(counts)[0]
    for j in range(int(counts.max())):
        act = regions[counts[regions] > j]
        if act.size == 0:
            break
        s = starts[act]
        mid = arrival[s + j]                          # j-th arrival per region
        p = s + pos[s + mid] + 1                      # global 1-based slot
        steps[s + j] = _prefix_many(tree, p) - _prefix_many(tree, s)
        _add_many(tree, p, -1)
    return steps
