"""Vectorized primitives shared by the model ladder and the event simulator.

Both sides of the paper's model/measurement gap — the closed-form models in
:mod:`repro.core.models` and the mechanistic simulator in
:mod:`repro.net.simulator` — need the same per-phase quantities: how many
processes on each node are actively injecting into the network, what the
max-rate transport time of each message is, and how many receive-queue slots
each envelope walks.  This module computes all of them with array ops
(``np.unique`` / ``bincount`` / batched Fenwick rounds) so neither consumer
keeps a per-message Python loop.

Imports numpy only: it sits *below* both ``repro.core`` and ``repro.net`` in
the layering, so either package can build on it without import cycles.
"""
from __future__ import annotations

import numpy as np


# -- active senders per node -------------------------------------------------

def active_senders_per_node(src, node, is_net) -> np.ndarray:
    """Per-message count of actively-communicating processes on the sender's node.

    ``src[i]`` / ``node[i]`` / ``is_net[i]`` are message ``i``'s sending
    process, that process's node, and whether the message is network-class.
    A process is *active* on its node if it sends at least one network-class
    message; every network message then contends with its node's active-sender
    count for injection bandwidth (the max-rate mechanism).  Non-network
    messages get 1.  Computed via ``np.unique`` over (node, sender) pairs —
    no dict-of-sets walk.
    """
    src = np.asarray(src, dtype=np.int64)
    node = np.asarray(node, dtype=np.int64)
    is_net = np.asarray(is_net, dtype=bool)
    ppn = np.ones(src.shape, dtype=np.float64)
    if src.size == 0 or not is_net.any():
        return ppn
    nd, sp = node[is_net], src[is_net]
    span = np.int64(sp.max()) + 1
    pair_node = np.unique(nd * span + sp) // span     # distinct (node, sender)
    nodes_u, senders = np.unique(pair_node, return_counts=True)
    ppn[is_net] = senders[np.searchsorted(nodes_u, nd)]
    return ppn


# -- max-rate message pricing ------------------------------------------------

def transport_times(size, alpha, Rb, RN, ppn, is_net,
                    use_maxrate: bool = True, rails: int = 1, xp=np):
    """Per-message transport time under the (node-aware) max-rate model.

    ``size`` is bytes per message, ``ppn`` the active-senders count on each
    sender's node; ``alpha``/``Rb``/``RN`` are the already-indexed per-message parameter
    arrays (locality x protocol lookup done by the caller, which owns the
    table layout).  Only network-class messages (``is_net``) contend for the
    node injection cap ``RN``; with ``use_maxrate=False`` the cap is ignored
    (pure postal model).

    ``rails`` is the node's NIC count (``CommParams.n_rails``): a node's
    ``ppn`` active senders divide across its rails, so only
    ``ceil(ppn / rails)`` processes contend per NIC and ``RN`` is the
    *per-rail* cap.  ``rails=1`` is bit-identical to the pre-rail formula.

    ``xp`` is the array namespace (:func:`repro.comm.xp.get_xp`): the
    default :mod:`numpy` is the bit-identity reference; with ``jax.numpy``
    the same formula runs device-resident in float32 (inputs already on
    device stay there — the stack's device pricing path).
    """
    f = np.float64 if xp is np else xp.float32
    size = xp.asarray(size, dtype=f)
    if not use_maxrate:
        return alpha + size / Rb
    eff = xp.asarray(ppn, dtype=f)
    if rails != 1:
        eff = xp.ceil(eff / rails)
    eff = xp.where(xp.asarray(is_net, dtype=bool), xp.maximum(eff, 1.0), 1.0)
    rate = xp.minimum(RN, eff * Rb)
    return alpha + eff * size / rate


def per_proc_sums(idx, values, n: int) -> np.ndarray:
    """Sum ``values`` into ``n`` bins by ``idx`` (send-side transport sums)."""
    return np.bincount(np.asarray(idx, dtype=np.int64),
                       weights=np.asarray(values, dtype=np.float64),
                       minlength=n)


def sum_by_pairs(a, b, w) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Aggregate weights ``w`` over distinct ``(a, b)`` pairs.

    Returns ``(ua, ub, sums)`` sorted by ``(a, b)``; ``sums[i]`` is the total
    weight of pair ``(ua[i], ub[i])``.  This is the engine's one aggregation
    idiom (``np.unique`` on a packed key + ``bincount`` on the inverse) — the
    strategy rewrites build every gather/inter/scatter message set with it.
    ``a`` and ``b`` must be non-negative integers.
    """
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    if a.size == 0:
        return a, b, w
    span = np.int64(b.max()) + 1
    uk, inv = np.unique(a * span + b, return_inverse=True)
    sums = np.bincount(inv, weights=w)
    return (uk // span).astype(np.int64), (uk % span).astype(np.int64), sums


def segmented_arange(counts) -> np.ndarray:
    """``[0..counts[0]), [0..counts[1]), ...`` concatenated (one arange per
    segment, no Python loop) — the rank index of each expanded element within
    its segment, used to fan a message out across ``counts[i]`` peers."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(total) - np.repeat(offsets, counts)


def group_by_receiver(dst, n_procs: int) -> tuple[np.ndarray, np.ndarray]:
    """Stable grouping of message indices by destination process ``dst``.

    Returns ``(order, bounds)``: ``order[bounds[p]:bounds[p+1]]`` are the
    indices of messages destined to process ``p`` (of ``n_procs``), in
    posting (array) order.
    """
    dst = np.asarray(dst, dtype=np.int64)
    order = np.argsort(dst, kind="stable")
    bounds = np.searchsorted(dst[order], np.arange(n_procs + 1))
    return order, bounds


# -- grouped receive-queue accounting ---------------------------------------

def flat_orders(orders):
    """Normalize a per-slot order spec to flat ``(slots, lens, ids)`` form.

    ``orders`` is either already flat — ``slots`` strictly increasing,
    ``ids`` the concatenated per-slot permutations of global message indices
    in slot order, ``lens`` their lengths — or a dict mapping each slot to
    its permutation (the per-receiver form, normalized here with one sort
    and one concatenate).  Returns None when there is nothing custom.
    """
    if orders is None:
        return None
    if isinstance(orders, tuple):
        slots, lens, ids = orders
        slots = np.asarray(slots, dtype=np.int64)
        if slots.size == 0:
            return None
        return (slots, np.asarray(lens, dtype=np.int64),
                np.asarray(ids, dtype=np.int64))
    if not orders:
        return None
    pairs = sorted((int(s), np.asarray(v, dtype=np.int64))
                   for s, v in orders.items())
    return (np.asarray([s for s, _ in pairs], dtype=np.int64),
            np.asarray([v.size for _, v in pairs], dtype=np.int64),
            np.concatenate([v for _, v in pairs]))


def _assemble_orders(flat, slots, counts, cbounds, local, group,
                     describe) -> np.ndarray:
    """Region-local permutation array for every custom slot, in slot order.

    ``flat`` is a normalized :func:`flat_orders` spec (or None); slots it
    does not cover — and covered slots outside the custom set ``slots``,
    mirroring the per-phase behaviour of silently ignoring orders for
    receivers with no messages — default to array order.  Assembly and
    validation (length, destination, permutation) are single vectorized
    passes.
    """
    out = segmented_arange(counts)                    # default: array order
    if flat is None:
        return out
    pslots, lens, ids_cat = flat
    keep = np.isin(pslots, slots, assume_unique=True)
    if not keep.all():
        sel = np.repeat(keep, lens)
        pslots, lens, ids_cat = pslots[keep], lens[keep], ids_cat[sel]
    if pslots.size == 0:
        return out
    rank = np.searchsorted(slots, pslots)             # position among customs
    bad = np.nonzero(lens != counts[rank])[0]
    if bad.size:
        raise ValueError(
            f"order for {describe(int(pslots[bad[0]]))} must be a "
            f"permutation of the {int(counts[rank[bad[0]]])} message "
            f"indices destined to it")
    slot_rep = np.repeat(pslots, lens)
    rank_rep = np.repeat(rank, lens)
    pos = cbounds[rank_rep] + segmented_arange(lens)
    ok = group[ids_cat] == slot_rep           # ids destined to another slot?
    if not ok.all():
        bad = int(np.argmax(~ok))
        raise ValueError(
            f"order for {describe(int(slot_rep[bad]))} must be a "
            f"permutation of the message indices destined to it")
    vals = local[ids_cat]                     # in [0, counts[slot]) given ok
    hits = np.bincount(cbounds[rank_rep] + vals, minlength=int(cbounds[-1]))
    if hits.max(initial=0) > 1:
        bad = int(np.argmax(hits[cbounds[rank_rep] + vals] > 1))
        raise ValueError(
            f"order for {describe(int(slot_rep[bad]))} must be a "
            f"permutation of the message indices destined to it")
    out[pos] = vals
    return out


def grouped_queue_steps(group, n_slots, recv_post_order=None,
                        arrival_order=None, groups=None,
                        describe=None, backend=None) -> np.ndarray:
    """Exact receive-queue traversal-step totals for ``n_slots`` receiver slots.

    ``group[i]`` is the receiver slot of message ``i`` (a process id, or a
    packed ``(phase, process)`` key for a stacked sweep).  The order specs —
    ``recv_post_order`` (posting order) and ``arrival_order``
    (envelope-arrival order) — give each custom slot a permutation of the
    global indices of its messages, as a dict or in the flat
    :func:`flat_orders` form; missing slots use array order (one
    step per arrival).  All custom slots pay the exact Fenwick walk in one
    batched sweep; assembly and validation of the custom permutations are
    vectorized (:func:`_assemble_orders`).

    ``groups`` optionally supplies a precomputed ``(order, bounds)`` stable
    grouping (e.g. :meth:`repro.comm.CommPhase.receiver_groups`); ``describe``
    renders a slot id in error messages.  ``backend`` selects where the
    Fenwick sweep itself runs (``None``/``'numpy'`` = the in-process numpy
    rounds; ``'jax'``/``'pallas'`` = the fused device walk in
    :func:`repro.kernels.comm_stack.queue_walk` — bit-equal, it is integer
    work).
    """
    group = np.asarray(group, dtype=np.int64)
    if describe is None:
        describe = "receiver {}".format
    if groups is not None:
        order, bounds = groups
    else:
        order, bounds = group_by_receiver(group, n_slots)
    counts = np.diff(bounds)
    qsteps = counts.astype(np.int64).copy()           # array order: 1/arrival
    if group.size == 0:
        return qsteps
    post = flat_orders(recv_post_order)
    arr = flat_orders(arrival_order)
    if post is None and arr is None:
        return qsteps
    cand = (post[0] if arr is None else
            arr[0] if post is None else np.union1d(post[0], arr[0]))
    cand = cand[(cand >= 0) & (cand < n_slots)]
    slots = cand[counts[cand] > 0]                    # silent slots excluded
    if slots.size == 0:
        return qsteps
    # local index of every message within its slot's group
    local = np.empty(group.size, dtype=np.int64)
    local[order] = np.arange(group.size) - np.repeat(bounds[:-1], counts)
    ccounts = counts[slots]
    cbounds = np.concatenate([[0], np.cumsum(ccounts)])
    posted = _assemble_orders(post, slots, ccounts, cbounds, local, group,
                              describe)
    arrive = _assemble_orders(arr, slots, ccounts, cbounds, local, group,
                              describe)
    if backend in (None, "numpy"):
        steps = batched_queue_traversal_steps(posted, arrive, cbounds)
    else:
        from repro.kernels.comm_stack import queue_walk
        steps = queue_walk(posted, arrive, cbounds, backend=backend)
    qsteps[slots] = np.add.reduceat(steps, cbounds[:-1])
    return qsteps


# -- receive-queue walk ------------------------------------------------------

class _Fenwick:
    """Binary indexed tree over n slots holding 0/1 'still unmatched' flags."""

    def __init__(self, n: int):
        self.n = n
        idx = np.arange(n + 1, dtype=np.int64)
        self.t = idx & -idx          # prefix tree of all-ones
        self.t[0] = 0

    def _add(self, i: int, v: int) -> None:
        while i <= self.n:
            self.t[i] += v
            i += i & -i

    def prefix(self, i: int) -> int:
        s = 0
        while i > 0:
            s += self.t[i]
            i -= i & -i
        return int(s)

    def remove(self, i: int) -> None:
        self._add(i, -1)


def queue_traversal_steps(posted_order, arrival_order) -> np.ndarray:
    """Exact queue-walk lengths for one receiving process (reference Fenwick).

    ``posted_order[k]`` = message id posted k-th; ``arrival_order[j]`` =
    message id of the j-th arriving envelope.  Returns steps per arrival: the
    1-based position of the match in the still-unmatched posted queue —
    exactly what CrayMPI's linear receive-queue search pays.

    This is the scalar per-process reference; the simulator uses
    :func:`batched_queue_traversal_steps` across all receivers at once.
    """
    posted_order = np.asarray(posted_order)
    n = len(posted_order)
    pos = np.empty(n, dtype=np.int64)
    pos[posted_order] = np.arange(n)
    fen = _Fenwick(n)
    steps = np.empty(n, dtype=np.int64)
    for j, mid in enumerate(np.asarray(arrival_order)):
        p = int(pos[mid]) + 1               # 1-based slot
        steps[j] = fen.prefix(p)            # unmatched entries at/before slot
        fen.remove(p)
    return steps


def _prefix_many(tree: np.ndarray, base: np.ndarray, i: np.ndarray,
                 depth: int) -> np.ndarray:
    """Fenwick prefix sums for an array of region-local 1-based indices.

    ``base[r]`` offsets region r's private tree inside the shared ``tree``
    array; the Fenwick index arithmetic runs on the *local* index, so walk
    depth is the bit-length of the region's padded span, not the global
    one.  Maskless: an index that reaches 0 stays 0 (``0 & -0 == 0``) and
    keeps adding the region's always-zero slot 0 — pure gathers, no
    reductions.
    """
    i = np.array(i, dtype=np.int64, copy=True)
    out = np.zeros(i.shape, dtype=np.int64)
    for _ in range(depth):
        out += tree[base + i]
        i -= i & -i
    return out


def _add_many(tree: np.ndarray, base: np.ndarray, i: np.ndarray,
              bound: np.ndarray, v: int, depth: int) -> None:
    """Fenwick point updates for distinct region-local 1-based indices.

    Maskless like :func:`_prefix_many`: a chain that climbs past its
    region's padded span ``bound[r]`` parks at the shared sink slot (the
    last tree cell, never read), so every round is one scatter-add plus
    index arithmetic.
    """
    sink = tree.size - 1
    i = np.array(i, dtype=np.int64, copy=True)
    idx = base + i
    for _ in range(depth):
        np.add.at(tree, idx, v)             # ancestors may collide across slots
        i += i & -i
        idx = np.where(i > bound, sink, base + i)


def batched_queue_traversal_steps(posted, arrival, bounds) -> np.ndarray:
    """Queue-walk lengths for many receiving processes in one batched sweep.

    Region ``r`` (one receiver) occupies slots ``bounds[r]:bounds[r+1]`` of
    the concatenated ``posted`` / ``arrival`` arrays, which hold region-local
    message indices.  Returns per-arrival steps in the same layout — equal to
    stacking :func:`queue_traversal_steps` per region.

    All regions advance in lock-step: one round per arrival *depth*, each
    round one maskless vectorized Fenwick prefix + one removal over every
    still-active receiver.  Every region owns a private Fenwick tree (padded
    to a power of two) inside one shared array, so walk depth is the
    bit-length of the *largest region*, not of the whole sweep, and the walk
    length is a single local prefix (no start-offset subtraction).
    Python-level work is O(max msgs-per-receiver * log max msgs-per-receiver)
    rounds-times-depth, with every array op spanning all active receivers.
    """
    posted = np.asarray(posted, dtype=np.int64)
    arrival = np.asarray(arrival, dtype=np.int64)
    bounds = np.asarray(bounds, dtype=np.int64)
    N = int(posted.size)
    steps = np.zeros(N, dtype=np.int64)
    if N == 0:
        return steps
    starts = bounds[:-1]
    counts = np.diff(bounds)
    region_of = np.repeat(np.arange(counts.size), counts)
    start_of = starts[region_of]
    pos = np.empty(N, dtype=np.int64)                 # local id -> local slot
    pos[start_of + posted] = np.arange(N) - start_of
    b = pos[start_of + arrival]                       # slot of j-th arrival
    # private per-region Fenwick trees in one shared array: region r owns
    # slots [toff[r], toff[r] + span[r]] (local 0 is its always-zero root),
    # spans padded to powers of two, one shared sink slot at the very end
    span = np.ones(counts.size, dtype=np.int64)
    while (span < counts).any():
        span = np.where(span < counts, span * 2, span)
    blk = span + 1
    toff = np.concatenate([[0], np.cumsum(blk)])
    tree = np.zeros(toff[-1] + 1, dtype=np.int64)     # +1: shared sink
    li = segmented_arange(blk)                        # local 0..span per region
    c_rep = np.repeat(counts, blk)
    lo = li - (li & -li)
    tree[:-1] = np.minimum(li, c_rep) - np.minimum(lo, c_rep)
    depth = int(span.max()).bit_length()              # chains: <= log2 + 1
    regions = np.nonzero(counts)[0]
    for j in range(int(counts.max())):
        act = regions[counts[regions] > j]
        if act.size == 0:
            break
        s = starts[act]
        p = b[s + j] + 1                              # local 1-based slot
        base = toff[act]
        steps[s + j] = _prefix_many(tree, base, p, depth)
        _add_many(tree, base, p, span[act], -1, depth)
    return steps
