"""DeltaStack: incremental re-pricing of a mutated sweep arena.

PR 3's :class:`~repro.comm.PhaseStack` made one-shot sweeps fast; this module
makes *search* fast.  A local-search move — shift a partition boundary,
re-aggregate one node — changes a few dozen messages, yet re-pricing the
candidate through ``PhaseStack.build`` pays the full O(total messages) cost
again: machine classification, ``np.unique`` active-sender counting, torus
routing, every segmented reduction.  ``DeltaStack`` wraps the same arena as
a sequence of per-phase incremental states and supports

    ``delta.apply(removed_idx, added) -> DeltaStack``

where the cost of re-deriving every ladder-level and simulator aggregate is
proportional to the *changed phases*, not the whole sweep — and, inside a
changed phase, the expensive derived quantities are delta-updated rather
than recomputed:

* **active-sender / node tables** — integer per-(phase, sender) network-send
  counts and per-(phase, node) active-sender counts are point-updated
  (``np.add.at``); ``active_ppn`` is then a table lookup, with re-pricing
  limited to network messages of nodes whose active count actually changed
  (plus the added messages) — no ``np.unique`` sort ever runs again;
* **per-(phase, process) transport sums** — the node-aware per-message
  transport times survive the move except at the re-priced subset; the dense
  send-side rows are re-binned per dirty phase in canonical order (survivors
  first, additions appended), which keeps them bit-identical to a fresh
  packed-key ``bincount``.  The postal / flat-max-rate rungs are pure
  elementwise functions of the phase arrays and are priced lazily, on first
  query per generation;
* **receive counts / queue terms** — integer point updates into the
  per-receiver count rows, with the per-phase worst receiver maintained by a
  point-updatable max tree (:class:`_MaxTree`) instead of a row rebuild;
* **routing / link contention** — lazy until the simulator first asks, then
  only *added* messages are routed: the surviving rows of the stored
  ``(message, link)`` expansion are filtered and re-merged in the
  dimension-major order ``route_link_ids`` emits, so the per-(link, source)
  histogram replays the fresh aggregation bit for bit.  A model-guided
  search that never simulates never routes at all (the ladder's contention
  term is the cube-partition estimate, a function of net bytes).

Bit-identity contract: every aggregate a ``DeltaStack`` serves equals a fresh
``PhaseStack.build`` over the mutated phases *bit for bit* (numpy backend).
Mutated phases are canonical: surviving messages keep their order, additions
append at the end — exactly the phase a caller would rebuild.  Floating-point
sums that depend on accumulation order (send-side ``bincount`` rows, the
pairwise-summed per-phase net bytes) are *replayed* over the dirty phase's
arrays rather than patched, because patching a float sum cannot reproduce
the fresh accumulation order; everything integer (receive counts, sender
tables, queue steps) is patched point-wise.  ``verify=True`` re-checks the
contract against a fresh build after every ``apply`` — use it in tests and
when debugging a new move generator, never in hot search loops.

Layering: numpy-only, below both consumers like the rest of
:mod:`repro.comm`.  :func:`repro.core.models.phase_cost_many` /
:func:`model_ladder_many` and :func:`repro.net.simulator.simulate_many`
accept a ``DeltaStack`` anywhere they accept a ``PhaseStack``; the
model-guided partition optimizer (:mod:`repro.sparse.optimize`) is the
intended driver.  Fitted-params overrides and the JAX/Pallas backends fall
back to a fresh arena (built once per generation and cached) — the delta
fast path serves the machine's own tables, which is what a search loop
prices.
"""
from __future__ import annotations

import hashlib

import numpy as np

from .phase import CommPhase
from .primitives import transport_times
from .stack import PhaseStack, StackSimArrays

__all__ = ["DeltaStack", "ARENA_TYPES", "phase_fingerprint",
           "pattern_fingerprint", "message_delta"]

def phase_fingerprint(src, dst, size, n_procs) -> str:
    """Content-hash of one phase's raw message arrays, as a hex string.

    SHA-256 over a canonical byte stream: a version tag, ``n_procs`` and the
    message count as int64, then the ``src`` / ``dst`` endpoint arrays as
    int64 and the ``size`` array as float64, **in message order**.  The hash
    is deliberately order-sensitive: simulator verdicts depend on message
    order (per-candidate seeded arrival streams), so two phases that differ
    only by a permutation must *not* share a cache entry.  Used by the
    strategy service's arena cache to key priced arenas.
    """
    src = np.ascontiguousarray(src, dtype=np.int64)
    dst = np.ascontiguousarray(dst, dtype=np.int64)
    size = np.ascontiguousarray(size, dtype=np.float64)
    h = hashlib.sha256()
    h.update(b"repro.phase.v1")
    h.update(np.asarray([int(n_procs), src.size], dtype=np.int64).tobytes())
    h.update(src.tobytes())
    h.update(dst.tobytes())
    h.update(size.tobytes())
    return h.hexdigest()


def pattern_fingerprint(pattern) -> str:
    """Content-hash of a :class:`repro.sparse.CommPattern`, as a hex string.

    Delegates to :func:`phase_fingerprint` over ``pattern``'s raw
    ``src`` / ``dst`` / ``size`` arrays and ``n_procs`` — anything with
    those four attributes (a ``CommPattern``, a bound ``CommPhase``) hashes
    identically, so a cache keyed on the unbound pattern hits for its bound
    phase too.
    """
    return phase_fingerprint(pattern.src, pattern.dst, pattern.size,
                             pattern.n_procs)


def message_delta(old, new):
    """The multiset message diff turning pattern ``old`` into pattern ``new``.

    Both ``old`` and ``new`` expose raw ``src`` / ``dst`` / ``size`` arrays
    (``CommPattern`` or bound ``CommPhase``).  Returns
    ``(removed_idx, (src, dst, size))`` suitable for
    :meth:`DeltaStack.apply` on a single-phase arena built from ``old``:
    ``removed_idx`` are message indices into ``old``'s order, the added
    arrays are the messages of ``new`` not covered by ``old``.

    Messages match as exact ``(src, dst, size)`` triples, multiset-style:
    when a triple appears ``a`` times in ``old`` and ``b`` times in ``new``,
    ``min(a, b)`` copies survive.  Removals take the *last* duplicate
    occurrences so the earliest survivors keep their slots, matching the
    canonical mutated order ``DeltaStack.apply`` produces (survivors in
    place, additions appended).  Note the resulting order is that canonical
    order, not ``new``'s own order — fingerprint the applied arena's phase,
    not ``new``, when caching the result.
    """
    os_ = np.asarray(old.src, dtype=np.int64).ravel()
    od = np.asarray(old.dst, dtype=np.int64).ravel()
    oz = np.asarray(old.size, dtype=np.float64).ravel()
    ns = np.asarray(new.src, dtype=np.int64).ravel()
    nd = np.asarray(new.dst, dtype=np.int64).ravel()
    nz = np.asarray(new.size, dtype=np.float64).ravel()
    n_old, n_new = os_.size, ns.size
    rec = np.empty(n_old + n_new, dtype=[("s", np.int64), ("d", np.int64),
                                         ("z", np.float64)])
    rec["s"] = np.concatenate([os_, ns])
    rec["d"] = np.concatenate([od, nd])
    rec["z"] = np.concatenate([oz, nz])
    _, inv = np.unique(rec, return_inverse=True)
    inv = inv.ravel()                      # numpy 2.x keeps input shape
    inv_old, inv_new = inv[:n_old], inv[n_old:]
    n_groups = int(inv.max(initial=-1)) + 1
    c_old = np.bincount(inv_old, minlength=n_groups)
    c_new = np.bincount(inv_new, minlength=n_groups)
    keep = np.minimum(c_old, c_new)

    def _ranks(invs, counts):
        # within-group occurrence rank, stable in original message order
        order = np.argsort(invs, kind="stable")
        starts = np.r_[0, np.cumsum(counts)[:-1]]
        r = np.empty(invs.size, dtype=np.int64)
        r[order] = np.arange(invs.size) - starts[invs[order]]
        return r

    removed = np.nonzero(_ranks(inv_old, c_old) >= keep[inv_old])[0]
    add = _ranks(inv_new, c_new) >= keep[inv_new]
    return removed, (ns[add], nd[add], nz[add])


#: The (node_aware, use_maxrate) flag pairs the model ladder prices.  The
#: ladder's five levels collapse onto these three transport passes (postal /
#: max-rate / node-aware; queue and contention reuse the node-aware pass).
_POSTAL = (False, False)
_MAXRATE = (False, True)
_NODE_AWARE = (True, True)
_FLAGS = (_POSTAL, _MAXRATE, _NODE_AWARE)


class _MaxTree:
    """Point-updatable maximum over a fixed slot span.

    A complete binary tree in one flat array (the segment-tree sibling of
    the Fenwick trees in :mod:`repro.comm.primitives`): ``update`` rewrites
    one leaf and climbs to the root, so the per-phase worst receive count
    survives removals — which a plain running max cannot — in O(log slots)
    instead of an O(slots) row rebuild.
    """

    __slots__ = ("n", "tree")

    def __init__(self, values: np.ndarray):
        values = np.asarray(values, dtype=np.int64)
        n = 1
        while n < values.size:
            n *= 2
        self.n = n
        t = np.zeros(2 * n, dtype=np.int64)
        t[n:n + values.size] = values
        size = n
        while size > 1:
            size //= 2
            lvl = t[2 * size:4 * size]
            t[size:2 * size] = np.maximum(lvl[0::2], lvl[1::2])
        self.tree = t

    def update(self, i: int, value: int) -> None:
        i += self.n
        t = self.tree
        t[i] = value
        i //= 2
        while i:
            t[i] = max(t[2 * i], t[2 * i + 1])
            i //= 2

    def update_many(self, idx: np.ndarray, values: np.ndarray) -> None:
        """Batch point updates: rewrite the leaves, then climb all the
        affected chains level by level (one vectorized gather-max per level,
        shared ancestors deduplicated)."""
        t = self.tree
        i = np.asarray(idx, dtype=np.int64) + self.n
        t[i] = values
        i = np.unique(i // 2)
        i = i[i > 0]
        while i.size:
            t[i] = np.maximum(t[2 * i], t[2 * i + 1])
            i = np.unique(i // 2)
            i = i[i > 0]

    def max(self) -> int:
        return int(self.tree[1])

    def copy(self) -> "_MaxTree":
        new = _MaxTree.__new__(_MaxTree)
        new.n = self.n
        new.tree = self.tree.copy()
        return new


class _PhaseState:
    """One phase's incrementally-maintained arrays and cached aggregates.

    Eager members are exactly what a model-guided search loop queries every
    move (node-aware transport, receive counts, byte totals) plus the integer
    tables the increments ride on.  Everything only the simulator or the
    lower ladder rungs need — the routing expansion, link contention, the
    postal / flat-max-rate rows — is lazy: priced on first query for a
    generation and, for the routing expansion, maintained incrementally from
    then on.  A search that never touches the simulator never routes.
    """

    __slots__ = ("phase", "span", "t_na", "row_na", "recv", "recv_tree",
                 "net_bytes", "total_bytes", "net_sends", "node_active",
                 "proc_nodes", "_exp", "_max_link", "_flag_rows")

    phase: CommPhase          # current bound phase (canonical message order)
    span: int                 # row length: covers n_procs and every src/dst
    t_na: np.ndarray          # node-aware per-message transport times
    row_na: np.ndarray        # node-aware send-side sums per process [span]
    recv: np.ndarray          # per-receiver message counts [span], int64
    recv_tree: _MaxTree       # point-updatable max over ``recv``
    net_bytes: float          # network-class bytes (pairwise .sum() replay)
    total_bytes: float        # all bytes (for node_aware=False net bytes)
    net_sends: np.ndarray     # per-sender count of network messages [span]
    node_active: np.ndarray   # per-node count of active senders
    proc_nodes: np.ndarray    # node of each process [span]
    # lazy: (exp_msg, exp_link) routing expansion | hottest contended bytes |
    # dense rows for the postal / flat-max-rate flag pairs
    _exp: tuple | None
    _max_link: float | None
    _flag_rows: dict

    def row(self, flags) -> np.ndarray:
        """Dense send-side transport sums for one ladder flag pair.

        The node-aware pair rides the incremental path (it depends on the
        point-updated active-sender tables); the postal and flat max-rate
        pairs are pure elementwise functions of the phase arrays, so they
        are priced fresh on first query per generation and cached — same
        bits as a full build, no ``np.unique`` involved either way.
        """
        if flags == _NODE_AWARE:
            return self.row_na
        row = self._flag_rows.get(flags)
        if row is None:
            ph = self.phase
            t = _price(ph.machine.params,
                       (ph.size, ph.loc, ph.proto, ph.is_net, ph.active_ppn),
                       flags)
            row = np.bincount(ph.src, weights=t, minlength=self.span)
            self._flag_rows[flags] = row
        return row

    def exp(self) -> tuple:
        """The (message id, link id) routing expansion, dimension-major.

        Routed fresh on first demand when no ancestor ever materialized it;
        once it exists, :func:`_mutate_state` maintains it incrementally
        (survivors filtered in place, only additions routed).
        """
        if self._exp is None:
            ph = self.phase
            sel = ph.is_net & (ph.torus_src != ph.torus_dst)
            if sel.any():
                sel_idx = np.nonzero(sel)[0]
                midx, link = ph.machine.torus.route_link_ids(
                    ph.torus_src[sel], ph.torus_dst[sel])
                self._exp = (sel_idx[midx], link)
            else:
                z = np.zeros(0, dtype=np.int64)
                self._exp = (z, z.copy())
        return self._exp

    def link_contention(self) -> float:
        """Hottest contended-link bytes (lazy; simulator-side only)."""
        if self._max_link is None:
            ph = self.phase
            exp_msg, exp_link = self.exp()
            self._max_link = _exp_contention(ph.machine.torus, ph.size,
                                             ph.torus_src, exp_msg, exp_link)
        return self._max_link


def _exp_contention(torus, size, torus_src, exp_msg, exp_link) -> float:
    """Hottest contended-link bytes from a stored routing expansion.

    Replays :meth:`CommPhase.link_contention`'s aggregation over the
    ``(message, link)`` rows — provided the rows are in the dimension-major
    order ``route_link_ids`` emits, the per-(link, source) ``bincount``
    accumulates in the identical order and the result is bit-equal.
    """
    if exp_link.size == 0:
        return 0.0
    tsrc = torus_src[exp_msg]
    span = np.int64(max(torus.size, int(tsrc.max()) + 1))
    key = exp_link * span + tsrc
    uk, inv = np.unique(key, return_inverse=True)
    per_src = np.bincount(inv, weights=size[exp_msg])
    pair_link = uk // span
    starts = np.nonzero(np.r_[True, pair_link[1:] != pair_link[:-1]])[0]
    totals = np.add.reduceat(per_src, starts)
    largest = np.maximum.reduceat(per_src, starts)
    return float((totals - largest).max(initial=0.0))


def _price(params, phase_arrays, flags, idx=None):
    """Transport times for one flag pair, on the whole phase or a subset.

    ``phase_arrays`` is ``(size, loc, proto, is_net, active_ppn)``; ``idx``
    restricts the evaluation to the re-priced subset.  Elementwise and
    deterministic, so a subset evaluation equals the same positions of a
    full fresh pass.
    """
    size, loc, proto, is_net, ppn = phase_arrays
    if idx is not None:
        size, loc, proto = size[idx], loc[idx], proto[idx]
        is_net, ppn = is_net[idx], ppn[idx]
    node_aware, use_maxrate = flags
    if node_aware:
        return transport_times(size, params.alpha[loc, proto],
                               params.Rb[loc, proto],
                               params.RN[loc, proto], ppn, is_net,
                               rails=params.n_rails)
    nl = params.network_locality
    alpha = params.alpha[nl][proto]
    Rb = params.Rb[nl][proto]
    if not use_maxrate:
        return transport_times(size, alpha, Rb, None, 1.0, False,
                               use_maxrate=False)
    # the flat max-rate level treats every message as network-class but keeps
    # the machine-classified active-sender counts (mirrors cost_arrays)
    return transport_times(size, alpha, Rb, params.RN[nl][proto], ppn, True,
                           rails=params.n_rails)


def _build_state(ph: CommPhase) -> _PhaseState:
    """Full (non-incremental) state for one bound phase — the generation-0
    cost, paid once per phase like ``PhaseStack.build``."""
    m = ph.machine
    p = m.params
    if getattr(ph, "loc_overridden", False):
        raise ValueError(
            "DeltaStack needs machine-classified phases: a phase built with "
            "an explicit loc override (a staged strategy step) cannot be "
            "mutated consistently — apply() would classify additions with "
            "the machine's locality()")
    span = int(max(ph.n_procs, ph.src.max(initial=-1) + 1,
                   ph.dst.max(initial=-1) + 1, 1))
    st = _PhaseState.__new__(_PhaseState)
    st.phase = ph
    st.span = span
    st.proc_nodes = np.asarray(m.node_of(np.arange(span)), dtype=np.int64)
    st.net_sends = np.bincount(ph.src[ph.is_net], minlength=span)
    n_nodes = int(st.proc_nodes.max(initial=-1)) + 1
    st.node_active = np.bincount(st.proc_nodes[st.net_sends > 0],
                                 minlength=n_nodes)
    arrays = (ph.size, ph.loc, ph.proto, ph.is_net, ph.active_ppn)
    st.t_na = _price(p, arrays, _NODE_AWARE)
    st.row_na = np.bincount(ph.src, weights=st.t_na, minlength=span)
    st.recv = np.bincount(ph.dst, minlength=span)
    st.recv_tree = _MaxTree(st.recv)
    st.net_bytes = float(ph.size[ph.is_net].sum())
    st.total_bytes = float(ph.size.sum())
    st._exp = None
    st._max_link = None
    st._flag_rows = {}
    return st


def _mutate_state(st: _PhaseState, rm_local: np.ndarray,
                  add: tuple | None) -> _PhaseState:
    """Apply one phase's delta: drop ``rm_local``, append ``add`` messages.

    The canonical mutated order — survivors in place, additions at the end —
    is what every replayed reduction runs over, so each cached aggregate
    equals a fresh build of the mutated phase.
    """
    ph = st.phase
    m = ph.machine
    p = m.params
    P = ph.n_procs
    n_old = ph.n_msgs

    if add is not None:
        # typed validation (PatternError is a ValueError, so existing
        # callers catching ValueError keep working): rejects length
        # mismatches, NaN/negative sizes and endpoints outside the phase's
        # fixed process count before any cached aggregate is touched
        from .guard import validate_messages
        validate_messages(np.asarray(add[0]).ravel(),
                          np.asarray(add[1]).ravel(),
                          np.asarray(add[2]).ravel(), n_procs=P,
                          where="DeltaStack.apply(added)")
        src_a = np.asarray(add[0], dtype=np.int64).ravel()
        dst_a = np.asarray(add[1], dtype=np.int64).ravel()
        size_a = np.asarray(add[2], dtype=np.float64).ravel()
    else:
        src_a = dst_a = np.zeros(0, dtype=np.int64)
        size_a = np.zeros(0)
    na = src_a.size

    keep = np.ones(n_old, dtype=bool)
    keep[rm_local] = False
    nkeep = n_old - rm_local.size

    # machine-derived fields: computed for the additions only
    loc_a = np.asarray(m.locality(src_a, dst_a), dtype=np.int64)
    proto_a = p.protocol_of(size_a)
    is_net_a = loc_a >= p.network_locality
    send_node_a = np.asarray(m.node_of(src_a), dtype=np.int64)

    cat = lambda old, new: np.concatenate([old[keep], new])
    src = cat(ph.src, src_a)
    dst = cat(ph.dst, dst_a)
    size = cat(ph.size, size_a)
    loc = cat(ph.loc, loc_a)
    proto = cat(ph.proto, proto_a)
    is_net = cat(ph.is_net, is_net_a)
    send_node = cat(ph.send_node, send_node_a)
    torus_src = cat(ph.torus_src,
                    np.asarray(m.torus_node_of(src_a), dtype=np.int64))
    torus_dst = cat(ph.torus_dst,
                    np.asarray(m.torus_node_of(dst_a), dtype=np.int64))

    out = _PhaseState.__new__(_PhaseState)
    out.span = st.span
    out.proc_nodes = st.proc_nodes

    # -- active-sender tables: integer point updates --------------------------
    rm_net_src = ph.src[rm_local][ph.is_net[rm_local]]
    net_sends = st.net_sends.copy()
    np.subtract.at(net_sends, rm_net_src, 1)
    np.add.at(net_sends, src_a[is_net_a], 1)
    touched = np.unique(np.concatenate([rm_net_src, src_a[is_net_a]]))
    was = st.net_sends[touched] > 0
    now = net_sends[touched] > 0
    node_active = st.node_active
    if (was != now).any():
        node_active = node_active.copy()
        np.add.at(node_active, st.proc_nodes[touched[now & ~was]], 1)
        np.subtract.at(node_active, st.proc_nodes[touched[was & ~now]], 1)
    changed_nodes = np.nonzero(node_active != st.node_active)[0]
    out.net_sends = net_sends
    out.node_active = node_active

    # -- active_ppn: lookup for additions + nodes whose count changed ---------
    active_ppn = np.concatenate([ph.active_ppn[keep], np.zeros(na)])
    active_ppn[nkeep:] = np.where(is_net_a, node_active[send_node_a], 1.0)
    if changed_nodes.size:
        nc = np.zeros(node_active.size, dtype=bool)
        nc[changed_nodes] = True
        aff = np.nonzero(is_net[:nkeep] & nc[send_node[:nkeep]])[0]
        active_ppn[aff] = node_active[send_node[aff]]
    else:
        aff = np.zeros(0, dtype=np.int64)

    out.phase = CommPhase(
        machine=m, src=src, dst=dst, size=size, n_procs=P, loc=loc,
        proto=proto, is_net=is_net, send_node=send_node,
        torus_src=torus_src, torus_dst=torus_dst, active_ppn=active_ppn)

    # -- node-aware transport times: re-price only what a fresh build would
    #    price differently (additions + ppn-affected network messages) --------
    arrays = (size, loc, proto, is_net, active_ppn)
    ppn_idx = np.concatenate([aff, np.arange(nkeep, nkeep + na)])
    t_na = np.concatenate([st.t_na[keep], np.zeros(na)])
    if ppn_idx.size:
        t_na[ppn_idx] = _price(p, arrays, _NODE_AWARE, ppn_idx)
    out.t_na = t_na
    out.row_na = np.bincount(src, weights=t_na, minlength=st.span)
    out._flag_rows = {}

    # -- receive counts: point updates + max-tree maintenance -----------------
    recv = st.recv.copy()
    np.subtract.at(recv, ph.dst[rm_local], 1)
    np.add.at(recv, dst_a, 1)
    tree = st.recv_tree.copy()
    touched_dst = np.unique(np.concatenate([ph.dst[rm_local], dst_a]))
    tree.update_many(touched_dst, recv[touched_dst])
    out.recv = recv
    out.recv_tree = tree

    # -- byte totals: pairwise-summation replay (order-sensitive) -------------
    out.net_bytes = float(size[is_net].sum())
    out.total_bytes = float(size.sum())

    # -- routing: once materialized, filter surviving expansion rows and
    #    route additions only; contention itself stays lazy ------------------
    if st._exp is None:
        out._exp = None                  # never queried: stay lazy
    else:
        old_msg, old_link = st._exp
        keep_exp = keep[old_msg]
        remap = np.cumsum(keep) - 1                   # old local -> new local
        exp_msg = remap[old_msg[keep_exp]]
        exp_link = old_link[keep_exp]
        sel_a = is_net_a & (torus_src[nkeep:] != torus_dst[nkeep:])
        if sel_a.any():
            sidx = nkeep + np.nonzero(sel_a)[0]
            midx, link = m.torus.route_link_ids(torus_src[sidx],
                                                torus_dst[sidx])
            exp_msg = np.concatenate([exp_msg, sidx[midx]])
            exp_link = np.concatenate([exp_link, link])
            # restore the dimension-major emission order of a fresh
            # route_link_ids call; the sort is stable, so the per-(dim,
            # message) hop order survives and the per-(link, source)
            # histogram replay stays bit-identical
            order = np.lexsort((exp_msg, exp_link % m.torus.ndim))
            exp_msg, exp_link = exp_msg[order], exp_link[order]
        out._exp = (exp_msg, exp_link)
    out._max_link = None
    return out


class DeltaStack:
    """A sweep arena that prices *mutations* at O(changed) cost.

    Construction (``from_phases``) pays the same one-time cost as
    ``PhaseStack.build``; every subsequent :meth:`apply` touches only the
    phases named by the delta.  ``apply`` is functional: it returns a new
    ``DeltaStack`` sharing every clean phase's state with its parent, so a
    rejected local-search candidate is discarded by dropping the object —
    no undo log.  The query surface mirrors :class:`~repro.comm.PhaseStack`
    (``cost_arrays`` / ``sim_arrays`` / ``phases`` / ``n_procs``), and the
    batched entry points accept either.
    """

    def __init__(self, machine, states: tuple, verify: bool = False):
        self.machine = machine
        self._states = states
        self.verify = bool(verify)
        self.phases = tuple(st.phase for st in states)
        counts = np.asarray([ph.n_msgs for ph in self.phases], dtype=np.int64)
        self.offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.n_procs = np.asarray([ph.n_procs for ph in self.phases],
                                  dtype=np.int64)
        self._fresh_cache = None

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_phases(cls, phases, *, verify: bool = False) -> "DeltaStack":
        """Bind a sweep ``phases`` (bound ``CommPhase``s or a ``PhaseStack``)
        as a delta arena.  Same-machine validation matches
        ``PhaseStack.build``; ``verify=True`` re-checks the bit-identity
        contract after construction and every ``apply``."""
        if isinstance(phases, PhaseStack):
            phases = phases.phases
        phases = tuple(phases)
        for ph in phases:
            if not isinstance(ph, CommPhase):
                raise TypeError(
                    f"DeltaStack wraps bound CommPhases, got {type(ph).__name__}")
        machine = phases[0].machine if phases else None
        for ph in phases:
            if ph.machine is not machine:
                raise ValueError(
                    "mixed machines: every phase in a DeltaStack must be "
                    "bound to the same machine object (rebind with "
                    "CommPhase.build / CommPattern.bind first)")
        out = cls(machine, tuple(_build_state(ph) for ph in phases),
                  verify=verify)
        if verify:
            out.check()
        return out

    # -- basic stats ----------------------------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self._states)

    @property
    def total_msgs(self) -> int:
        return int(self.offsets[-1]) if self.offsets.size else 0

    def __len__(self) -> int:
        return self.n_phases

    def __iter__(self):
        return iter(self.phases)

    def fingerprint(self) -> str:
        """Content-hash of the arena's current phases, as a hex string.

        SHA-256 over the per-phase :func:`phase_fingerprint` digests in
        phase order — so a ``DeltaStack`` and a fresh arena over the same
        phases (same message order) hash identically, and any ``apply``
        changes the fingerprint.  This is the cache key the strategy
        service's :class:`repro.serve.ArenaCache` stores priced verdicts
        under.
        """
        h = hashlib.sha256()
        h.update(b"repro.delta.v1")
        for ph in self.phases:
            h.update(bytes.fromhex(
                phase_fingerprint(ph.src, ph.dst, ph.size, ph.n_procs)))
        return h.hexdigest()

    # -- mutation -------------------------------------------------------------
    def apply(self, removed_idx=None, added=None, *,
              verify: bool | None = None) -> "DeltaStack":
        """One delta step: drop messages, append messages, re-price.

        Parameters
        ----------
        removed_idx : arena indices (into the current concatenated message
            order, ``offsets[p] + local``) of messages to remove.  Must be
            unique and in range.
        added : ``{phase_index: (src, dst, size)}`` mapping (or a sequence
            with one entry — possibly None — per phase).  Added endpoints
            must lie inside the phase's fixed process count.
        verify : override the stack's debug flag for this step.

        Returns a new ``DeltaStack``; phases outside the delta share state
        with ``self``.  An empty delta returns an equal-valued stack.
        """
        verify = self.verify if verify is None else bool(verify)
        rm = (np.zeros(0, dtype=np.int64) if removed_idx is None
              else np.asarray(removed_idx, dtype=np.int64).ravel())
        if rm.size:
            uniq = np.unique(rm)
            if uniq.size != rm.size:
                raise ValueError("removed_idx contains duplicate indices")
            rm = uniq
            if rm[0] < 0 or rm[-1] >= self.total_msgs:
                raise ValueError(
                    f"removed_idx out of range for an arena of "
                    f"{self.total_msgs} messages")
        if added is None:
            added = {}
        elif not isinstance(added, dict):
            added = {i: a for i, a in enumerate(added) if a is not None}
        added = {int(k): v for k, v in added.items()}
        for k in added:
            if not 0 <= k < self.n_phases:
                raise ValueError(
                    f"added phase index {k} out of range for "
                    f"{self.n_phases} phases")
        pid = np.searchsorted(self.offsets, rm, side="right") - 1
        local = rm - self.offsets[pid]
        dirty = sorted(set(pid.tolist()) | {int(k) for k, v in added.items()
                                            if np.asarray(v[0]).size})
        states = list(self._states)
        for i in dirty:
            states[i] = _mutate_state(self._states[i], local[pid == i],
                                      added.get(i))
        out = DeltaStack(self.machine, tuple(states), verify=verify)
        if verify:
            out.check()
        return out

    # -- fallback arena -------------------------------------------------------
    def _fresh(self) -> PhaseStack:
        """A fresh ``PhaseStack`` over the current phases — the delegate for
        fitted-params overrides and non-numpy backends, and the reference
        :meth:`check` compares against.  Built once per generation."""
        if self._fresh_cache is None:
            self._fresh_cache = PhaseStack.build(self.phases)
        return self._fresh_cache

    # -- model-side aggregates ------------------------------------------------
    def cost_arrays(self, params=None, *, node_aware: bool = True,
                    use_maxrate: bool = True, with_queue: bool = True,
                    with_net_bytes: bool = True, backend=None):
        """Per-phase ``(transport, max_recv, net_bytes)`` from the delta
        caches — same contract (and same ``params`` / ``node_aware`` /
        ``use_maxrate`` / ``with_queue`` / ``with_net_bytes`` / ``backend``
        arguments) as :meth:`PhaseStack.cost_arrays`.

        The fast path serves the machine's own parameter tables on the numpy
        backend; a fitted-params override or an accelerator backend
        delegates to a fresh arena over the current phases (built once per
        generation), so results stay correct either way.
        """
        backend_name, mod = PhaseStack._backend(backend)  # eager validation
        if backend_name == "auto":
            # resolve the autotuned default here so auto -> numpy keeps the
            # O(changed) delta fast path (auto -> jax delegates, correctly)
            backend_name = mod.resolve_backend("auto",
                                               n_values=self.total_msgs)
        N = self.n_phases
        zeros = np.zeros(N)
        if N == 0 or self.total_msgs == 0:
            return zeros, zeros.copy(), zeros.copy()
        m = self.machine
        p = params if params is not None else m.params
        flags = (node_aware, use_maxrate)
        if p is not m.params or backend_name != "numpy" or flags not in _FLAGS:
            return self._fresh().cost_arrays(
                params, node_aware=node_aware, use_maxrate=use_maxrate,
                with_queue=with_queue, with_net_bytes=with_net_bytes,
                backend=backend)
        transport = np.asarray([st.row(flags).max(initial=0.0)
                                for st in self._states], dtype=np.float64)
        max_recv = (np.asarray([st.recv_tree.max() for st in self._states],
                               dtype=np.float64)
                    if with_queue else zeros.copy())
        if not with_net_bytes:
            net_bytes = zeros.copy()
        elif node_aware:
            net_bytes = np.asarray([st.net_bytes for st in self._states])
        else:                       # every message priced as network-class
            net_bytes = np.asarray([st.total_bytes for st in self._states])
        return transport, max_recv, net_bytes

    # -- simulator-side aggregates --------------------------------------------
    def sim_arrays(self, recv_post_orders=None, arrival_orders=None,
                   backend=None) -> StackSimArrays:
        """Raw simulator aggregates — same contract (and same
        ``recv_post_orders`` / ``arrival_orders`` / ``backend`` arguments)
        as :meth:`PhaseStack.sim_arrays`.  Transport and link contention
        come from the delta caches; default-order queue steps are the
        maintained receive counts, custom orders pay the per-phase Fenwick
        walk.
        """
        backend_name, mod = PhaseStack._backend(backend)
        if backend_name == "auto":
            backend_name = mod.resolve_backend("auto",
                                               n_values=self.total_msgs)
        if backend_name != "numpy":
            return self._fresh().sim_arrays(recv_post_orders, arrival_orders,
                                            backend=backend_name)
        if self.n_phases == 0:
            z = np.zeros(0)
            return StackSimArrays(z, [], [], z.copy(), z.copy())
        empty_f = np.zeros(0)
        empty_i = np.zeros(0, dtype=np.int64)
        per_proc, qsteps = [], []
        default_orders = recv_post_orders is None and arrival_orders is None
        for i, st in enumerate(self._states):
            ph = st.phase
            if ph.n_msgs == 0:
                per_proc.append(empty_f)
                qsteps.append(empty_i)
                continue
            per_proc.append(st.row_na[:ph.n_procs].copy())
            if default_orders:
                qsteps.append(st.recv[:ph.n_procs].copy())
            else:
                qsteps.append(ph.queue_steps(
                    recv_post_orders[i] if recv_post_orders else None,
                    arrival_orders[i] if arrival_orders else None))
        transport = np.asarray([st.row_na.max(initial=0.0)
                                for st in self._states], dtype=np.float64)
        return StackSimArrays(
            transport=transport, per_proc=per_proc, qsteps=qsteps,
            max_link=np.asarray([st.link_contention()
                                 for st in self._states]),
            net_bytes=np.asarray([st.net_bytes for st in self._states]))

    # -- the debug contract ---------------------------------------------------
    def check(self) -> None:
        """Assert bit-identity against a freshly built arena.

        Three layers: the mutated phases' cached per-message fields must
        equal ``CommPhase.build`` from their raw arrays; every ladder flag
        pair's ``cost_arrays`` must equal the fresh stack's; and the
        default-order ``sim_arrays`` must match field for field.  Raises
        ``AssertionError`` on the first divergence.
        """
        for i, ph in enumerate(self.phases):
            rb = CommPhase.build(ph.machine, ph.src, ph.dst, ph.size,
                                 n_procs=ph.n_procs)
            for f in ("loc", "proto", "is_net", "send_node", "torus_src",
                      "torus_dst", "active_ppn"):
                assert np.array_equal(getattr(ph, f), getattr(rb, f)), \
                    f"phase {i}: cached {f} drifted from a fresh build"
        fresh = PhaseStack.build(self.phases)
        for flags in _FLAGS:
            got = self.cost_arrays(node_aware=flags[0], use_maxrate=flags[1])
            want = fresh.cost_arrays(node_aware=flags[0],
                                     use_maxrate=flags[1])
            for g, w, name in zip(got, want,
                                  ("transport", "max_recv", "net_bytes")):
                assert np.array_equal(g, w), \
                    f"cost_arrays{flags} {name} drifted from a fresh build"
        got = self.sim_arrays()
        want = fresh.sim_arrays()
        assert np.array_equal(got.transport, want.transport)
        assert np.array_equal(got.max_link, want.max_link)
        assert np.array_equal(got.net_bytes, want.net_bytes)
        for g, w in zip(got.per_proc, want.per_proc):
            assert np.array_equal(g, w), "per-proc transport drifted"
        for g, w in zip(got.qsteps, want.qsteps):
            assert np.array_equal(g, w), "queue steps drifted"
        self._fresh_cache = fresh


#: The arena types the batched entry points price straight from cached
#: aggregates (both expose the cost_arrays / sim_arrays query surface).
#: Import this instead of spelling the pair out so a future arena type has
#: one edit point.
ARENA_TYPES = (PhaseStack, DeltaStack)
