"""Typed input validation for communication patterns (the ``PatternError``
hierarchy).

A long-lived strategy service cannot afford to price garbage: a NaN-sized
message silently poisons every float aggregate downstream, a negative rank
indexes the wrong ``bincount`` bin, and an arena whose packed keys exceed
``int32`` crashes the device backends mid-sweep.  This module rejects all
of them *before* they reach the kernels, with precise, typed errors:

* :class:`PatternError` — base class, a ``ValueError`` (so existing
  callers that catch ``ValueError`` keep working);
* :class:`MessageSizeError` — NaN / infinite / negative message sizes;
* :class:`RankError` — negative or out-of-range endpoint ranks, bad
  process counts;
* :class:`ArenaOverflowError` — arenas whose ranks or packed keys exceed
  the device backends' ``int32`` index range (the numpy path still prices
  them — this error doubles as the typed signal the degradation policy in
  :class:`repro.comm.PhaseStack` catches to fall back).

Entry points: :func:`validate_messages` (one message set),
:func:`validate_phase` (a built phase/pattern, duck-typed).  Wired into
:meth:`repro.comm.CommPhase.build` (``validate=True``),
:meth:`repro.sparse.CommPattern.validate`, the workload derivers in
:mod:`repro.workloads`, and :class:`repro.serve.StrategyService` (which
validates every query by default).  Validation is O(messages) numpy work —
a few vectorized reductions, no Python loops.

See DESIGN.md §12 for where validation sits in the failure-handling
contract.
"""
from __future__ import annotations

import numpy as np

__all__ = ["PatternError", "MessageSizeError", "RankError",
           "ArenaOverflowError", "validate_messages", "validate_phase",
           "INT32_MAX"]

#: The device backends' index ceiling: ranks and packed keys beyond this
#: cannot ship as int32 arena columns (numpy still prices them).
INT32_MAX = np.iinfo(np.int32).max


class PatternError(ValueError):
    """Base class for typed communication-pattern validation errors."""


class MessageSizeError(PatternError):
    """A message size is NaN, infinite, or negative."""


class RankError(PatternError):
    """An endpoint rank is negative, non-integral, or out of range."""


class ArenaOverflowError(PatternError):
    """Ranks or packed keys exceed the device backends' int32 range."""


def _first_bad(mask: np.ndarray) -> int:
    """Index of the first True element (callers guarantee one exists)."""
    return int(np.argmax(mask))


def validate_messages(src, dst, size, n_procs: int | None = None, *,
                      where: str = "pattern") -> None:
    """Validate one message set ``(src, dst, size)``; raise a typed error.

    Checks, in order (first violation raises, naming the offending index
    and value):

    * ``src`` / ``dst`` / ``size`` are one-dimensional and equal-length
      (:class:`PatternError`);
    * endpoint ranks are integral, non-negative, and — when ``n_procs`` is
      given — below it (:class:`RankError`);
    * ``n_procs``, when given, is a positive integer (:class:`RankError`);
    * sizes are finite and non-negative: NaN, ``inf`` and negative byte
      counts all raise (:class:`MessageSizeError`);
    * ranks fit the device backends' int32 index range
      (:class:`ArenaOverflowError` — numpy-only arenas this large still
      price, but only via ``backend='numpy'`` or the degradation fallback).

    ``where`` labels the message set in error text (e.g. a scenario name).
    An empty message set is valid.  O(messages), fully vectorized.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    size = np.asarray(size)
    if src.ndim != 1 or dst.ndim != 1 or size.ndim != 1:
        raise PatternError(
            f"{where}: src/dst/size must be one-dimensional arrays, got "
            f"shapes {src.shape}/{dst.shape}/{size.shape}")
    if not (src.shape == dst.shape == size.shape):
        raise PatternError(
            f"{where}: src/dst/size lengths differ "
            f"({src.size}/{dst.size}/{size.size})")
    if n_procs is not None:
        n_procs = int(n_procs)
        if n_procs < 1:
            raise RankError(f"{where}: n_procs must be >= 1, got {n_procs}")
    for name, ranks in (("src", src), ("dst", dst)):
        if ranks.size == 0:
            continue
        if not np.issubdtype(ranks.dtype, np.integer):
            f = np.asarray(ranks, dtype=np.float64)
            if not np.isfinite(f).all() or (f != np.trunc(f)).any():
                bad = _first_bad(~np.isfinite(f) | (f != np.trunc(f)))
                raise RankError(
                    f"{where}: {name}[{bad}] = {ranks[bad]!r} is not an "
                    "integral rank")
            ranks = f.astype(np.int64)
        lo, hi = int(ranks.min()), int(ranks.max())
        if lo < 0:
            bad = _first_bad(ranks < 0)
            raise RankError(
                f"{where}: {name}[{bad}] = {ranks[bad]} is negative")
        if n_procs is not None and hi >= n_procs:
            bad = _first_bad(ranks >= n_procs)
            raise RankError(
                f"{where}: {name}[{bad}] = {ranks[bad]} is out of range for "
                f"n_procs = {n_procs}")
        if hi > INT32_MAX:
            raise ArenaOverflowError(
                f"{where}: {name} reaches {hi}, beyond the device backends' "
                f"int32 range (max {INT32_MAX}); such arenas price on the "
                "numpy backend only")
    if size.size:
        sz = np.asarray(size, dtype=np.float64)
        bad_mask = ~np.isfinite(sz)
        if bad_mask.any():
            bad = _first_bad(bad_mask)
            raise MessageSizeError(
                f"{where}: size[{bad}] = {sz[bad]} is not finite")
        if (sz < 0).any():
            bad = _first_bad(sz < 0)
            raise MessageSizeError(
                f"{where}: size[{bad}] = {sz[bad]} is negative")


def validate_phase(phase, *, where: str | None = None) -> None:
    """Validate a built pattern/phase (anything with ``src`` / ``dst`` /
    ``size`` and optionally ``n_procs`` — a :class:`repro.sparse.CommPattern`
    or a bound :class:`repro.comm.CommPhase`).

    ``where`` labels the object in error text (default: its class name).
    Delegates to :func:`validate_messages`.
    """
    if where is None:
        where = type(phase).__name__
    validate_messages(phase.src, phase.dst, phase.size,
                      n_procs=getattr(phase, "n_procs", None), where=where)
