"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6, first
layer dense [arXiv:2401.06066; hf]."""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "deepseek-moe-16b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944,                      # dense first layer FFN
        vocab_size=102400,
        d_head=128, rope_theta=10000.0,
        n_experts=64, n_experts_active=6, n_shared_experts=2,
        moe_d_ff=1408, first_dense_layers=1,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=3, d_model=64, n_heads=4,
                               n_kv_heads=4, d_head=16, d_ff=128,
                               vocab_size=256, n_experts=8,
                               n_experts_active=2, n_shared_experts=1,
                               moe_d_ff=32, first_dense_layers=1)
