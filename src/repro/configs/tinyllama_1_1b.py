"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385; hf]."""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "tinyllama-1.1b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=5632, vocab_size=32000,
        d_head=64, rope_theta=10000.0,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_head=16, d_ff=128,
                               vocab_size=256)
