"""qwen2-vl-72b — VLM backbone with M-RoPE [arXiv:2409.12191; hf].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, S, d_model] and M-RoPE position ids.
"""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab_size=152064,
        d_head=128, rope_theta=1000000.0, m_rope=True,
        frontend="patch_embed",
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_head=16, d_ff=128,
                               vocab_size=256)
