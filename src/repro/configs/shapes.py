"""Assigned input shapes and per-cell applicability.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention: it runs for
ssm/hybrid families and is skipped (with a reason) for pure full-attention
architectures — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses

from repro.nn.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 512k-token decode needs a "
                       "sub-quadratic mixer; runs only for ssm/hybrid "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""


def all_cells(configs: dict[str, ArchConfig]) -> list[tuple[str, str, bool, str]]:
    """Every (arch, shape) pair with applicability flags — 40 cells."""
    out = []
    for arch, cfg in configs.items():
        for sname, sp in SHAPES.items():
            ok, why = cell_applicable(cfg, sp)
            out.append((arch, sname, ok, why))
    return out
