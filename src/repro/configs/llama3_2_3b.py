"""llama3.2-3b — dense llama3-family [hf:meta-llama/Llama-3.2-1B; unverified]."""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "llama3.2-3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab_size=128256,
        d_head=128, rope_theta=500000.0, tie_embeddings=True,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_head=16, d_ff=128,
                               vocab_size=256)
