"""hymba-1.5b — hybrid: parallel attention + mamba heads [arXiv:2411.13676; hf]."""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "hymba-1.5b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        d_head=64, rope_theta=10000.0,
        ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_head=16, d_ff=128,
                               vocab_size=256, ssm_state=8, ssm_head_dim=16,
                               ssm_chunk=16)
