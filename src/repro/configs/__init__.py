"""Architecture registry: the 10 assigned configs + the paper's AMG problem."""
from __future__ import annotations

import importlib

from repro.nn.config import ArchConfig

_MODULES = {
    "llama3.2-3b": "llama3_2_3b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-32b": "qwen3_32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-130m": "mamba2_130m",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "whisper-small": "whisper_small",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ArchConfig:
    return _mod(arch).config()


def get_smoke_config(arch: str) -> ArchConfig:
    return _mod(arch).smoke_config()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


from .shapes import SHAPES, ShapeSpec, cell_applicable, all_cells  # noqa: E402

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "all_configs",
           "SHAPES", "ShapeSpec", "cell_applicable", "all_cells"]
