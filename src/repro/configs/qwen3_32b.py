"""qwen3-32b — dense, qk-norm + GQA [hf:Qwen/Qwen3-8B; hf]."""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "qwen3-32b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        d_ff=25600, vocab_size=151936,
        d_head=128, rope_theta=1000000.0, qk_norm=True,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_head=16, d_ff=128,
                               vocab_size=256)
