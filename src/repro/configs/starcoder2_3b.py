"""starcoder2-3b — GQA + RoPE code model [arXiv:2402.19173; hf].

StarCoder2 uses a gelu MLP (not SwiGLU) and LayerNorm.
"""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "starcoder2-3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        d_ff=12288, vocab_size=49152,
        d_head=128, rope_theta=999999.4, mlp_type="gelu",
        norm_type="layernorm", norm_eps=1e-5,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_head=16, d_ff=128,
                               vocab_size=256)
