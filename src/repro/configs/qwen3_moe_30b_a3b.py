"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "qwen3-moe-30b-a3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=0,                          # no dense MLP on MoE layers
        vocab_size=151936,
        d_head=128, rope_theta=1000000.0, qk_norm=True,
        n_experts=128, n_experts_active=8, moe_d_ff=768,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_head=16, vocab_size=256,
                               n_experts=8, n_experts_active=2, moe_d_ff=32)
