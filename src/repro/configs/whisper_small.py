"""whisper-small — enc-dec audio backbone [arXiv:2212.04356; unverified].

The conv frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, encoder_seq, d_model].  Deviation from the original: RoPE
replaces learned/sinusoidal positions (noted in DESIGN.md).
"""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "whisper-small"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        d_head=64, rope_theta=10000.0, mlp_type="gelu",
        norm_type="layernorm", norm_eps=1e-5,
        encoder_layers=12, encoder_seq=1500, cross_attention=True,
        frontend="audio_conv", tie_embeddings=True,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_head=16, d_ff=128,
                               vocab_size=256, encoder_layers=2,
                               encoder_seq=30)
