"""mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
import dataclasses
from repro.nn.config import ArchConfig

ARCH_ID = "mamba2-130m"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        rope_theta=0.0, tie_embeddings=True,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64,
                               vocab_size=256, ssm_state=16, ssm_head_dim=16,
                               ssm_chunk=16)
