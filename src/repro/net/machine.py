"""Machine descriptions: process -> node -> torus-node maps and ground truth.

Blue Waters: 3-D Gemini torus; each Gemini serves 2 XE nodes; each node has
2 sockets x 8 cores = 16 ppn — the torus unit (Gemini) *contains* nodes.

TPU v5e: 2-D ICI torus of chips, one "process" per chip, 4 chips per host —
the torus unit (chip) is *contained in* the node (host).  ``torus_over_procs``
switches between the two nestings.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.params import CommParams, blue_waters, tpu_v5e
from repro.core.topology import TorusTopology


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    name: str
    params: CommParams            # ground-truth parameters for the simulator
    torus: TorusTopology          # torus of torus-units (Geminis / chips)
    nodes_per_torus_node: int     # BW: 2 nodes per Gemini; TPU: n/a (set 1)
    procs_per_node: int           # BW: 16 ppn; TPU: 4 chips(procs) per host
    sockets_per_node: int
    link_bw: float                # per-torus-link bandwidth (bytes/s)
    torus_over_procs: bool = False  # TPU: each proc(chip) is its own torus node
    cross_node_locality: int = 2    # locality class for cross-node traffic

    @property
    def procs_per_torus_node(self) -> int:
        if self.torus_over_procs:
            return 1
        return self.nodes_per_torus_node * self.procs_per_node

    @property
    def n_procs(self) -> int:
        return self.torus.size * self.procs_per_torus_node

    # -- maps ---------------------------------------------------------------
    def node_of(self, p) -> np.ndarray:
        return np.asarray(p) // self.procs_per_node

    def socket_of(self, p) -> np.ndarray:
        p = np.asarray(p)
        per_socket = max(1, self.procs_per_node // self.sockets_per_node)
        return (p % self.procs_per_node) // per_socket

    def torus_node_of(self, p) -> np.ndarray:
        if self.torus_over_procs:
            return np.asarray(p)
        return self.node_of(p) // self.nodes_per_torus_node

    def locality(self, a, b) -> np.ndarray:
        """Locality class index per (a, b) pair (vectorized).

        Blue Waters: 0 = intra-socket, 1 = intra-node, 2 = inter-node.
        TPU v5e:     0 = intra-host,  1 = intra-pod ICI (cross-host).
        """
        a, b = np.asarray(a), np.asarray(b)
        same_node = self.node_of(a) == self.node_of(b)
        if self.sockets_per_node > 1:
            same_socket = same_node & (self.socket_of(a) == self.socket_of(b))
            mid = np.where(same_node, 1, self.cross_node_locality)
            return np.where(same_socket, 0, mid).astype(np.int64)
        return np.where(same_node, 0, self.cross_node_locality).astype(np.int64)

    def procs_of_node(self, node: int) -> np.ndarray:
        base = node * self.procs_per_node
        return np.arange(base, base + self.procs_per_node)


def blue_waters_machine(torus_dims: tuple[int, ...] = (4, 4, 4),
                        wrap: bool = False) -> MachineSpec:
    """A partition of Blue Waters' Gemini torus.

    ``wrap=False`` because a job partition inside the full torus does not
    wrap.  Gemini link bandwidth ~9.4 GB/s per direction.
    """
    return MachineSpec(
        name="blue_waters",
        params=blue_waters(),
        torus=TorusTopology(torus_dims, wrap=wrap),
        nodes_per_torus_node=2,
        procs_per_node=16,
        sockets_per_node=2,
        link_bw=9.4e9,
    )


def tpu_v5e_machine(torus_dims: tuple[int, int] = (16, 16)) -> MachineSpec:
    """One TPU v5e pod: 2-D ICI torus of chips, 4 chips per host.

    One process per chip; the "node" is the host (4 chips).  Locality 0 =
    intra-host, 1 = intra-pod ICI.  Inter-pod DCN (class 2) only appears in
    multi-pod model evaluation via :mod:`repro.core.decompose`, never in the
    single-pod simulator.
    """
    return MachineSpec(
        name="tpu_v5e",
        params=tpu_v5e(),
        torus=TorusTopology(torus_dims, wrap=True),
        nodes_per_torus_node=1,
        procs_per_node=4,         # chips per host
        sockets_per_node=1,
        link_bw=50e9,
        torus_over_procs=True,
        cross_node_locality=1,
    )
