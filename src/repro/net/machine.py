"""Machine descriptions: process -> device -> node -> torus-node maps.

Blue Waters: 3-D Gemini torus; each Gemini serves 2 XE nodes; each node has
2 sockets x 8 cores = 16 ppn — the torus unit (Gemini) *contains* nodes.

TPU v5e: 2-D ICI torus of chips, one "process" per chip, 4 chips per host —
the torus unit (chip) is *contained in* the node (host).  ``torus_over_procs``
switches between the two nestings.

Heterogeneous nodes (Lockhart et al. 2022): each node holds
``devices_per_node`` GPUs with ``procs_per_device`` ranks each, and an
inter-node pair can take one of two network paths — staged through host
memory and the host NIC (``host_staged``) or GPU-NIC direct
(``device_direct``).  ``locality`` classifies pairs as intra-device /
intra-node-cross-device / the machine's configured network path;
the staged classes (``h2d`` copies, the non-default path) are assigned by
the GPU-aware strategy rewrites via explicit class overrides.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.params import (CommParams, blue_waters, frontier, lassen,
                               tpu_v5e)
from repro.core.topology import TorusTopology


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """One machine: parameter tables plus the process/node/torus geometry.

    Attributes
    ----------
    name: preset name (``blue_waters`` / ``tpu_v5e`` / ``lassen`` / ...).
    params: ground-truth :class:`~repro.core.params.CommParams` rate table
        for the simulator (models may substitute a fitted table).
    torus: torus of torus-units (Geminis / chips / nodes).
    nodes_per_torus_node: nodes sharing one torus unit (Blue Waters: 2 per
        Gemini; 1 elsewhere).
    procs_per_node: processes (MPI ranks) per node.
    sockets_per_node: CPU sockets per node (drives the homogeneous-node
        intra-socket locality split; ignored when devices are present).
    link_bw: per-torus-link bandwidth (bytes/s).
    torus_over_procs: TPU nesting — each proc (chip) is its own torus node.
    cross_node_locality: locality class assigned to cross-node pairs — the
        machine's *default network path* (a hetero machine points it at
        ``host_staged`` or ``device_direct``).
    devices_per_node: GPU/GCD devices per node (0 = homogeneous CPU node).
    procs_per_device: ranks sharing one device (hetero machines only; must
        satisfy ``procs_per_node == devices_per_node * procs_per_device``).
    """

    name: str
    params: CommParams            # ground-truth parameters for the simulator
    torus: TorusTopology          # torus of torus-units (Geminis / chips)
    nodes_per_torus_node: int     # BW: 2 nodes per Gemini; TPU: n/a (set 1)
    procs_per_node: int           # BW: 16 ppn; TPU: 4 chips(procs) per host
    sockets_per_node: int
    link_bw: float                # per-torus-link bandwidth (bytes/s)
    torus_over_procs: bool = False  # TPU: each proc(chip) is its own torus node
    cross_node_locality: int = 2    # locality class for cross-node traffic
    devices_per_node: int = 0       # 0 = homogeneous (no device endpoints)
    procs_per_device: int = 0

    def __post_init__(self):
        if self.devices_per_node:
            if self.procs_per_device <= 0:
                raise ValueError(
                    "a heterogeneous machine needs procs_per_device >= 1")
            if self.procs_per_node != (self.devices_per_node
                                       * self.procs_per_device):
                raise ValueError(
                    f"procs_per_node ({self.procs_per_node}) must equal "
                    f"devices_per_node * procs_per_device "
                    f"({self.devices_per_node} * {self.procs_per_device})")

    @property
    def procs_per_torus_node(self) -> int:
        if self.torus_over_procs:
            return 1
        return self.nodes_per_torus_node * self.procs_per_node

    @property
    def n_procs(self) -> int:
        return self.torus.size * self.procs_per_torus_node

    # -- maps ---------------------------------------------------------------
    def node_of(self, p) -> np.ndarray:
        """Node hosting process ``p`` (vectorized)."""
        return np.asarray(p) // self.procs_per_node

    def socket_of(self, p) -> np.ndarray:
        """Socket of process ``p`` within its node (vectorized)."""
        p = np.asarray(p)
        per_socket = max(1, self.procs_per_node // self.sockets_per_node)
        return (p % self.procs_per_node) // per_socket

    def device_of(self, p) -> np.ndarray:
        """Global device id hosting process ``p`` (hetero machines only)."""
        if not self.devices_per_node:
            raise ValueError(f"{self.name} has no device endpoints")
        return np.asarray(p) // self.procs_per_device

    def torus_node_of(self, p) -> np.ndarray:
        """Torus unit (Gemini / chip / node) hosting process ``p``."""
        if self.torus_over_procs:
            return np.asarray(p)
        return self.node_of(p) // self.nodes_per_torus_node

    def locality(self, a, b) -> np.ndarray:
        """Locality class index per ``(a, b)`` process pair (vectorized).

        Blue Waters: 0 = intra-socket, 1 = intra-node, 2 = inter-node.
        TPU v5e:     0 = intra-host,  1 = intra-pod ICI (cross-host).
        Hetero (Lassen/Frontier-like): 0 = intra-device, 1 = intra-node
        cross-device, and cross-node pairs take the machine's configured
        network path (``cross_node_locality`` -> ``host_staged`` or
        ``device_direct``); the staged classes only appear via explicit
        overrides in the strategy rewrites.
        """
        a, b = np.asarray(a), np.asarray(b)
        same_node = self.node_of(a) == self.node_of(b)
        if self.devices_per_node:
            same_dev = same_node & (self.device_of(a) == self.device_of(b))
            mid = np.where(same_node, 1, self.cross_node_locality)
            return np.where(same_dev, 0, mid).astype(np.int64)
        if self.sockets_per_node > 1:
            same_socket = same_node & (self.socket_of(a) == self.socket_of(b))
            mid = np.where(same_node, 1, self.cross_node_locality)
            return np.where(same_socket, 0, mid).astype(np.int64)
        return np.where(same_node, 0, self.cross_node_locality).astype(np.int64)

    def procs_of_node(self, node: int) -> np.ndarray:
        """Process ids hosted by ``node``."""
        base = node * self.procs_per_node
        return np.arange(base, base + self.procs_per_node)


def blue_waters_machine(torus_dims: tuple[int, ...] = (4, 4, 4),
                        wrap: bool = False) -> MachineSpec:
    """A ``torus_dims`` partition of Blue Waters' Gemini torus.

    ``wrap=False`` because a job partition inside the full torus does not
    wrap.  Gemini link bandwidth ~9.4 GB/s per direction.
    """
    return MachineSpec(
        name="blue_waters",
        params=blue_waters(),
        torus=TorusTopology(torus_dims, wrap=wrap),
        nodes_per_torus_node=2,
        procs_per_node=16,
        sockets_per_node=2,
        link_bw=9.4e9,
    )


def tpu_v5e_machine(torus_dims: tuple[int, int] = (16, 16)) -> MachineSpec:
    """One TPU v5e pod: a ``torus_dims`` 2-D ICI torus, 4 chips per host.

    One process per chip; the "node" is the host (4 chips).  Locality 0 =
    intra-host, 1 = intra-pod ICI.  Inter-pod DCN (class 2) only appears in
    multi-pod model evaluation via :mod:`repro.core.decompose`, never in the
    single-pod simulator.
    """
    return MachineSpec(
        name="tpu_v5e",
        params=tpu_v5e(),
        torus=TorusTopology(torus_dims, wrap=True),
        nodes_per_torus_node=1,
        procs_per_node=4,         # chips per host
        sockets_per_node=1,
        link_bw=50e9,
        torus_over_procs=True,
        cross_node_locality=1,
    )


def lassen_machine(torus_dims: tuple[int, ...] = (2, 2, 2),
                   network_path: str = "device_direct") -> MachineSpec:
    """Lassen-like fat GPU nodes on a ``torus_dims`` node torus.

    4 V100-class devices per node, 2 ranks per device (8 ppn), dual-rail
    host NICs.  ``network_path`` picks the class cross-node pairs are born
    with — ``"device_direct"`` (GPU-aware MPI default) or ``"host_staged"``;
    the GPU-aware strategy rewrites compare the two regardless.  Lassen is a
    fat-tree machine; the torus stands in as the contention substrate, same
    as every preset here.
    """
    params = lassen()
    return MachineSpec(
        name="lassen",
        params=params,
        torus=TorusTopology(torus_dims, wrap=False),
        nodes_per_torus_node=1,
        procs_per_node=8,
        sockets_per_node=2,
        link_bw=12.5e9,
        cross_node_locality=params.class_index(network_path),
        devices_per_node=4,
        procs_per_device=2,
    )


def frontier_machine(torus_dims: tuple[int, ...] = (2, 2, 2),
                     network_path: str = "device_direct") -> MachineSpec:
    """Frontier-like 8-GCD nodes on a ``torus_dims`` node torus.

    8 GCDs per node, 1 rank per GCD (8 ppn), 4 Slingshot NICs per node
    attached GPU-side — the device-direct path is native and fast here,
    the mirror image of :func:`lassen_machine`.  ``network_path`` as in
    :func:`lassen_machine`.
    """
    params = frontier()
    return MachineSpec(
        name="frontier",
        params=params,
        torus=TorusTopology(torus_dims, wrap=False),
        nodes_per_torus_node=1,
        procs_per_node=8,
        sockets_per_node=1,
        link_bw=25e9,
        cross_node_locality=params.class_index(network_path),
        devices_per_node=8,
        procs_per_device=1,
    )
