"""Event-level communication simulator (the "measured" side of the paper).

For one communication *phase* (a set of point-to-point messages that are all
posted, then all completed — e.g. one SpMV halo exchange or one direction of a
HighVolumePingPong):

* every message is priced with the machine's ground-truth node-aware
  parameters, with node-injection saturation computed from the *actual* number
  of actively-sending processes per node (the max-rate mechanism, mechanistic);
* the MPI receive queue is simulated: each process posts receives in a given
  order, envelopes arrive in network order, and every arrival walks the posted
  queue until it matches — traversal steps are counted exactly (Fenwick tree,
  O(n log n)) and priced at gamma per step;
* network messages are routed dimension-ordered over the torus; per-link byte
  counters feed a contention penalty of delta * (hottest-link bytes).

The closed-form model of :mod:`repro.core.models` must predict these outputs
across the same inferential gap the paper has between model and machine
(cube-partition estimate vs real routing, n^2 upper bound vs actual traversal).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .machine import MachineSpec


class _Fenwick:
    """Binary indexed tree over n slots holding 0/1 'still unmatched' flags."""

    def __init__(self, n: int):
        self.n = n
        self.t = np.zeros(n + 1, dtype=np.int64)
        for i in range(1, n + 1):
            self._add(i, 1)

    def _add(self, i: int, v: int) -> None:
        while i <= self.n:
            self.t[i] += v
            i += i & -i

    def prefix(self, i: int) -> int:
        s = 0
        while i > 0:
            s += self.t[i]
            i -= i & -i
        return int(s)

    def remove(self, i: int) -> None:
        self._add(i, -1)


def queue_traversal_steps(posted_order: np.ndarray, arrival_order: np.ndarray) -> np.ndarray:
    """Exact queue-walk lengths for one receiving process.

    ``posted_order[k]`` = message id posted k-th; ``arrival_order[j]`` =
    message id of the j-th arriving envelope.  Returns steps per arrival: the
    1-based position of the match in the still-unmatched posted queue —
    exactly what CrayMPI's linear receive-queue search pays.
    """
    n = len(posted_order)
    pos = np.empty(n, dtype=np.int64)
    pos[np.asarray(posted_order)] = np.arange(n)
    fen = _Fenwick(n)
    steps = np.empty(n, dtype=np.int64)
    for j, mid in enumerate(np.asarray(arrival_order)):
        p = int(pos[mid]) + 1               # 1-based slot
        steps[j] = fen.prefix(p)            # unmatched entries at/before slot
        fen.remove(p)
    return steps


@dataclasses.dataclass
class PhaseResult:
    time: float                      # modeled wall time of the phase (seconds)
    transport: float                 # max over procs of send-side transport
    queue: float                     # gamma * steps, worst process
    contention: float                # delta * hottest-link bytes
    per_proc_transport: np.ndarray
    per_proc_queue_steps: np.ndarray
    max_link_bytes: float
    total_net_bytes: float


def simulate_phase(machine: MachineSpec, src, dst, size,
                   recv_post_order: dict[int, np.ndarray] | None = None,
                   arrival_order: dict[int, np.ndarray] | None = None,
                   rng: np.random.Generator | None = None,
                   noise: float = 0.0) -> PhaseResult:
    """Simulate one phase of point-to-point messages.

    ``recv_post_order[p]`` / ``arrival_order[p]``: permutations of the indices
    (into src/dst/size) of messages destined to process ``p``, giving the
    order receives are posted and envelopes arrive.  Default: array order for
    both (best case, O(n) queue cost).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    size = np.asarray(size, dtype=np.float64)
    params = machine.params
    n_procs = int(max(src.max(initial=0), dst.max(initial=0))) + 1 if src.size else 0
    if src.size == 0:
        z = np.zeros(0)
        return PhaseResult(0.0, 0.0, 0.0, 0.0, z, z, 0.0, 0.0)

    loc = machine.locality(src, dst)
    proto = params.protocol_of(size)
    is_net = loc >= params.network_locality

    # --- max-rate transport: actual active senders per node ----------------
    send_node = machine.node_of(src)
    active: dict[int, set[int]] = {}
    for p, nd, n in zip(src, send_node, is_net):
        if n:
            active.setdefault(int(nd), set()).add(int(p))
    ppn = np.asarray([len(active.get(int(nd), ())) if n else 1
                      for nd, n in zip(send_node, is_net)], dtype=np.float64)
    ppn = np.maximum(ppn, 1.0)

    alpha = params.alpha[loc, proto]
    Rb = params.Rb[loc, proto]
    RN = params.RN[loc, proto]
    rate = np.minimum(RN, ppn * Rb)
    t_msg = alpha + ppn * size / rate

    per_proc = np.zeros(n_procs)
    np.add.at(per_proc, src, t_msg)
    transport = float(per_proc.max())

    # --- queue search (exact traversal counts) ----------------------------
    qsteps = np.zeros(n_procs, dtype=np.int64)
    recv_ids: dict[int, np.ndarray] = {}
    order = np.argsort(dst, kind="stable")
    bounds = np.searchsorted(dst[order], np.arange(n_procs + 1))
    for p in range(n_procs):
        ids = order[bounds[p]:bounds[p + 1]]
        if ids.size:
            recv_ids[p] = ids
    for p, ids in recv_ids.items():
        n = ids.size
        local = {mid: k for k, mid in enumerate(ids)}
        posted = (np.asarray([local[m] for m in recv_post_order[p]])
                  if recv_post_order and p in recv_post_order
                  else np.arange(n))
        arrive = (np.asarray([local[m] for m in arrival_order[p]])
                  if arrival_order and p in arrival_order
                  else np.arange(n))
        steps = queue_traversal_steps(posted, arrive)
        qsteps[p] = int(steps.sum())
    queue = params.gamma * float(qsteps.max(initial=0))

    # --- link contention (actual dimension-ordered routing) ---------------
    # A single node's flows over one link are already bounded by its injection
    # cap R_N, so only bytes *beyond the largest single-source contribution*
    # on a link constitute contention (multiple nodes funneling into it, as in
    # the paper's Fig. 6 G1-G2 link).
    tsrc = machine.torus_node_of(src)
    tdst = machine.torus_node_of(dst)
    net = is_net & (tsrc != tdst)
    link_total: dict[tuple, float] = {}
    link_by_src: dict[tuple, dict[int, float]] = {}
    for s_, d_, z_ in zip(tsrc[net], tdst[net], size[net]):
        for link in machine.torus.route_links(int(s_), int(d_)):
            link_total[link] = link_total.get(link, 0.0) + float(z_)
            link_by_src.setdefault(link, {})
            link_by_src[link][int(s_)] = link_by_src[link].get(int(s_), 0.0) + float(z_)
    max_link = 0.0
    for link, tot in link_total.items():
        contended = tot - max(link_by_src[link].values())
        max_link = max(max_link, contended)
    contention = params.delta * max_link

    total = transport + queue + contention
    if noise > 0.0:
        rng = rng or np.random.default_rng(0)
        total *= float(np.exp(rng.normal(0.0, noise)))
    return PhaseResult(total, transport, queue, contention,
                       per_proc, qsteps, max_link, float(size[is_net].sum()))
