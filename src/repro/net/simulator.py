"""Event-level communication simulator (the "measured" side of the paper).

For one communication *phase* (a set of point-to-point messages that are all
posted, then all completed — e.g. one SpMV halo exchange or one direction of a
HighVolumePingPong):

* every message is priced with the machine's ground-truth node-aware
  parameters, with node-injection saturation computed from the *actual* number
  of actively-sending processes per node (the max-rate mechanism, mechanistic);
* the MPI receive queue is simulated: each process posts receives in a given
  order, envelopes arrive in network order, and every arrival walks the posted
  queue until it matches — traversal steps are counted exactly (Fenwick tree,
  batched across all receiving processes) and priced at gamma per step;
* network messages are routed dimension-ordered over the torus in one
  vectorized segment expansion; per-link byte counters feed a contention
  penalty of delta * (hottest-link contended bytes).

All hot paths are thin layers over the shared engine in :mod:`repro.comm`:
:class:`repro.comm.CommPhase` caches locality / protocol / routing endpoints /
active-sender counts once, and the same primitives also feed the closed-form
model of :mod:`repro.core.models`, which must predict these outputs across the
same inferential gap the paper has between model and machine (cube-partition
estimate vs real routing, n^2 upper bound vs actual traversal).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.comm import ARENA_TYPES as _ARENAS
from repro.comm import CommPhase, PhaseStack
from repro.comm.stack import as_stack
from repro.comm.primitives import (per_proc_sums, queue_traversal_steps,
                                   transport_times)

from .machine import MachineSpec

__all__ = ["PhaseResult", "SequenceResult", "simulate", "simulate_phase",
           "simulate_many", "simulate_sequence", "queue_traversal_steps"]


@dataclasses.dataclass
class PhaseResult:
    time: float                      # modeled wall time of the phase (seconds)
    transport: float                 # max over procs of send-side transport
    queue: float                     # gamma * steps, worst process
    contention: float                # delta * hottest-link bytes
    per_proc_transport: np.ndarray
    per_proc_queue_steps: np.ndarray
    max_link_bytes: float
    total_net_bytes: float


def simulate(phase: CommPhase,
             recv_post_order: dict[int, np.ndarray] | None = None,
             arrival_order: dict[int, np.ndarray] | None = None,
             rng: np.random.Generator | None = None,
             noise: float = 0.0) -> PhaseResult:
    """Simulate one prebuilt :class:`CommPhase`.

    ``recv_post_order[p]`` / ``arrival_order[p]``: permutations of the indices
    (into src/dst/size) of messages destined to process ``p``, giving the
    order receives are posted and envelopes arrive.  Default: array order for
    both (best case, O(n) queue cost).

    ``noise`` multiplies the total by a lognormal factor drawn from ``rng``.
    The generator is owned by the *sweep*: create it once (e.g.
    ``np.random.default_rng(seed)``) and thread it through every call, as
    :func:`simulate_many` and the ping-pong harnesses do — a per-call default
    would re-seed on every call and make repeated noisy calls draw identical
    noise.
    """
    if noise > 0.0 and rng is None:
        raise ValueError(
            "noise > 0 needs an explicit rng, created once at the sweep "
            "level (a per-call default would redraw the same noise); "
            "simulate_many seeds np.random.default_rng(0) for you")
    if phase.n_msgs == 0:
        z = np.zeros(0)
        return PhaseResult(0.0, 0.0, 0.0, 0.0, z, z, 0.0, 0.0)
    params = phase.machine.params

    # --- max-rate transport: actual active senders per node ----------------
    alpha = params.alpha[phase.loc, phase.proto]
    Rb = params.Rb[phase.loc, phase.proto]
    RN = params.RN[phase.loc, phase.proto]
    t_msg = transport_times(phase.size, alpha, Rb, RN, phase.active_ppn,
                            phase.is_net, rails=params.n_rails)
    per_proc = per_proc_sums(phase.src, t_msg, phase.n_procs)
    transport = float(per_proc.max())

    # --- queue search (exact traversal counts, batched Fenwick) ------------
    qsteps = phase.queue_steps(recv_post_order, arrival_order)
    queue = params.gamma * float(qsteps.max(initial=0))

    # --- link contention (actual dimension-ordered routing) ----------------
    max_link, net_bytes = phase.link_contention()
    contention = params.delta * max_link

    total = transport + queue + contention
    if noise > 0.0:
        total *= float(np.exp(rng.normal(0.0, noise)))
    return PhaseResult(total, transport, queue, contention,
                       per_proc, qsteps, max_link, net_bytes)


@dataclasses.dataclass
class SequenceResult:
    """Summed result of a multi-phase sequence (a strategy rewrite): the
    phases execute back-to-back, so times add; per-phase results are kept
    for breakdown tables."""
    time: float
    transport: float
    queue: float
    contention: float
    phases: list[PhaseResult]


def simulate_sequence(phases,
                      recv_post_orders=None,
                      arrival_orders=None,
                      rng: np.random.Generator | None = None,
                      noise: float = 0.0) -> SequenceResult:
    """Simulate a phase *sequence* end-to-end (e.g. the gather -> inter ->
    scatter steps of a strategy rewrite) and sum the step times."""
    results = simulate_many(phases, recv_post_orders=recv_post_orders,
                            arrival_orders=arrival_orders, rng=rng,
                            noise=noise)
    return SequenceResult(
        time=sum(r.time for r in results),
        transport=sum(r.transport for r in results),
        queue=sum(r.queue for r in results),
        contention=sum(r.contention for r in results),
        phases=results)


def simulate_phase(machine: MachineSpec, src, dst, size,
                   recv_post_order: dict[int, np.ndarray] | None = None,
                   arrival_order: dict[int, np.ndarray] | None = None,
                   rng: np.random.Generator | None = None,
                   noise: float = 0.0, validate: bool = False) -> PhaseResult:
    """Simulate one phase of point-to-point messages (array-level entry).

    ``validate=True`` runs the typed validation layer over the message
    arrays first (:func:`repro.comm.guard.validate_messages` via
    :meth:`repro.comm.CommPhase.build`): NaN/negative sizes and
    out-of-range ranks raise a precise
    :class:`repro.comm.guard.PatternError` subclass instead of simulating
    garbage.
    """
    return simulate(CommPhase.build(machine, src, dst, size,
                                    validate=validate),
                    recv_post_order=recv_post_order,
                    arrival_order=arrival_order, rng=rng, noise=noise)


def _simulate_stack(stack: PhaseStack, recv_post_orders,
                    arrival_orders, backend=None) -> list[PhaseResult]:
    """Price a stacked sweep's raw aggregates into PhaseResult rows.

    One segmented pass per quantity (transport sums, queue steps, link
    contention) over the whole arena — bit-identical to per-phase
    :func:`simulate` (DESIGN.md §8) on the numpy backend; device backends
    are allclose for the float aggregates and bit-equal for queue steps."""
    if stack.n_phases == 0:
        return []
    params = stack.machine.params
    raw = stack.sim_arrays(recv_post_orders=recv_post_orders,
                           arrival_orders=arrival_orders, backend=backend)
    out = []
    for i in range(stack.n_phases):
        if stack.phases[i].n_msgs == 0:
            z = np.zeros(0)
            out.append(PhaseResult(0.0, 0.0, 0.0, 0.0, z, z, 0.0, 0.0))
            continue
        transport = float(raw.transport[i])
        queue = params.gamma * float(raw.qsteps[i].max(initial=0))
        contention = params.delta * float(raw.max_link[i])
        out.append(PhaseResult(
            transport + queue + contention, transport, queue, contention,
            raw.per_proc[i], raw.qsteps[i],
            float(raw.max_link[i]), float(raw.net_bytes[i])))
    return out


def simulate_many(phases,
                  recv_post_orders=None,
                  arrival_orders=None,
                  rng: np.random.Generator | None = None,
                  noise: float = 0.0,
                  backend=None) -> list[PhaseResult]:
    """Simulate a sweep of :class:`CommPhase` objects (an AMG hierarchy, a
    partition or machine scan) in one call.

    ``recv_post_orders[i]`` / ``arrival_orders[i]`` apply to ``phases[i]``;
    a single shared ``rng`` drives the noise stream across the whole sweep
    (default: ``np.random.default_rng(0)``, created once per call so the
    sweep is reproducible — pass your own generator to chain sweeps).

    Fast path: phases bound to one machine (or an already-built
    :class:`repro.comm.PhaseStack` / :class:`repro.comm.DeltaStack`) are
    simulated in one segmented pass over the arena, bit-identical to the
    per-phase loop; single phases and mixed-machine sweeps fall back to
    :func:`simulate`.  A ``DeltaStack`` serves transport and contention from
    its incrementally-maintained caches.  ``backend`` selects the arena's
    reduction backend (as in :meth:`repro.comm.PhaseStack.sim_arrays`;
    ``None`` defaults to ``REPRO_STACK_BACKEND`` or numpy, ``'auto'`` is
    the autotuned per-call choice) and is ignored on the per-phase
    fallback path.
    """
    if noise > 0.0 and rng is None:
        rng = np.random.default_rng(0)
    if isinstance(phases, _ARENAS):
        stack = phases
    else:
        phases = list(phases)
        stack = as_stack(phases)
    if stack is not None:
        out = _simulate_stack(stack, recv_post_orders, arrival_orders,
                              backend=backend)
        if noise > 0.0:
            # same draw order as the per-phase loop, which returns early for
            # empty phases without touching the rng
            for r, ph in zip(out, stack.phases):
                if ph.n_msgs:
                    r.time *= float(np.exp(rng.normal(0.0, noise)))
        return out
    return [simulate(
        ph,
        recv_post_order=recv_post_orders[i] if recv_post_orders else None,
        arrival_order=arrival_orders[i] if arrival_orders else None,
        rng=rng, noise=noise) for i, ph in enumerate(phases)]
