"""Mechanistic network simulator — the framework's "measured" data source.

No Cray hardware is available, so the paper's Blue Waters measurements are
replaced by an event-level simulator (:mod:`repro.net.simulator`) that prices
every message with ground-truth parameters, *actually walks* MPI receive
queues, and routes bytes over a torus with per-link accounting.  The model in
:mod:`repro.core` then has to predict this simulator across the same
inferential gap the paper has between closed-form model and machine.
"""
from .machine import (MachineSpec, blue_waters_machine, tpu_v5e_machine,
                      lassen_machine, frontier_machine)
from .simulator import (PhaseResult, SequenceResult, simulate, simulate_phase,
                        simulate_many, simulate_sequence)
from .pingpong import (
    pingpong_time, pingpong_sweep, ppn_sweep, high_volume_pingpong,
    contention_line_test,
)

__all__ = [
    "MachineSpec", "blue_waters_machine", "tpu_v5e_machine",
    "lassen_machine", "frontier_machine",
    "PhaseResult", "SequenceResult", "simulate", "simulate_phase",
    "simulate_many", "simulate_sequence",
    "pingpong_time", "pingpong_sweep", "ppn_sweep", "high_volume_pingpong",
    "contention_line_test",
]
