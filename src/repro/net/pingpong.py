"""Ping-pong harnesses (paper Section 4, Algorithm 1) on the simulator.

These generate the measurement sets the paper collects with Baseenv on Blue
Waters: classic two-process ping-pongs split by locality (Figs. 2-3), the
ppn sweep behind the max-rate R_N measurement, the HighVolumePingPong with
same/reversed receive ordering (Figs. 4-5) and the 1-D Gemini-line contention
test (Figs. 6-7, 9).
"""
from __future__ import annotations

import numpy as np

from .machine import MachineSpec
from .simulator import simulate_phase, PhaseResult


def _pair_for(machine: MachineSpec, kind: str) -> tuple[int, int]:
    """A canonical process pair on ``machine`` for a locality-class ``kind``.

    Hetero kinds: ``intra_device`` needs more than one rank per device;
    ``cross_device`` is the next device over; the network-path kinds
    (``host_staged`` / ``device_direct``) give a cross-node pair and demand
    that the machine is *configured* with that path (its ``locality`` is
    what classifies the pair) — a mismatch raises instead of silently
    measuring the other path's rate class.
    """
    ppn = machine.procs_per_node
    if kind in ("intra_socket", "closest", "intra_device"):
        if kind == "intra_device" and machine.procs_per_device < 2:
            raise ValueError(
                f"{machine.name} has {machine.procs_per_device} rank(s) per "
                "device; no intra-device pair exists")
        return 0, 1
    if kind in ("intra_node", "cross_device"):
        if machine.devices_per_node:
            return 0, machine.procs_per_device       # next device over
        if machine.sockets_per_node > 1:
            return 0, ppn // machine.sockets_per_node  # cross-socket
        return 0, 1
    if kind in ("inter_node", "host_staged", "device_direct"):
        if kind != "inter_node":
            want = machine.params.class_index(kind)  # raises w/o the class
            if machine.cross_node_locality != want:
                have = machine.params.locality_names[
                    machine.cross_node_locality]
                raise ValueError(
                    f"{machine.name} is configured with network path "
                    f"{have!r}; rebuild the preset with "
                    f"network_path={kind!r} to measure that class")
        return 0, ppn * machine.nodes_per_torus_node  # next torus node over
    raise ValueError(f"unknown pair kind {kind!r}")


def pingpong_time(machine: MachineSpec, a: int, b: int, size: float,
                  rng=None, noise: float = 0.0) -> float:
    """Half round-trip time for a single message of ``size`` bytes."""
    t1 = simulate_phase(machine, [a], [b], [size], rng=rng, noise=noise).time
    t2 = simulate_phase(machine, [b], [a], [size], rng=rng, noise=noise).time
    return 0.5 * (t1 + t2)


def pingpong_sweep(machine: MachineSpec, kind: str, sizes,
                   reps: int = 4, noise: float = 0.02,
                   seed: int = 0) -> np.ndarray:
    """Mean ping-pong time per size for a locality class (Figs. 2-3 data)."""
    a, b = _pair_for(machine, kind)
    rng = np.random.default_rng(seed)
    out = []
    for s in sizes:
        ts = [pingpong_time(machine, a, b, float(s), rng=rng, noise=noise)
              for _ in range(reps)]
        out.append(np.mean(ts))
    return np.asarray(out)


def ppn_sweep(machine: MachineSpec, size: float, max_ppn: int | None = None,
              noise: float = 0.0, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Inter-node exchange with k = 1..ppn active pairs (max-rate R_N data).

    Process i on node 0 sends one ``size``-byte message to process i on the
    next torus node over.  Returns (ppn values, phase times).
    """
    ppn = machine.procs_per_node
    max_ppn = max_ppn or ppn
    other = machine.procs_per_node * machine.nodes_per_torus_node
    rng = np.random.default_rng(seed)
    ks, ts = [], []
    for k in range(1, max_ppn + 1):
        src = np.arange(k)
        dst = other + np.arange(k)
        res = simulate_phase(machine, src, dst, np.full(k, float(size)),
                             rng=rng, noise=noise)
        ks.append(k)
        ts.append(res.time)
    return np.asarray(ks), np.asarray(ts)


def high_volume_pingpong(machine: MachineSpec, pairs, n: int, size: float,
                         order: str = "same", noise: float = 0.0,
                         seed: int = 0) -> tuple[float, PhaseResult, PhaseResult]:
    """Algorithm 1: each (a, b) pair exchanges ``n`` messages of ``size`` bytes.

    ``order='same'``: receives posted in arrival order (O(n) queue cost).
    ``order='reversed'``: receives posted opposite to arrival order — every
    arrival walks the whole remaining queue (O(n^2), paper Fig. 4 right).
    Returns (total time, phase a->b, phase b->a).
    """
    pairs = np.asarray(list(pairs), dtype=np.int64).reshape(-1, 2)
    src = np.repeat(pairs[:, 0], n)
    dst = np.repeat(pairs[:, 1], n)
    sizes = np.full(src.shape, float(size))
    rng = np.random.default_rng(seed)

    def post_order(dsts):
        if order == "same":
            return None
        po = {}
        for p in np.unique(dsts):
            ids = np.nonzero(dsts == p)[0]
            po[int(p)] = ids[::-1]          # posted opposite to arrival
        return po

    r1 = simulate_phase(machine, src, dst, sizes, recv_post_order=post_order(dst),
                        rng=rng, noise=noise)
    r2 = simulate_phase(machine, dst, src, sizes, recv_post_order=post_order(src),
                        rng=rng, noise=noise)
    return r1.time + r2.time, r1, r2


def contention_line_test(machine: MachineSpec, n: int, size: float,
                         order: str = "same", noise: float = 0.0,
                         seed: int = 0) -> tuple[float, PhaseResult, PhaseResult]:
    """Paper Fig. 6: Geminis G0..G3 on a line; G0->G2 and G1->G3 pairwise.

    All bytes funnel through the single G1-G2 link, producing contention that
    the max-rate + queue model misses (Fig. 7) and the delta*ell term captures
    (Fig. 9).  ``machine`` should be a 1-D line partition, e.g.
    ``blue_waters_machine((4, 1, 1))``.
    """
    ppt = machine.procs_per_torus_node
    pairs = [(0 * ppt + j, 2 * ppt + j) for j in range(ppt)]
    pairs += [(1 * ppt + j, 3 * ppt + j) for j in range(ppt)]
    return high_volume_pingpong(machine, pairs, n, size, order=order,
                                noise=noise, seed=seed)
