"""Sparse-matrix substrate: CSR ops, model problems, row partitions with
communication-pattern extraction, and classical AMG — the application layer
the paper validates its models on (SpMV / SpGEMM across hierarchy levels)."""
from .csr import CSR, eye, diag
from .problems import poisson_3d, elasticity_like_3d
from .partition import (RowPartition, CommPattern, spmv_comm_pattern,
                        spgemm_comm_pattern, stack_patterns,
                        SpmvPatternState, spmv_comm_pattern_delta)
from .amg import build_hierarchy, vcycle, AMGLevel
from .optimize import Move, OptimizeResult, optimize_partition

__all__ = [
    "CSR", "eye", "diag",
    "poisson_3d", "elasticity_like_3d",
    "RowPartition", "CommPattern", "spmv_comm_pattern", "spgemm_comm_pattern",
    "stack_patterns", "SpmvPatternState", "spmv_comm_pattern_delta",
    "build_hierarchy", "vcycle", "AMGLevel",
    "Move", "OptimizeResult", "optimize_partition",
]
