"""Model problems: 3-D Poisson and a 3-D linear-elasticity-like operator.

The paper's application is an unstructured 3-D linear elasticity system from
MFEM (840k unknowns, 65M nnz ~ 77 nnz/row, i.e. a 27-point vertex stencil
with 3 dof/node).  Without MFEM we generate the same *structure*: a 27-point
hexahedral stencil with 3x3 displacement-coupling blocks and mild
coefficient jitter ("unstructured-like" variability).  Communication volume
and sparsity pattern — what the models consume — match the paper's regime;
FEM-exact entries are not required.
"""
from __future__ import annotations

import numpy as np

from .csr import CSR


def poisson_3d(nx: int, ny: int | None = None, nz: int | None = None) -> CSR:
    """Standard 7-point Laplacian on an nx x ny x nz grid (Dirichlet)."""
    ny = ny or nx
    nz = nz or nx
    n = nx * ny * nz
    idx = np.arange(n).reshape(nx, ny, nz)
    rows = [np.arange(n)]
    cols = [np.arange(n)]
    vals = [np.full(n, 6.0)]
    for axis, extent in ((0, nx), (1, ny), (2, nz)):
        if extent < 2:
            continue
        lo = np.take(idx, np.arange(extent - 1), axis=axis).ravel()
        hi = np.take(idx, np.arange(1, extent), axis=axis).ravel()
        rows += [lo, hi]
        cols += [hi, lo]
        vals += [np.full(lo.size, -1.0), np.full(hi.size, -1.0)]
    return CSR.from_coo(np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals), (n, n))


def elasticity_like_3d(nx: int, ny: int | None = None, nz: int | None = None,
                       jitter: float = 0.1, seed: int = 0) -> CSR:
    """27-point vertex stencil with 3x3 blocks (3 dof/node), SPD by dominance.

    Structure-faithful stand-in for the paper's MFEM linear elasticity matrix:
    ~81 nnz/row, strong diagonal blocks, symmetric cross-component coupling.
    """
    ny = ny or nx
    nz = nz or nx
    n_nodes = nx * ny * nz
    idx = np.arange(n_nodes).reshape(nx, ny, nz)
    rng = np.random.default_rng(seed)

    # enumerate unique neighbor offsets (half-space to keep symmetry)
    offsets = [(dx, dy, dz)
               for dx in (-1, 0, 1) for dy in (-1, 0, 1) for dz in (-1, 0, 1)
               if (dx, dy, dz) > (0, 0, 0)]
    rows_l, cols_l, vals_l = [], [], []

    # per-node random symmetric 3x3 coupling scale ("material" jitter)
    node_w = 1.0 + jitter * rng.standard_normal(n_nodes)

    dof = np.arange(3)
    off_diag_total = np.zeros(n_nodes)      # accumulate |off-block| row sums
    for (dx, dy, dz) in offsets:
        sl_a = tuple(slice(max(0, -d), min(s, s - d))
                     for d, s in ((dx, nx), (dy, ny), (dz, nz)))
        sl_b = tuple(slice(max(0, d), min(s, s + d))
                     for d, s in ((dx, nx), (dy, ny), (dz, nz)))
        a = idx[sl_a].ravel()
        b = idx[sl_b].ravel()
        if a.size == 0:
            continue
        dist = abs(dx) + abs(dy) + abs(dz)
        w = -1.0 / dist * 0.5 * (node_w[a] + node_w[b])   # symmetric weight
        # 3x3 block: -w*I plus small symmetric coupling eps between components
        eps = 0.15 * w
        for di in range(3):
            for dj in range(3):
                coef = w if di == dj else eps
                rows_l += [3 * a + di, 3 * b + dj]
                cols_l += [3 * b + dj, 3 * a + di]
                vals_l += [coef, coef]
        blk_rowsum = np.abs(w) + 2 * np.abs(eps)
        np.add.at(off_diag_total, a, blk_rowsum)   # block a->b in a's rows
        np.add.at(off_diag_total, b, blk_rowsum)   # block b->a in b's rows

    # diagonal 3x3 blocks: full (cross-component coupling) + dominance margin
    nodes = np.arange(n_nodes)
    cross = 0.05 * (off_diag_total + 1e-3)         # symmetric off-diagonals
    diag_val = (off_diag_total + 2 * cross) * 1.05 + 1e-3
    for di in range(3):
        rows_l.append(3 * nodes + di)
        cols_l.append(3 * nodes + di)
        vals_l.append(diag_val)
        for dj in range(di + 1, 3):
            rows_l += [3 * nodes + di, 3 * nodes + dj]
            cols_l += [3 * nodes + dj, 3 * nodes + di]
            vals_l += [cross, cross]

    n = 3 * n_nodes
    return CSR.from_coo(np.concatenate([np.asarray(r) for r in rows_l]),
                        np.concatenate([np.asarray(c) for c in cols_l]),
                        np.concatenate([np.asarray(v) for v in vals_l]),
                        (n, n))
