"""Row partitions and the communication patterns of parallel SpMV / SpGEMM.

This is what the paper models: given a 1-D (row-wise) partition of a sparse
matrix over P processes, extract exactly which process sends how many bytes
to which process for

* **SpMV** (y = A x): process p needs x[j] for every column j with a nonzero
  in p's rows owned by another process — one message per (owner -> p) pair
  containing the distinct required entries (8 bytes each);
* **SpGEMM** (C = A B): process p needs the full *rows* of B matching its
  off-process A columns — one message per (owner -> p) pair containing the
  CSR rows (12 bytes per nonzero: 8 value + 4 index).

Returned patterns are (src, dst, size_bytes) arrays directly consumable by
:func:`repro.core.models.phase_cost` and :func:`repro.net.simulate_phase`;
:meth:`CommPattern.bind` converts a pattern to a machine-bound
:class:`repro.comm.CommPhase` for the vectorized batched APIs
(:func:`repro.core.models.phase_cost_many`, :func:`repro.net.simulate_many`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.comm import CommPhase, PhaseStack

from .csr import CSR

SPMV_ENTRY_BYTES = 8
SPGEMM_NNZ_BYTES = 12


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous balanced row partition: rows [starts[p], starts[p+1])."""

    starts: np.ndarray   # [P+1]

    @classmethod
    def balanced(cls, n_rows: int, n_procs: int) -> "RowPartition":
        base = n_rows // n_procs
        extra = n_rows % n_procs
        sizes = np.full(n_procs, base, dtype=np.int64)
        sizes[:extra] += 1
        return cls(np.concatenate([[0], np.cumsum(sizes)]))

    @property
    def n_procs(self) -> int:
        return len(self.starts) - 1

    def owner_of(self, rows) -> np.ndarray:
        return np.searchsorted(self.starts, np.asarray(rows), side="right") - 1

    def rows_of(self, p: int) -> tuple[int, int]:
        return int(self.starts[p]), int(self.starts[p + 1])


@dataclasses.dataclass
class CommPattern:
    """One communication phase: message (src[i] -> dst[i], size[i] bytes)."""

    src: np.ndarray
    dst: np.ndarray
    size: np.ndarray
    n_procs: int

    @property
    def n_msgs(self) -> int:
        return int(self.src.size)

    @property
    def total_bytes(self) -> float:
        return float(self.size.sum())

    def max_msgs_per_proc(self) -> int:
        if self.src.size == 0:
            return 0
        return int(np.bincount(self.dst, minlength=self.n_procs).max())

    def bind(self, machine, n_procs: int | None = None) -> CommPhase:
        """Bind this pattern to a machine: returns a :class:`CommPhase` with
        locality, protocol, torus endpoints and active-sender counts cached."""
        return CommPhase.build(machine, self.src, self.dst, self.size,
                               n_procs=self.n_procs if n_procs is None else n_procs)

    def rewrite(self, machine, strategy: str):
        """Bind to ``machine`` and apply a node-aware strategy rewrite.

        Returns a :class:`repro.comm.StrategyPlan` whose phase sequence the
        batched entry points price directly (``sequence_cost`` /
        ``simulate_sequence``)."""
        from repro.comm.strategies import rewrite
        return rewrite(self.bind(machine), strategy)

    def best_strategy(self, machine, **kw):
        """Sweep every strategy on this pattern: the model ladder's predicted
        winner plus the simulator's verdict (:func:`repro.comm.best_strategy`)."""
        from repro.comm.strategies import best_strategy
        return best_strategy(self, machine, **kw)


def stack_patterns(patterns, machine) -> PhaseStack:
    """Bind a sweep of :class:`CommPattern` objects (an AMG hierarchy, a
    partition scan) to one machine as a single :class:`repro.comm.PhaseStack`.

    The stack is the fast-path input of the batched entry points: pass it
    straight to :func:`repro.core.models.phase_cost_many` /
    :func:`repro.core.models.model_ladder_many` /
    :func:`repro.net.simulator.simulate_many` to sweep every pattern in one
    segmented pass per quantity.
    """
    return PhaseStack.build([p.bind(machine) for p in patterns])


def _needed_pairs(A: CSR, part: RowPartition) -> tuple[np.ndarray, np.ndarray]:
    """Distinct (requesting proc, off-proc column) pairs over A's nonzeros."""
    rows = np.repeat(np.arange(A.n_rows), A.row_lengths())
    req = part.owner_of(rows)          # proc that owns the row
    own = part.owner_of(A.indices)     # proc that owns the column
    off = req != own
    if not off.any():
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    key = req[off].astype(np.int64) * A.n_cols + A.indices[off]
    uniq = np.unique(key)
    return (uniq // A.n_cols).astype(np.int64), (uniq % A.n_cols).astype(np.int64)


def spmv_comm_pattern(A: CSR, part: RowPartition) -> CommPattern:
    """Messages for the halo exchange of y = A x under ``part``."""
    req, col = _needed_pairs(A, part)
    if req.size == 0:
        return CommPattern(np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64),
                           np.zeros(0), part.n_procs)
    owner = part.owner_of(col)
    # one message per distinct (owner -> requester), size = count * 8
    pair_key = owner * part.n_procs + req
    uniq, counts = np.unique(pair_key, return_counts=True)
    return CommPattern(src=(uniq // part.n_procs).astype(np.int64),
                       dst=(uniq % part.n_procs).astype(np.int64),
                       size=counts.astype(np.float64) * SPMV_ENTRY_BYTES,
                       n_procs=part.n_procs)


def spgemm_comm_pattern(A: CSR, B: CSR, part: RowPartition) -> CommPattern:
    """Messages to fetch remote B rows for C = A B under ``part``.

    Process p gathers B rows for its off-process A columns; message size is
    the total nnz of those rows times 12 bytes.
    """
    req, col = _needed_pairs(A, part)
    if req.size == 0:
        return CommPattern(np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64),
                           np.zeros(0), part.n_procs)
    owner = part.owner_of(col)
    row_nnz = B.row_lengths()[col].astype(np.float64)
    pair_key = owner * part.n_procs + req
    order = np.argsort(pair_key, kind="stable")
    pair_key, row_nnz = pair_key[order], row_nnz[order]
    uniq, starts = np.unique(pair_key, return_index=True)
    sums = np.add.reduceat(row_nnz, starts)
    return CommPattern(src=(uniq // part.n_procs).astype(np.int64),
                       dst=(uniq % part.n_procs).astype(np.int64),
                       size=sums * SPGEMM_NNZ_BYTES,
                       n_procs=part.n_procs)
