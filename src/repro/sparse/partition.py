"""Row partitions and the communication patterns of parallel SpMV / SpGEMM.

This is what the paper models: given a 1-D (row-wise) partition of a sparse
matrix over P processes, extract exactly which process sends how many bytes
to which process for

* **SpMV** (y = A x): process p needs x[j] for every column j with a nonzero
  in p's rows owned by another process — one message per (owner -> p) pair
  containing the distinct required entries (8 bytes each);
* **SpGEMM** (C = A B): process p needs the full *rows* of B matching its
  off-process A columns — one message per (owner -> p) pair containing the
  CSR rows (12 bytes per nonzero: 8 value + 4 index).

Returned patterns are (src, dst, size_bytes) arrays directly consumable by
:func:`repro.core.models.phase_cost` and :func:`repro.net.simulate_phase`;
:meth:`CommPattern.bind` converts a pattern to a machine-bound
:class:`repro.comm.CommPhase` for the vectorized batched APIs
(:func:`repro.core.models.phase_cost_many`, :func:`repro.net.simulate_many`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.comm import CommPhase, PhaseStack

from .csr import CSR

SPMV_ENTRY_BYTES = 8
SPGEMM_NNZ_BYTES = 12


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous balanced row partition: rows [starts[p], starts[p+1])."""

    starts: np.ndarray   # [P+1]

    @classmethod
    def balanced(cls, n_rows: int, n_procs: int) -> "RowPartition":
        base = n_rows // n_procs
        extra = n_rows % n_procs
        sizes = np.full(n_procs, base, dtype=np.int64)
        sizes[:extra] += 1
        return cls(np.concatenate([[0], np.cumsum(sizes)]))

    @property
    def n_procs(self) -> int:
        return len(self.starts) - 1

    def owner_of(self, rows) -> np.ndarray:
        return np.searchsorted(self.starts, np.asarray(rows), side="right") - 1

    def rows_of(self, p: int) -> tuple[int, int]:
        return int(self.starts[p]), int(self.starts[p + 1])


@dataclasses.dataclass
class CommPattern:
    """One communication phase: message (src[i] -> dst[i], size[i] bytes)."""

    src: np.ndarray
    dst: np.ndarray
    size: np.ndarray
    n_procs: int

    @property
    def n_msgs(self) -> int:
        return int(self.src.size)

    @property
    def total_bytes(self) -> float:
        return float(self.size.sum())

    def max_msgs_per_proc(self) -> int:
        if self.src.size == 0:
            return 0
        return int(np.bincount(self.dst, minlength=self.n_procs).max())

    def validate(self, where: str | None = None) -> "CommPattern":
        """Run the typed validation layer over this pattern and return it.

        Raises a precise :class:`repro.comm.guard.PatternError` subclass
        for NaN / negative message sizes, out-of-range or non-integral
        ranks, or an int32-overflow arena — before the pattern reaches any
        kernel.  ``where`` labels the pattern in error text (default:
        ``'CommPattern'``).  Returns ``self``, so it chains:
        ``pattern.validate().bind(machine)``.
        """
        from repro.comm.guard import validate_phase
        validate_phase(self, where=where)
        return self

    def bind(self, machine, n_procs: int | None = None,
             validate: bool = False) -> CommPhase:
        """Bind this pattern to a machine: returns a :class:`CommPhase` with
        locality, protocol, torus endpoints and active-sender counts cached.
        ``validate=True`` runs :meth:`validate` first."""
        return CommPhase.build(machine, self.src, self.dst, self.size,
                               n_procs=self.n_procs if n_procs is None else n_procs,
                               validate=validate)

    def rewrite(self, machine, strategy: str):
        """Bind to ``machine`` and apply a node-aware strategy rewrite.

        Returns a :class:`repro.comm.StrategyPlan` whose phase sequence the
        batched entry points price directly (``sequence_cost`` /
        ``simulate_sequence``)."""
        from repro.comm.strategies import rewrite
        return rewrite(self.bind(machine), strategy)

    def best_strategy(self, machine, **kw):
        """Sweep every strategy on this pattern: the model ladder's predicted
        winner plus the simulator's verdict (:func:`repro.comm.best_strategy`)."""
        from repro.comm.strategies import best_strategy
        return best_strategy(self, machine, **kw)


def stack_patterns(patterns, machine) -> PhaseStack:
    """Bind a sweep of :class:`CommPattern` objects (an AMG hierarchy, a
    partition scan) to one machine as a single :class:`repro.comm.PhaseStack`.

    The stack is the fast-path input of the batched entry points: pass it
    straight to :func:`repro.core.models.phase_cost_many` /
    :func:`repro.core.models.model_ladder_many` /
    :func:`repro.net.simulator.simulate_many` to sweep every pattern in one
    segmented pass per quantity.
    """
    return PhaseStack.build([p.bind(machine) for p in patterns])


def _needed_pairs(A: CSR, part: RowPartition) -> tuple[np.ndarray, np.ndarray]:
    """Distinct (requesting proc, off-proc column) pairs over A's nonzeros."""
    rows = np.repeat(np.arange(A.n_rows), A.row_lengths())
    req = part.owner_of(rows)          # proc that owns the row
    own = part.owner_of(A.indices)     # proc that owns the column
    off = req != own
    if not off.any():
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    key = req[off].astype(np.int64) * A.n_cols + A.indices[off]
    uniq = np.unique(key)
    return (uniq // A.n_cols).astype(np.int64), (uniq % A.n_cols).astype(np.int64)


def spmv_comm_pattern(A: CSR, part: RowPartition) -> CommPattern:
    """Messages for the halo exchange of y = A x under ``part``."""
    req, col = _needed_pairs(A, part)
    if req.size == 0:
        return CommPattern(np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64),
                           np.zeros(0), part.n_procs)
    owner = part.owner_of(col)
    # one message per distinct (owner -> requester), size = count * 8
    pair_key = owner * part.n_procs + req
    uniq, counts = np.unique(pair_key, return_counts=True)
    return CommPattern(src=(uniq // part.n_procs).astype(np.int64),
                       dst=(uniq % part.n_procs).astype(np.int64),
                       size=counts.astype(np.float64) * SPMV_ENTRY_BYTES,
                       n_procs=part.n_procs)


# -- incremental SpMV pattern re-derivation ----------------------------------
#
# A local-search move on the row partition (shift one boundary) changes the
# ownership of a handful of rows — and therefore only the messages that
# involve the two adjacent processes.  ``SpmvPatternState`` keeps the
# partition-independent needs of every process (the distinct columns its rows
# touch) as one sorted packed array, so a move re-derives exactly the
# affected messages:
#
# * the *requester* side — messages **to** a changed process — from that
#   process's recomputed need set (O(its rows' nnz));
# * the *owner* side — messages **from** a changed process to everyone else —
#   by counting each unchanged process's needs inside the mover's new
#   contiguous row range: two ``searchsorted`` probes per process on the
#   packed (process, column) array, no nnz traversal at all.
#
# The returned (removed indices, added messages) pair feeds
# :meth:`repro.comm.DeltaStack.apply` directly; survivors keep their arena
# positions, additions append — the delta arena and the state stay in
# lockstep message order.

@dataclasses.dataclass(frozen=True)
class SpmvPatternState:
    """Incrementally-maintained SpMV halo-exchange pattern for one matrix.

    ``pairs`` holds every distinct (row-owner process ``q``, column ``c``)
    pair — including locally-owned columns, because a boundary move can turn
    a local column remote — packed as ``q * n_cols + c`` and globally
    sorted; ``seg[q]:seg[q+1]`` is process ``q``'s slice.  ``src/dst/size``
    mirror the live message order of the delta arena built from this state.

    Successor states created by :func:`spmv_comm_pattern_delta` carry the
    splice of the changed processes' need segments *lazily*: candidate
    evaluation never touches it, so a rejected candidate's state costs
    nothing beyond its own message delta; the splice resolves on first
    access (i.e. when an accepted state is searched from).
    """

    A: CSR
    starts: np.ndarray       # [P+1] current partition boundaries
    src: np.ndarray          # current messages, arena order
    dst: np.ndarray
    size: np.ndarray
    # resolved form {"pairs": ..., "seg": ...}, or the deferred splice
    # {"parent": state, "changed": ..., "segs_new": ...}
    _box: dict = dataclasses.field(repr=False, compare=False,
                                   default_factory=dict)

    @classmethod
    def build(cls, A: CSR, part: RowPartition) -> "SpmvPatternState":
        """Full derivation (the one-time cost a fresh pattern also pays)."""
        starts = np.asarray(part.starts, dtype=np.int64)
        P = part.n_procs
        rows = np.repeat(np.arange(A.n_rows), A.row_lengths())
        req = part.owner_of(rows).astype(np.int64)
        pairs = np.unique(req * A.n_cols + A.indices)
        seg = np.searchsorted(pairs, np.arange(P + 1) * A.n_cols)
        src, dst, size = _pairs_to_messages(pairs, starts, A.n_cols, P)
        return cls(A=A, starts=starts, src=src, dst=dst, size=size,
                   _box={"pairs": pairs, "seg": seg})

    def _resolve(self) -> dict:
        box = self._box
        if "pairs" not in box:
            parent = box.pop("parent")
            changed = box.pop("changed")
            segs_new = box.pop("segs_new")
            P = self.n_procs
            parts, prev = [], 0
            for q in changed:
                parts.append(parent.pairs[parent.seg[prev]:parent.seg[q]])
                parts.append(segs_new[int(q)])
                prev = int(q) + 1
            parts.append(parent.pairs[parent.seg[prev]:])
            box["pairs"] = np.concatenate(parts)
            box["seg"] = np.searchsorted(box["pairs"],
                                         np.arange(P + 1) * self.A.n_cols)
        return box

    @property
    def pairs(self) -> np.ndarray:
        return self._resolve()["pairs"]

    @property
    def seg(self) -> np.ndarray:
        return self._resolve()["seg"]

    @property
    def n_procs(self) -> int:
        return len(self.starts) - 1

    @property
    def part(self) -> RowPartition:
        return RowPartition(self.starts)

    @property
    def pattern(self) -> CommPattern:
        """The current messages as a :class:`CommPattern` (arena order)."""
        return CommPattern(self.src, self.dst, self.size, self.n_procs)


def _pairs_to_messages(pairs, starts, n_cols, P):
    """Messages per distinct (owner -> requester) pair, sorted by (src, dst)
    — the same derivation and order as :func:`spmv_comm_pattern`."""
    if pairs.size == 0:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64),
                np.zeros(0))
    q = pairs // n_cols
    col = pairs % n_cols
    owner = np.searchsorted(starts, col, side="right") - 1
    off = owner != q
    key = owner[off] * P + q[off]
    uniq, counts = np.unique(key, return_counts=True)
    return ((uniq // P).astype(np.int64), (uniq % P).astype(np.int64),
            counts.astype(np.float64) * SPMV_ENTRY_BYTES)


def spmv_comm_pattern_delta(state: SpmvPatternState, new_starts
                            ) -> tuple[np.ndarray, tuple, "SpmvPatternState"]:
    """Re-derive only the messages a partition change affects.

    Returns ``(removed_idx, (src, dst, size), new_state)``: the indices (into
    the state's — and the delta arena's — current message order) of every
    message that involves a process whose row range changed, the replacement
    messages for those processes, and the successor state.  Functional: the
    input state is untouched, so a rejected candidate is discarded for free.
    The surviving + added message multiset always equals a fresh
    :func:`spmv_comm_pattern` under ``new_starts``.
    """
    A = state.A
    starts = state.starts
    P = state.n_procs
    new_starts = np.asarray(new_starts, dtype=np.int64)
    if new_starts.shape != starts.shape:
        raise ValueError("new_starts must keep the process count fixed")
    if (new_starts[0] != 0 or new_starts[-1] != A.n_rows
            or (np.diff(new_starts) < 0).any()):
        raise ValueError("new_starts must be a non-decreasing partition of "
                         f"[0, {A.n_rows}]")
    changed = np.nonzero((starts[:-1] != new_starts[:-1])
                         | (starts[1:] != new_starts[1:]))[0]
    empty = (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64),
             np.zeros(0))
    if changed.size == 0:
        return np.zeros(0, dtype=np.int64), empty, state
    cmask = np.zeros(P, dtype=bool)
    cmask[changed] = True
    removed_idx = np.nonzero(cmask[state.src] | cmask[state.dst])[0]

    # recompute the need segments of the changed processes only
    segs_new = {}
    for q in changed:
        r0, r1 = int(new_starts[q]), int(new_starts[q + 1])
        cols_q = np.unique(A.indices[A.indptr[r0]:A.indptr[r1]])
        segs_new[int(q)] = int(q) * A.n_cols + cols_q

    add_src, add_dst, add_size = [], [], []
    # requester side: messages *to* each changed process, from its needs
    for q in changed:
        cols_q = segs_new[int(q)] - int(q) * A.n_cols
        owner = np.searchsorted(new_starts, cols_q, side="right") - 1
        off = owner != q
        cnt = np.bincount(owner[off], minlength=P)
        o = np.nonzero(cnt)[0]
        add_src.append(o)
        add_dst.append(np.full(o.size, q, dtype=np.int64))
        add_size.append(cnt[o].astype(np.float64) * SPMV_ENTRY_BYTES)
    # owner side: messages *from* each changed process to unchanged ones —
    # count every other process's needs inside the new contiguous row range.
    # Unchanged processes' segments are identical in the current ``pairs``
    # array, so the probes run on it directly; the spliced successor array
    # is deferred (see SpmvPatternState._resolve) and never built for a
    # candidate that gets rejected.
    pairs = state.pairs
    others = np.nonzero(~cmask)[0]
    base = others * A.n_cols
    for o in changed:
        lo, hi = new_starts[o], new_starts[o + 1]
        cnt = (np.searchsorted(pairs, base + hi)
               - np.searchsorted(pairs, base + lo))
        sel = cnt > 0
        add_src.append(np.full(int(sel.sum()), o, dtype=np.int64))
        add_dst.append(others[sel])
        add_size.append(cnt[sel].astype(np.float64) * SPMV_ENTRY_BYTES)

    added = (np.concatenate(add_src) if add_src else empty[0],
             np.concatenate(add_dst) if add_dst else empty[1],
             np.concatenate(add_size) if add_size else empty[2])
    keep = np.ones(state.src.size, dtype=bool)
    keep[removed_idx] = False
    new_state = SpmvPatternState(
        A=A, starts=new_starts,
        src=np.concatenate([state.src[keep], added[0]]),
        dst=np.concatenate([state.dst[keep], added[1]]),
        size=np.concatenate([state.size[keep], added[2]]),
        _box={"parent": state, "changed": changed, "segs_new": segs_new})
    return removed_idx, added, new_state


def spgemm_comm_pattern(A: CSR, B: CSR, part: RowPartition) -> CommPattern:
    """Messages to fetch remote B rows for C = A B under ``part``.

    Process p gathers B rows for its off-process A columns; message size is
    the total nnz of those rows times 12 bytes.
    """
    req, col = _needed_pairs(A, part)
    if req.size == 0:
        return CommPattern(np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64),
                           np.zeros(0), part.n_procs)
    owner = part.owner_of(col)
    row_nnz = B.row_lengths()[col].astype(np.float64)
    pair_key = owner * part.n_procs + req
    order = np.argsort(pair_key, kind="stable")
    pair_key, row_nnz = pair_key[order], row_nnz[order]
    uniq, starts = np.unique(pair_key, return_index=True)
    sums = np.add.reduceat(row_nnz, starts)
    return CommPattern(src=(uniq // part.n_procs).astype(np.int64),
                       dst=(uniq % part.n_procs).astype(np.int64),
                       size=sums * SPGEMM_NNZ_BYTES,
                       n_procs=part.n_procs)
