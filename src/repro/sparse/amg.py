"""Classical (Ruge-Stueben-style) algebraic multigrid in pure numpy.

Builds the hierarchy whose per-level SpMV/SpGEMM communication patterns the
paper models (Figs. 1, 10, 11): successively coarser but denser matrices,
with fine levels sending few large messages and coarse levels sending many
small ones.

Components: classical strength-of-connection, greedy independent-set C/F
splitting (PMIS-flavored, deterministic), direct interpolation with
positive/negative splitting, and the Galerkin product A_c = P^T A P via two
SpGEMMs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSR


def strength_matrix(A: CSR, theta: float = 0.25) -> CSR:
    """Classical strength: keep a_ij with |a_ij| >= theta * max_{k!=i} |a_ik|."""
    rows = np.repeat(np.arange(A.n_rows), A.row_lengths())
    off = rows != A.indices
    mags = np.where(off, np.abs(A.data), 0.0)
    row_max = np.zeros(A.n_rows)
    np.maximum.at(row_max, rows, mags)
    keep = off & (mags >= theta * row_max[rows]) & (mags > 0)
    indptr = np.zeros(A.n_rows + 1, dtype=np.int64)
    np.add.at(indptr, rows[keep] + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSR(indptr, A.indices[keep], A.data[keep], A.shape)


def cf_split(S: CSR, seed: int = 0) -> np.ndarray:
    """Greedy independent-set C/F splitting.

    Returns +1 for C points, -1 for F points.  Weights = in-degree of the
    strength graph (how many points depend on me) with a deterministic random
    tiebreak; repeatedly promote the heaviest unassigned point to C and mark
    its strong neighbors F.
    """
    n = S.n_rows
    ST = S.transpose()
    weight = ST.row_lengths().astype(np.float64)
    rng = np.random.default_rng(seed)
    weight += rng.random(n)
    state = np.zeros(n, dtype=np.int8)          # 0 unassigned
    order = np.argsort(-weight, kind="stable")
    rows = np.repeat(np.arange(n), S.row_lengths())
    # adjacency (union of S and S^T) for marking neighbors F
    nbr_ptr_s, nbr_idx_s = S.indptr, S.indices
    nbr_ptr_t, nbr_idx_t = ST.indptr, ST.indices
    for i in order:
        if state[i] != 0:
            continue
        state[i] = 1                             # C point
        for ptr, idx in ((nbr_ptr_s, nbr_idx_s), (nbr_ptr_t, nbr_idx_t)):
            nbrs = idx[ptr[i]:ptr[i + 1]]
            free = nbrs[state[nbrs] == 0]
            state[free] = -1                     # F points
    state[state == 0] = 1                        # isolated points become C
    return state


def direct_interpolation(A: CSR, S: CSR, state: np.ndarray) -> CSR:
    """Classical direct interpolation with +/- splitting.

    F-point i interpolates from its strong C neighbors j with
        w_ij = -(sum_k a_ik^- / sum_{j in C_i} a_ij^-) * a_ij / a_ii    (negatives)
    plus the symmetric positive-part term; C points interpolate identity.
    """
    n = A.n_rows
    cpts = np.nonzero(state == 1)[0]
    coarse_id = -np.ones(n, dtype=np.int64)
    coarse_id[cpts] = np.arange(len(cpts))
    nc = len(cpts)

    diag = A.diagonal()
    rows_A = np.repeat(np.arange(n), A.row_lengths())
    off = rows_A != A.indices
    neg = off & (A.data < 0)
    pos = off & (A.data > 0)
    sum_neg = np.zeros(n)
    sum_pos = np.zeros(n)
    np.add.at(sum_neg, rows_A[neg], A.data[neg])
    np.add.at(sum_pos, rows_A[pos], A.data[pos])

    # strong C-neighbor entries of S
    rows_S = np.repeat(np.arange(n), S.row_lengths())
    sC = state[S.indices] == 1
    is_f_row = state[rows_S] == -1
    keep = sC & is_f_row
    r, c, v = rows_S[keep], S.indices[keep], S.data[keep]
    csum_neg = np.zeros(n)
    csum_pos = np.zeros(n)
    np.add.at(csum_neg, r[v < 0], v[v < 0])
    np.add.at(csum_pos, r[v > 0], v[v > 0])

    scale_neg = np.divide(sum_neg, csum_neg, out=np.zeros(n),
                          where=csum_neg != 0)
    scale_pos = np.divide(sum_pos, csum_pos, out=np.zeros(n),
                          where=csum_pos != 0)
    w = np.where(v < 0, -scale_neg[r] * v / diag[r],
                 -scale_pos[r] * v / diag[r])

    rows_P = np.concatenate([cpts, r])
    cols_P = np.concatenate([np.arange(nc), coarse_id[c]])
    vals_P = np.concatenate([np.ones(nc), w])
    good = cols_P >= 0
    return CSR.from_coo(rows_P[good], cols_P[good], vals_P[good], (n, nc))


def galerkin(A: CSR, P: CSR) -> CSR:
    """A_c = P^T (A P) — the two SpGEMMs the paper prices per level."""
    AP = A.matmul(P)
    return P.transpose().matmul(AP)


@dataclasses.dataclass
class AMGLevel:
    A: CSR
    P: CSR | None       # prolongation to THIS level's fine grid (None on finest)


def build_hierarchy(A: CSR, theta: float = 0.25, max_levels: int = 12,
                    min_size: int = 64, seed: int = 0,
                    prune_tol: float = 1e-10) -> list[AMGLevel]:
    """Build the AMG hierarchy (list of levels, finest first)."""
    levels = [AMGLevel(A=A, P=None)]
    while len(levels) < max_levels and levels[-1].A.n_rows > min_size:
        Af = levels[-1].A
        S = strength_matrix(Af, theta)
        state = cf_split(S, seed=seed + len(levels))
        nc = int((state == 1).sum())
        if nc == 0 or nc >= Af.n_rows:
            break
        P = direct_interpolation(Af, S, state)
        Ac = galerkin(Af, P).prune(prune_tol)
        levels.append(AMGLevel(A=Ac, P=P))
        if Ac.n_rows <= min_size:
            break
    return levels


# ----------------------------------------------------------- V-cycle --------
def _jacobi(A: CSR, x: np.ndarray, b: np.ndarray, omega: float = 0.7,
            iters: int = 2) -> np.ndarray:
    dinv = 1.0 / A.diagonal()
    for _ in range(iters):
        x = x + omega * dinv * (b - A.spmv(x))
    return x


def vcycle(levels: list[AMGLevel], b: np.ndarray, x: np.ndarray | None = None,
           lvl: int = 0) -> np.ndarray:
    """One V(2,2) cycle with damped-Jacobi smoothing."""
    A = levels[lvl].A
    if x is None:
        x = np.zeros_like(b)
    if lvl == len(levels) - 1 or A.n_rows <= 8:
        # coarsest: a few strong Jacobi sweeps stand in for a direct solve
        return _jacobi(A, x, b, iters=50)
    x = _jacobi(A, x, b)
    r = b - A.spmv(x)
    P = levels[lvl + 1].P
    rc = P.transpose().spmv(r)
    ec = vcycle(levels, rc, None, lvl + 1)
    x = x + P.spmv(ec)
    return _jacobi(A, x, b)
