"""Model-guided local search over row partitions, priced incrementally.

The point of the paper's model ladder is that it is cheap enough to *steer*
communication decisions, not just report them — the follow-up node-aware
strategy work (Lockhart et al., Collom et al.) uses exactly such models to
choose among layouts.  This module closes that loop for the partition axis:
:func:`optimize_partition` walks the space of contiguous row partitions with
boundary-shift moves, prices every candidate with the chosen ladder level,
and keeps the moves the model likes.

Each candidate costs O(changed), not O(matrix):

* :func:`repro.sparse.spmv_comm_pattern_delta` re-derives only the messages
  the move's two processes touch (their recomputed need sets plus two
  ``searchsorted`` probes per other process);
* the resulting (removed, added) message delta feeds
  :meth:`repro.comm.DeltaStack.apply`, which re-prices the mutated arena
  from its incremental caches instead of rebuilding the phase.

``pricer="rebuild"`` runs the same search loop with full per-candidate
reconstruction (fresh pattern extraction + ``CommPhase.build`` + pricing) —
the reference implementation.  Each move also records its candidate
partition (``Move.starts``), so the recorded candidate sequence can be
re-priced independently: ``benchmarks/bench_delta.py`` replays it through
full reconstruction to time delta-vs-rebuild over identical candidates and
to assert the costs agree, and ``tests/test_delta.py`` pins the same
equivalence.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.comm import DeltaStack

from .csr import CSR
from .partition import (CommPattern, RowPartition, SpmvPatternState,
                        spmv_comm_pattern, spmv_comm_pattern_delta)

__all__ = ["Move", "OptimizeResult", "optimize_partition"]

PRICERS = ("delta", "rebuild")


@dataclasses.dataclass(frozen=True)
class Move:
    """One local-search step: a boundary shift and the model's verdict.

    ``cost`` is the candidate's modeled total (NaN when the proposal was
    infeasible and never priced); ``starts`` is the candidate partition —
    kept so a replay (e.g. the rebuild-pricer benchmark) can re-price the
    exact same candidates.
    """

    boundary: int
    shift: int
    cost: float
    accepted: bool
    starts: np.ndarray


@dataclasses.dataclass(frozen=True)
class OptimizeResult:
    """Outcome of a partition search.

    ``verdicts`` holds ``(move index, StrategyVerdict)`` rows for accepted
    moves when ``rerun_strategies=True`` — the strategy sweep re-judged on
    the improved partition.
    """

    partition: RowPartition
    pattern: CommPattern
    initial_cost: float
    cost: float
    moves: list
    verdicts: list

    @property
    def n_accepted(self) -> int:
        return sum(m.accepted for m in self.moves)

    @property
    def improvement(self) -> float:
        """Fractional modeled-cost reduction (0 = no gain)."""
        if self.initial_cost <= 0.0:
            return 0.0
        return 1.0 - self.cost / self.initial_cost


def optimize_partition(A: CSR, machine, n_procs: int | None = None, *,
                       part: RowPartition | None = None, moves: int = 64,
                       step: int | None = None, level: str = "contention",
                       seed: int = 0, pricer: str = "delta",
                       verify: bool = False,
                       rerun_strategies: bool = False) -> OptimizeResult:
    """Greedy local search over contiguous row partitions of ``A``.

    Parameters
    ----------
    A, machine : the operator and the machine whose model prices candidates.
    n_procs / part : either a process count (balanced initial partition) or
        an explicit starting :class:`RowPartition`.
    moves : number of candidate moves to propose and price.
    step : rows moved per boundary shift (default: ``max(1, n_rows /
        (8 P))``).
    level : model-ladder level the search optimizes
        (:data:`repro.core.models.MODEL_LEVELS`).
    seed : drives the move proposals (boundary + direction per step).
    pricer : ``"delta"`` (incremental, the point of this module) or
        ``"rebuild"`` (full per-candidate reconstruction, the reference).
    verify : run the :class:`~repro.comm.DeltaStack` bit-identity check
        after every apply — debugging only, it re-prices the whole arena.
    rerun_strategies : judge the strategy sweep
        (:func:`repro.comm.best_strategy`) on every accepted move's pattern
        and collect the verdicts.

    A move shifts one interior boundary by ``±step`` rows (reassigning that
    many boundary rows between the two adjacent processes); proposals that
    would empty a process are recorded as infeasible and skipped.  A
    candidate is accepted when its modeled total at ``level`` drops.
    """
    from repro.core.models import MODEL_LEVELS, phase_cost_many
    if level not in MODEL_LEVELS:
        raise ValueError(f"unknown model level {level!r}")
    if pricer not in PRICERS:
        raise ValueError(f"unknown pricer {pricer!r}; expected one of "
                         f"{PRICERS}")
    if part is None:
        if n_procs is None:
            raise ValueError("pass n_procs or an explicit part")
        part = RowPartition.balanced(A.n_rows, n_procs)
    starts = np.asarray(part.starts, dtype=np.int64).copy()
    P = len(starts) - 1
    if step is None:
        step = max(1, A.n_rows // (8 * P))

    state = SpmvPatternState.build(A, RowPartition(starts))
    delta = None
    if pricer == "delta":
        delta = DeltaStack.from_phases([state.pattern.bind(machine)],
                                       verify=verify)
        cost = phase_cost_many(delta, level=level)[0].total
    else:
        cost = phase_cost_many([state.pattern.bind(machine)],
                               level=level)[0].total
    initial = cost

    rng = np.random.default_rng(seed)
    trace: list[Move] = []
    verdicts: list = []
    for it in range(moves):
        b = int(rng.integers(1, P)) if P > 1 else 0
        d = int(rng.choice((-step, step)))
        if b == 0:
            trace.append(Move(b, d, math.nan, False, starts.copy()))
            continue
        new_starts = starts.copy()
        new_starts[b] += d
        if not starts[b - 1] < new_starts[b] < starts[b + 1]:
            trace.append(Move(b, d, math.nan, False, new_starts))
            continue
        if pricer == "delta":
            rm, add, cand_state = spmv_comm_pattern_delta(state, new_starts)
            cand = delta.apply(rm, {0: add})
            cand_cost = phase_cost_many(cand, level=level)[0].total
        else:
            cand_state = cand = None
            cand_cost = phase_cost_many(
                [spmv_comm_pattern(A, RowPartition(new_starts))
                 .bind(machine)], level=level)[0].total
        accepted = cand_cost < cost
        trace.append(Move(b, d, cand_cost, accepted, new_starts))
        if accepted:
            starts, cost = new_starts, cand_cost
            if pricer == "delta":
                state, delta = cand_state, cand
            else:
                state = SpmvPatternState.build(A, RowPartition(starts))
            if rerun_strategies:
                from repro.comm.strategies import best_strategy
                phase = (delta.phases[0] if delta is not None
                         else state.pattern.bind(machine))
                verdicts.append((it, best_strategy(phase, seed=seed)))
    return OptimizeResult(partition=RowPartition(starts),
                          pattern=state.pattern, initial_cost=initial,
                          cost=cost, moves=trace, verdicts=verdicts)
