"""Compressed-sparse-row matrices in pure numpy (no scipy available).

Implements the operations the paper's applications need: SpMV, transpose,
and a vectorized Gustavson SpGEMM (row-chunked expand/sort/reduce, no Python
inner loops).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSR:
    indptr: np.ndarray    # [n_rows + 1] int64
    indices: np.ndarray   # [nnz] int64 column ids
    data: np.ndarray      # [nnz] float64
    shape: tuple[int, int]

    # ------------------------------------------------------------ basics ----
    @property
    def nnz(self) -> int:
        return int(self.indices.size)

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.data[s:e]

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)

    def copy(self) -> "CSR":
        return CSR(self.indptr.copy(), self.indices.copy(), self.data.copy(),
                   self.shape)

    @classmethod
    def from_coo(cls, rows, cols, vals, shape) -> "CSR":
        """Build CSR from COO triplets, summing duplicates."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        key = rows * shape[1] + cols
        order = np.argsort(key, kind="stable")
        key, vals = key[order], vals[order]
        uniq, starts = np.unique(key, return_index=True)
        summed = np.add.reduceat(vals, starts) if vals.size else vals
        r = (uniq // shape[1]).astype(np.int64)
        c = (uniq % shape[1]).astype(np.int64)
        indptr = np.zeros(shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, r + 1, 1)
        np.cumsum(indptr, out=indptr)
        return cls(indptr, c, summed, shape)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape)
        rows = np.repeat(np.arange(self.n_rows), self.row_lengths())
        out[rows, self.indices] = self.data
        return out

    # --------------------------------------------------------------- ops ----
    def spmv(self, x: np.ndarray) -> np.ndarray:
        """y = A @ x (numpy reference; the TPU path is kernels/spmv_ell)."""
        prod = self.data * x[self.indices]
        out = np.zeros(self.n_rows)
        rows = np.repeat(np.arange(self.n_rows), self.row_lengths())
        np.add.at(out, rows, prod)
        return out

    def transpose(self) -> "CSR":
        rows = np.repeat(np.arange(self.n_rows), self.row_lengths())
        return CSR.from_coo(self.indices, rows, self.data,
                            (self.n_cols, self.n_rows))

    def diagonal(self) -> np.ndarray:
        d = np.zeros(min(self.shape))
        rows = np.repeat(np.arange(self.n_rows), self.row_lengths())
        on_diag = rows == self.indices
        d[rows[on_diag]] = self.data[on_diag]
        return d

    def scale_rows(self, s: np.ndarray) -> "CSR":
        rows = np.repeat(np.arange(self.n_rows), self.row_lengths())
        return CSR(self.indptr, self.indices, self.data * s[rows], self.shape)

    def matmul(self, B: "CSR", chunk_rows: int = 4096) -> "CSR":
        """C = A @ B — vectorized Gustavson (expand, sort, reduce) by chunks."""
        assert self.n_cols == B.n_rows, (self.shape, B.shape)
        n, m = self.n_rows, B.n_cols
        out_i: list[np.ndarray] = []
        out_j: list[np.ndarray] = []
        out_v: list[np.ndarray] = []
        Blen = B.row_lengths()
        for r0 in range(0, n, chunk_rows):
            r1 = min(r0 + chunk_rows, n)
            s, e = self.indptr[r0], self.indptr[r1]
            if s == e:
                continue
            a_rows = np.repeat(np.arange(r0, r1),
                               np.diff(self.indptr[r0:r1 + 1]))
            a_cols = self.indices[s:e]
            a_vals = self.data[s:e]
            cnt = Blen[a_cols]
            total = int(cnt.sum())
            if total == 0:
                continue
            # flat indices into B storage for each expanded product
            starts = B.indptr[a_cols]
            base = np.repeat(starts, cnt)
            csum = np.concatenate([[0], np.cumsum(cnt)[:-1]])
            within = np.arange(total) - np.repeat(csum, cnt)
            flat = base + within
            ci = np.repeat(a_rows, cnt)
            cj = B.indices[flat]
            cv = np.repeat(a_vals, cnt) * B.data[flat]
            # reduce duplicates within the chunk
            key = ci * m + cj
            order = np.argsort(key, kind="stable")
            key, cv = key[order], cv[order]
            uniq, ustarts = np.unique(key, return_index=True)
            out_i.append((uniq // m).astype(np.int64))
            out_j.append((uniq % m).astype(np.int64))
            out_v.append(np.add.reduceat(cv, ustarts))
        if not out_i:
            return CSR(np.zeros(n + 1, dtype=np.int64),
                       np.zeros(0, dtype=np.int64), np.zeros(0), (n, m))
        rows = np.concatenate(out_i)
        cols = np.concatenate(out_j)
        vals = np.concatenate(out_v)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSR(indptr, cols, vals, (n, m))

    def __matmul__(self, other):
        if isinstance(other, CSR):
            return self.matmul(other)
        return self.spmv(np.asarray(other))

    def prune(self, tol: float = 0.0) -> "CSR":
        """Drop entries with |a_ij| <= tol."""
        keep = np.abs(self.data) > tol
        rows = np.repeat(np.arange(self.n_rows), self.row_lengths())[keep]
        indptr = np.zeros(self.n_rows + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSR(indptr, self.indices[keep], self.data[keep], self.shape)


def eye(n: int) -> CSR:
    return CSR(np.arange(n + 1, dtype=np.int64),
               np.arange(n, dtype=np.int64), np.ones(n), (n, n))


def diag(d: np.ndarray) -> CSR:
    n = len(d)
    return CSR(np.arange(n + 1, dtype=np.int64),
               np.arange(n, dtype=np.int64), np.asarray(d, dtype=np.float64),
               (n, n))
