"""Unified architecture configuration covering all assigned families:
dense / MoE / SSM / hybrid decoder-only LMs, an encoder-decoder (whisper) and
modality-stub backbones (VLM, audio)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 = attention-free)
    n_kv_heads: int
    d_ff: int                   # dense-MLP hidden size (0 = none)
    vocab_size: int

    d_head: int = 0             # default: d_model // n_heads
    mlp_type: str = "swiglu"    # swiglu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 1e4
    qk_norm: bool = False
    m_rope: bool = False        # qwen2-vl multimodal rope
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0   # top-k
    n_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden size
    first_dense_layers: int = 0  # deepseek: leading dense layers
    capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 128

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0
    cross_attention: bool = False

    # modality frontend stub: input_specs() provides precomputed embeddings
    frontend: str | None = None   # patch_embed | audio_conv | None

    # serving: store the decode KV cache as int8 with per-(position, head)
    # scales (halves cache HBM traffic vs bf16; decode is memory-bound)
    kv_quant: bool = False

    norm_eps: float = 1e-6

    # ---------------------------------------------------------- derived ----
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence scaling: SSM and hybrid-with-SSM families."""
        return self.family in ("ssm", "hybrid")

    @property
    def block_kind(self) -> str:
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            return "hybrid"
        if self.is_moe:
            return "moe"
        return "attn"

    def n_params(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOP estimates)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.has_attention and self.block_kind != "ssm":
            hd = self.head_dim
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        if self.has_ssm:
            di = self.ssm_d_inner
            n = self.ssm_state
            per_layer += d * (2 * di + 2 * n + self.ssm_heads) + di * d
        if self.is_moe:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
            per_layer += self.n_shared_experts * 3 * d * self.moe_d_ff
        elif self.d_ff:
            mult = 3 if self.mlp_type == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        total = emb + L * per_layer
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            cross = self.n_layers * 4 * d * d
            total += enc + cross
        return int(total)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k + shared experts)."""
        if not self.is_moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        inactive = (self.n_experts - self.n_experts_active) * 3 * d * self.moe_d_ff
        return int(self.n_params() - L * inactive)
