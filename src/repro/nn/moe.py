"""Mixture-of-experts layer: top-k router + capacity-buffer dispatch.

Dispatch is sort-based (argsort by expert, scatter into an [E, C, d] capacity
buffer, batched expert matmuls, gather-combine) — static shapes throughout,
so it lowers cleanly under pjit with the expert dimension sharded over the
"model" axis (expert parallelism).  Tokens over capacity are dropped, as in
GShard/Switch.

This dense-dispatch formulation is the *baseline* the paper's model critiques:
the scatter/gather lower to all-gathers whose message pattern the queue-search
term punishes; the shard_map all-to-all variant in
:mod:`repro.parallel.ep_a2a` is the optimized path (hillclimb cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig


def moe_param_shapes(cfg: ArchConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    shapes = {
        "router": (d, e),
        "w1": (e, d, f),    # gate
        "w3": (e, d, f),    # up
        "w2": (e, f, d),    # down
    }
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * f
        shapes.update({"shared_w1": (d, sf), "shared_w3": (d, sf),
                       "shared_w2": (sf, d)})
    return shapes


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(n_tokens * cfg.n_experts_active * cfg.capacity_factor
            // cfg.n_experts) + 1
    # round to a lane-friendly multiple
    return max(8, ((c + 7) // 8) * 8)


def _dp_groups(b: int) -> tuple[int, object]:
    """Number of data shards D dividing the batch, and the dp spec (or None).

    Routing/dispatch is *batched over data shards* so every scatter/gather
    carries a leading D dim sharded over the dp axes — GSPMD partitions
    batched scatters cleanly, where a single global [E, C, d] scatter would
    be replicated per device (hundreds of GiB at production shapes).
    """
    from repro.parallel import context as pctx
    ctx = pctx.current()
    if ctx is None:
        return 1, None
    D = 1
    axes = []
    rem = b
    for a in ctx.dp_axes:
        s = ctx.mesh.shape[a]
        if rem % s == 0 and rem >= s:
            D *= s
            axes.append(a)
            rem //= s
        else:
            break
    if not axes:
        return 1, None
    return D, (tuple(axes) if len(axes) > 1 else axes[0])


MOE_CHUNK_TOKENS = 16384   # cap per-shard tokens processed at once


def moe_ffn(x, p, cfg: ArchConfig):
    """x: [b, s, d] -> ([b, s, d], aux_loss).

    Dispatch is batched per data shard; when a shard holds more than
    ``MOE_CHUNK_TOKENS`` tokens (32k prefill, unmicrobatched train), the
    shard's tokens are processed in sequential chunks via lax.scan so the
    gather/sort transients stay bounded (~chunk x K x d per device) instead
    of scaling with the full sequence.
    """
    b, s, d = x.shape
    D, dp_spec = _dp_groups(b)
    T = (b * s) // D                                          # tokens per shard
    if T > MOE_CHUNK_TOKENS and T % MOE_CHUNK_TOKENS == 0:
        sub = T // MOE_CHUNK_TOKENS
        xr = x.reshape(D, sub, MOE_CHUNK_TOKENS, d).swapaxes(0, 1)
        # pin the chunked view's layout: x arrives sequence-sharded (SP) and
        # without the constraint GSPMD replicates the whole reshape per chunk
        xr = _constrain(xr, (None, dp_spec, None, None))

        def body(aux_acc, xc):
            yc, aux = _moe_groups(xc, p, cfg, dp_spec)
            return aux_acc + aux / sub, _constrain(yc, (dp_spec, None, None))

        aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), xr)
        y = ys.swapaxes(0, 1).reshape(b, s, d)
        return y, aux
    y, aux = _moe_groups(x.reshape(D, T, d), p, cfg, dp_spec)
    return y.reshape(b, s, d), aux


def _constrain(t, parts):
    from repro.parallel import context as pctx
    from jax.sharding import NamedSharding, PartitionSpec as P
    ctx = pctx.current()
    if ctx is None:
        return t
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(ctx.mesh, P(*parts)))


def _moe_groups(xf, p, cfg: ArchConfig, dp_spec):
    """Routed-expert forward for [D, T, d] token groups (D over dp axes)."""
    D, T, d = xf.shape
    E, K = cfg.n_experts, cfg.n_experts_active

    dtype = xf.dtype
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                  # [D, T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch): E * sum_e f_e * P_e  (global)
    gi = jnp.broadcast_to(jnp.arange(D)[:, None], (D, T * K))
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (D * T * K)
    aux = E * jnp.sum(me * ce)

    # ---- per-shard sort + GATHER dispatch -----------------------------------
    # Everything indexing the [E, C] capacity grid is a batched gather with
    # indices into the expert-sorted token list; scatters only touch
    # dp-batch-sharded targets ([D, E] counts, [D, T, d] combine), which
    # GSPMD partitions locally.  Scattering into the E-sharded buffer
    # directly would be replicated per device (hundreds of GiB).
    C = capacity(T, cfg)
    eflat = idx.reshape(D, T * K)
    gflat = gate_vals.reshape(D, T * K)
    order = jnp.argsort(eflat, axis=-1)                       # [D, T*K]
    e_sorted = jnp.take_along_axis(eflat, order, axis=-1)
    tok_sorted = order // K
    counts = jnp.zeros((D, E), dtype=jnp.int32).at[gi, eflat].add(1)
    offsets = jnp.cumsum(counts, axis=-1) - counts
    rank = (jnp.arange(T * K)[None, :]
            - jnp.take_along_axis(offsets, e_sorted, axis=-1))
    keep = rank < C

    # slot (e, c) holds the c-th entry of expert e in the sorted list
    gidx = offsets[:, :, None] + jnp.arange(C)[None, None, :]   # [D, E, C]
    in_use = gidx < (offsets + jnp.minimum(counts, C))[:, :, None]
    gclip = jnp.clip(gidx, 0, T * K - 1).reshape(D, E * C)
    xs = jnp.take_along_axis(xf, tok_sorted[..., None], axis=1)  # sorted toks
    buf = jnp.take_along_axis(xs, gclip[..., None], axis=1)
    buf = jnp.where(in_use.reshape(D, E * C)[..., None], buf, 0)
    buf = buf.reshape(D, E, C, d)
    buf = _constrain_moe_buf(buf, dp_spec)

    # ---- batched expert SwiGLU ---------------------------------------------
    gate = jnp.einsum("gecd,edf->gecf", buf, p["w1"])
    up = jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w2"])

    # ---- combine: gather each assignment's slot, unsort, weighted sum ------
    flat_slot = jnp.clip(e_sorted * C + rank, 0, E * C - 1)     # [D, T*K]
    gathered = jnp.take_along_axis(out_buf.reshape(D, E * C, d),
                                   flat_slot[..., None], axis=1)
    g_sorted = jnp.take_along_axis(gflat, order, axis=-1)
    contrib = jnp.where(keep[..., None],
                        gathered * g_sorted[..., None].astype(dtype), 0)
    y = jnp.zeros((D, T, d), dtype=dtype)
    y = y.at[gi, tok_sorted].add(contrib)

    if cfg.n_shared_experts:
        sg = jnp.einsum("gtd,df->gtf", xf, p["shared_w1"])
        su = jnp.einsum("gtd,df->gtf", xf, p["shared_w3"])
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(dtype) * su
        y = y + jnp.einsum("gtf,fd->gtd", sh, p["shared_w2"])

    return y, aux


def _constrain_moe_buf(buf, dp_spec):
    """Pin the capacity buffer to (dp, model-on-E) so expert matmuls run
    expert-parallel instead of GSPMD replicating the scatter output."""
    from repro.parallel import context as pctx
    from jax.sharding import NamedSharding, PartitionSpec as P
    ctx = pctx.current()
    if ctx is None:
        return buf
    E = buf.shape[1]
    tp = ctx.mesh.shape[ctx.model_axis]
    e_ax = ctx.model_axis if E % tp == 0 else None
    ns = NamedSharding(ctx.mesh, P(dp_spec, e_ax, None, None))
    return jax.lax.with_sharding_constraint(buf, ns)
