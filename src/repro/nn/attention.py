"""Grouped-query attention: full (train/prefill), cached decode, cross-attn.

Pure-jnp reference path (what the dry-run lowers — analyzable HLO); the
Pallas flash kernel in :mod:`repro.kernels` is the TPU production path with
identical semantics (validated against this in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import apply_rope, apply_m_rope, rmsnorm

NEG_INF = -1e30


def _project_qkv(x, p, cfg: ArchConfig):
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        x.shape[0], x.shape[1], cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(
        x.shape[0], x.shape[1], cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(
        x.shape[0], x.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_qk(q, k, positions, cfg: ArchConfig):
    if cfg.m_rope:
        return (apply_m_rope(q, positions, cfg.rope_theta),
                apply_m_rope(k, positions, cfg.rope_theta))
    return (apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta))


def _sdpa_block(q, k, v, causal: bool, q_offset):
    """q: [B,Sq,H,D], k/v: [B,Sk,KH,D] -> [B,Sq,H,D] (GQA by head repeat)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    rep = H // KH
    qg = q.reshape(B, Sq, KH, rep, D)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v)
    return out.reshape(B, Sq, H, D)


def _sdpa(q, k, v, causal: bool, q_offset=0, q_chunk: int | None = None):
    """Attention with optional query chunking.

    Long sequences scan over query blocks (each block attends the full K/V
    with masking) so S^2 score tensors never materialize — the pure-jnp
    analogue of the flash kernel's outer loop; the inner body is rematerialized
    in the backward pass.
    """
    B, Sq, H, D = q.shape
    from repro.parallel import context as pctx
    ctx = pctx.current()
    q_chunk = q_chunk or (ctx.q_chunk if ctx else 0)
    if not q_chunk or Sq <= q_chunk or Sq % q_chunk != 0:
        return _sdpa_block(q, k, v, causal, q_offset)
    nb = Sq // q_chunk
    qb = q.reshape(B, nb, q_chunk, H, D)

    if ctx is not None and ctx.unroll_loops:
        outs = [_sdpa_block(qb[:, i], k, v, causal, q_offset + i * q_chunk)
                for i in range(nb)]
        return jnp.stack(outs, axis=1).reshape(B, Sq, H, D)

    @jax.checkpoint
    def body(carry, inp):
        qi, i = inp
        out = _sdpa_block(qi, k, v, causal, q_offset + i * q_chunk)
        return carry, out

    _, outs = jax.lax.scan(body, (),
                           (jnp.moveaxis(qb, 1, 0), jnp.arange(nb)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)


def attention(x, p, cfg: ArchConfig, positions, causal: bool = True):
    """Full self-attention (training / prefill)."""
    q, k, v = _project_qkv(x, p, cfg)
    if cfg.rope_theta:
        q, k = _rope_qk(q, k, positions, cfg)
    out = _sdpa(q, k, v, causal)
    out = out.reshape(x.shape[0], x.shape[1], cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), (k, v)


def decode_attention(x, p, cfg: ArchConfig, cache_k, cache_v, pos,
                     k_scale=None, v_scale=None):
    """One-token decode against a KV cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, KH, D] (sequence dim shardable);
    pos: scalar current position.  With ``cfg.kv_quant`` the cache is int8
    and ``k_scale``/``v_scale`` [B, S_max, KH] hold per-entry scales.
    Returns (out [B,1,d], new_k, new_v[, new_k_scale, new_v_scale]).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(x, p, cfg)
    if cfg.rope_theta:
        positions = jnp.full((B, 1), pos, dtype=jnp.int32)
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[..., None], (B, 1, 3))
        q, k = _rope_qk(q, k, positions, cfg)

    new_scales = ()
    if cfg.kv_quant:
        def quant(val):
            s = jnp.max(jnp.abs(val.astype(jnp.float32)), axis=-1) / 127.0
            s = jnp.maximum(s, 1e-8)                       # [B,1,KH]
            qv = jnp.clip(jnp.round(val.astype(jnp.float32) / s[..., None]),
                          -127, 127).astype(jnp.int8)
            return qv, s
        kq, ks = quant(k)
        vq, vs = quant(v)
        new_k = jax.lax.dynamic_update_slice(cache_k, kq, (0, pos, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache_v, vq, (0, pos, 0, 0))
        nks = jax.lax.dynamic_update_slice(k_scale, ks, (0, pos, 0))
        nvs = jax.lax.dynamic_update_slice(v_scale, vs, (0, pos, 0))
        k_eff = new_k.astype(jnp.float32) * nks[..., None]
        v_eff = (new_v.astype(jnp.float32) * nvs[..., None]).astype(jnp.bfloat16)
        new_scales = (nks, nvs)
    else:
        new_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                             (0, pos, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                             (0, pos, 0, 0))
        k_eff, v_eff = new_k, new_v

    # mask out positions beyond pos
    S = cache_k.shape[1]
    KH, D = cfg.n_kv_heads, cfg.head_dim
    rep = cfg.n_heads // KH
    qg = q.reshape(B, 1, KH, rep, D)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k_eff).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    valid = (jnp.arange(S) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v_eff.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v_eff).reshape(
        B, 1, cfg.n_heads * D).astype(x.dtype)
    return (jnp.einsum("bsh,hd->bsd", out, p["wo"]), new_k, new_v) + new_scales


def cross_attention(x, p, cfg: ArchConfig, enc_out):
    """Decoder cross-attention onto encoder output (whisper)."""
    B, Sq, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, Sq, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(
        B, enc_out.shape[1], cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(
        B, enc_out.shape[1], cfg.n_kv_heads, hd)
    out = _sdpa(q, k, v, causal=False)
    out = out.reshape(B, Sq, cfg.n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])
