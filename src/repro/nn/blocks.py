"""Decoder/encoder blocks, unified across attn / moe / ssm / hybrid families.

Each block is a pure function ``(x, layer_params, cfg, ...) -> x`` designed to
be scanned over stacked layer parameters ([L, ...] leaves).
"""
from __future__ import annotations

import jax.numpy as jnp

from .attention import attention, decode_attention, cross_attention
from .config import ArchConfig
from .layers import mlp, norm
from .moe import moe_ffn
from .ssm import ssm_mixer, ssm_decode


def _norm(x, p, cfg):
    return norm(x, p, cfg.norm_type, cfg.norm_eps)


# ----------------------------------------------------------- full-seq -------
def block_forward(x, lp, cfg: ArchConfig, positions, causal: bool = True,
                  collect_cache: bool = False):
    """One decoder block, full sequence (train / prefill).

    Returns (x, aux_loss, cache_el): ``cache_el`` is a dict of decode-cache
    elements ({"k","v"} and/or {"conv","ssd"}) when ``collect_cache``.
    """
    aux = jnp.zeros((), dtype=jnp.float32)
    cache_el: dict = {}
    kind = cfg.block_kind

    if kind == "ssm":
        res = ssm_mixer(_norm(x, lp["ln1"], cfg), lp["ssm"], cfg,
                        return_state=collect_cache)
        if collect_cache:
            y, (conv_st, ssd_st) = res
            cache_el.update(conv=conv_st, ssd=ssd_st)
        else:
            y = res
        x = x + y
    elif kind == "hybrid":
        xn = _norm(x, lp["ln1"], cfg)
        a_out, kv = attention(xn, lp["attn"], cfg, positions, causal=causal)
        res = ssm_mixer(xn, lp["ssm"], cfg, return_state=collect_cache)
        if collect_cache:
            s_out, (conv_st, ssd_st) = res
            cache_el.update(k=kv[0], v=kv[1], conv=conv_st, ssd=ssd_st)
        else:
            s_out = res
        x = x + 0.5 * (a_out + s_out)
    else:
        a_out, kv = attention(_norm(x, lp["ln1"], cfg), lp["attn"], cfg,
                              positions, causal=causal)
        if collect_cache:
            cache_el.update(k=kv[0], v=kv[1])
        x = x + a_out

    if kind == "moe":
        m_out, aux = moe_ffn(_norm(x, lp["ln2"], cfg), lp["moe"], cfg)
        x = x + m_out
    elif cfg.d_ff:
        x = x + mlp(_norm(x, lp["ln2"], cfg), lp["mlp"], cfg.mlp_type)
    return x, aux, cache_el


def encoder_block(x, lp, cfg: ArchConfig, positions):
    """Bidirectional encoder block (whisper)."""
    a_out, _ = attention(_norm(x, lp["ln1"], cfg), lp["attn"], cfg,
                         positions, causal=False)
    x = x + a_out
    x = x + mlp(_norm(x, lp["ln2"], cfg), lp["mlp"], cfg.mlp_type)
    return x


def cross_block(x, lp, cfg: ArchConfig, positions, enc_out):
    """Decoder block with cross-attention (whisper decoder)."""
    a_out, kv = attention(_norm(x, lp["ln1"], cfg), lp["attn"], cfg,
                          positions, causal=True)
    x = x + a_out
    x = x + cross_attention(_norm(x, lp["ln3"], cfg), lp["xattn"], cfg, enc_out)
    x = x + mlp(_norm(x, lp["ln2"], cfg), lp["mlp"], cfg.mlp_type)
    return x, kv


# -------------------------------------------------------------- decode ------
def block_decode(x, lp, cfg: ArchConfig, cache_l: dict, pos):
    """One-token decode through one block.  Returns (x, new_cache_l)."""
    new_cache = dict(cache_l)
    kind = cfg.block_kind

    def _dec_attn(xn):
        res = decode_attention(xn, lp["attn"], cfg, cache_l["k"],
                               cache_l["v"], pos,
                               k_scale=cache_l.get("k_scale"),
                               v_scale=cache_l.get("v_scale"))
        a_out, nk, nv = res[:3]
        new_cache.update(k=nk, v=nv)
        if cfg.kv_quant:
            new_cache.update(k_scale=res[3], v_scale=res[4])
        return a_out

    if kind == "ssm":
        y, new_conv, new_ssd = ssm_decode(_norm(x, lp["ln1"], cfg), lp["ssm"],
                                          cfg, cache_l["conv"], cache_l["ssd"])
        x = x + y
        new_cache["conv"], new_cache["ssd"] = new_conv, new_ssd
    elif kind == "hybrid":
        xn = _norm(x, lp["ln1"], cfg)
        a_out = _dec_attn(xn)
        s_out, new_conv, new_ssd = ssm_decode(xn, lp["ssm"], cfg,
                                              cache_l["conv"], cache_l["ssd"])
        x = x + 0.5 * (a_out + s_out)
        new_cache.update(conv=new_conv, ssd=new_ssd)
    else:
        x = x + _dec_attn(_norm(x, lp["ln1"], cfg))

    if cfg.cross_attention:
        x = x + cross_attention(_norm(x, lp["ln3"], cfg), lp["xattn"], cfg,
                                cache_l["enc_out"])
        new_cache["enc_out"] = cache_l["enc_out"]

    if kind == "moe":
        m_out, _ = moe_ffn(_norm(x, lp["ln2"], cfg), lp["moe"], cfg)
        x = x + m_out
    elif cfg.d_ff:
        x = x + mlp(_norm(x, lp["ln2"], cfg), lp["mlp"], cfg.mlp_type)
    return x, new_cache
