"""Neural substrate: unified LM implementation for the assigned architectures."""
from .config import ArchConfig
from .model import (param_shapes, abstract_params, init_params, forward_logits,
                    lm_loss, decode_step, prefill, abstract_cache, init_cache,
                    cache_shapes)

__all__ = [
    "ArchConfig", "param_shapes", "abstract_params", "init_params",
    "forward_logits", "lm_loss", "decode_step", "prefill", "abstract_cache",
    "init_cache", "cache_shapes",
]
