"""Unified LM: parameter specs/init + train / prefill / decode forwards.

One implementation covers all 10 assigned architectures; family differences
(MoE, SSD, hybrid, enc-dec, modality stubs) are dispatched via ArchConfig.
Layers are scanned (stacked [L, ...] parameter leaves) to keep HLO size
bounded for 64-80-layer configs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import block_forward, block_decode, encoder_block, cross_block
from .config import ArchConfig
from .layers import norm
from .moe import moe_param_shapes
from .ssm import ssm_param_shapes, ssm_decode_state_shapes

PyTree = Any


# ==================================================================== shapes =
def _norm_shapes(cfg: ArchConfig) -> dict:
    if cfg.norm_type == "layernorm":
        return {"scale": (cfg.d_model,), "bias": (cfg.d_model,)}
    return {"scale": (cfg.d_model,)}


def _attn_shapes(cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    s = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        s["q_norm"] = (hd,)
        s["k_norm"] = (hd,)
    return s


def _mlp_shapes(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    ff = d_ff or cfg.d_ff
    if cfg.mlp_type == "swiglu":
        return {"w1": (cfg.d_model, ff), "w3": (cfg.d_model, ff),
                "w2": (ff, cfg.d_model)}
    return {"w1": (cfg.d_model, ff), "w2": (ff, cfg.d_model)}


def _layer_shapes(cfg: ArchConfig, kind: str | None = None) -> dict:
    kind = kind or cfg.block_kind
    s: dict = {"ln1": _norm_shapes(cfg)}
    if kind == "ssm":
        s["ssm"] = ssm_param_shapes(cfg)
    elif kind == "hybrid":
        s["attn"] = _attn_shapes(cfg)
        s["ssm"] = ssm_param_shapes(cfg)
    else:
        s["attn"] = _attn_shapes(cfg)
    if kind == "moe":
        s["moe"] = moe_param_shapes(cfg)
        s["ln2"] = _norm_shapes(cfg)
    elif cfg.d_ff:
        s["mlp"] = _mlp_shapes(cfg)
        s["ln2"] = _norm_shapes(cfg)
    if cfg.cross_attention:
        s["xattn"] = _attn_shapes(cfg)
        s["ln3"] = _norm_shapes(cfg)
    return s


def param_shapes(cfg: ArchConfig) -> PyTree:
    """Nested dict of parameter shapes (tuples); layers stacked on axis 0."""
    def stack(shapes: dict, n: int) -> dict:
        return jax.tree.map(lambda sh: (n,) + sh, shapes,
                            is_leaf=lambda x: isinstance(x, tuple))

    shapes: dict = {"embed": (cfg.vocab_size, cfg.d_model),
                    "final_norm": _norm_shapes(cfg)}
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab_size)

    n_scanned = cfg.n_layers - cfg.first_dense_layers
    shapes["layers"] = stack(_layer_shapes(cfg), n_scanned)
    if cfg.first_dense_layers:
        dense = {"ln1": _norm_shapes(cfg), "attn": _attn_shapes(cfg),
                 "ln2": _norm_shapes(cfg), "mlp": _mlp_shapes(cfg)}
        shapes["dense_layers"] = stack(dense, cfg.first_dense_layers)
    if cfg.encoder_layers:
        enc = {"ln1": _norm_shapes(cfg), "attn": _attn_shapes(cfg),
               "ln2": _norm_shapes(cfg), "mlp": _mlp_shapes(cfg)}
        shapes["encoder"] = stack(enc, cfg.encoder_layers)
        shapes["enc_final_norm"] = _norm_shapes(cfg)
    if cfg.frontend:
        shapes["frontend_proj"] = (cfg.d_model, cfg.d_model)
    return shapes


def param_dtype(path: tuple, cfg: ArchConfig) -> jnp.dtype:
    """bf16 weights; f32 for norms and SSM dynamics scalars."""
    name = path[-1] if path else ""
    if name in ("scale", "bias", "A_log", "D", "dt_bias", "norm",
                "q_norm", "k_norm"):
        return jnp.float32
    return jnp.bfloat16


def _tree_with_paths(shapes: PyTree):
    # jax.tree_util spelling: jax.tree.flatten_with_path only exists in
    # newer jax releases than the pinned toolchain ships
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, int) for i in x))
    return flat, treedef


def abstract_params(cfg: ArchConfig) -> PyTree:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    shapes = param_shapes(cfg)
    flat, treedef = _tree_with_paths(shapes)
    leaves = [jax.ShapeDtypeStruct(sh, param_dtype(_names(p), cfg))
              for p, sh in flat]
    return jax.tree.unflatten(treedef, leaves)


def _names(path) -> tuple:
    out = []
    for k in path:
        out.append(getattr(k, "key", getattr(k, "idx", str(k))))
    return tuple(out)


def init_params(cfg: ArchConfig, seed: int = 0) -> PyTree:
    """Real random init (smoke tests / small-scale training)."""
    shapes = param_shapes(cfg)
    flat, treedef = _tree_with_paths(shapes)
    rng = jax.random.PRNGKey(seed)
    keys = jax.random.split(rng, len(flat))
    leaves = []
    for (path, sh), k in zip(flat, keys):
        names = _names(path)
        dt = param_dtype(names, cfg)
        name = names[-1]
        if name in ("scale", "norm", "q_norm", "k_norm"):
            leaves.append(jnp.ones(sh, dt))
        elif name in ("bias", "conv_b", "dt_bias"):
            leaves.append(jnp.zeros(sh, dt))
        elif name == "A_log":
            leaves.append(jnp.log(jnp.linspace(1.0, 16.0, sh[-1]))
                          * jnp.ones(sh, dt))
        elif name == "D":
            leaves.append(jnp.ones(sh, dt))
        else:
            fan_in = sh[-2] if len(sh) >= 2 else sh[-1]
            leaves.append((jax.random.normal(k, sh, jnp.float32)
                           / np.sqrt(fan_in)).astype(dt))
    return jax.tree.unflatten(treedef, leaves)


# ==================================================================== fwd ====
def _embed(params, cfg: ArchConfig, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _unembed(params, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def _run_encoder(params, cfg: ArchConfig, frames):
    """Modality stub: precomputed frame embeddings -> encoder stack."""
    x = jnp.einsum("bsd,de->bse", frames.astype(jnp.bfloat16),
                   params["frontend_proj"])
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(h, lp):
        return encoder_block(h, lp, cfg, positions), ()

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return norm(x, params["enc_final_norm"], cfg.norm_type, cfg.norm_eps)


def _constrain_residual(x):
    """Apply the ambient sequence-sharding constraint (Megatron-SP), if any."""
    from repro.parallel import context as pctx
    ctx = pctx.current()
    if ctx is None:
        return x
    ns = ctx.residual_sharding(x.shape[0], x.shape[1])
    if ns is None:
        return x
    return jax.lax.with_sharding_constraint(x, ns)


def _index_layer(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _run_layers_full(params, cfg: ArchConfig, x, positions, enc_out=None,
                     remat: bool = True, collect_kv: bool = False,
                     unroll: bool = False):
    """Scan all decoder layers over a full sequence.

    ``unroll=True`` replaces the scan with a Python loop — used by the
    dry-run's flops calibration (XLA cost_analysis counts while bodies once).
    """
    aux_total = jnp.zeros((), jnp.float32)
    x = _constrain_residual(x)

    if cfg.first_dense_layers:
        dense_cfg = _dense_view(cfg)

        def dbody(carry, lp):
            h, aux = carry
            h, a, _ = block_forward(h, lp, dense_cfg, positions)
            return (_constrain_residual(h), aux + a), ()
        if unroll:
            for i in range(cfg.first_dense_layers):
                (x, aux_total), _ = dbody((x, aux_total),
                                          _index_layer(params["dense_layers"], i))
        else:
            (x, aux_total), _ = jax.lax.scan(dbody, (x, aux_total),
                                             params["dense_layers"])

    if cfg.cross_attention:
        def cbody(carry, lp):
            h, aux = carry
            h, kv = cross_block(h, lp, cfg, positions, enc_out)
            cache_el = {"k": kv[0], "v": kv[1]} if collect_kv else ()
            return (_constrain_residual(h), aux), cache_el
        body = cbody
    else:
        def abody(carry, lp):
            h, aux = carry
            h, a, cache_el = block_forward(h, lp, cfg, positions,
                                           collect_cache=collect_kv)
            return (_constrain_residual(h), aux + a), \
                (cache_el if collect_kv else ())
        body = abody

    if remat:
        body = jax.checkpoint(body)
    if unroll:
        n = jax.tree.leaves(params["layers"])[0].shape[0]
        outs = []
        for i in range(n):
            (x, aux_total), o = body((x, aux_total),
                                     _index_layer(params["layers"], i))
            outs.append(o)
        kvs = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
               if collect_kv else ())
        return x, aux_total, kvs
    (x, aux_total), kvs = jax.lax.scan(body, (x, aux_total), params["layers"])
    return x, aux_total, kvs


def _dense_view(cfg: ArchConfig) -> ArchConfig:
    import dataclasses
    return dataclasses.replace(cfg, n_experts=0, n_experts_active=0)


def forward_logits(params, cfg: ArchConfig, tokens=None, embeds=None,
                   positions=None, enc_frames=None, remat: bool = True,
                   unroll: bool = False):
    """Full-sequence forward -> logits [B, S, V].

    ``embeds`` (precomputed modality embeddings) replaces token lookup for
    [vlm]; ``enc_frames`` feeds the encoder for [audio]; ``positions`` is
    [B, S] (or [B, S, 3] for M-RoPE).
    """
    if embeds is not None:
        x = jnp.einsum("bsd,de->bse", embeds.astype(jnp.bfloat16),
                       params["frontend_proj"]) if cfg.frontend else embeds
    else:
        x = _embed(params, cfg, tokens)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    enc_out = _run_encoder(params, cfg, enc_frames) if cfg.encoder_layers else None
    x, aux, _ = _run_layers_full(params, cfg, x, positions, enc_out, remat,
                                 unroll=unroll)
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    return _unembed(params, cfg, x), aux


def forward_hidden(params, cfg: ArchConfig, tokens=None, embeds=None,
                   positions=None, enc_frames=None, remat: bool = True,
                   unroll: bool = False):
    """Full-sequence forward up to the final norm -> ([B, S, d], aux)."""
    if embeds is not None:
        x = jnp.einsum("bsd,de->bse", embeds.astype(jnp.bfloat16),
                       params["frontend_proj"]) if cfg.frontend else embeds
    else:
        x = _embed(params, cfg, tokens)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    enc_out = _run_encoder(params, cfg, enc_frames) if cfg.encoder_layers else None
    x, aux, _ = _run_layers_full(params, cfg, x, positions, enc_out, remat,
                                 unroll=unroll)
    return norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps), aux


def _chunked_xent(params, cfg: ArchConfig, x, targets, mask,
                  chunk: int = 512, unroll: bool = False):
    """Cross-entropy without materializing [B, S, V] logits.

    Scans over sequence chunks; each chunk's logits are recomputed in the
    backward pass (jax.checkpoint), so live logits memory is
    B * chunk * V_shard instead of B * S * V_shard.
    """
    B, S, d = x.shape
    if S % chunk or S <= chunk:
        lse_tgt = _xent_block(params, cfg, x, targets, mask)
        return lse_tgt / jnp.maximum(mask.sum(), 1)
    nc = S // chunk
    xs = (x.reshape(B, nc, chunk, d).swapaxes(0, 1),
          targets.reshape(B, nc, chunk).swapaxes(0, 1),
          mask.reshape(B, nc, chunk).swapaxes(0, 1))

    @jax.checkpoint
    def body(tot, inp):
        xc, tc, mc = inp
        return tot + _xent_block(params, cfg, xc, tc, mc), ()

    if unroll:
        tot = jnp.zeros((), jnp.float32)
        for i in range(nc):
            tot, _ = body(tot, (xs[0][i], xs[1][i], xs[2][i]))
    else:
        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return tot / jnp.maximum(mask.sum(), 1)


def _xent_block(params, cfg, xc, tc, mc):
    lf = _unembed(params, cfg, xc).astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, tc[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - tgt) * mc)


def lm_loss(params, cfg: ArchConfig, batch: dict, remat: bool = True,
            aux_weight: float = 0.01, unroll: bool = False,
            loss_chunk: int = 512):
    """Next-token cross-entropy (+ MoE load-balance aux), chunked over S."""
    x, aux = forward_hidden(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions=batch.get("positions"),
        enc_frames=batch.get("frames"),
        remat=remat, unroll=unroll)
    B, S, _ = x.shape
    if "targets" in batch:
        targets = batch["targets"]
        mask = jnp.ones((B, S), jnp.float32)
    else:
        tokens = batch["tokens"]
        targets = jnp.concatenate([tokens[:, 1:],
                                   jnp.zeros((B, 1), tokens.dtype)], axis=1)
        mask = jnp.concatenate([jnp.ones((B, S - 1), jnp.float32),
                                jnp.zeros((B, 1), jnp.float32)], axis=1)
    nll = _chunked_xent(params, cfg, x, targets, mask, chunk=loss_chunk,
                        unroll=unroll)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ================================================================= decode ====
def cache_shapes(cfg: ArchConfig, batch: int, max_seq: int) -> PyTree:
    """Shapes of the per-layer decode cache (stacked [L, ...])."""
    kind = cfg.block_kind
    n_scanned = cfg.n_layers - cfg.first_dense_layers
    per: dict = {}
    if kind in ("attn", "moe", "hybrid"):
        per["k"] = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        per["v"] = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_quant:
            per["k_scale"] = (batch, max_seq, cfg.n_kv_heads)
            per["v_scale"] = (batch, max_seq, cfg.n_kv_heads)
    if kind in ("ssm", "hybrid"):
        per.update(ssm_decode_state_shapes(cfg, batch))
    if cfg.cross_attention:
        per["enc_out"] = (batch, cfg.encoder_seq, cfg.d_model)
    shapes = {"layers": {k: (n_scanned,) + v for k, v in per.items()}}
    if cfg.first_dense_layers:
        shapes["dense_layers"] = {
            "k": (cfg.first_dense_layers, batch, max_seq, cfg.n_kv_heads,
                  cfg.head_dim),
            "v": (cfg.first_dense_layers, batch, max_seq, cfg.n_kv_heads,
                  cfg.head_dim)}
    return shapes


def cache_dtype(name: str, cfg: ArchConfig | None = None) -> jnp.dtype:
    if name in ("conv", "ssd"):
        return jnp.float32
    if name.endswith("_scale"):
        return jnp.float32
    if cfg is not None and cfg.kv_quant and name in ("k", "v"):
        return jnp.int8
    return jnp.bfloat16


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int) -> PyTree:
    shapes = cache_shapes(cfg, batch, max_seq)
    return jax.tree_util.tree_map_with_path(
        lambda p, sh: jax.ShapeDtypeStruct(sh, cache_dtype(_names(p)[-1], cfg)),
        shapes, is_leaf=lambda x: isinstance(x, tuple))


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> PyTree:
    shapes = cache_shapes(cfg, batch, max_seq)
    return jax.tree_util.tree_map_with_path(
        lambda p, sh: jnp.zeros(sh, cache_dtype(_names(p)[-1], cfg)),
        shapes, is_leaf=lambda x: isinstance(x, tuple))


def decode_step(params, cfg: ArchConfig, cache: PyTree, token, pos,
                unroll: bool = False):
    """One-token decode.  token: [B] int32; pos: scalar int32.

    Returns (logits [B, V], new_cache).
    """
    x = _embed(params, cfg, token[:, None])

    if cfg.first_dense_layers:
        dense_cfg = _dense_view(cfg)

        def dbody(h, inp):
            lp, cl = inp
            h, ncl = block_decode(h, lp, dense_cfg, cl, pos)
            return h, ncl
        x, new_dense = jax.lax.scan(dbody, x,
                                    (params["dense_layers"],
                                     cache["dense_layers"]))

    # The stacked cache is threaded through the scan CARRY and updated with
    # dynamic_update_index_in_dim: XLA keeps one donated buffer in the while
    # loop, where emitting the cache as scan ys would double-buffer it
    # (2x KV memory at 32k-500k context).
    static = ("enc_out",)

    def body(carry, inp):
        h, layer_cache = carry
        lp, i = inp
        cl = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            layer_cache)
        h, ncl = block_decode(h, lp, cfg, cl, pos)
        layer_cache = jax.tree_util.tree_map_with_path(
            lambda p_, c, n_: c if str(getattr(p_[-1], "key", "")) in static
            else jax.lax.dynamic_update_index_in_dim(
                c, n_.astype(c.dtype), i, 0),
            layer_cache, ncl)
        return (h, layer_cache), ()

    n = jax.tree.leaves(params["layers"])[0].shape[0]
    if unroll:
        carry = (x, cache["layers"])
        for i in range(n):
            carry, _ = body(carry, (_index_layer(params["layers"], i),
                                    jnp.asarray(i)))
        x, new_layer_cache = carry
    else:
        (x, new_layer_cache), _ = jax.lax.scan(
            body, (x, cache["layers"]), (params["layers"], jnp.arange(n)))
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = _unembed(params, cfg, x)[:, 0]
    new_cache = {"layers": new_layer_cache}
    if cfg.first_dense_layers:
        new_cache["dense_layers"] = new_dense
    return logits, new_cache


def prefill(params, cfg: ArchConfig, tokens=None, embeds=None,
            enc_frames=None, max_seq: int | None = None,
            unroll: bool = False):
    """Run the prompt, build the decode cache.  Returns (last_logits, cache).

    Works for every family: attention archs emit packed K/V (padded to
    ``max_seq``); SSM/hybrid archs additionally emit the final conv/SSD
    states from the chunked scan.
    """
    if cfg.frontend and embeds is not None:
        x = jnp.einsum("bsd,de->bse", embeds.astype(jnp.bfloat16),
                       params["frontend_proj"])
    else:
        x = _embed(params, cfg, tokens) if tokens is not None else embeds
    B, S = x.shape[:2]
    max_seq = max_seq or S
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.m_rope:
        positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
    enc_out = _run_encoder(params, cfg, enc_frames) if cfg.encoder_layers else None
    x, _, cache_els = _run_layers_full(params, cfg, x, positions, enc_out,
                                       remat=False, collect_kv=True,
                                       unroll=unroll)
    x = norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:])[:, 0]
    pad = max_seq - S
    cache_layers = dict(cache_els)
    for name in ("k", "v"):
        if name in cache_layers:
            cache_layers[name] = jnp.pad(
                cache_layers[name],
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if cfg.cross_attention:
        n_scanned = cfg.n_layers - cfg.first_dense_layers
        cache_layers["enc_out"] = jnp.broadcast_to(
            enc_out[None], (n_scanned,) + enc_out.shape)
    return logits, {"layers": cache_layers}
