"""Mamba2 SSD (state-space duality) mixer — chunked matmul form.

Training/prefill uses the SSD block decomposition (intra-chunk attention-like
matmuls + inter-chunk recurrent state passing, arXiv:2405.21060 Sec. 5);
decode is the O(1) recurrent update.  Single B/C group (G=1) as in the
assigned configs.

The intra-chunk matmuls are the compute hot-spot; :mod:`repro.kernels.ssd`
provides the Pallas TPU kernel for them, validated against this reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import rmsnorm


def ssm_param_shapes(cfg: ArchConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    return {
        "in_proj": (d, 2 * di + 2 * n + h),
        "conv_w": (cfg.ssm_conv_kernel, conv_ch),
        "conv_b": (conv_ch,),
        "A_log": (h,),
        "D": (h,),
        "dt_bias": (h,),
        "norm": (di,),
        "out_proj": (di, d),
    }


def _split_proj(zxbcdt, cfg: ArchConfig):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, K: int):
    """Depthwise causal conv1d, kernel K (stacked-slice form)."""
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    L = xBC.shape[1]
    out = sum(pad[:, k:k + L, :] * w[k] for k in range(K))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xBC.dtype)


def ssd_chunked(x, Bm, Cm, dt, A_log, D, chunk: int,
                return_final_state: bool = False):
    """SSD scan in chunked matmul form.

    x: [b, l, h, p]; Bm/Cm: [b, l, n]; dt: [b, l, h] (post-softplus).
    Returns y: [b, l, h, p] (and the final SSD state [b, h, n, p] when
    ``return_final_state`` — used by prefill to seed decode).
    """
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, l)
    nc = l // q
    assert nc * q == l, f"seq {l} not divisible by chunk {q}"

    xr = x.reshape(b, nc, q, h, p)
    Br = Bm.reshape(b, nc, q, n)
    Cr = Cm.reshape(b, nc, q, n)
    dtr = dt.reshape(b, nc, q, h).astype(jnp.float32)
    a = -jnp.exp(A_log.astype(jnp.float32)) * dtr          # [b,nc,q,h] log-decay
    cumA = jnp.cumsum(a, axis=2)                            # inclusive
    dtx = (xr.astype(jnp.float32) * dtr[..., None])         # dt_j * x_j

    # ---- intra-chunk (the Pallas-kernel target) ---------------------------
    # scores[b,c,h,i,j] = (C_i . B_j) * exp(cumA_i - cumA_j), i >= j.
    # Mask the *log* decay before exp: the upper triangle has positive
    # exponents that overflow, and where() after exp leaks NaN into grads.
    cb = jnp.einsum("bcin,bcjn->bcij", Cr.astype(jnp.float32),
                    Br.astype(jnp.float32))
    ln_decay = cumA[:, :, :, None, :] - cumA[:, :, None, :, :]  # [b,c,i,j,h]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    ln_decay = jnp.where(mask[None, None, :, :, None], ln_decay, -1e30)
    scores = cb[..., None] * jnp.exp(ln_decay)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, dtx)

    # ---- chunk summary states --------------------------------------------
    seg = jnp.exp(cumA[:, :, -1:, :] - cumA)                # [b,c,q,h]
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Br.astype(jnp.float32),
                     seg, dtx)

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(cumA[:, :, -1, :])                # [b,c,h]

    def step(carry, inp):
        s_in = carry                                        # [b,h,n,p]
        s_c, dec = inp
        out = s_in
        carry = s_in * dec[..., None, None] + s_c
        return carry, out

    s0 = jnp.zeros((b, h, n, p), dtype=jnp.float32)
    s_fin, S_in = jax.lax.scan(step, s0,
                               (jnp.moveaxis(S_c, 1, 0),
                                jnp.moveaxis(chunk_decay, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)                          # [b,nc,h,n,p]

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cr.astype(jnp.float32),
                         S_in, jnp.exp(cumA))
    y = y_intra + y_inter + D.astype(jnp.float32)[None, None, None, :, None] \
        * xr.astype(jnp.float32)
    y = y.reshape(b, l, h, p)
    if return_final_state:
        return y, s_fin
    return y


def ssm_mixer(xin, p, cfg: ArchConfig, return_state: bool = False):
    """Full Mamba2 mixer (training/prefill).  xin: [b, l, d] -> [b, l, d].

    With ``return_state``, also returns (conv_state, ssd_state) ready for
    decode continuation.
    """
    di, n, h, phd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.ssm_conv_kernel
    zxbcdt = jnp.einsum("bld,de->ble", xin, p["in_proj"])
    z, xBC_raw, dt = _split_proj(zxbcdt, cfg)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"], K)
    x = xBC[..., :di].reshape(xin.shape[0], xin.shape[1], h, phd)
    Bm = xBC[..., di:di + n]
    Cm = xBC[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    res = ssd_chunked(x, Bm, Cm, dt, p["A_log"], p["D"], cfg.ssm_chunk,
                      return_final_state=return_state)
    y, s_fin = res if return_state else (res, None)
    y = y.reshape(xin.shape[0], xin.shape[1], di).astype(xin.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(xin.dtype),
                p["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"])
    if return_state:
        conv_state = xBC_raw[:, -(K - 1):, :].astype(jnp.float32)
        return out, (conv_state, s_fin)
    return out


# -------------------------------------------------------------- decode ------
def ssm_decode_state_shapes(cfg: ArchConfig, batch: int) -> dict:
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    return {
        "conv": (batch, cfg.ssm_conv_kernel - 1, di + 2 * n),
        "ssd": (batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
    }


def ssm_decode(xin, p, cfg: ArchConfig, conv_state, ssd_state):
    """One-token recurrent update.  xin: [b, 1, d].

    Returns (y [b,1,d], new_conv_state, new_ssd_state).
    """
    b = xin.shape[0]
    di, n, h, phd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.ssm_conv_kernel
    zxbcdt = jnp.einsum("bld,de->ble", xin, p["in_proj"])
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    # rolling conv buffer: [b, K-1, C] + current input
    window = jnp.concatenate([conv_state, xBC], axis=1)       # [b, K, C]
    new_conv = window[:, 1:, :]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(xin.dtype)
    x = conv_out[:, :di].reshape(b, h, phd)
    Bm = conv_out[:, di:di + n]
    Cm = conv_out[:, di + n:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # [b,h]
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dtv)  # [b,h]
    dtx = x.astype(jnp.float32) * dtv[..., None]               # [b,h,p]
    new_ssd = ssd_state * a[..., None, None] \
        + jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), dtx)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), new_ssd) \
        + p["D"].astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(xin.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(xin.dtype),
                p["norm"], cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, p["out_proj"]), new_conv, new_ssd
