"""Shared neural layers: norms, RoPE / M-RoPE, MLPs.

Functional style: params are dicts of jnp arrays; every function is pure.
Compute is bf16 with f32 norm/softmax accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x, p: dict, kind: str, eps: float):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


# --------------------------------------------------------------- RoPE -------
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Half-split RoPE.  x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                              # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                 sections=(2, 1, 1)) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: head_dim split into (t, h, w) sections.

    x: [..., S, H, D]; positions: [..., S, 3] (temporal, height, width ids —
    text tokens use (t, t, t)).  ``sections`` are relative half-dim weights
    (2:1:1 over D/2 frequency slots, matching Qwen2-VL's 16/24/24 split shape).
    """
    d = x.shape[-1]
    half = d // 2
    w = np.asarray(sections, dtype=np.float64)
    sizes = np.floor(half * w / w.sum()).astype(int)
    sizes[0] += half - sizes.sum()
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)  # [D/2]
    # per-frequency-slot position component: slot i uses section s(i)
    sec_of_slot = np.repeat(np.arange(3), sizes)                  # [D/2]
    pos = positions.astype(jnp.float32)                          # [..., S, 3]
    pos_per_slot = jnp.take(pos, jnp.asarray(sec_of_slot), axis=-1)  # [..., S, D/2]
    ang = pos_per_slot * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP -------
def mlp(x: jnp.ndarray, p: dict, mlp_type: str) -> jnp.ndarray:
    if mlp_type == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, p["w1"])
        up = jnp.einsum("...d,df->...f", x, p["w3"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:  # gelu
        h = jnp.einsum("...d,df->...f", x, p["w1"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w2"])
