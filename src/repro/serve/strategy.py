"""StrategyService: the never-fail query front-end for strategy selection.

The strategy sweep (:func:`repro.comm.best_strategy_many`) is graduating
into a long-lived service: callers hand it traffic shapes (patterns) and
expect an answer for every one of them, whatever the state of the device
backends, the autotune cache, or the input itself.  This module is that
front door.  Contract: :meth:`StrategyService.query_many` **returns one
:class:`ServiceResult` per pattern and never raises** —

* an invalid pattern (NaN sizes, out-of-range ranks, …) comes back as a
  result with ``verdict=None`` and the precise typed
  :class:`repro.comm.guard.PatternError` in ``error``, while the other
  patterns in the batch still price normally;
* a device-backend failure degrades to the numpy bit-identity reference
  inside the stack (DESIGN.md §12) — the verdict is still exact, flagged
  ``degraded=True``, with the events in the
  :class:`repro.comm.health.BackendHealth` ledger;
* should the sweep itself still fail, the service retries the worst-case
  configuration — the ``standard`` strategy alone, priced on the numpy
  backend — and only if *that* fails does it return ``verdict=None`` with
  the error recorded (never raised).

numpy-only import: ``from repro.serve import StrategyService`` works
without jax (the batched :class:`repro.serve.ServeEngine` is a separate,
lazily-imported module).
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["ServiceResult", "StrategyService"]


@dataclasses.dataclass(frozen=True)
class ServiceResult:
    """One pattern's answer from :class:`StrategyService`.

    ``verdict`` is the :class:`repro.comm.StrategyVerdict` (None when even
    the worst-case retry could not price the pattern — then ``error`` holds
    the reason).  ``degraded`` marks any answer that did not come from the
    requested configuration: a backend fallback inside the stack, or the
    service's standard-on-numpy retry.  ``error`` is the triggering
    exception for rejected/failed patterns (a typed
    :class:`repro.comm.guard.PatternError` for invalid input), None for
    clean answers.
    """

    verdict: Any | None
    degraded: bool = False
    error: Exception | None = None

    @property
    def ok(self) -> bool:
        """Whether a verdict was produced (possibly degraded)."""
        return self.verdict is not None


class StrategyService:
    """A hardened, stateful wrapper around :func:`repro.comm.best_strategy_many`.

    Parameters
    ----------
    machine : the machine preset queries bind to (any
        :class:`repro.net.MachineSpec`).
    level : model-ladder level queries price at (default ``'contention'``).
    arrival : simulator arrival regime (``'random'`` / ``'posted'``).
    seed : per-candidate arrival seed (default 0).
    backend : stacked-pass backend request (None = the session default).
    strategies : strategy names to sweep (default: every strategy the
        machine supports, via :func:`repro.comm.strategies_for`).
    validate : run the typed validation layer over every query pattern
        (default True — the service's whole point is rejecting garbage
        precisely instead of pricing it).

    :meth:`query` / :meth:`query_many` never raise; see the module
    docstring for the degradation ladder.  The service is stateless between
    calls except for the process-wide
    :class:`repro.comm.health.BackendHealth` ledger it shares with the
    stack (inspect via :meth:`health`).
    """

    def __init__(self, machine, *, level: str = "contention",
                 arrival: str = "random", seed: int = 0,
                 backend: str | None = None,
                 strategies: tuple[str, ...] | None = None,
                 validate: bool = True):
        self.machine = machine
        self.level = level
        self.arrival = arrival
        self.seed = seed
        self.backend = backend
        self.strategies = strategies
        self.validate = validate

    def health(self):
        """The process-wide :class:`repro.comm.health.BackendHealth` ledger
        (degradation events, quarantines) this service's queries report to."""
        from repro.comm.health import get_health
        return get_health()

    def query(self, pattern) -> ServiceResult:
        """Price one pattern; never raises (the one-pattern
        :meth:`query_many`)."""
        return self.query_many([pattern])[0]

    def query_many(self, patterns) -> list[ServiceResult]:
        """Price a batch of patterns: one :class:`ServiceResult` each.

        Invalid patterns are rejected individually (typed error in
        ``error``) without failing the batch; the valid remainder prices in
        one arena sweep.  A sweep failure retries the worst case —
        ``strategies=('standard',)`` on ``backend='numpy'`` — before giving
        up on a pattern, and any fallback anywhere marks the affected
        results ``degraded=True``.
        """
        from repro.comm.guard import PatternError, validate_phase
        from repro.comm.health import get_health
        from repro.comm.strategies import best_strategy_many

        patterns = list(patterns)
        results: list[ServiceResult | None] = [None] * len(patterns)
        live: list[int] = []
        for i, pat in enumerate(patterns):
            if self.validate:
                try:
                    validate_phase(pat, where=f"query[{i}]")
                except PatternError as e:
                    results[i] = ServiceResult(verdict=None, error=e)
                    continue
            live.append(i)
        if not live:
            return results

        health = get_health()

        def _sweep(idx, strategies, backend):
            verdicts = best_strategy_many(
                [patterns[i] for i in idx], self.machine,
                strategies=strategies, level=self.level,
                arrival=self.arrival, seed=self.seed, backend=backend,
                validate=False)          # already validated above
            return verdicts

        try:
            verdicts = _sweep(live, self.strategies, self.backend)
            for i, v in zip(live, verdicts):
                results[i] = ServiceResult(verdict=v, degraded=v.degraded)
            return results
        except Exception as e:  # noqa: BLE001 - the service must answer
            health.record_failure(str(self.backend or "auto"),
                                  "serve.query_many", e)

        # worst case: the standard strategy alone, priced on numpy — one
        # pattern at a time so a single pathological pattern cannot take
        # the rest of the batch down with it
        for i in live:
            try:
                v = _sweep([i], ("standard",), "numpy")[0]
                results[i] = ServiceResult(verdict=v, degraded=True)
            except Exception as e:  # noqa: BLE001
                health.record_failure("numpy", "serve.query_many", e)
                results[i] = ServiceResult(verdict=None, degraded=True,
                                           error=e)
        return results
