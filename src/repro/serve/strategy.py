"""StrategyService: the never-fail production query path for strategy
selection.

The strategy sweep (:func:`repro.comm.best_strategy_many`) runs here as a
long-lived service: callers hand it traffic shapes (patterns) and expect an
answer for every one of them, whatever the state of the device backends,
the caches, or the input itself.  Contract:
:meth:`StrategyService.query_many` **returns one :class:`ServiceResult`
per pattern and never raises**.  The request path, in order
(DESIGN.md §13):

1. **validation** — an invalid pattern (NaN sizes, out-of-range ranks, …)
   comes back as a result with ``verdict=None`` and the precise typed
   :class:`repro.comm.guard.PatternError` in ``error``; the rest of the
   batch still prices.
2. **admission** — a bounded :class:`repro.serve.admission.AdmissionQueue`
   sheds whole batches under overload (typed
   :class:`~repro.serve.admission.Overloaded` in ``error``) or blocks until
   capacity frees, bounded by the per-request
   :class:`~repro.serve.admission.Deadline` (cooperatively checked at every
   service loop point, never mid-kernel).
3. **cache** — pattern fingerprints
   (:func:`repro.comm.delta.pattern_fingerprint`) key priced verdicts in a
   crash-consistent :class:`repro.serve.cache.ArenaCache`; hits skip the
   sweep entirely (``cached=True``, ``plans`` empty on restored verdicts).
4. **sweep** — cache misses price in one arena sweep on the requested
   backend, wrapped in the service's
   :class:`~repro.serve.admission.RetryPolicy` and a per-backend
   :class:`repro.comm.health.CircuitBreaker`: repeated primary-backend
   failures open the breaker and subsequent batches route straight to the
   numpy reference (full strategy set, ``degraded=True``) until a
   half-open probe heals it.
5. **worst case** — should a sweep still fail, each affected pattern
   retries alone as ``strategies=('standard',)`` on ``backend='numpy'``;
   only if *that* fails does the pattern get ``verdict=None`` with the
   error recorded (never raised).

Traffic drift prices incrementally: :meth:`StrategyService.reprice` diffs
the new shape against a retained :class:`repro.comm.delta.DeltaStack`
arena (:func:`repro.comm.delta.message_delta`), applies the delta at
O(changed) cost, and falls back to a full rebuild when the drift fraction
exceeds the service's threshold or delta verification trips.

numpy-only import: ``from repro.serve import StrategyService`` works
without jax (the batched :class:`repro.serve.ServeEngine` is a separate,
lazily-imported module).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
from typing import Any

from .admission import (AdmissionQueue, Deadline, DeadlineExceeded,
                        Overloaded, RetryPolicy)
from .cache import ArenaCache

__all__ = ["ServiceResult", "StrategyService"]

# "use the service's default timeout" marker for per-call overrides, so an
# explicit timeout=None can still mean "no deadline for this call"
_DEFAULT_TIMEOUT = object()


@dataclasses.dataclass(frozen=True)
class ServiceResult:
    """One pattern's answer from :class:`StrategyService`.

    ``verdict`` is the :class:`repro.comm.StrategyVerdict` (None when even
    the worst-case retry could not price the pattern — then ``error`` holds
    the reason).  ``degraded`` marks any answer that did not come from the
    requested configuration: a backend fallback inside the stack, a
    breaker-open reroute to numpy, or the service's standard-on-numpy
    retry.  ``error`` is the triggering exception for rejected/failed
    patterns (a typed :class:`repro.comm.guard.PatternError` for invalid
    input, :class:`~repro.serve.admission.Overloaded` for shed batches,
    :class:`~repro.serve.admission.DeadlineExceeded` for expired ones),
    None for clean answers.  ``cached`` marks verdicts served from the
    arena cache (exact same numbers as a fresh sweep; ``plans`` is empty
    on verdicts restored from disk or a snapshot).
    """

    verdict: Any | None
    degraded: bool = False
    error: Exception | None = None
    cached: bool = False

    @property
    def ok(self) -> bool:
        """Whether a verdict was produced (possibly degraded)."""
        return self.verdict is not None

    @property
    def overloaded(self) -> bool:
        """Whether the admission queue shed this request."""
        return isinstance(self.error, Overloaded)


def _verdict_body(v) -> dict:
    """A verdict's cacheable numbers as a JSON-safe dict (plans excluded)."""
    return {"model": {k: float(x) for k, x in v.model.items()},
            "sim": {k: float(x) for k, x in v.sim.items()},
            "model_winner": v.model_winner, "sim_winner": v.sim_winner}


def _verdict_from_body(body):
    from repro.comm.strategies import StrategyVerdict
    return StrategyVerdict(plans={}, model=dict(body["model"]),
                           sim=dict(body["sim"]),
                           model_winner=body["model_winner"],
                           sim_winner=body["sim_winner"], degraded=False)


class StrategyService:
    """A hardened, stateful wrapper around :func:`repro.comm.best_strategy_many`.

    Parameters
    ----------
    machine : the machine preset queries bind to (any
        :class:`repro.net.MachineSpec`).
    level : model-ladder level queries price at (default ``'contention'``).
    arrival : simulator arrival regime (``'random'`` / ``'posted'``).
    seed : per-candidate arrival seed (default 0).
    backend : stacked-pass backend request (None = the session default).
    strategies : strategy names to sweep (default: every strategy the
        machine supports, via :func:`repro.comm.strategies_for`).
    validate : run the typed validation layer over every query pattern
        (default True — the service's whole point is rejecting garbage
        precisely instead of pricing it).
    cache : an :class:`repro.serve.cache.ArenaCache` for priced verdicts
        (share one across services for a shared cache), or None for a
        fresh memory-only cache.  Keys mix the pattern fingerprint with
        the full pricing configuration, so services with different
        levels/seeds/machines never cross-serve.
    admission : an :class:`repro.serve.admission.AdmissionQueue` (share
        one across services for a global load bound), or None for a fresh
        default queue (capacity 64, policy ``'reject'``).
    retry : a :class:`repro.serve.admission.RetryPolicy` for the primary
        sweep, or None for a single attempt (no retry) — note the pinned
        fallback ladder runs either way.
    timeout : default per-request deadline in seconds (None = none);
        ``query_many(timeout=...)`` overrides per call.
    breaker_threshold / breaker_reset : the per-backend circuit breaker's
        consecutive-failure trip count and open-state hold in seconds
        (see :class:`repro.comm.health.CircuitBreaker`); the breaker lives
        in the process-wide health ledger, shared by every service
        pricing the same backend.
    drift_threshold : :meth:`reprice` falls back to a full rebuild when
        ``(removed + added) / new_messages`` exceeds this fraction
        (default 0.25).
    verify_reprice : re-check the delta bit-identity contract on every
        reprice (slow; a trip degrades to a rebuild, never an error).
    arena_capacity : how many repricing arenas (:class:`DeltaStack`)
        the service retains in memory, LRU (default 16).

    :meth:`query` / :meth:`query_many` / :meth:`reprice` never raise; see
    the module docstring for the degradation ladder.  Thread-safe: any
    number of callers may query concurrently.
    """

    def __init__(self, machine, *, level: str = "contention",
                 arrival: str = "random", seed: int = 0,
                 backend: str | None = None,
                 strategies: tuple[str, ...] | None = None,
                 validate: bool = True,
                 cache: ArenaCache | None = None,
                 admission: AdmissionQueue | None = None,
                 retry: RetryPolicy | None = None,
                 timeout: float | None = None,
                 breaker_threshold: int = 3,
                 breaker_reset: float = 30.0,
                 drift_threshold: float = 0.25,
                 verify_reprice: bool = False,
                 arena_capacity: int = 16):
        self.machine = machine
        self.level = level
        self.arrival = arrival
        self.seed = seed
        self.backend = backend
        self.strategies = strategies
        self.validate = validate
        self.cache = cache if cache is not None else ArenaCache()
        self.admission = admission if admission is not None else AdmissionQueue()
        self.retry = retry
        self.timeout = timeout
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_reset = float(breaker_reset)
        self.drift_threshold = float(drift_threshold)
        self.verify_reprice = bool(verify_reprice)
        if arena_capacity < 1:
            raise ValueError(
                f"arena_capacity must be >= 1, got {arena_capacity}")
        self.arena_capacity = int(arena_capacity)
        self._arenas: collections.OrderedDict[str, Any] = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        mname = getattr(machine, "name", type(machine).__name__)
        strat = ",".join(strategies) if strategies else "auto"
        self._config_token = (f"{mname}|{getattr(machine, 'n_procs', '?')}|"
                              f"{level}|{arrival}|{seed}|{strat}|"
                              f"{backend or 'auto'}")

    # -- introspection --------------------------------------------------------
    def health(self):
        """The process-wide :class:`repro.comm.health.BackendHealth` ledger
        (degradation events, quarantines, circuit breakers) this service's
        queries report to."""
        from repro.comm.health import get_health
        return get_health()

    def snapshot(self) -> dict:
        """The verdict cache as a versioned, checksummed, JSON-safe dict
        (:meth:`repro.serve.cache.ArenaCache.snapshot`) — feed it to a
        fresh service's :meth:`restore` for a warm restart."""
        return self.cache.snapshot()

    def restore(self, snapshot: dict) -> int:
        """Warm-start the verdict cache from a :meth:`snapshot`; returns
        how many entries landed (0, with a health event, when ``snapshot``
        is damaged or version-skewed — never an error)."""
        return self.cache.restore(snapshot)

    def _key(self, pattern) -> str:
        from repro.comm.delta import pattern_fingerprint
        raw = pattern_fingerprint(pattern) + "|" + self._config_token
        return hashlib.sha256(raw.encode()).hexdigest()

    # -- the query path -------------------------------------------------------
    def query(self, pattern, *,
              timeout: float | None = _DEFAULT_TIMEOUT) -> ServiceResult:
        """Price one pattern (the one-pattern :meth:`query_many`, same
        ``pattern`` / ``timeout`` contract); never raises."""
        return self.query_many([pattern], timeout=timeout)[0]

    def query_many(self, patterns, *,
                   timeout: float | None = _DEFAULT_TIMEOUT
                   ) -> list[ServiceResult]:
        """Price a batch of patterns: one :class:`ServiceResult` each.

        ``timeout`` (seconds; omitted = the service's ``timeout``, an
        explicit None = no deadline for this call) arms a
        cooperative per-request deadline checked at every service loop
        point — admission wait, before the sweep, between retry attempts,
        and before each worst-case fallback pattern — turning expiry into
        per-pattern :class:`~repro.serve.admission.DeadlineExceeded` error
        results.  Invalid patterns are rejected individually (typed error
        in ``error``) without failing the batch; cache hits return
        immediately (``cached=True``); the remainder prices in one arena
        sweep behind admission control, the retry policy, and the
        per-backend circuit breaker.  Any fallback anywhere marks the
        affected results ``degraded=True``.  Never raises.
        """
        from repro.comm.guard import PatternError, validate_phase

        patterns = list(patterns)
        results: list[ServiceResult | None] = [None] * len(patterns)
        deadline = Deadline(self.timeout if timeout is _DEFAULT_TIMEOUT
                            else timeout)
        live: list[int] = []
        for i, pat in enumerate(patterns):
            if self.validate:
                try:
                    validate_phase(pat, where=f"query[{i}]")
                except PatternError as e:
                    results[i] = ServiceResult(verdict=None, error=e)
                    continue
            live.append(i)
        if not live:
            return results

        try:
            self.admission.acquire(len(live), deadline)
        except (Overloaded, DeadlineExceeded) as e:
            for i in live:
                results[i] = ServiceResult(verdict=None, error=e)
            return results
        try:
            misses: list[int] = []
            keys: dict[int, str] = {}
            for i in live:
                keys[i] = self._key(patterns[i])
                body = self.cache.get(keys[i])
                if body is not None:
                    results[i] = ServiceResult(
                        verdict=_verdict_from_body(body), cached=True)
                else:
                    misses.append(i)
            if misses:
                self._price(patterns, misses, keys, results, deadline)
        finally:
            self.admission.release(len(live))
        return results

    def _price(self, patterns, misses, keys, results, deadline) -> None:
        """Sweep the cache-miss patterns through the hardened ladder,
        filling ``results`` in place (one result per index in ``misses``,
        whatever happens)."""
        from repro.comm import strategies as _strategies
        from repro.comm.health import get_health

        health = get_health()
        backend_label = str(self.backend or "auto")

        def sweep(idx, strats, backend):
            return _strategies.best_strategy_many(
                [patterns[i] for i in idx], self.machine,
                strategies=strats, level=self.level, arrival=self.arrival,
                seed=self.seed, backend=backend,
                validate=False)          # already validated above

        def fill(idx, verdicts, *, degraded=None, cacheable=True):
            for i, v in zip(idx, verdicts):
                deg = v.degraded if degraded is None else degraded
                results[i] = ServiceResult(verdict=v, degraded=deg)
                if cacheable:
                    self.cache.put(keys[i], _verdict_body(v))

        def expire(idx, e):
            for i in idx:
                if results[i] is None:
                    results[i] = ServiceResult(verdict=None, error=e)

        try:
            deadline.check(where="sweep")
        except DeadlineExceeded as e:
            expire(misses, e)
            return

        rerouted = False
        if backend_label != "numpy":
            breaker = health.breaker_for(
                backend_label, fail_threshold=self.breaker_threshold,
                reset_after=self.breaker_reset)
            if breaker.allow():
                retry = self.retry if self.retry is not None \
                    else RetryPolicy(attempts=1)

                def on_failure(e, attempt):
                    breaker.record_failure()

                try:
                    verdicts = retry.run(
                        lambda: sweep(misses, self.strategies, self.backend),
                        deadline=deadline, on_failure=on_failure)
                    breaker.record_success()
                    fill(misses, verdicts)
                    return
                except DeadlineExceeded as e:
                    expire(misses, e)
                    return
                except Exception as e:  # noqa: BLE001 - the service answers
                    health.record_failure(backend_label, "serve.query_many", e)
            else:
                rerouted = True
        if rerouted or backend_label == "numpy":
            # breaker open: full strategy set on the numpy reference (same
            # numbers — the fallback is the bit-identity reference); or
            # numpy was the requested backend in the first place
            try:
                deadline.check(where="numpy sweep")
                verdicts = sweep(misses, self.strategies, "numpy")
                fill(misses, verdicts, degraded=rerouted or None)
                return
            except DeadlineExceeded as e:
                expire(misses, e)
                return
            except Exception as e:  # noqa: BLE001
                health.record_failure("numpy", "serve.query_many", e)

        # worst case: the standard strategy alone, priced on numpy — one
        # pattern at a time so a single pathological pattern cannot take
        # the rest of the batch down with it.  Not cached: the one-strategy
        # verdict is not the configured sweep's answer.
        for i in misses:
            try:
                deadline.check(where=f"fallback[{i}]")
                v = sweep([i], ("standard",), "numpy")[0]
                results[i] = ServiceResult(verdict=v, degraded=True)
            except DeadlineExceeded as e:
                results[i] = ServiceResult(verdict=None, error=e)
            except Exception as e:  # noqa: BLE001
                health.record_failure("numpy", "serve.query_many", e)
                results[i] = ServiceResult(verdict=None, degraded=True,
                                           error=e)

    # -- drift repricing ------------------------------------------------------
    def _remember_arena(self, fp: str, arena) -> None:
        with self._lock:
            self._arenas[fp] = arena
            self._arenas.move_to_end(fp)
            while len(self._arenas) > self.arena_capacity:
                self._arenas.popitem(last=False)

    def reprice(self, old, new, *,
                timeout: float | None = _DEFAULT_TIMEOUT) -> ServiceResult:
        """Price drifted traffic ``new`` incrementally against ``old``.

        ``old`` is a previously-repriced (or any) pattern; ``new`` is the
        drifted shape; ``timeout`` arms the same per-request deadline as
        :meth:`query_many`.  The service diffs the shapes as message
        multisets (:func:`repro.comm.delta.message_delta`), applies the
        delta to a retained :class:`repro.comm.delta.DeltaStack` arena at
        O(changed) cost, and prices the mutated phase through the full
        hardened query path (admission, cache, breaker, fallbacks) — so
        repeated drift against a warm cache is nearly free.  Falls back to
        a plain :meth:`query` of ``new`` when the drift fraction exceeds
        ``drift_threshold``, no arena for ``old`` can be built, or delta
        verification trips (``verify_reprice=True``) — with the trip
        recorded in the health ledger.  Never raises.

        The repriced verdict is for the *canonical mutated order*
        (survivors of ``old`` in place, additions appended): bit-identical
        to rebuilding that order from scratch, and the same message
        multiset as ``new``.
        """
        from repro.comm.delta import (DeltaStack, message_delta,
                                      pattern_fingerprint)
        from repro.comm.guard import PatternError, validate_phase
        from repro.comm.health import get_health

        if self.validate:
            try:
                validate_phase(new, where="reprice(new)")
            except PatternError as e:
                return ServiceResult(verdict=None, error=e)

        old_fp = pattern_fingerprint(old)
        with self._lock:
            arena = self._arenas.get(old_fp)
        if arena is None:
            try:
                arena = DeltaStack.from_phases([old.bind(self.machine)]
                                               if hasattr(old, "bind")
                                               else [old])
                self._remember_arena(old_fp, arena)
            except Exception as e:  # noqa: BLE001 - degrade to full rebuild
                get_health().record_failure("numpy", "serve.reprice", e)
                return self.query(new, timeout=timeout)

        removed, added = message_delta(arena.phases[0], new)
        n_new = int(getattr(new, "n_msgs", len(new.src)))
        frac = (removed.size + added[0].size) / max(1, n_new)
        if frac > self.drift_threshold:
            result = self.query(new, timeout=timeout)
            if result.ok:
                try:
                    fresh = DeltaStack.from_phases(
                        [new.bind(self.machine)] if hasattr(new, "bind")
                        else [new])
                    self._remember_arena(pattern_fingerprint(new), fresh)
                except Exception:  # noqa: BLE001 - arena retention is best-effort
                    pass
            return result

        try:
            mutated = arena.apply(removed, {0: added},
                                  verify=self.verify_reprice)
        except Exception as e:  # noqa: BLE001 - verify trip or bad delta
            get_health().record_failure("numpy", "serve.reprice", e)
            return self.query(new, timeout=timeout)

        phase = mutated.phases[0]
        result = self.query_many([phase], timeout=timeout)[0]
        if result.ok:
            self._remember_arena(
                pattern_fingerprint(phase), mutated)
        return result
