"""Batched serving engine: prefill + decode with slot-based batching.

A fixed-size batch of decode slots; requests queue up, are prefetched into
free slots (prefill), and decode proceeds for the whole batch every step
(continuous-batching-lite: finished slots are refilled between steps without
stopping the batch).  CPU-runnable with smoke configs; the same
``decode_step`` is what the dry-run lowers at production shapes.

jax is imported lazily, at :class:`ServeEngine` construction: importing
this module (or touching ``repro.serve.ServeEngine``) on a numpy-only host
works, and building an engine there fails with one clear ``RuntimeError``
instead of an import-time crash at package-attribute access.
"""
from __future__ import annotations

import dataclasses
import typing
from collections import deque

import numpy as np

if typing.TYPE_CHECKING:               # repro.nn pulls in jax at import time
    from repro.nn.config import ArchConfig

jax = jnp = M = None       # bound by _require_jax at first engine construction


def _require_jax() -> None:
    """Bind the module's ``jax`` / ``jnp`` / model globals, or raise a
    clear ``RuntimeError`` on hosts without jax (the numpy-only
    :class:`repro.serve.StrategyService` is unaffected)."""
    global jax, jnp, M
    if jax is not None:
        return
    try:
        import jax as _jax
        import jax.numpy as _jnp
        from repro.nn import model as _M
    except ImportError as e:
        raise RuntimeError(
            "ServeEngine needs jax, which is not importable on this host; "
            "install jax or use the numpy-only repro.serve.StrategyService"
        ) from e
    jax, jnp, M = _jax, _jnp, _M


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_seq: int = 128, greedy: bool = True):
        _require_jax()
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, dtype=np.int64)
        self.finished: list[Request] = []
        self.cache = M.init_cache(cfg, batch_slots, max_seq)
        self._decode = jax.jit(
            lambda p, c, t, i: M.decode_step(p, cfg, c, t, i))

    def submit(self, req: Request):
        """Enqueue a request after validating it.

        A malformed request is rejected here with a precise ``ValueError``
        instead of crashing (or silently wedging) the shared batch loop
        mid-decode: the prompt must be non-empty, ``max_new_tokens`` must be
        positive, and prompt plus generation budget must fit the engine's
        ``max_seq`` cache window.
        """
        if not req.prompt:
            raise ValueError(f"request {req.uid}: prompt must be non-empty")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}")
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt length {len(req.prompt)} "
                f"exceeds the engine's max_seq = {self.max_seq} window")
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        """Fill free slots by decoding the prompt token-by-token.

        Prompt ingestion reuses decode_step (teacher-forcing the prompt);
        attention archs could use the fused prefill path, but stepwise works
        for every family including SSM states.
        """
        for s in range(self.B):
            if self.slots[s] is None and self.queue:
                req = self.queue.popleft()
                self.slots[s] = req
                self._reset_slot(s)
                self.pos[s] = 0
                for t in req.prompt[:-1]:
                    self._step_single(s, t)
                req._next = req.prompt[-1]

    def _reset_slot(self, s: int):
        """Zero a reused slot's recurrent state.

        KV entries are gated by position masks, but SSM conv/ssd states are
        unbounded accumulators and must be cleared on slot reuse.
        """
        def f(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name in ("conv", "ssd"):
                return leaf.at[:, s].set(0)
            return leaf
        self.cache = jax.tree_util.tree_map_with_path(f, self.cache)

    def _step_single(self, s: int, token: int):
        """Advance one slot one token (prompt ingestion)."""
        toks = np.zeros(self.B, dtype=np.int32)
        toks[s] = token
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          int(self.pos[s]))
        self.pos[s] += 1
        return logits

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit work, decode one token for active slots."""
        self._admit()
        active = [s for s in range(self.B) if self.slots[s] is not None]
        if not active:
            return False
        # batch decode: each slot advances with its own pending token.
        # Positions differ per slot; decode_step takes one pos, so slots at
        # different depths step in sub-groups of equal position.
        by_pos: dict[int, list[int]] = {}
        for s in active:
            by_pos.setdefault(int(self.pos[s]), []).append(s)
        for pos, group in by_pos.items():
            toks = np.zeros(self.B, dtype=np.int32)
            for s in group:
                toks[s] = self.slots[s]._next
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks), pos)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s in group:
                req = self.slots[s]
                tok = int(nxt[s])
                req.output.append(tok)
                req._next = tok
                self.pos[s] += 1
                if (len(req.output) >= req.max_new_tokens
                        or tok == req.eos_id
                        or self.pos[s] >= self.max_seq - 1):
                    req.done = True
                    self.slots[s] = None
                    self.finished.append(req)
        return True

    def run_until_done(self, max_ticks: int = 1000) -> list[Request]:
        """Run engine ticks until queue and slots drain (or ``max_ticks``).

        Returns every request completed so far, in completion order (the
        engine's cumulative ``finished`` list).
        """
        ticks = 0
        while (self.queue or any(self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
