"""Serving layer: the batched decode engine and the strategy query service.

:class:`ServeEngine` / :class:`Request` (in :mod:`repro.serve.engine`)
need jax; :class:`StrategyService` / :class:`ServiceResult` (in
:mod:`repro.serve.strategy`) are numpy-only.  Imports are lazy per
attribute so ``from repro.serve import StrategyService`` works on hosts
without jax.
"""
__all__ = ["ServeEngine", "Request", "StrategyService", "ServiceResult"]

_ENGINE = ("ServeEngine", "Request")
_STRATEGY = ("StrategyService", "ServiceResult")


def __getattr__(name):
    if name in _ENGINE:
        from . import engine
        return getattr(engine, name)
    if name in _STRATEGY:
        from . import strategy
        return getattr(strategy, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
