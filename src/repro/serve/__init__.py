"""Serving layer: the batched decode engine and the strategy query service.

:class:`ServeEngine` / :class:`Request` (in :mod:`repro.serve.engine`)
need jax — imported lazily at engine construction, so touching them on a
numpy-only host raises one clear error instead of an import crash.
:class:`StrategyService` / :class:`ServiceResult` (in
:mod:`repro.serve.strategy`), the admission layer
(:class:`AdmissionQueue` / :class:`Deadline` / :class:`RetryPolicy` and
the typed :class:`Overloaded` / :class:`DeadlineExceeded` errors, in
:mod:`repro.serve.admission`) and the crash-consistent
:class:`ArenaCache` (:mod:`repro.serve.cache`) are numpy-only.  Imports
are lazy per attribute so ``from repro.serve import StrategyService``
works on hosts without jax.
"""
__all__ = ["ServeEngine", "Request", "StrategyService", "ServiceResult",
           "AdmissionQueue", "Deadline", "RetryPolicy", "Overloaded",
           "DeadlineExceeded", "ArenaCache"]

_ENGINE = ("ServeEngine", "Request")
_STRATEGY = ("StrategyService", "ServiceResult")
_ADMISSION = ("AdmissionQueue", "Deadline", "RetryPolicy", "Overloaded",
              "DeadlineExceeded")
_CACHE = ("ArenaCache",)


def __getattr__(name):
    if name in _ENGINE:
        from . import engine
        return getattr(engine, name)
    if name in _STRATEGY:
        from . import strategy
        return getattr(strategy, name)
    if name in _ADMISSION:
        from . import admission
        return getattr(admission, name)
    if name in _CACHE:
        from . import cache
        return getattr(cache, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
