"""Admission control for the strategy service: queue, deadlines, retry.

Three pieces, all numpy-free and jax-free, shared by
:class:`repro.serve.StrategyService`:

* :class:`AdmissionQueue` — a bounded counter of in-flight work units with
  two load-shedding policies: ``'reject'`` sheds the newest batch with a
  typed :class:`Overloaded` (the service turns it into per-pattern error
  results, never an exception), ``'block'`` parks the caller on a condition
  variable until capacity frees or its :class:`Deadline` expires.  A batch
  larger than the whole capacity is admitted when the queue is idle, so an
  oversized request degrades to serial admission instead of wedging forever.

* :class:`Deadline` — a cooperative per-request deadline over a monotonic
  clock, the same pattern the autotune probe uses
  (:mod:`repro.kernels.comm_stack`): construct once, call :meth:`check` at
  loop points.  Armed deadlines pass through the ``serve.deadline`` fault
  site, so a chaos run can expire any request deterministically.

* :class:`RetryPolicy` — deterministic jittered exponential backoff for the
  service's primary-backend sweep.  The jitter stream is seeded, so a test
  replays the exact delay sequence.

Everything here raises only the two typed errors below; the service catches
both and returns them inside :class:`repro.serve.ServiceResult`.
"""
from __future__ import annotations

import contextlib
import random
import threading
import time

__all__ = ["Overloaded", "DeadlineExceeded", "Deadline", "AdmissionQueue",
           "RetryPolicy", "ADMISSION_POLICIES"]

#: The load-shedding policies :class:`AdmissionQueue` accepts.
ADMISSION_POLICIES = ("reject", "block")


class Overloaded(RuntimeError):
    """The admission queue shed this request (policy ``'reject'``).

    Carried in :attr:`repro.serve.ServiceResult.error`; the service never
    raises it at a caller.
    """


class DeadlineExceeded(TimeoutError):
    """A per-request deadline expired (or was expired by an injected fault).

    A ``TimeoutError`` so callers guarding against real timeouts see the
    same exception family; carried in
    :attr:`repro.serve.ServiceResult.error`, never raised at a caller by
    the service.
    """


class Deadline:
    """A cooperative deadline: construct with ``timeout``, :meth:`check` at
    loop points.

    Parameters
    ----------
    timeout : seconds from now until expiry, or None for no deadline (every
        method becomes a no-op — callers hold one ``Deadline`` object
        unconditionally instead of branching).
    clock : the time source (default ``time.monotonic``); injectable so
        tests expire deadlines without sleeping.
    """

    __slots__ = ("timeout", "_clock", "_expires")

    def __init__(self, timeout: float | None = None, clock=time.monotonic):
        if timeout is not None and timeout < 0:
            raise ValueError(f"timeout must be >= 0, got {timeout}")
        self.timeout = None if timeout is None else float(timeout)
        self._clock = clock
        self._expires = None if timeout is None else clock() + float(timeout)

    def remaining(self) -> float | None:
        """Seconds left (>= 0.0), or None when no deadline is armed."""
        if self._expires is None:
            return None
        return max(0.0, self._expires - self._clock())

    @property
    def expired(self) -> bool:
        """Whether the deadline has passed (always False when unarmed)."""
        return self._expires is not None and self._clock() >= self._expires

    def check(self, where: str = "request") -> None:
        """Raise :class:`DeadlineExceeded` if the deadline has passed.

        ``where`` labels the enforcement point in the error text.  Armed
        deadlines fire the ``serve.deadline`` fault site first, so an
        injected fault expires the request exactly like a real timeout
        (converted to :class:`DeadlineExceeded`, never leaked as an
        :class:`repro.comm.faults.InjectedFault`).  Unarmed deadlines are a
        complete no-op — the fault site stays silent too.
        """
        if self._expires is None:
            return
        from repro.comm import faults
        try:
            faults.fail_point("serve.deadline")
        except faults.InjectedFault as e:
            raise DeadlineExceeded(
                f"injected deadline expiry at {where}") from e
        if self._clock() >= self._expires:
            raise DeadlineExceeded(
                f"deadline of {self.timeout}s exceeded at {where}")


class AdmissionQueue:
    """A bounded in-flight work counter with configurable load shedding.

    Parameters
    ----------
    capacity : maximum admitted work units (a unit is one pattern; a
        ``query_many`` batch acquires ``len(batch)`` units).  Must be >= 1.
    policy : ``'reject'`` sheds a batch that would exceed capacity with
        :class:`Overloaded`; ``'block'`` waits for capacity, bounded by the
        caller's :class:`Deadline` (expiry raises
        :class:`DeadlineExceeded`).  See :data:`ADMISSION_POLICIES`.

    A batch larger than ``capacity`` is admitted when the queue is idle
    (nothing else in flight), so oversized batches make progress instead of
    deadlocking.  Thread-safe; counters (:attr:`n_admitted`,
    :attr:`n_shed`, :attr:`pending`) are monotone except ``pending``.
    """

    def __init__(self, capacity: int = 64, policy: str = "reject"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {ADMISSION_POLICIES}")
        self.capacity = int(capacity)
        self.policy = policy
        self._cond = threading.Condition()
        self._pending = 0
        self._admitted = 0
        self._shed = 0

    @property
    def pending(self) -> int:
        """Work units currently admitted and not yet released."""
        with self._cond:
            return self._pending

    @property
    def n_admitted(self) -> int:
        """Total work units ever admitted."""
        with self._cond:
            return self._admitted

    @property
    def n_shed(self) -> int:
        """Total work units shed (rejected or deadline-expired waiting)."""
        with self._cond:
            return self._shed

    def acquire(self, units: int = 1, deadline: Deadline | None = None) -> None:
        """Admit ``units`` work units or shed the request.

        Policy ``'reject'`` raises :class:`Overloaded` immediately when the
        queue is non-idle and ``units`` would exceed capacity; ``'block'``
        waits until capacity frees, bounded by ``deadline`` (expiry while
        waiting raises :class:`DeadlineExceeded`).  Callers must pair every
        successful ``acquire`` with :meth:`release` — or use :meth:`admit`.
        """
        if units < 0:
            raise ValueError(f"units must be >= 0, got {units}")
        with self._cond:
            while self._pending and self._pending + units > self.capacity:
                if self.policy == "reject":
                    self._shed += units
                    raise Overloaded(
                        f"admission queue full ({self._pending}/"
                        f"{self.capacity} in flight, batch of {units} shed)")
                remaining = None if deadline is None else deadline.remaining()
                if remaining is not None and remaining <= 0:
                    self._shed += units
                    raise DeadlineExceeded(
                        f"deadline expired waiting for admission "
                        f"({self._pending}/{self.capacity} in flight)")
                self._cond.wait(remaining)
            self._pending += units
            self._admitted += units

    def release(self, units: int = 1) -> None:
        """Return ``units`` previously-acquired work units to the queue."""
        with self._cond:
            self._pending = max(0, self._pending - units)
            self._cond.notify_all()

    @contextlib.contextmanager
    def admit(self, units: int = 1, deadline: Deadline | None = None):
        """Context manager pairing :meth:`acquire` of ``units`` (bounded by
        ``deadline``) with a guaranteed :meth:`release`."""
        self.acquire(units, deadline)
        try:
            yield
        finally:
            self.release(units)


class RetryPolicy:
    """Deterministic jittered exponential backoff.

    Parameters
    ----------
    attempts : total tries including the first (>= 1); 1 means no retry.
    base : first retry's nominal delay in seconds.
    cap : upper bound on any single delay.
    jitter : fractional jitter — each delay is scaled by a factor drawn
        uniformly from ``[1 - jitter, 1 + jitter]``.  0 disables jitter.
    seed : seeds the jitter stream, so a given policy object replays the
        exact same delay sequence (deterministic chaos runs).
    sleep : the sleeper (default ``time.sleep``); injectable for tests.
    """

    def __init__(self, attempts: int = 3, base: float = 0.05,
                 cap: float = 1.0, jitter: float = 0.5, seed: int = 0,
                 sleep=time.sleep):
        if attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {attempts}")
        if not 0 <= jitter <= 1:
            raise ValueError(f"jitter must be in [0, 1], got {jitter}")
        if base < 0 or cap < 0:
            raise ValueError("base and cap must be >= 0")
        self.attempts = int(attempts)
        self.base = float(base)
        self.cap = float(cap)
        self.jitter = float(jitter)
        self.seed = int(seed)
        self._rng = random.Random(seed)
        self._sleep = sleep

    def delay(self, attempt: int) -> float:
        """The backoff before retry number ``attempt`` (0-based: the delay
        after the first failure is ``delay(0)``), jittered and capped."""
        nominal = min(self.cap, self.base * (2.0 ** attempt))
        if self.jitter:
            nominal *= 1.0 + self.jitter * (2.0 * self._rng.random() - 1.0)
        return min(self.cap, nominal)

    def run(self, fn, *, deadline: Deadline | None = None,
            on_failure=None):
        """Call ``fn()`` up to :attr:`attempts` times with backoff between.

        ``deadline`` is checked before every attempt and bounds each sleep
        (an expired deadline raises :class:`DeadlineExceeded` instead of
        burning the remaining attempts).  ``on_failure(error, attempt)`` is
        called after each failed attempt — the service hooks the circuit
        breaker and health ledger there.  Re-raises the last error when
        every attempt fails; returns ``fn()``'s value on the first success.
        """
        last: Exception | None = None
        for attempt in range(self.attempts):
            if deadline is not None:
                deadline.check(where=f"retry attempt {attempt}")
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 - policy decides, not us
                last = e
                if on_failure is not None:
                    on_failure(e, attempt)
                if attempt + 1 >= self.attempts:
                    break
                pause = self.delay(attempt)
                if deadline is not None:
                    remaining = deadline.remaining()
                    if remaining is not None:
                        pause = min(pause, remaining)
                if pause > 0:
                    self._sleep(pause)
        raise last
