"""ArenaCache: the crash-consistent pattern-fingerprint → verdict cache.

:class:`repro.serve.StrategyService` keys priced strategy verdicts by
content-hash fingerprints of the query patterns
(:func:`repro.comm.delta.pattern_fingerprint`).  This module stores those
entries so a warm service answers a repeated traffic shape without
re-running the sweep, across three tiers:

* **memory** — an LRU-bounded dict, always on;
* **disk** — optional write-through persistence (``path`` directory), one
  file per entry, written atomically (tempfile + ``os.replace``) so a crash
  mid-write leaves either the old entry or no entry, never a torn one;
* **snapshot** — :meth:`ArenaCache.snapshot` / :meth:`ArenaCache.restore`
  serialize the whole memory tier to one JSON-safe dict for warm restarts.

Every on-disk entry (and every snapshot) is versioned and checksummed::

    {"version": 1, "checksum": sha256(canonical-body-json), "body": {...}}

Corruption, partial writes, version skew, or unparseable files detected at
load **degrade to a miss** — the caller rebuilds, a failure event lands in
the :class:`repro.comm.health.BackendHealth` ledger (backend ``'cache'``),
and nothing ever raises out of :meth:`ArenaCache.get`.  Reads and writes
pass through the ``serve.cache_read`` / ``serve.cache_write`` fault sites,
so chaos runs can corrupt or fail any I/O deterministically.

numpy-free and jax-free; safe to import on minimal hosts.
"""
from __future__ import annotations

import collections
import contextlib
import hashlib
import json
import os
import tempfile
import threading

__all__ = ["ArenaCache", "CACHE_VERSION"]

#: On-disk / snapshot format version; entries from any other version are
#: rejected at load (degrading to a rebuild, never an error).
CACHE_VERSION = 1


def _canonical(body) -> str:
    return json.dumps(body, sort_keys=True, separators=(",", ":"))


def _wrap(body) -> str:
    canon = _canonical(body)
    checksum = hashlib.sha256(canon.encode()).hexdigest()
    return json.dumps({"version": CACHE_VERSION, "checksum": checksum,
                       "body": body}, sort_keys=True)


def _unwrap(text: str):
    """Parse + validate one wrapped entry; raises ValueError on anything
    short of a clean, current-version, checksum-true entry."""
    obj = json.loads(text)
    if not isinstance(obj, dict):
        raise ValueError("cache entry is not an object")
    if obj.get("version") != CACHE_VERSION:
        raise ValueError(f"cache version skew: entry v{obj.get('version')!r}"
                         f", this build reads v{CACHE_VERSION}")
    body = obj.get("body")
    canon = _canonical(body)
    if hashlib.sha256(canon.encode()).hexdigest() != obj.get("checksum"):
        raise ValueError("cache entry checksum mismatch (corrupt or torn)")
    return body


class ArenaCache:
    """A crash-consistent key → JSON-body cache with LRU memory and
    optional atomic disk persistence.

    Parameters
    ----------
    path : directory for write-through disk persistence (created on first
        write), or None for a memory-only cache.  Each entry lives in its
        own checksummed file, named by the SHA-256 of its key.
    max_entries : memory-tier LRU bound (>= 1).  Disk entries are not
        evicted — a key aged out of memory reloads from disk on the next
        :meth:`get`.

    The contract: :meth:`get` / :meth:`put` / :meth:`snapshot` /
    :meth:`restore` **never raise** on I/O or data problems — every failure
    degrades to a miss / skipped write plus a health-ledger event under
    backend ``'cache'``.  Thread-safe.
    """

    def __init__(self, path: str | None = None, *, max_entries: int = 1024):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.path = path
        self.max_entries = int(max_entries)
        self._mem: collections.OrderedDict[str, object] = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._rejected = 0
        self._write_errors = 0

    # -- stats ----------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        """Entries currently in the memory tier."""
        with self._lock:
            return len(self._mem)

    def stats(self) -> dict:
        """Counters: ``hits`` / ``misses`` (per :meth:`get`), ``rejected``
        (entries refused at load: corruption, version skew, parse failure)
        and ``write_errors`` (disk writes that failed and were skipped)."""
        with self._lock:
            return {"hits": self._hits, "misses": self._misses,
                    "rejected": self._rejected,
                    "write_errors": self._write_errors,
                    "entries": len(self._mem)}

    # -- internals ------------------------------------------------------------
    def _file(self, key: str) -> str:
        return os.path.join(self.path,
                            hashlib.sha256(key.encode()).hexdigest() + ".json")

    def _event(self, site: str, error: Exception) -> None:
        from repro.comm.health import get_health
        get_health().record_failure("cache", site, error)

    def _remember(self, key: str, body) -> None:
        # caller holds no lock
        with self._lock:
            self._mem[key] = body
            self._mem.move_to_end(key)
            while len(self._mem) > self.max_entries:
                self._mem.popitem(last=False)

    # -- the cache contract ---------------------------------------------------
    def get(self, key: str):
        """The entry body stored under ``key``, or None on a miss.

        Memory first; on a memory miss with a disk tier, the entry file is
        read through the ``serve.cache_read`` fault site and validated
        (version + checksum) — any defect degrades to None with a health
        event, never an exception.
        """
        with self._lock:
            if key in self._mem:
                self._mem.move_to_end(key)
                self._hits += 1
                return self._mem[key]
        if self.path is not None:
            from repro.comm import faults
            fname = self._file(key)
            try:
                faults.fail_point("serve.cache_read")
                if os.path.exists(fname):
                    with open(fname, encoding="utf-8") as f:
                        text = f.read()
                    text = faults.poison("serve.cache_read", text)
                    body = _unwrap(text)
                    self._remember(key, body)
                    with self._lock:
                        self._hits += 1
                    return body
            except Exception as e:  # noqa: BLE001 - degrade, never raise
                with self._lock:
                    self._rejected += 1
                self._event("serve.cache_read", e)
        with self._lock:
            self._misses += 1
        return None

    def put(self, key: str, body) -> None:
        """Store ``body`` (a JSON-serializable dict) under ``key``.

        Always lands in the memory tier; with a disk tier the entry is
        written through the ``serve.cache_write`` fault site as a
        checksummed file via tempfile + atomic rename, so a crash mid-write
        can never leave a torn entry.  A failed write is skipped with a
        health event (the memory tier still serves the entry).
        """
        self._remember(key, body)
        if self.path is None:
            return
        from repro.comm import faults
        try:
            faults.fail_point("serve.cache_write")
            text = faults.poison("serve.cache_write", _wrap(body))
            os.makedirs(self.path, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            try:
                with os.fdopen(fd, "w", encoding="utf-8") as f:
                    f.write(text)
                os.replace(tmp, self._file(key))
            except BaseException:
                with contextlib.suppress(OSError):
                    os.unlink(tmp)
                raise
        except Exception as e:  # noqa: BLE001 - degrade, never raise
            with self._lock:
                self._write_errors += 1
            self._event("serve.cache_write", e)

    # -- warm restarts --------------------------------------------------------
    def snapshot(self) -> dict:
        """The whole memory tier as one versioned, checksummed, JSON-safe
        dict — hand it to :meth:`restore` on a fresh cache for a warm
        restart."""
        with self._lock:
            entries = dict(self._mem)
        body = {"entries": entries}
        canon = _canonical(body)
        return {"version": CACHE_VERSION,
                "checksum": hashlib.sha256(canon.encode()).hexdigest(),
                "body": json.loads(canon)}

    def restore(self, snapshot: dict) -> int:
        """Load a :meth:`snapshot` into the memory tier; returns how many
        entries landed.

        Version skew, checksum mismatch, or a malformed ``snapshot`` object
        degrades to restoring nothing (0) with a health event — a warm
        restart from a stale or damaged snapshot starts cold, it does not
        crash.
        """
        try:
            body = _unwrap(_canonical(snapshot) if isinstance(snapshot, dict)
                           else snapshot)
            entries = body["entries"]
            if not isinstance(entries, dict):
                raise ValueError("snapshot entries is not a dict")
        except Exception as e:  # noqa: BLE001 - degrade, never raise
            with self._lock:
                self._rejected += 1
            self._event("serve.cache_read", e)
            return 0
        for key, entry in entries.items():
            self._remember(key, entry)
        return len(entries)

    def clear(self) -> None:
        """Drop the memory tier (disk files are left in place)."""
        with self._lock:
            self._mem.clear()
