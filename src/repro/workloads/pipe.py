"""Pipeline-parallel stage-boundary traffic as point-to-point messages.

The GPipe schedule in :func:`repro.parallel.pipeline.gpipe` runs ``M``
microbatches through ``S`` stages in ``M + S - 1`` ticks; every tick each
stage ``ppermute``\\ s its activation ``[microbatch, d_model]`` to the next
stage.  The *useful* payload — what a real point-to-point lowering would
send — is one microbatch activation per interior boundary ``s -> s + 1``
per microbatch: ``(S - 1) * M`` messages of ``microbatch_tokens * d_model *
dtype_bytes`` bytes, the total the property tests pin.  The ring
wrap-around ``S - 1 -> 0`` carries garbage the schedule masks out (bubble
ticks), so it is excluded here, as are the bubble ticks themselves: they
exist in the SPMD lowering only because ``ppermute`` is collective.

Stages are pinned to ranks the way a pod-per-stage launch lays them out:
with ``n_procs`` total ranks, stage ``s`` talks from rank
``s * (n_procs // n_stages)`` — the first rank of its contiguous block —
so on multi-node machines stage boundaries are exactly the node (or
torus-hop) crossings whose cost the node-aware model separates.

Deterministic (no RNG): equal arguments always produce bit-identical
patterns.
"""
from __future__ import annotations

from repro.nn.config import ArchConfig
from repro.sparse.partition import CommPattern

from .moe import ACT_BYTES

import numpy as np


def pipeline_p2p_pattern(cfg: ArchConfig, n_stages: int, n_microbatches: int,
                         microbatch_tokens: int, n_procs: int | None = None,
                         dtype_bytes: int = ACT_BYTES) -> CommPattern:
    """Stage-boundary activation traffic of one GPipe forward pass.

    ``cfg`` supplies ``d_model``; each of the ``n_microbatches`` microbatches
    of ``microbatch_tokens`` tokens crosses each of the ``n_stages - 1``
    interior stage boundaries once, as one message of ``microbatch_tokens *
    cfg.d_model * dtype_bytes`` bytes (the ``[mb, d_model]`` activation on
    the wire; the masked ring wrap-around is not counted).  ``n_procs``
    spreads the stages over that many ranks in contiguous equal blocks
    (stage ``s`` sends from rank ``s * n_procs // n_stages``; ``n_stages``
    must divide ``n_procs``); it defaults to one rank per stage.
    """
    if n_stages < 2:
        raise ValueError(f"a pipeline needs n_stages >= 2, got {n_stages}")
    if n_microbatches < 1:
        raise ValueError(f"n_microbatches must be >= 1, "
                         f"got {n_microbatches}")
    if n_procs is None:
        n_procs = n_stages
    if n_procs % n_stages:
        raise ValueError(f"n_stages ({n_stages}) must divide n_procs "
                         f"({n_procs}) for contiguous stage blocks")
    block = n_procs // n_stages
    stage_rank = np.arange(n_stages, dtype=np.int64) * block
    src = np.repeat(stage_rank[:-1], n_microbatches)
    dst = np.repeat(stage_rank[1:], n_microbatches)
    size = np.full(src.size,
                   float(microbatch_tokens) * cfg.d_model * dtype_bytes)
    # typed output validation: a bad config (negative token count, zero
    # d_model) surfaces as a precise PatternError here, not as garbage
    # pricing downstream
    return CommPattern(src=src, dst=dst, size=size,
                       n_procs=n_procs).validate(where="pipeline_p2p_pattern")
