"""MoE expert-parallel all-to-all traffic as irregular point-to-point phases.

The optimized MoE path in this repo (:mod:`repro.parallel.ep_a2a`) moves
tokens between ranks with two ``jax.lax.all_to_all`` exchanges: **dispatch**
ships every routed token from its origin rank to the rank owning its expert,
and **combine** returns the expert outputs along the exact reverse routes.
Which rank owes how many tokens to which rank is decided by the *router* —
a data-dependent top-K choice — so the exchange is exactly the kind of
irregular point-to-point phase the paper's node-aware + queue-search model
prices: per-pair sizes follow the token-routing histogram, not a regular
collective schedule.

This module derives those phases without running any jax: a routing-count
histogram ``counts[rank, expert]`` is lowered to ``(src, dst, size)``
triples (:func:`pattern_from_counts`) that mirror the ``ep_a2a`` schedule —
per-(rank, expert) capacity clipping included — with the histogram itself
coming either from a seeded numpy **router forward pass** (the same
logits → softmax → top-K math as :func:`repro.nn.moe.moe_ffn`, reproduced
in numpy so the derivation runs where jax is absent) or from a seeded
synthetic **top-K multinomial** with a skewed expert-popularity prior.

RNG contract (pinned by the property tests): every function takes an
integer ``seed`` and creates its own ``np.random.default_rng(seed)`` —
the same seed always yields bit-identical histograms and patterns across
calls, processes and platforms; no global numpy state is read or written.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.nn.config import ArchConfig
from repro.sparse.partition import CommPattern

#: Bytes per activation element crossing the wire (bf16, matching the
#: production stack's activation dtype).
ACT_BYTES = 2


@dataclasses.dataclass(frozen=True)
class MoeA2APattern:
    """Both exchanges of one MoE layer's expert-parallel all-to-all.

    ``dispatch`` carries routed tokens origin-rank → expert-rank; ``combine``
    is its exact mirror (same pair volumes, direction reversed) — expert
    outputs travel back along the routes the tokens arrived on, which is the
    flow-conservation identity the property tests certify.  ``counts`` is
    the raw routing histogram ``[n_ranks, n_experts]``; ``sent`` is the same
    histogram after per-(rank, expert) capacity clipping (what actually
    rides the wire); ``capacity`` is the per-expert slot count of the
    ``ep_a2a`` buffer; ``token_bytes`` the wire size of one token's
    activation vector.
    """

    dispatch: CommPattern
    combine: CommPattern
    counts: np.ndarray          # [n_ranks, n_experts] routed assignments
    sent: np.ndarray            # [n_ranks, n_experts] after capacity clip
    capacity: int
    token_bytes: int

    @property
    def n_ranks(self) -> int:
        return self.dispatch.n_procs

    @property
    def dropped_tokens(self) -> int:
        """Assignments lost to capacity clipping (over-capacity drops)."""
        return int((self.counts - self.sent).sum())

    def phases(self) -> list[tuple[str, CommPattern]]:
        """The two exchanges in schedule order, labelled."""
        return [("dispatch", self.dispatch), ("combine", self.combine)]


def a2a_capacity(tokens_per_rank: int, cfg: ArchConfig) -> int:
    """Per-expert capacity of the ``ep_a2a`` dispatch buffer.

    The same formula :func:`repro.parallel.ep_a2a.moe_ffn_ep` computes
    inline from ``tokens_per_rank`` (its per-shard token count ``T``) and
    ``cfg`` (``n_experts_active``, ``capacity_factor``, ``n_experts``);
    kept in sync by the jax cross-check in ``tests/test_workloads.py``.
    """
    return max(8, int(tokens_per_rank * cfg.n_experts_active
                      * cfg.capacity_factor // cfg.n_experts) + 1)


def synthetic_routing_counts(n_ranks: int, tokens_per_rank: int,
                             n_experts: int, top_k: int, seed: int = 0,
                             concentration: float = 0.3) -> np.ndarray:
    """Seeded synthetic routing histogram: top-K multinomial token routing.

    Each of the ``n_ranks * tokens_per_rank`` tokens picks ``top_k``
    *distinct* experts out of ``n_experts`` with probability proportional to
    a shared expert-popularity vector drawn from a symmetric Dirichlet with
    parameter ``concentration`` (< 1 skews popular experts — the hot-expert
    imbalance real routers exhibit).  Sampling-without-replacement is the
    Gumbel-top-K trick, fully vectorized.  Returns integer counts
    ``[n_ranks, n_experts]``.  ``seed`` follows the module RNG contract:
    same seed, bit-identical histogram.
    """
    if top_k > n_experts:
        raise ValueError(f"top_k ({top_k}) cannot exceed n_experts "
                         f"({n_experts})")
    rng = np.random.default_rng(seed)
    popularity = rng.dirichlet(np.full(n_experts, concentration))
    # Gumbel top-K over log-popularity == K draws without replacement
    n_tokens = n_ranks * tokens_per_rank
    keys = np.log(popularity)[None, :] + rng.gumbel(size=(n_tokens, n_experts))
    experts = np.argpartition(-keys, top_k - 1, axis=1)[:, :top_k]
    rank_of_token = np.repeat(np.arange(n_ranks, dtype=np.int64),
                              tokens_per_rank)
    flat = rank_of_token[:, None] * n_experts + experts
    return np.bincount(flat.ravel(), minlength=n_ranks * n_experts) \
             .reshape(n_ranks, n_experts)


def router_routing_counts(cfg: ArchConfig, n_ranks: int, tokens_per_rank: int,
                          seed: int = 0) -> np.ndarray:
    """Routing histogram from an actual seeded router forward pass (numpy).

    Runs the router math of :func:`repro.nn.moe.moe_ffn` — token activations
    × router weight matrix → float32 logits → softmax → top-K — on seeded
    Gaussian activations and a seeded Gaussian router ``[cfg.d_model,
    cfg.n_experts]`` (scaled ``1/sqrt(d)``), entirely in numpy so the
    derivation runs where jax is absent.  Top-K uses a stable descending
    argsort, which matches ``jax.lax.top_k``'s lowest-index tie-breaking on
    identical logits (asserted against the real jax routing in
    ``tests/test_workloads.py`` when jax is importable).  Returns counts
    ``[n_ranks, n_experts]``; ``tokens_per_rank`` tokens are routed per
    rank, ``seed`` per the module RNG contract.
    """
    rng = np.random.default_rng(seed)
    d, E, K = cfg.d_model, cfg.n_experts, cfg.n_experts_active
    if not (E and K):
        raise ValueError(f"{cfg.name!r} is not a MoE config "
                         f"(n_experts={E}, n_experts_active={K})")
    n_tokens = n_ranks * tokens_per_rank
    x = rng.standard_normal((n_tokens, d)).astype(np.float32)
    router = (rng.standard_normal((d, E)) / np.sqrt(d)).astype(np.float32)
    logits = x @ router
    # softmax is monotone per row, kept for fidelity with the moe_ffn path
    z = np.exp(logits - logits.max(axis=1, keepdims=True))
    probs = z / z.sum(axis=1, keepdims=True)
    experts = np.argsort(-probs, axis=1, kind="stable")[:, :K]
    rank_of_token = np.repeat(np.arange(n_ranks, dtype=np.int64),
                              tokens_per_rank)
    flat = rank_of_token[:, None] * E + experts
    return np.bincount(flat.ravel(), minlength=n_ranks * E).reshape(n_ranks, E)


def pattern_from_counts(counts, d_model: int, capacity: int,
                        act_bytes: int = ACT_BYTES) -> MoeA2APattern:
    """Lower a routing histogram to the two-exchange ``ep_a2a`` message set.

    ``counts[r, e]`` tokens routed by rank ``r`` to expert ``e`` are clipped
    at ``capacity`` slots per (rank, expert) — the ``[E, C]`` dispatch
    buffer of :func:`repro.parallel.ep_a2a.moe_ffn_ep` drops over-capacity
    tokens per *source* rank — then summed over each destination rank's
    contiguous expert shard (expert ``e`` lives on rank ``e // (E // M)``,
    the ``shard_map``-over-experts layout).  Dispatch message sizes are
    ``tokens * d_model * act_bytes``; self-pairs (tokens staying on their
    origin rank) are local buffer traffic, not communication, and are
    dropped.  The combine exchange reuses the same pair volumes with src/dst
    swapped.  Deterministic: no randomness, so equal ``counts`` (plus equal
    ``d_model`` / ``capacity`` / ``act_bytes``) give bit-identical patterns.
    """
    counts = np.asarray(counts, dtype=np.int64)
    if counts.ndim != 2:
        raise ValueError(f"counts must be [n_ranks, n_experts], "
                         f"got shape {counts.shape}")
    M, E = counts.shape
    if E % M:
        raise ValueError(f"n_experts ({E}) must divide evenly over "
                         f"n_ranks ({M}), as in ep_a2a")
    sent = np.minimum(counts, int(capacity))
    # tokens per (src rank, dst rank): sum each destination's expert shard
    pair_tokens = sent.reshape(M, M, E // M).sum(axis=2)
    np.fill_diagonal(pair_tokens, 0)            # local dispatch: no message
    src, dst = np.nonzero(pair_tokens)
    size = pair_tokens[src, dst].astype(np.float64) * d_model * act_bytes
    dispatch = CommPattern(src=src.astype(np.int64), dst=dst.astype(np.int64),
                           size=size, n_procs=M).validate(
                               where="pattern_from_counts(dispatch)")
    # combine mirrors dispatch exactly: outputs retrace the token routes
    order = np.lexsort((src, dst))              # canonical (src, dst) order
    combine = CommPattern(src=dst[order].astype(np.int64),
                          dst=src[order].astype(np.int64),
                          size=size[order].copy(), n_procs=M).validate(
                              where="pattern_from_counts(combine)")
    return MoeA2APattern(dispatch=dispatch, combine=combine, counts=counts,
                         sent=sent, capacity=int(capacity),
                         token_bytes=int(d_model) * int(act_bytes))


def moe_a2a_pattern(cfg: ArchConfig, n_ranks: int, tokens_per_rank: int,
                    seed: int = 0, source: str = "synthetic",
                    act_bytes: int = ACT_BYTES) -> MoeA2APattern:
    """One MoE layer's expert-parallel all-to-all for ``cfg`` on ``n_ranks``.

    ``source`` picks the routing histogram: ``"router"`` runs the seeded
    numpy router forward pass (:func:`router_routing_counts`),
    ``"synthetic"`` the top-K multinomial fallback
    (:func:`synthetic_routing_counts`).  ``tokens_per_rank`` tokens are
    routed per rank and lowered through :func:`pattern_from_counts` with the
    ``ep_a2a`` capacity for that token count (:func:`a2a_capacity`);
    ``act_bytes`` scales the per-token wire size.  ``seed`` per the module
    RNG contract: same seed (and same arguments) → bit-identical pattern.
    """
    if source == "router":
        counts = router_routing_counts(cfg, n_ranks, tokens_per_rank,
                                       seed=seed)
    elif source == "synthetic":
        counts = synthetic_routing_counts(n_ranks, tokens_per_rank,
                                          cfg.n_experts,
                                          cfg.n_experts_active, seed=seed)
    else:
        raise ValueError(f"unknown source {source!r}; expected 'router' "
                         "or 'synthetic'")
    return pattern_from_counts(counts, cfg.d_model,
                               a2a_capacity(tokens_per_rank, cfg),
                               act_bytes=act_bytes)
