"""Real LLM traffic shapes, derived from the in-repo model stack, priced
by the comm model.

The :mod:`repro.nn` / :mod:`repro.parallel` half of the repo *generates*
irregular point-to-point communication (MoE expert all-to-all, TP ring
collectives, pipeline stage boundaries); the :mod:`repro.comm` /
:mod:`repro.core` half *prices* it.  This package connects them: numpy-only
derivations of :class:`repro.sparse.CommPattern` from the real schedules
(capacity formulas, sharding rules and microbatch counts are taken from —
and cross-checked against — the jax implementations, without importing
jax), plus a scenario registry that sweeps every derived shape through one
:func:`repro.comm.strategies.best_strategy_many` arena.
"""
from .moe import (ACT_BYTES, MoeA2APattern, a2a_capacity, moe_a2a_pattern,
                  pattern_from_counts, router_routing_counts,
                  synthetic_routing_counts)
from .pipe import pipeline_p2p_pattern
from .registry import (DEFAULT_SCENARIOS, Scenario, SweepRow,
                       default_machines, scenario_patterns, sweep,
                       winner_table)
from .tp import (TpCollectives, row_parallel_ops_from_pspecs,
                 row_parallel_ops_per_layer, tp_collective_patterns)

__all__ = [
    "ACT_BYTES", "MoeA2APattern", "a2a_capacity", "moe_a2a_pattern",
    "pattern_from_counts", "router_routing_counts", "synthetic_routing_counts",
    "pipeline_p2p_pattern",
    "TpCollectives", "row_parallel_ops_from_pspecs",
    "row_parallel_ops_per_layer", "tp_collective_patterns",
    "DEFAULT_SCENARIOS", "Scenario", "SweepRow", "default_machines",
    "scenario_patterns", "sweep", "winner_table",
]
