"""Tensor-parallel collective traffic as point-to-point phases.

Every row-parallel matmul in the TP layout (:mod:`repro.parallel.sharding`:
attention ``wo``, MLP ``w2``, shared-expert ``shared_w2``, SSM ``out_proj`` —
weights sharded on their *contraction* dimension) produces partial sums that
must be all-reduced across the TP group once per layer.  Lowered as the
standard ring (reduce-scatter then all-gather), an all-reduce of ``bytes``
payload moves exactly ``2 * (M - 1) / M * bytes`` per rank for a TP degree
of ``M`` — the analytic volume the property tests pin — as ``M - 1``
neighbor messages of ``bytes / M`` per rank per phase.

This module derives those phases numpy-only: the row-parallel op count comes
from an :class:`~repro.nn.config.ArchConfig` via the same divisibility rules
:func:`repro.parallel.sharding.param_pspecs` applies (cross-checked against
the real pspec tree in ``tests/test_workloads.py`` when jax is importable —
:func:`row_parallel_ops_from_pspecs` inspects the actual sharding), and the
ring schedule is pure arithmetic.  Ranks of TP group ``g`` are the
contiguous block ``[g * tp, (g + 1) * tp)`` — the model-axis-innermost
layout of :class:`repro.parallel.sharding.MeshPlan` — so on a machine with
``ppn`` ranks per node the ring crosses a node boundary every ``ppn``
hops: regular per-edge sizes, irregular locality, which is precisely where
the node-aware model earns its keep.

Everything here is deterministic (no RNG): equal arguments always produce
bit-identical patterns.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.nn.config import ArchConfig
from repro.sparse.partition import CommPattern

from .moe import ACT_BYTES


@dataclasses.dataclass(frozen=True)
class TpCollectives:
    """One layer's TP all-reduce traffic, lowered to ring phases.

    ``reduce_scatter`` and ``all_gather`` are the two ring phases (each rank
    sends ``n_ops * (tp - 1)`` chunk messages of ``payload_bytes / tp`` to
    its ring successor per phase); ``payload_bytes`` is one activation
    tensor's wire size per group, ``n_ops`` the row-parallel matmuls per
    layer the all-reduce repeats for, ``tp`` the group degree.
    """

    reduce_scatter: CommPattern
    all_gather: CommPattern
    payload_bytes: float
    n_ops: int
    tp: int

    @property
    def per_rank_bytes(self) -> float:
        """Analytic ring all-reduce volume per rank:
        ``n_ops * 2 * (tp - 1) / tp * payload_bytes``."""
        return self.n_ops * 2.0 * (self.tp - 1) / self.tp * self.payload_bytes

    def phases(self) -> list[tuple[str, CommPattern]]:
        """The two ring phases in schedule order, labelled."""
        return [("reduce_scatter", self.reduce_scatter),
                ("all_gather", self.all_gather)]


def row_parallel_ops_per_layer(cfg: ArchConfig, tp: int) -> int:
    """Row-parallel matmuls per repeating layer of ``cfg`` at TP degree ``tp``.

    Mirrors the contraction-dimension sharding rules of
    :func:`repro.parallel.sharding.param_pspecs` (each rule degrades to
    replication — no collective — when the dimension is not divisible by
    ``tp``): attention ``wo`` (``n_heads * head_dim``), MLP ``w2``
    (``d_ff``, dense layers only — routed-expert ``w2`` is expert-parallel
    and combines through the all-to-all instead), shared-expert
    ``shared_w2`` (``n_shared_experts * moe_d_ff``), SSM ``out_proj``
    (``ssm_d_inner``).  The count covers the *scanned* (repeating) layer;
    deepseek-style leading dense layers are not included.
    """
    ops = 0
    if cfg.has_attention and cfg.block_kind != "ssm":
        if (cfg.n_heads * cfg.head_dim) % tp == 0:
            ops += 1
    if cfg.has_ssm:
        if cfg.ssm_d_inner % tp == 0:
            ops += 1
    if cfg.is_moe:
        sf = cfg.n_shared_experts * cfg.moe_d_ff
        if sf and sf % tp == 0:
            ops += 1
    elif cfg.d_ff and cfg.d_ff % tp == 0:
        ops += 1
    return ops


def row_parallel_ops_from_pspecs(cfg: ArchConfig, plan=None) -> int:
    """The same per-layer op count read off the *actual* sharding tree.

    Builds :func:`repro.parallel.sharding.param_pspecs` for ``cfg`` (on
    ``plan``, or a fresh single-axis :class:`~repro.parallel.sharding.MeshPlan`
    over however many devices jax exposes when ``plan`` is None) and counts
    the leaves of the scanned ``layers`` stack whose PartitionSpec places the
    model axis on the contraction (second-to-last) dimension — the
    row-parallel signature.  Requires jax (imported lazily); the numpy-only
    twin :func:`row_parallel_ops_per_layer` is the derivation the patterns
    actually use, and the cross-check test holds the two equal.
    """
    import jax
    from repro.nn.model import param_shapes, _names
    from repro.parallel.sharding import MODEL_AXIS, make_mesh_plan, param_pspecs

    if plan is None:
        from repro.launch.mesh import make_mesh
        devices = jax.devices()
        plan = make_mesh_plan(make_mesh((1, len(devices)), ("data", "model")))
    specs = param_pspecs(cfg, plan)
    shapes = param_shapes(cfg)
    flat_specs = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]
    flat_shapes = {path: sh for path, sh in
                   jax.tree_util.tree_flatten_with_path(
                       shapes, is_leaf=lambda x: isinstance(x, tuple)
                       and all(isinstance(i, int) for i in x))[0]}
    ops = 0
    for path, spec in flat_specs:
        names = _names(path)
        if not names or names[0] != "layers":
            continue
        sh = flat_shapes[path]
        parts = tuple(spec) + (None,) * (len(sh) - len(spec))
        if len(sh) >= 2 and parts[len(sh) - 2] == MODEL_AXIS:
            ops += 1
    return ops


def tp_collective_patterns(cfg: ArchConfig, tp: int, tokens: int,
                           n_groups: int = 1,
                           act_bytes: int = ACT_BYTES) -> TpCollectives:
    """One layer's TP all-reduces for ``cfg``, lowered to ring phases.

    The all-reduced payload is one activation tensor of ``tokens`` rows —
    ``tokens * cfg.d_model * act_bytes`` bytes per group — repeated for the
    layer's ``row_parallel_ops_per_layer(cfg, tp)`` row-parallel matmuls.
    Each of the ``n_groups`` TP groups (contiguous rank blocks of ``tp``)
    runs its ring concurrently: per phase, rank ``i`` of a group sends
    ``n_ops * (tp - 1)`` chunk messages of ``payload / tp`` bytes to rank
    ``(i + 1) % tp`` of the same group.  Raises if ``cfg`` has no
    row-parallel op at this ``tp`` (nothing to derive).
    """
    n_ops = row_parallel_ops_per_layer(cfg, tp)
    if n_ops == 0:
        raise ValueError(
            f"{cfg.name!r} has no row-parallel matmul at tp={tp} (every "
            "sharded dimension indivisible): no TP collective to derive")
    if tp < 2:
        raise ValueError(f"a TP collective needs tp >= 2, got {tp}")
    payload = float(tokens) * cfg.d_model * act_bytes
    chunk = payload / tp
    # every group's ring edges, each repeated for (tp-1) chunks x n_ops
    base = np.repeat(np.arange(n_groups, dtype=np.int64) * tp, tp)
    i = np.tile(np.arange(tp, dtype=np.int64), n_groups)
    edge_src = base + i
    edge_dst = base + (i + 1) % tp
    reps = n_ops * (tp - 1)
    src = np.repeat(edge_src, reps)
    dst = np.repeat(edge_dst, reps)
    size = np.full(src.size, chunk)
    n_procs = n_groups * tp

    def ring() -> CommPattern:
        return CommPattern(src=src.copy(), dst=dst.copy(), size=size.copy(),
                           n_procs=n_procs).validate(
                               where="tp_collective_patterns")

    return TpCollectives(reduce_scatter=ring(), all_gather=ring(),
                         payload_bytes=payload, n_ops=n_ops, tp=tp)
