"""Scenario registry: config × workload × machine, priced in one arena.

A :class:`Scenario` names one traffic shape the in-repo LLM stack emits —
an MoE expert-parallel all-to-all (:mod:`repro.workloads.moe`), a TP
ring collective pair (:mod:`repro.workloads.tp`) or a pipeline
stage-boundary exchange (:mod:`repro.workloads.pipe`) — for one
architecture from :mod:`repro.configs` at one rank count.
:data:`DEFAULT_SCENARIOS` enumerates the shipped set over the production
configs; :func:`default_machines` supplies the machine presets (two GPU
machines plus the paper's CPU baseline, all sized to the same 64 ranks);
:func:`sweep` prices every scenario phase on every machine through **one**
:func:`repro.comm.strategies.best_strategy_many` arena and returns rows
:func:`winner_table` renders.

The whole registry is deterministic: scenarios carry their own seeds, the
sweep threads one arrival seed, and equal inputs give bit-identical rows —
which is what lets ``tests/test_workloads_golden.py`` pin the winner table.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.net.machine import (blue_waters_machine, frontier_machine,
                               lassen_machine)

from .moe import moe_a2a_pattern
from .pipe import pipeline_p2p_pattern
from .tp import tp_collective_patterns

WORKLOADS = ("moe_a2a", "tp_collective", "pipeline_p2p")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One registry entry: ``workload`` traffic of config ``arch`` on
    ``n_ranks`` ranks.

    ``name`` labels the sweep rows; ``tokens_per_rank`` sizes the activation
    payloads (per rank for MoE, total per TP group for collectives,
    per microbatch for pipelines); ``seed`` feeds the routing histogram
    (MoE only — TP and pipeline shapes are deterministic); ``n_stages`` /
    ``n_microbatches`` shape the ``pipeline_p2p`` schedule and are ignored
    elsewhere.
    """

    name: str
    arch: str
    workload: str               # one of WORKLOADS
    n_ranks: int
    tokens_per_rank: int
    seed: int = 0
    n_stages: int = 8
    n_microbatches: int = 8

    def __post_init__(self):
        if self.workload not in WORKLOADS:
            raise ValueError(f"unknown workload {self.workload!r}; "
                             f"expected one of {WORKLOADS}")


def scenario_patterns(sc: Scenario):
    """Derive ``sc``'s labelled, unbound phase list.

    Returns ``[(label, CommPattern), ...]`` in schedule order: MoE gives
    the dispatch + combine exchanges, TP the reduce-scatter + all-gather
    rings, pipeline a single p2p phase.  Deterministic per the workload
    modules' RNG contracts.
    """
    cfg = get_config(sc.arch)
    if sc.workload == "moe_a2a":
        return moe_a2a_pattern(cfg, sc.n_ranks, sc.tokens_per_rank,
                               seed=sc.seed).phases()
    if sc.workload == "tp_collective":
        return tp_collective_patterns(cfg, sc.n_ranks,
                                      sc.tokens_per_rank).phases()
    mb_tokens = sc.tokens_per_rank
    return [("p2p", pipeline_p2p_pattern(cfg, sc.n_stages,
                                         sc.n_microbatches, mb_tokens,
                                         n_procs=sc.n_ranks))]


#: The shipped scenario set: the three production parallelism styles over
#: the MoE and dense configs, all at 64 ranks so every machine preset in
#: :func:`default_machines` hosts every scenario.
DEFAULT_SCENARIOS = (
    Scenario(name="qwen3-moe-a2a", arch="qwen3-moe-30b-a3b",
             workload="moe_a2a", n_ranks=64, tokens_per_rank=256),
    Scenario(name="deepseek-moe-a2a", arch="deepseek-moe-16b",
             workload="moe_a2a", n_ranks=64, tokens_per_rank=256),
    Scenario(name="llama3-tp", arch="llama3.2-3b",
             workload="tp_collective", n_ranks=64, tokens_per_rank=2048),
    Scenario(name="llama3-pipeline", arch="llama3.2-3b",
             workload="pipeline_p2p", n_ranks=64, tokens_per_rank=512,
             n_stages=8, n_microbatches=8),
)


def default_machines():
    """The sweep's machine presets, every one hosting 64 ranks.

    ``lassen`` (fat V100-class nodes, 2×2×2 node torus) and ``frontier``
    (8-GCD nodes, 2×2×2) are the GPU machines; ``blue_waters`` (Gemini
    torus, 2×1×1 — 2 Geminis × 2 nodes × 16 ppn) is the paper's CPU
    baseline.
    """
    return {
        "lassen": lassen_machine((2, 2, 2)),
        "frontier": frontier_machine((2, 2, 2)),
        "blue_waters": blue_waters_machine((2, 1, 1)),
    }


@dataclasses.dataclass(frozen=True)
class SweepRow:
    """One (machine, scenario, phase) verdict of :func:`sweep`.

    ``model_winner`` is the model ladder's predicted strategy,
    ``sim_winner`` the simulator's ground truth, ``agree`` their match;
    ``model`` / ``sim`` are the winning costs in seconds; ``n_msgs`` /
    ``total_bytes`` describe the derived phase itself.  ``degraded``
    marks rows priced under a backend fallback (DESIGN.md §12) — the
    numbers are still the numpy bit-identity reference's.
    """

    machine: str
    scenario: str
    phase: str
    n_msgs: int
    total_bytes: float
    model_winner: str
    sim_winner: str
    agree: bool
    model: float
    sim: float
    degraded: bool = False


def sweep(scenarios=DEFAULT_SCENARIOS, machines=None,
          level: str = "contention", seed: int = 0,
          validate: bool = True) -> list[SweepRow]:
    """Price every scenario phase on every machine in ONE arena call.

    Each scenario in ``scenarios`` is derived once (seeded per the workload
    RNG contracts), validated through the typed guard layer
    (``validate=True``, the default — a NaN-sized or out-of-range derived
    pattern raises a precise :class:`repro.comm.guard.PatternError` before
    any pricing), bound to each machine in ``machines`` (default
    :func:`default_machines`), and the whole cross product goes through a
    single :func:`repro.comm.strategies.best_strategy_many` call — the
    mixed-machine candidate set stacks per machine group inside — at model
    ladder ``level`` with one arrival ``seed``.  Returns one
    :class:`SweepRow` per (machine, scenario, phase), machines in dict
    order, scenarios in input order; rows priced under a backend fallback
    carry ``degraded=True``.
    """
    from repro.comm.strategies import best_strategy_many

    if machines is None:
        machines = default_machines()
    derived = [(sc, scenario_patterns(sc)) for sc in scenarios]
    if validate:
        from repro.comm.guard import validate_phase
        for sc, phases in derived:
            for label, pat in phases:
                validate_phase(pat, where=f"{sc.name}/{label}")
    keys, bound = [], []
    for mname, machine in machines.items():
        for sc, phases in derived:
            for label, pat in phases:
                keys.append((mname, sc.name, label, pat))
                bound.append(pat.bind(machine))
    verdicts = best_strategy_many(bound, seed=seed, level=level)
    return [SweepRow(machine=mname, scenario=sname, phase=label,
                     n_msgs=pat.n_msgs, total_bytes=pat.total_bytes,
                     model_winner=v.model_winner, sim_winner=v.sim_winner,
                     agree=v.agree, model=v.model[v.model_winner],
                     sim=v.sim[v.sim_winner], degraded=v.degraded)
            for (mname, sname, label, pat), v in zip(keys, verdicts)]


def winner_table(rows) -> str:
    """Render :func:`sweep` ``rows`` with :func:`repro.core.report.format_table`."""
    from repro.core.report import format_table
    cols = ["machine", "scenario", "phase", "n_msgs", "total_bytes",
            "model_winner", "sim_winner", "agree", "model", "sim"]
    return format_table([dataclasses.asdict(r) for r in rows], columns=cols,
                        title="LLM workload winner table")
