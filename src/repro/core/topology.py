"""d-dimensional torus topology: coordinates, routes, hop counts, link ids.

The paper's contention model assumes the job occupies a perfect cube of Blue
Waters' 3-D Gemini torus (Fig. 8) and estimates the bytes crossing the hottest
link as ``ell = 2 * h^d * b * ppn`` where ``h`` is the average hops per byte.
TPU v5e pods are 2-D ICI tori, so the torus dimension is a parameter here.

Ranks are *torus-node* ranks (Geminis on Blue Waters, chips on TPU); the
mapping from processes to torus nodes lives in :mod:`repro.net.machine`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np


@dataclasses.dataclass(frozen=True)
class TorusTopology:
    """A torus with extent ``dims[i]`` in dimension ``i`` (row-major ranks)."""

    dims: tuple[int, ...]
    wrap: bool = True   # tori wrap; a job partition inside a larger torus may not

    @property
    def ndim(self) -> int:
        return len(self.dims)

    @property
    def size(self) -> int:
        return int(np.prod(self.dims))

    # -- coordinates ------------------------------------------------------
    def coords(self, rank) -> np.ndarray:
        """rank (or array of ranks) -> coords array [..., ndim]."""
        rank = np.asarray(rank)
        out = np.empty(rank.shape + (self.ndim,), dtype=np.int64)
        rem = rank
        for i in range(self.ndim - 1, -1, -1):
            out[..., i] = rem % self.dims[i]
            rem = rem // self.dims[i]
        return out

    def rank(self, coords) -> np.ndarray:
        coords = np.asarray(coords)
        r = np.zeros(coords.shape[:-1], dtype=np.int64)
        for i in range(self.ndim):
            r = r * self.dims[i] + coords[..., i]
        return r

    # -- distances --------------------------------------------------------
    def _dim_delta(self, a, b, i):
        """Signed minimal step direction and distance along dim i."""
        d = (np.asarray(b) - np.asarray(a)) % self.dims[i]
        if not self.wrap:
            return np.asarray(b) - np.asarray(a)
        # choose the shorter way around the ring
        alt = d - self.dims[i]
        return np.where(np.abs(alt) < d, alt, d)

    def hops(self, a, b) -> np.ndarray:
        """Minimal hop count between ranks a and b (arrays ok)."""
        ca, cb = self.coords(a), self.coords(b)
        total = np.zeros(np.broadcast_shapes(np.shape(a), np.shape(b)), dtype=np.int64)
        for i in range(self.ndim):
            total = total + np.abs(self._dim_delta(ca[..., i], cb[..., i], i))
        return total

    # -- routing ----------------------------------------------------------
    @property
    def link_slots(self) -> int:
        """Size of the dense link-id space: link (node, dim) <-> node*ndim+dim."""
        return self.size * self.ndim

    def _strides(self) -> np.ndarray:
        s = np.ones(self.ndim, dtype=np.int64)
        for i in range(self.ndim - 2, -1, -1):
            s[i] = s[i + 1] * self.dims[i + 1]
        return s

    def route_link_ids(self, a, b) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized dimension-ordered routing over message arrays.

        For messages ``a[k] -> b[k]``, emit every traversed link as a pair
        ``(message index k, dense link id node*ndim + dim)``; the id names the
        undirected link between ``node`` and its +1 neighbour along ``dim`` —
        the same normalization as :meth:`route_links`.  One per-dimension
        segment expansion replaces the per-message hop loop: all messages'
        hops along dimension ``i`` are emitted at once with coordinates
        ``dims < i`` already at the destination and ``dims > i`` still at the
        source.
        """
        a = np.atleast_1d(np.asarray(a, dtype=np.int64))
        b = np.atleast_1d(np.asarray(b, dtype=np.int64))
        ca, cb = self.coords(a), self.coords(b)
        strides = self._strides()
        n = a.size
        msg_parts: list[np.ndarray] = []
        link_parts: list[np.ndarray] = []
        for i in range(self.ndim):
            delta = np.asarray(self._dim_delta(ca[:, i], cb[:, i], i))
            hops = np.abs(delta)
            total = int(hops.sum())
            if total == 0:
                continue
            msg = np.repeat(np.arange(n), hops)
            first = np.cumsum(hops) - hops
            k = np.arange(total) - np.repeat(first, hops)   # 0..hops-1 per msg
            down = np.repeat(delta < 0, hops)
            c0 = np.repeat(ca[:, i], hops)
            # +1 steps own the link at the pre-step coord; -1 steps at the
            # post-step coord (normalized to the lower-coordinate node)
            coord = np.where(down, c0 - k - 1, c0 + k) % self.dims[i]
            base = np.zeros(n, dtype=np.int64)
            for j in range(self.ndim):
                if j != i:
                    cj = cb[:, j] if j < i else ca[:, j]
                    base = base + cj * strides[j]
            node = np.repeat(base, hops) + coord * strides[i]
            msg_parts.append(msg)
            link_parts.append(node * self.ndim + i)
        if not msg_parts:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy()
        return np.concatenate(msg_parts), np.concatenate(link_parts)

    def link_bytes(self, srcs, dsts, sizes) -> np.ndarray:
        """Dense per-link byte totals (length ``link_slots``) for a message set."""
        sizes = np.atleast_1d(np.asarray(sizes, dtype=np.float64))
        midx, link = self.route_link_ids(srcs, dsts)
        return np.bincount(link, weights=sizes[midx], minlength=self.link_slots)

    def route_links(self, a: int, b: int) -> list[tuple[int, int, int]]:
        """Dimension-ordered route from rank a to rank b.

        Returns a list of directed-link ids normalized to undirected form:
        ``(node_rank, dim, +1)`` meaning the link between ``node`` and its
        ``+1`` neighbor along ``dim``.  Negative-direction hops are normalized
        to the equivalent link owned by the lower-coordinate node.
        """
        ca = self.coords(a).copy()
        cb = self.coords(b)
        links: list[tuple[int, int, int]] = []
        for i in range(self.ndim):
            delta = int(self._dim_delta(ca[i], cb[i], i))
            step = 1 if delta > 0 else -1
            for _ in range(abs(delta)):
                if step > 0:
                    links.append((int(self.rank(ca)), i, 1))
                    ca[i] = (ca[i] + 1) % self.dims[i]
                else:
                    ca[i] = (ca[i] - 1) % self.dims[i]
                    links.append((int(self.rank(ca)), i, 1))
        return links

    def accumulate_link_bytes(self, srcs, dsts, sizes) -> dict[tuple[int, int, int], float]:
        """Route every (src, dst, size) message; return per-link byte totals.

        Dict view of :meth:`link_bytes`, keyed ``(node, dim, +1)`` like
        :meth:`route_links` output.
        """
        dense = self.link_bytes(srcs, dsts, sizes)
        return {(int(lid) // self.ndim, int(lid) % self.ndim, 1): float(dense[lid])
                for lid in np.nonzero(dense)[0]}


# -- the paper's cube-partition estimate -----------------------------------

def cube_side(n_units: int, ndim: int) -> int:
    """Side length of the smallest ndim-cube holding n_units torus nodes."""
    return max(1, math.ceil(n_units ** (1.0 / ndim) - 1e-9))


def average_hops(n_units: int, ndim: int) -> float:
    """Average hops ``h`` per byte under the perfect-cube assumption.

    For uniform random endpoints on a line of length c (no wraparound inside
    the job partition), E|i-j| = (c^2-1)/(3c); L1 distance sums over ndim
    dimensions.  This is the paper's Fig.-8 style estimate generalized to any
    torus dimension.
    """
    c = cube_side(n_units, ndim)
    if c <= 1:
        return 0.0
    per_dim = (c * c - 1.0) / (3.0 * c)
    return ndim * per_dim


def contention_ell(n_units: int, ndim: int, avg_bytes_per_proc: float,
                   ppn: int) -> float:
    """The paper's Eq. (7): ell = 2 * h^d * b * ppn.

    ``h^d`` estimates how many torus nodes are within ``h`` hops of a given
    link (i.e. whose traffic can be funneled through it) and ``2*b*ppn`` is the
    average bytes leaving each torus node (2 compute nodes per Gemini on Blue
    Waters; chips-per-host on TPU).  The torus dimension generalizes the
    paper's cube (d=3) to the v5e 2-D torus.
    """
    h = average_hops(n_units, ndim)
    return 2.0 * (h ** ndim) * avg_bytes_per_proc * ppn
