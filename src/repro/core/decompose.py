"""Decompose XLA collectives into point-to-point messages and price them.

This is where the paper's model becomes a first-class framework feature: the
compiled HLO's collectives (parsed by :mod:`repro.core.hlo`) are lowered to
per-chip message lists under canonical algorithms (ring all-reduce /
all-gather / reduce-scatter, pairwise all-to-all, direct permute), each
message is classified by physical locality on the pod (intra-host / intra-pod
ICI / inter-pod DCN), and the phase is priced with the node-aware max-rate
model **plus the paper's queue-search (gamma*n^2) and contention (delta*ell)
terms**.

The naive estimate ``bytes / link_bw`` is reported alongside; the gap between
the two is precisely the paper's thesis (message counts and link sharing
matter, not just bytes).

Messages are kept in compressed form: arrays ``(src, dst, size, mult)`` where
``mult`` counts how many times the (src, dst, size) message repeats across
the algorithm's rounds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .hlo import CollectiveOp
from .params import (CommParams, V5E_ICI_LINK_BW, V5E_ICI_LINKS_PER_CHIP,
                     V5E_DCN_BW_PER_HOST, V5E_CHIPS_PER_HOST)


@dataclasses.dataclass(frozen=True)
class PodGeometry:
    """Physical layout of the production slice.

    Device ids are laid out pod-major, then row-major over the pod's 2-D ICI
    torus: ``device = pod * chips_per_pod + row * cols + col``.  Hosts are
    groups of ``chips_per_host`` consecutive chips along a row.
    """

    n_pods: int = 1
    rows: int = 16
    cols: int = 16
    chips_per_host: int = V5E_CHIPS_PER_HOST
    torus_ndim: int = 2

    @property
    def chips_per_pod(self) -> int:
        return self.rows * self.cols

    @property
    def n_devices(self) -> int:
        return self.n_pods * self.chips_per_pod

    def pod_of(self, d) -> np.ndarray:
        return np.asarray(d) // self.chips_per_pod

    def host_of(self, d) -> np.ndarray:
        d = np.asarray(d)
        within = d % self.chips_per_pod
        return (self.pod_of(d) * (self.chips_per_pod // self.chips_per_host)
                + within // self.chips_per_host)

    def locality(self, a, b) -> np.ndarray:
        """0 = intra-host, 1 = intra-pod (ICI), 2 = inter-pod (DCN)."""
        a, b = np.asarray(a), np.asarray(b)
        same_pod = self.pod_of(a) == self.pod_of(b)
        same_host = self.host_of(a) == self.host_of(b)
        return np.where(same_host, 0, np.where(same_pod, 1, 2)).astype(np.int64)

    def hop_components(self, a, b) -> tuple[np.ndarray, np.ndarray]:
        """Per-dimension ICI ring distances (dr, dc); 0 for cross-pod pairs."""
        a, b = np.asarray(a), np.asarray(b)
        wa, wb = a % self.chips_per_pod, b % self.chips_per_pod
        ra, ca_ = wa // self.cols, wa % self.cols
        rbb, cb = wb // self.cols, wb % self.cols
        dr = np.abs(ra - rbb)
        dc = np.abs(ca_ - cb)
        dr = np.minimum(dr, self.rows - dr)
        dc = np.minimum(dc, self.cols - dc)
        same = self.pod_of(a) == self.pod_of(b)
        return np.where(same, dr, 0), np.where(same, dc, 0)

    def hops(self, a, b) -> np.ndarray:
        """ICI torus hop count (intra-pod); inter-pod pairs return 0 (DCN)."""
        dr, dc = self.hop_components(a, b)
        return dr + dc

    def transit_hops(self, a, b) -> np.ndarray:
        """Links shared with other nodes' traffic: sum_dim max(d_dim - 1, 0).

        A nearest-neighbor hop uses only the sender's own injection link
        (priced by R_N); each extra hop in a dimension rides through
        intermediate chips whose links carry other flows.
        """
        dr, dc = self.hop_components(a, b)
        return np.maximum(dr - 1, 0) + np.maximum(dc - 1, 0)


@dataclasses.dataclass
class MessageSet:
    """Compressed p2p message set: mult[i] repeats of src->dst of size bytes.

    ``outstanding`` is the maximum number of *simultaneously posted* receives
    per chip and ``waves`` the number of posting waves: a ring algorithm posts
    one receive per round (outstanding=1, waves=rounds) while a pairwise
    all-to-all posts k-1 at once (outstanding=k-1, waves=1).  The TPU
    adaptation of the paper's queue term is ``gamma * outstanding^2 * waves``
    — the quadratic matching cost applies to what is in flight together.
    """

    src: np.ndarray
    dst: np.ndarray
    size: np.ndarray
    mult: np.ndarray
    rounds: int      # serialized algorithm rounds
    outstanding: int = 1
    waves: int = 1

    @classmethod
    def empty(cls) -> "MessageSet":
        z = np.zeros(0, dtype=np.int64)
        return cls(z, z, np.zeros(0), np.zeros(0), 0, 0, 0)

    @classmethod
    def concat(cls, sets: list["MessageSet"]) -> "MessageSet":
        sets = [s for s in sets if s.src.size]
        if not sets:
            return cls.empty()
        return cls(np.concatenate([s.src for s in sets]),
                   np.concatenate([s.dst for s in sets]),
                   np.concatenate([s.size for s in sets]),
                   np.concatenate([s.mult for s in sets]),
                   max(s.rounds for s in sets),
                   max(s.outstanding for s in sets),
                   max(s.waves for s in sets))


def decompose_collective(op: CollectiveOp) -> MessageSet:
    """Lower one collective execution (all groups) to a compressed message set."""
    if op.kind == "collective-permute":
        pairs = op.source_target_pairs or []
        if not pairs:
            return MessageSet.empty()
        src = np.asarray([p[0] for p in pairs], dtype=np.int64)
        dst = np.asarray([p[1] for p in pairs], dtype=np.int64)
        indeg = int(np.bincount(dst).max())
        return MessageSet(src, dst, np.full(len(pairs), op.result_bytes),
                          np.ones(len(pairs)), 1, outstanding=indeg, waves=1)

    if op.groups is None:
        return MessageSet.empty()

    parts: list[MessageSet] = []
    for group in op.groups:
        k = len(group)
        if k <= 1:
            continue
        g = np.asarray(group, dtype=np.int64)
        ring_dst = np.roll(g, -1)
        if op.kind == "all-reduce":
            # ring reduce-scatter + ring all-gather: 2(k-1) rounds of B/k
            parts.append(MessageSet(g, ring_dst,
                                    np.full(k, op.result_bytes / k),
                                    np.full(k, 2.0 * (k - 1)), 2 * (k - 1),
                                    outstanding=1, waves=2 * (k - 1)))
        elif op.kind == "all-gather":
            # result is the gathered buffer -> shard = result/k; k-1 rounds
            parts.append(MessageSet(g, ring_dst,
                                    np.full(k, op.result_bytes / k),
                                    np.full(k, float(k - 1)), k - 1,
                                    outstanding=1, waves=k - 1))
        elif op.kind == "reduce-scatter":
            # result is the scattered shard; k-1 ring rounds of shard bytes
            parts.append(MessageSet(g, ring_dst,
                                    np.full(k, float(op.result_bytes)),
                                    np.full(k, float(k - 1)), k - 1,
                                    outstanding=1, waves=k - 1))
        elif op.kind in ("all-to-all", "ragged-all-to-all"):
            # pairwise: each device sends B/k to each of k-1 peers
            src = np.repeat(g, k - 1)
            dst = np.concatenate([np.delete(g, i) for i in range(k)])
            parts.append(MessageSet(src, dst,
                                    np.full(k * (k - 1), op.result_bytes / k),
                                    np.ones(k * (k - 1)), k - 1,
                                    outstanding=k - 1, waves=1))
    return MessageSet.concat(parts)


@dataclasses.dataclass
class CollectiveCost:
    kind: str
    count: int
    payload_bytes: float          # per-device payload per execution
    wire_bytes_per_chip: float    # p2p bytes sent by busiest chip, per exec
    n_msgs_per_chip: float        # messages sent by busiest chip, per exec
    naive_time: float             # bytes / link-bw estimate (per exec)
    transport: float              # node-aware max-rate term (per exec)
    queue: float                  # gamma * n^2 (per exec)
    contention: float             # delta * ell (per exec)

    @property
    def model_time(self) -> float:
        return self.transport + self.queue + self.contention


def price_collective(op: CollectiveOp, geom: PodGeometry,
                     params: CommParams) -> CollectiveCost:
    """Apply the full model ladder to one collective execution."""
    ms = decompose_collective(op)
    if ms.src.size == 0:
        return CollectiveCost(op.kind, op.count, op.result_bytes, 0.0, 0.0,
                              0.0, 0.0, 0.0, 0.0)
    src, dst, size, mult = ms.src, ms.dst, ms.size, ms.mult
    loc = geom.locality(src, dst)
    n_dev = geom.n_devices
    wbytes = size * mult

    send_bytes = np.zeros(n_dev)
    np.add.at(send_bytes, src, wbytes)
    sends = np.zeros(n_dev)
    np.add.at(sends, src, mult)
    recvs = np.zeros(n_dev)
    np.add.at(recvs, dst, mult)
    busiest = float(send_bytes.max())
    n_msgs = float(sends.max())

    # --- naive: wire bytes / available link bandwidth ----------------------
    dcn = loc == 2
    per_chip_ici = np.zeros(n_dev)
    np.add.at(per_chip_ici, src[~dcn], wbytes[~dcn])
    # ring traffic uses one link at a time; all-to-all spreads over links
    links = V5E_ICI_LINKS_PER_CHIP if op.kind in ("all-to-all", "ragged-all-to-all") else 1
    naive = float(per_chip_ici.max()) / (V5E_ICI_LINK_BW * links)
    if dcn.any():
        per_chip_dcn = np.zeros(n_dev)
        np.add.at(per_chip_dcn, src[dcn], wbytes[dcn])
        naive += float(per_chip_dcn.max()) * geom.chips_per_host / V5E_DCN_BW_PER_HOST

    # --- node-aware max-rate transport -------------------------------------
    proto = params.protocol_of(size)
    alpha = params.alpha[loc, proto]
    Rb = params.Rb[loc, proto]
    RN = params.RN[loc, proto]
    # active senders per host (the max-rate ppn analogue for DCN egress)
    host = geom.host_of(src)
    is_net = loc >= params.network_locality
    ppn = np.ones(size.shape)
    if is_net.any():
        act: dict[int, set] = {}
        for h, p, n in zip(host, src, is_net):
            if n:
                act.setdefault(int(h), set()).add(int(p))
        counts = {h: len(s) for h, s in act.items()}
        ppn = np.asarray([counts.get(int(h), 1) if n else 1
                          for h, n in zip(host, is_net)], dtype=np.float64)
    rate = np.minimum(RN, ppn * Rb)
    t_msg = (alpha + ppn * size / rate) * mult
    per_chip_t = np.zeros(n_dev)
    np.add.at(per_chip_t, src, t_msg)
    transport = float(per_chip_t.max())

    # --- queue-search term (paper Eq. 3, TPU adaptation) --------------------
    # gamma * n^2 with n = simultaneously outstanding receives, per wave
    queue = float(params.gamma) * float(ms.outstanding) ** 2 * float(ms.waves)

    # --- contention term (paper Eqs. 5-7, TPU adaptation) -------------------
    # The paper assumes a cube partition because the MPI rank->torus mapping
    # is unknown (ell = 2*h^d*b*ppn).  Here the decomposition knows every
    # endpoint, so the unknown-partition h^d funneling estimate is replaced
    # by *measured transit hops* (links beyond the sender's own injection
    # link), keeping the ell = 2*h*b form with delta calibrated per machine —
    # exactly how the paper fits delta empirically.  Nearest-neighbor rings
    # (transit 0) pay nothing; strided rings and pod-wide all-to-all pay
    # proportionally to how many shared links each byte rides.
    group_devs = np.unique(np.concatenate([src, dst]))
    ici = loc == 1
    net_bytes = float(wbytes[ici].sum())
    contention = 0.0
    if net_bytes > 0 and len(group_devs) > 1:
        th = geom.transit_hops(src[ici], dst[ici]).astype(np.float64)
        h_transit = float((th * wbytes[ici]).sum() / net_bytes)
        b = net_bytes / len(group_devs)
        ell = 2.0 * h_transit * b
        contention = float(params.delta) * ell

    return CollectiveCost(op.kind, op.count, op.result_bytes, busiest, n_msgs,
                          naive, transport, queue, contention)


@dataclasses.dataclass
class StepCommModel:
    """Whole-step communication cost: sum over collective executions."""

    per_op: list[CollectiveCost]
    naive_time: float
    transport: float
    queue: float
    contention: float
    model_time: float
    total_wire_bytes: float       # busiest-chip wire bytes, whole step
    total_msgs: float             # busiest-chip message count, whole step

    def as_dict(self) -> dict:
        return {
            "naive_time": self.naive_time, "transport": self.transport,
            "queue": self.queue, "contention": self.contention,
            "model_time": self.model_time,
            "total_wire_bytes": self.total_wire_bytes,
            "total_msgs": self.total_msgs,
            "ops": [dataclasses.asdict(o) for o in self.per_op],
        }


def price_step(ops: list[CollectiveOp], geom: PodGeometry,
               params: CommParams) -> StepCommModel:
    per_op = [price_collective(op, geom, params) for op in ops]
    naive = sum(c.naive_time * c.count for c in per_op)
    transport = sum(c.transport * c.count for c in per_op)
    queue = sum(c.queue * c.count for c in per_op)
    cont = sum(c.contention * c.count for c in per_op)
    wire = sum(c.wire_bytes_per_chip * c.count for c in per_op)
    msgs = sum(c.n_msgs_per_chip * c.count for c in per_op)
    return StepCommModel(per_op, naive, transport, queue, cont,
                         transport + queue + cont, wire, msgs)
