"""Model-vs-measured reporting (the paper's Figs. 1-11 as tables)."""
from __future__ import annotations

from .models import CostBreakdown, MODEL_LEVELS


def accuracy_row(measured: float, ladder: dict[str, CostBreakdown]) -> dict:
    """One phase: measured time + every model level's prediction and rel-error."""
    row: dict[str, float] = {"measured": measured}
    for lvl in MODEL_LEVELS:
        if lvl in ladder:
            t = ladder[lvl].total
            row[lvl] = t
            row[f"{lvl}_relerr"] = abs(t - measured) / measured if measured else 0.0
    return row


def format_table(rows: list[dict], columns: list[str] | None = None,
                 title: str = "") -> str:
    if not rows:
        return f"{title}\n(empty)"
    columns = columns or list(rows[0].keys())
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in columns}
    lines = []
    if title:
        lines.append(title)
    lines.append("  ".join(c.rjust(widths[c]) for c in columns))
    for r in rows:
        lines.append("  ".join(_fmt(r.get(c)).rjust(widths[c]) for c in columns))
    return "\n".join(lines)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4f}"
    return str(v)
