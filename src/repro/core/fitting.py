"""Recover model parameters from (simulated) measurements — paper Section 3-4.

The paper calibrates every parameter from ping-pong style tests on at most
eight nodes and then applies the model at 512 nodes unchanged.  We follow the
same recipe: :mod:`repro.net.pingpong` generates the measurements, the fits
here recover (alpha, R_b) per locality x protocol, R_N from a ppn sweep,
gamma from reversed-order HighVolumePingPong residuals and delta from the
Gemini-line contention residuals.  Plain least squares (float64).
"""
from __future__ import annotations

import numpy as np

from .params import CommParams, PROTOCOL_NAMES


def _lstsq(A: np.ndarray, y: np.ndarray) -> np.ndarray:
    sol, *_ = np.linalg.lstsq(np.asarray(A, dtype=np.float64),
                              np.asarray(y, dtype=np.float64), rcond=None)
    return sol


def fit_alpha_beta(sizes, times, params: CommParams) -> dict[str, tuple[float, float]]:
    """Fit postal (alpha, R_b) per protocol from a single-pair size sweep.

    Returns {protocol: (alpha, Rb)}.  Protocol buckets follow ``params``'
    size thresholds.
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    times = np.asarray(times, dtype=np.float64)
    proto = params.protocol_of(sizes)
    out: dict[str, tuple[float, float]] = {}
    for pi, name in enumerate(PROTOCOL_NAMES):
        m = proto == pi
        if m.sum() < 2:
            continue
        s, t = sizes[m], times[m]
        # scale columns for conditioning: t = a + (1/Rb) * s
        scale = s.max()
        A = np.stack([np.ones_like(s), s / scale], axis=1)
        a, b = _lstsq(A, t)
        beta = max(b / scale, 1e-16)
        out[name] = (max(float(a), 0.0), float(1.0 / beta))
    return out


def fit_node_aware_table(sweeps: dict[str, tuple[np.ndarray, np.ndarray]],
                         params: CommParams) -> dict[str, dict[str, tuple[float, float]]]:
    """Fit the full Table-1 structure.

    ``sweeps[locality_name] = (sizes, times)`` from
    :func:`repro.net.pingpong.pingpong_sweep`.  Returns
    {locality: {protocol: (alpha, Rb)}}.
    """
    return {loc: fit_alpha_beta(sizes, times, params)
            for loc, (sizes, times) in sweeps.items()}


def fit_RN(ks, times, size: float, alpha: float, Rb: float) -> float:
    """Recover the node injection bandwidth R_N from a ppn sweep.

    Model: T(k) = alpha + k*size / min(R_N, k*R_b).  In the saturated regime
    T grows linearly in k with slope size/R_N; fit the slope over the upper
    half of the sweep.
    """
    ks = np.asarray(ks, dtype=np.float64)
    times = np.asarray(times, dtype=np.float64)
    hi = ks >= max(4, ks.max() / 2)          # paper: >=4 procs/node saturate
    if hi.sum() < 2:
        hi = ks >= np.median(ks)
    A = np.stack([np.ones(hi.sum()), ks[hi]], axis=1)
    _, slope = _lstsq(A, times[hi])
    if slope <= 0:
        return float("inf")
    RN = size / float(slope)
    # never report an injection cap above the unsaturated aggregate rate
    return float(RN)


def fit_RN_rails(ks, times, size: float, alpha: float, Rb: float,
                 rails: int = 1, rel_margin: float = 0.05) -> float:
    """Multi-rail-exact R_N recovery from a ppn sweep.

    :func:`fit_RN` regresses a straight line through the saturated sweep,
    which is exact only for single-rail machines — with ``rails`` > 1 the
    saturated curve is the *staircase* ``T(k) = alpha + x*size/R_N`` with
    ``x = ceil(k / rails)``, whose secant slope is not ``size/R_N``.  Given
    the rail count (recover it first with :func:`fit_rails`), invert the
    staircase point-wise instead: every saturated point yields
    ``R_N = x*size / (T(k) - alpha)`` exactly; return the median over the
    points whose time ``times`` exceeds the unsaturated plateau
    ``alpha + size/Rb`` by more than ``rel_margin`` (relative).  Pass the
    *fitted* ``alpha`` (which absorbs the simulator's per-message queue
    step) and ``Rb`` for the sweep's ``size`` protocol class, and the
    sweeps' ``ks`` process counts — the queue offset then cancels out of
    the subtraction.  Returns ``inf`` when no point saturates (the cap
    never binds within the sweep, matching an uncapped rate table)."""
    ks = np.asarray(ks, dtype=np.float64)
    times = np.asarray(times, dtype=np.float64)
    x = np.ceil(ks / float(rails))
    flat = alpha + size / Rb
    sat = times > flat * (1.0 + rel_margin)
    if not sat.any():
        return float("inf")
    return float(np.median(x[sat] * size / (times[sat] - alpha)))


def fit_rails(ks, times, rel_tol: float = 1e-9) -> int:
    """Recover the per-node NIC (rail) count from a ppn saturation sweep.

    Under the multi-rail max-rate model the sweep obeys
    ``T(k) = alpha + ceil(k / r) * size / min(R_N, ceil(k / r) * R_b)``:
    below saturation the ceil cancels out of the ratio (T is flat in k),
    and once the per-rail cap ``R_N`` binds, T is a *staircase* that steps
    up only when ``ceil(k / r)`` increments — every ``r``-th process.  The
    rail count is therefore the step period: the median spacing between
    consecutive rises when the sweep holds two or more, or the length of
    the leading plateau before a single rise.  Use a rendezvous-regime
    ``size`` (as for :func:`fit_RN`) so the cap binds early in the sweep.

    Returns 1 when no rise is seen — a single rail and an unsaturated
    sweep are indistinguishable from the measurement.
    """
    ks = np.asarray(ks, dtype=np.float64)
    times = np.asarray(times, dtype=np.float64)
    d = np.diff(times)
    if d.size == 0:
        return 1
    thresh = rel_tol * float(np.abs(times).max())
    rises = np.nonzero(d > thresh)[0]
    if rises.size == 0:
        return 1
    if rises.size >= 2:
        return int(round(float(np.median(np.diff(ks[rises])))))
    # one rise: the first step ends the leading plateau of length r
    return int(round(float(ks[rises[0] + 1] - ks[0])))


def fit_gamma(n_msgs, measured, modeled_no_queue) -> float:
    """gamma from reversed-order HighVolumePingPong: T - T_model ~ gamma*n^2."""
    n = np.asarray(n_msgs, dtype=np.float64)
    resid = np.asarray(measured, dtype=np.float64) - np.asarray(modeled_no_queue, dtype=np.float64)
    x = n * n
    denom = float((x * x).sum())
    if denom == 0:
        return 0.0
    return float(max((x * resid).sum() / denom, 0.0))


def fit_delta(ells, measured, modeled_no_contention) -> float:
    """delta from contention tests: T - T_model ~ delta * ell."""
    x = np.asarray(ells, dtype=np.float64)
    resid = (np.asarray(measured, dtype=np.float64)
             - np.asarray(modeled_no_contention, dtype=np.float64))
    denom = float((x * x).sum())
    if denom == 0:
        return 0.0
    return float(max((x * resid).sum() / denom, 0.0))
