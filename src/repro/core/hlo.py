"""Parse compiled (post-SPMD) HLO text into a table of collective operations.

``compiled.as_text()`` shapes are per-device.  We extract every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
(sync or async ``-start`` form), its payload bytes, and its replica groups —
including the iota form ``[G,S]<=[dims]T(perm)`` — so the decomposition in
:mod:`repro.core.decompose` can recover *which physical devices* talk and
apply the node-aware model.

Collectives inside ``while`` bodies (e.g. a scan over layers) execute once per
iteration; callers pass ``loop_trip_counts`` mapping body-computation names
(or a default) to trip counts, typically the layer count.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<type>\(?[\w\[\],{}\s/]*?\)?)\s*"
    r"(?P<kind>" + "|".join(COLLECTIVE_KINDS) + r")"
    r"(?P<async>-start)?\(")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9,{}\s]*\})\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([0-9,{}\s]*)\}")


def shape_bytes(type_str: str) -> float:
    """Total bytes of an HLO result type (handles tuples)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_iota_groups(g: int, s: int, dims: list[int],
                      perm: list[int] | None) -> np.ndarray:
    n = int(np.prod(dims))
    ids = np.arange(n).reshape(dims)
    if perm:
        ids = ids.transpose(perm)
    return ids.reshape(g, s)


@dataclasses.dataclass
class CollectiveOp:
    kind: str                   # e.g. "all-reduce"
    result_bytes: float         # per-device result payload (bytes)
    groups: np.ndarray | None   # [n_groups, group_size] device ids, or None
    source_target_pairs: list[tuple[int, int]] | None
    count: int                  # static occurrences x loop trip count
    line: str                   # HLO line (for debugging / attribution)

    @property
    def group_size(self) -> int:
        if self.groups is not None:
            return int(self.groups.shape[1])
        if self.source_target_pairs:
            return 2
        return 1


def _computation_spans(text: str) -> dict[str, tuple[int, int]]:
    """Map computation name -> (start, end) character span in the HLO text."""
    spans: dict[str, tuple[int, int]] = {}
    for m in re.finditer(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$",
                         text, re.MULTILINE):
        name = m.group(1)
        # find matching closing brace at column 0
        end = text.find("\n}", m.end())
        spans[name] = (m.end(), end if end != -1 else len(text))
    return spans


def _loop_computations(text: str, spans: dict[str, tuple[int, int]]) -> set[str]:
    """Names of computations reachable from any ``while`` body."""
    bodies: set[str] = set()
    for m in re.finditer(r"\bwhile\(", text):
        line_end = text.find("\n", m.start())
        line = text[m.start():line_end if line_end != -1 else len(text)]
        bm = re.search(r"body=%?([\w.\-]+)", line)
        if bm:
            bodies.add(bm.group(1))
    # transitive closure over %name references inside each computation span
    marked = set(bodies)
    frontier = list(bodies)
    while frontier:
        comp = frontier.pop()
        if comp not in spans:
            continue
        s0, s1 = spans[comp]
        for ref in re.findall(r"%([\w.\-]+)", text[s0:s1]):
            if ref in spans and ref not in marked:
                marked.add(ref)
                frontier.append(ref)
    return marked


def parse_collectives(text: str,
                      default_trip_count: int = 1) -> list[CollectiveOp]:
    """Extract all collectives; ops inside while bodies get the trip multiplier.

    ``default_trip_count`` applies to every op found inside any while-body
    computation (our models scan over layers, so the trip count is the layer
    count; fwd and bwd scans both use it).
    """
    spans = _computation_spans(text)
    looped = _loop_computations(text, spans)
    body_ranges = [spans[b] for b in looped if b in spans]

    ops: list[CollectiveOp] = []
    for m in _OP_RE.finditer(text):
        line_start = text.rfind("\n", 0, m.start()) + 1
        line_end = text.find("\n", m.start())
        line = text[line_start:line_end if line_end != -1 else len(text)]
        if line.lstrip().startswith("//"):
            continue
        kind = m.group("kind")
        type_str = m.group("type")
        rb = shape_bytes(type_str)

        groups = None
        gm = _IOTA_GROUPS_RE.search(line)
        if gm:
            g, s = int(gm.group(1)), int(gm.group(2))
            dims = [int(x) for x in gm.group(3).split(",")]
            perm = [int(x) for x in gm.group(4).split(",")] if gm.group(4) else None
            groups = parse_iota_groups(g, s, dims, perm)
        else:
            em = _EXPLICIT_GROUPS_RE.search(line)
            if em:
                rows = re.findall(r"\{([0-9,\s]*)\}", em.group(1))
                parsed = [[int(x) for x in r.split(",") if x.strip()] for r in rows]
                if parsed and all(len(r) == len(parsed[0]) for r in parsed):
                    groups = np.asarray(parsed)

        pairs = None
        pm = _PAIRS_RE.search(line)
        if pm:
            pairs = [tuple(int(x) for x in p.split(","))
                     for p in re.findall(r"\{([0-9,\s]+)\}", pm.group(0))]

        count = 1
        for (s0, s1) in body_ranges:
            if s0 <= m.start() < s1:
                count = default_trip_count
                break
        ops.append(CollectiveOp(kind=kind, result_bytes=rb, groups=groups,
                                source_target_pairs=pairs, count=count,
                                line=line.strip()[:400]))
    return ops


def collective_summary(ops: list[CollectiveOp]) -> dict[str, dict[str, float]]:
    """Aggregate ops by kind: occurrence count and total per-device bytes."""
    out: dict[str, dict[str, float]] = {}
    for op in ops:
        d = out.setdefault(op.kind, {"ops": 0.0, "bytes": 0.0})
        d["ops"] += op.count
        d["bytes"] += op.result_bytes * op.count
    return out
