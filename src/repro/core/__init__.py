"""The paper's contribution: node-aware performance models for irregular
point-to-point communication (Bienz/Gropp/Olson, EuroMPI 2018), adapted to
TPU pods and wired into the framework's roofline and autotuning.

Layout:
  params     — locality x protocol parameter tables (Blue Waters Table 1; TPU v5e)
  models     — postal / max-rate / node-aware / +queue / +contention cost ladder
  topology   — d-dim torus math (hops, routes, the cube-partition estimate)
  fitting    — parameter recovery from ping-pong measurements
  hlo        — compiled-HLO collective extraction (incl. iota replica groups)
  decompose  — collective -> p2p messages on the physical pod; model pricing
  report     — accuracy tables
"""
from .params import (CommParams, blue_waters, tpu_v5e, lassen, frontier,
                     HETERO_LOCALITIES, SHORT, EAGER, REND, PROTOCOL_NAMES)
from .models import (CostBreakdown, message_time, queue_time, contention_time,
                     phase_cost, model_ladder, MODEL_LEVELS,
                     phase_cost_phase, phase_cost_many, model_ladder_many,
                     sequence_cost)
from .topology import TorusTopology, average_hops, contention_ell, cube_side
from .fitting import (fit_alpha_beta, fit_node_aware_table, fit_RN, fit_gamma,
                      fit_delta, fit_rails)
from .hlo import CollectiveOp, parse_collectives, collective_summary, shape_bytes
from .decompose import (PodGeometry, MessageSet, decompose_collective,
                        price_collective, price_step, StepCommModel,
                        CollectiveCost)

__all__ = [
    "CommParams", "blue_waters", "tpu_v5e", "lassen", "frontier",
    "HETERO_LOCALITIES", "SHORT", "EAGER", "REND",
    "PROTOCOL_NAMES",
    "CostBreakdown", "message_time", "queue_time", "contention_time",
    "phase_cost", "model_ladder", "MODEL_LEVELS",
    "phase_cost_phase", "phase_cost_many", "model_ladder_many",
    "sequence_cost",
    "TorusTopology", "average_hops", "contention_ell", "cube_side",
    "fit_alpha_beta", "fit_node_aware_table", "fit_RN", "fit_gamma", "fit_delta",
    "fit_rails",
    "CollectiveOp", "parse_collectives", "collective_summary", "shape_bytes",
    "PodGeometry", "MessageSet", "decompose_collective", "price_collective",
    "price_step", "StepCommModel", "CollectiveCost",
]
