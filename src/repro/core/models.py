"""Communication performance models (postal -> max-rate -> node-aware -> +queue/+contention).

All functions are vectorized over *message arrays*: ``size[i]`` bytes from
process ``src[i]`` to ``dst[i]`` with locality class ``loc[i]``.  Aggregation
follows the paper: per-process transport sums (max over processes), a single
worst-process queue term ``gamma * n^2`` and a single contention term
``delta * ell`` per phase.

Model hierarchy (each row adds one of the paper's contributions):

==============  =====================================================
``postal``      T = alpha + s / Rb                      (single class)
``maxrate``     T = alpha + ppn*s / min(RN, ppn*Rb)     (single class)
``node_aware``  per-locality (alpha, Rb, RN)            (Section 3)
``+queue``      + gamma * n_recv^2                      (Section 4.1)
``+contention`` + delta * ell                           (Section 4.2)
==============  =====================================================
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.comm.delta import ARENA_TYPES as _ARENAS
from repro.comm.primitives import active_senders_per_node, transport_times
from repro.comm.stack import PhaseStack, as_stack

from .params import CommParams
from .topology import contention_ell

MODEL_LEVELS = ("postal", "maxrate", "node_aware", "queue", "contention")


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Seconds per phase, split by source (paper Figs. 10-11 stacked bars)."""

    transport: float       # max-rate (or postal) term, max over processes
    queue: float           # gamma * n^2, worst process
    contention: float      # delta * ell
    total: float

    def as_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self)


# -- per-message time ------------------------------------------------------

def message_time(params: CommParams, size, loc, ppn=1, node_aware: bool = True,
                 use_maxrate: bool = True) -> np.ndarray:
    """Vectorized single-message time.

    ``ppn`` is the number of *actively communicating* processes on the sending
    node (scalar or per-message array).  With ``node_aware=False`` every
    message is priced with the network-class parameters (the paper's Fig.-2
    baseline).  With ``use_maxrate=False`` the injection cap is ignored
    (pure postal).
    """
    size = np.asarray(size, dtype=np.float64)
    loc = np.asarray(loc, dtype=np.int64)
    if not node_aware:
        loc = np.full_like(loc, params.network_locality)
    proto = params.protocol_of(size)
    alpha = params.alpha[loc, proto]
    Rb = params.Rb[loc, proto]
    if not use_maxrate:
        return transport_times(size, alpha, Rb, None, 1.0, False,
                               use_maxrate=False)
    # only network-class messages contend for injection bandwidth; a node's
    # active senders divide across its NICs (CommParams.n_rails)
    return transport_times(size, alpha, Rb, params.RN[loc, proto], ppn,
                           loc >= params.network_locality,
                           rails=params.n_rails)


def queue_time(params: CommParams, n_messages) -> np.ndarray:
    """Paper Eq. (3): T_q = gamma * n^2 (upper bound, adverse receive order)."""
    n = np.asarray(n_messages, dtype=np.float64)
    return params.gamma * n * n


def contention_time(params: CommParams, n_torus_nodes: int, torus_ndim: int,
                    avg_net_bytes_per_proc: float, procs_per_torus_node: int) -> float:
    """Paper Eqs. (5)-(7): T_c = delta * ell, cube-partition estimate."""
    ell = contention_ell(n_torus_nodes, torus_ndim, avg_net_bytes_per_proc,
                         procs_per_torus_node)
    return float(params.delta * ell)


# -- phase-level aggregation ------------------------------------------------

def _sender_nodes(src: np.ndarray, node_of) -> np.ndarray:
    """Resolve a process->node map (array or callable) to per-message nodes."""
    if callable(node_of):
        try:
            nodes = np.asarray(node_of(src), dtype=np.int64)
            if nodes.shape != src.shape:
                raise TypeError
        except (TypeError, ValueError):   # scalar-only callable fallback
            nodes = np.asarray([node_of(int(p)) for p in src], dtype=np.int64)
        return nodes
    return np.asarray(node_of, dtype=np.int64)[src]


def phase_cost(params: CommParams, src, dst, size, loc, *,
               node_of=None,
               n_torus_nodes: int | None = None,
               torus_ndim: int = 3,
               procs_per_torus_node: int = 1,
               n_procs: int | None = None,
               level: str = "contention",
               active_ppn=None, validate: bool = False) -> CostBreakdown:
    """Model the cost of one communication phase (e.g. one SpMV halo exchange).

    Parameters
    ----------
    src, dst, size, loc : per-message arrays.
    node_of : process -> node map (callable or array); required for max-rate.
    n_torus_nodes, torus_ndim, procs_per_torus_node : contention geometry.
    level : which rung of the model ladder to evaluate (``MODEL_LEVELS``).
    active_ppn : precomputed active-senders-per-node array (e.g. the cached
        ``CommPhase.active_ppn``); skips the ``node_of`` recomputation.
    validate : run the typed validation layer
        (:func:`repro.comm.guard.validate_messages`) over the message
        arrays first — NaN/negative sizes and out-of-range ranks raise a
        precise :class:`repro.comm.guard.PatternError` subclass instead of
        pricing garbage.
    """
    if level not in MODEL_LEVELS:
        raise ValueError(f"unknown model level {level!r}")
    if validate:
        from repro.comm.guard import validate_messages
        validate_messages(np.asarray(src).ravel(), np.asarray(dst).ravel(),
                          np.asarray(size).ravel(), n_procs=n_procs,
                          where="phase_cost")
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    size = np.asarray(size, dtype=np.float64)
    loc = np.asarray(loc, dtype=np.int64)
    node_aware = MODEL_LEVELS.index(level) >= MODEL_LEVELS.index("node_aware")
    use_maxrate = MODEL_LEVELS.index(level) >= MODEL_LEVELS.index("maxrate")

    if src.size == 0:
        return CostBreakdown(0.0, 0.0, 0.0, 0.0)

    if use_maxrate and active_ppn is not None:
        ppn = np.asarray(active_ppn, dtype=np.float64)
    elif use_maxrate and node_of is not None:
        ppn = active_senders_per_node(src, _sender_nodes(src, node_of),
                                      loc >= params.network_locality)
    else:
        ppn = np.ones_like(size)
    t_msg = message_time(params, size, loc, ppn=ppn, node_aware=node_aware,
                         use_maxrate=use_maxrate)

    # transport: worst process over (send-side sums)
    n_procs = int(n_procs if n_procs is not None else max(src.max(), dst.max()) + 1)
    per_proc = np.bincount(src, weights=t_msg, minlength=n_procs)
    transport = float(per_proc.max())

    queue = 0.0
    if MODEL_LEVELS.index(level) >= MODEL_LEVELS.index("queue"):
        n_recv = np.bincount(dst, minlength=n_procs)
        queue = float(queue_time(params, n_recv.max()))

    cont = 0.0
    if level == "contention" and n_torus_nodes is not None and n_torus_nodes > 1:
        is_net = loc >= params.network_locality
        net_bytes = float(size[is_net].sum())
        if net_bytes > 0.0:
            b = net_bytes / n_procs   # avg bytes sent per process (paper's b)
            cont = contention_time(params, n_torus_nodes, torus_ndim, b,
                                   procs_per_torus_node)

    return CostBreakdown(transport, queue, cont, transport + queue + cont)


def model_ladder(params: CommParams, src, dst, size, loc, **kw) -> dict[str, CostBreakdown]:
    """Evaluate every model level on the same phase (for accuracy tables)."""
    return {lvl: phase_cost(params, src, dst, size, loc, level=lvl, **kw)
            for lvl in MODEL_LEVELS}


# -- batched entry points over CommPhase objects ----------------------------

def phase_cost_phase(phase, level: str = "contention",
                     params: CommParams | None = None) -> CostBreakdown:
    """Price one bound :class:`repro.comm.CommPhase` (duck-typed).

    Locality, active-sender counts and contention geometry all come from the
    phase's cached arrays and machine; ``params`` overrides the machine's
    ground-truth table (e.g. with a fitted one) while keeping the machine's
    locality classification.
    """
    m = phase.machine
    p = params if params is not None else m.params
    if p.network_locality == m.params.network_locality:
        ppn = phase.active_ppn
    else:
        # the cached counts were gated on the machine's network locality;
        # an override that reclassifies localities needs them recomputed
        ppn = active_senders_per_node(phase.src, phase.send_node,
                                      phase.loc >= p.network_locality)
    return phase_cost(p, phase.src, phase.dst, phase.size, phase.loc,
                      n_torus_nodes=m.torus.size, torus_ndim=m.torus.ndim,
                      procs_per_torus_node=m.procs_per_torus_node,
                      n_procs=phase.n_procs, level=level,
                      active_ppn=ppn)


def _stack_costs(stack: PhaseStack, level: str,
                 params: CommParams | None,
                 backend: str | None = None,
                 agg_cache: dict | None = None) -> list[CostBreakdown]:
    """Price a stacked sweep: one segmented pass per quantity, bit-identical
    to the :func:`phase_cost_phase` loop (see DESIGN.md §8).

    ``agg_cache`` memoizes the raw aggregates by (node_aware, use_maxrate):
    the three ladder levels at or above ``node_aware`` share the exact same
    transport pass, so a full-ladder sweep prices messages three times, not
    five (queue/net aggregates are level-independent stack caches anyway).
    """
    if stack.n_phases == 0:
        return []
    m = stack.machine
    p = params if params is not None else m.params
    rank = MODEL_LEVELS.index(level)
    with_queue = rank >= MODEL_LEVELS.index("queue")
    with_cont = level == "contention" and m.torus.size > 1
    flags = (rank >= MODEL_LEVELS.index("node_aware"),
             rank >= MODEL_LEVELS.index("maxrate"))
    if agg_cache is not None and flags in agg_cache:
        transport, max_recv, net_bytes = agg_cache[flags]
    else:
        transport, max_recv, net_bytes = stack.cost_arrays(
            p, node_aware=flags[0], use_maxrate=flags[1],
            # when memoizing, request the (cached, level-independent) queue
            # counts up front: the queue/contention levels reuse this entry.
            # Net bytes only matter on the node-aware branch — the levels
            # below never serve a contention row.
            with_queue=with_queue or agg_cache is not None,
            with_net_bytes=with_cont or (agg_cache is not None and flags[0]),
            backend=backend)
        if agg_cache is not None:
            agg_cache[flags] = (transport, max_recv, net_bytes)
    queue = queue_time(p, max_recv) if with_queue else np.zeros_like(transport)
    cont = np.zeros_like(transport)
    if with_cont:
        b = net_bytes / stack.n_procs    # avg bytes sent per process
        ell = contention_ell(m.torus.size, m.torus.ndim, b,
                             m.procs_per_torus_node)
        cont = np.where(net_bytes > 0.0, p.delta * ell, 0.0)
    return [CostBreakdown(float(t), float(q), float(c), float(t) + float(q)
                          + float(c))
            for t, q, c in zip(transport, queue, cont)]


def phase_cost_many(phases, level: str = "contention",
                    params: CommParams | None = None,
                    backend: str | None = None) -> list[CostBreakdown]:
    """Price a whole sweep of phases (an AMG hierarchy, a partition or
    machine scan) in one call.

    Fast path: phases bound to one machine (or an already-built
    :class:`repro.comm.PhaseStack` / :class:`repro.comm.DeltaStack`) are
    priced in one segmented pass via the arena — bit-identical to the
    per-phase loop, which remains the fallback for single phases and
    mixed-machine sweeps.  A ``DeltaStack`` is priced from its incremental
    caches (even for a single phase, which is the partition-optimizer case).
    ``backend`` selects the arena's reduction backend: numpy (default, or
    via ``REPRO_STACK_BACKEND``), ``'jax'``/``'pallas'`` device-resident, or
    ``'auto'`` — the autotuned per-call numpy/jax choice.
    """
    if level not in MODEL_LEVELS:
        raise ValueError(f"unknown model level {level!r}")
    if isinstance(phases, _ARENAS):
        return _stack_costs(phases, level, params, backend=backend)
    phases = list(phases)
    stack = as_stack(phases)
    if stack is None:
        return [phase_cost_phase(ph, level=level, params=params)
                for ph in phases]
    return _stack_costs(stack, level, params, backend=backend)


def model_ladder_many(phases, params: CommParams | None = None,
                      backend: str | None = None
                      ) -> list[dict[str, CostBreakdown]]:
    """Evaluate the full model ladder on a sweep of phases: the arena is
    stacked once and swept once per ladder level (a :class:`PhaseStack` or
    :class:`repro.comm.DeltaStack` passes straight through)."""
    if isinstance(phases, _ARENAS):
        stack = phases
    else:
        phases = list(phases)
        stack = as_stack(phases)
    if stack is None:
        return [{lvl: phase_cost_phase(ph, level=lvl, params=params)
                 for lvl in MODEL_LEVELS} for ph in phases]
    out: list[dict[str, CostBreakdown]] = [{} for _ in range(stack.n_phases)]
    agg_cache: dict = {}
    for lvl in MODEL_LEVELS:
        for row, cb in zip(out, _stack_costs(stack, lvl, params,
                                             backend=backend,
                                             agg_cache=agg_cache)):
            row[lvl] = cb
    return out


def sequence_cost(phases, level: str = "contention",
                  params: CommParams | None = None) -> CostBreakdown:
    """Price a multi-phase *sequence* (e.g. a strategy rewrite's
    gather -> inter -> scatter).  Phases execute back-to-back — each must
    complete before the next posts — so per-phase costs add.  This is what
    lets the strategy layer reuse the cost code unchanged: a rewrite only
    produces more CommPhases, never new cost formulas."""
    parts = phase_cost_many(phases, level=level, params=params)
    return CostBreakdown(
        transport=sum(p.transport for p in parts),
        queue=sum(p.queue for p in parts),
        contention=sum(p.contention for p in parts),
        total=sum(p.total for p in parts))
